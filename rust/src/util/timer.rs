//! Wall-clock timing helpers used by the coordinator and benchkit.

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
        assert!(t.elapsed_secs() > 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
