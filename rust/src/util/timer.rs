//! Wall-clock timing helpers used by the coordinator and benchkit.

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        // no sleeps here: benchmark suites import this module and a
        // hard-coded sleep on the timing path would pollute their runs.
        let t = Timer::start();
        let mut acc = 0u64;
        for i in 0..50_000u64 {
            acc = acc.wrapping_add(i).rotate_left(1);
        }
        std::hint::black_box(acc);
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(a > 0.0);
        assert!(b >= a, "clock must be monotone");
        assert!((t.elapsed_ms() - t.elapsed_secs() * 1e3).abs() < 1e3);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
