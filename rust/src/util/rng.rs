//! Deterministic pseudo-random numbers (SplitMix64 core).
//!
//! Every stochastic decision in the coordinator (data synthesis, shard
//! assignment, client sampling, weight init) flows through [`Rng`] so runs
//! are exactly reproducible from a single seed. SplitMix64 passes BigCrush
//! for our purposes and needs no dependencies.

/// SplitMix64 PRNG with convenience samplers.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second normal from Box–Muller
    spare: Option<f32>,
}

impl Rng {
    /// Create from a seed. Equal seeds ⇒ identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (e.g. one per client) from this one.
    pub fn fork(&mut self, salt: u64) -> Rng {
        let s = self.next_u64() ^ salt.wrapping_mul(0xBF58476D1CE4E5B9);
        Rng::new(s)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given std.
    pub fn normal_scaled(&mut self, std: f32) -> f32 {
        self.normal() * std
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Raw generator state `(state, spare)` for checkpointing. Restoring
    /// via [`Rng::from_parts`] resumes the stream mid-sequence, including
    /// a cached Box–Muller half.
    pub fn state_parts(&self) -> (u64, Option<f32>) {
        (self.state, self.spare)
    }

    /// Rebuild from [`Rng::state_parts`] output. Unlike [`Rng::new`] this
    /// does **not** perturb the seed — it installs the raw state verbatim.
    pub fn from_parts(state: u64, spare: Option<f32>) -> Rng {
        Rng { state, spare }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n), sorted.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        let mut out = idx[..k].to_vec();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(42);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_k_distinct_sorted() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let v = r.choose_k(20, 7);
            assert_eq!(v.len(), 7);
            for w in v.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(v.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_parts_round_trip_mid_stream() {
        let mut a = Rng::new(42);
        for _ in 0..7 {
            a.next_u64();
        }
        a.normal(); // leave a cached Box–Muller spare in flight
        let (state, spare) = a.state_parts();
        let mut b = Rng::from_parts(state, spare);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }
}
