//! Minimal JSON parser + writer (serde_json substitute for offline builds).
//!
//! Parses the full JSON grammar into a [`Json`] value tree; enough for
//! `artifacts/manifest.json`, run-config files, and metric reports. Not a
//! streaming parser — everything we read is < 10 MB.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Shape-style helper: array of usize.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------------------------------------------------------- serialize

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 1-space indent (matches python json.dump).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b.len() - self.i >= word.len() && &self.b[self.i..self.i + word.len()] == word.as_bytes() {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // re-sync to char boundary for multi-byte utf-8
                    let rest = std::str::from_utf8(&self.b[self.i - 1..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8() - 1;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\nthere\"").unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"shape":[28,28,1],"k":[1,2,12,9],"f":1.5,"s":"x\"y","b":false}}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        let j = parse(r#""Aéß""#).unwrap();
        assert_eq!(j, Json::Str("Aéß".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1 2]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn usize_vec_helper() {
        let j = parse("[5, 5, 1, 6]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![5, 5, 1, 6]);
        assert!(parse("[1.5]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let j = parse(&text).unwrap();
            assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 1);
            assert!(j.get("models").unwrap().as_obj().unwrap().len() >= 1);
        }
    }
}
