//! Tiny CLI flag parser (clap substitute for offline builds).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Each binary declares its flags up front so
//! `--help` output stays accurate.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declared flag.
#[derive(Debug, Clone)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub boolean: bool,
}

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

/// Declarative parser: declare flags, then parse.
pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    flags: Vec<Flag>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, flags: Vec::new() }
    }

    /// Declare a value flag with an optional default.
    pub fn flag(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default, boolean: false });
        self
    }

    /// Declare a boolean flag (presence = true).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, boolean: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nFLAGS:\n", self.name, self.about);
        for f in &self.flags {
            let d = f
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<22} {}{}\n", f.name, f.help, d));
        }
        s
    }

    /// Parse an explicit argv (no program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args> {
        let mut args = Args::default();
        for f in &self.flags {
            if let Some(d) = f.default {
                args.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let decl = self
                    .flags
                    .iter()
                    .find(|f| f.name == name);
                let Some(decl) = decl else {
                    bail!("unknown flag --{name}\n\n{}", self.usage());
                };
                let val = if decl.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else if let Some(v) = it.next() {
                    v
                } else {
                    bail!("flag --{name} expects a value");
                };
                args.values.insert(name, val);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process args (skipping argv[0]).
    pub fn parse(&self) -> Result<Args> {
        self.parse_from(std::env::args().skip(1))
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("missing --{name}"))
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        Ok(self.str(name)?.parse()?)
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        Ok(self.str(name)?.parse()?)
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        Ok(self.str(name)?.parse()?)
    }

    pub fn f32(&self, name: &str) -> Result<f32> {
        Ok(self.str(name)?.parse()?)
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of usize (e.g. `--buckets 10,20,100`).
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>> {
        self.str(name)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| Ok(s.trim().parse()?))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .flag("rounds", Some("10"), "rounds")
            .flag("model", None, "model name")
            .switch("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Result<Args> {
        cli().parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.usize("rounds").unwrap(), 10);
        let a = parse(&["--rounds", "33"]).unwrap();
        assert_eq!(a.usize("rounds").unwrap(), 33);
        let a = parse(&["--rounds=7"]).unwrap();
        assert_eq!(a.usize("rounds").unwrap(), 7);
    }

    #[test]
    fn booleans_and_positionals() {
        let a = parse(&["train", "--verbose", "x"]).unwrap();
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["train", "x"]);
        let a = parse(&["train"]).unwrap();
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(parse(&["--nope", "1"]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&["--model"]).is_err());
    }

    #[test]
    fn lists() {
        let c = Cli::new("t", "t").flag("buckets", Some("10,20"), "");
        let a = c.parse_from(Vec::<String>::new()).unwrap();
        assert_eq!(a.usize_list("buckets").unwrap(), vec![10, 20]);
    }
}
