//! Small substrates the offline build cannot pull from crates.io:
//! deterministic RNG, JSON, CLI flags, wall-clock timing.

pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;

pub use rng::Rng;
