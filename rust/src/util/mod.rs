//! Small substrates the offline build cannot pull from crates.io:
//! deterministic RNG, JSON, CLI flags, wall-clock timing.
//!
//! Paper: no section of its own — every Table 1/2 and Fig. 5 artifact
//! leans on these. Invariant: all randomness flows through [`Rng`]
//! (SplitMix64) seeded from the run config, so every experiment is
//! replayable bit-for-bit.

pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;

pub use rng::Rng;
