//! Metrics: accuracy, loss tracking, round logs, report tables.
//!
//! Paper: produces the Table 3/4 accuracy numbers (New/Local test), the
//! per-round log behind the convergence plots, and the ASCII tables every
//! bench renders. Invariant: a [`RoundLog`] records both logical params
//! and measured wire bytes; `sim_round_secs` is the round's virtual-clock
//! duration under the configured [`crate::sched`] policy (the max over
//! clients under the sync barrier), and `client_secs` exposes the
//! per-client straggler distribution that duration was decided from.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::tensor::Tensor;
use crate::util::json::Json;

/// Top-1 accuracy of `logits [n, classes]` against labels, over the first
/// `valid` rows (the rest are padding from static eval batches).
pub fn accuracy(logits: &Tensor, labels: &[i32], valid: usize) -> Result<f64> {
    let preds = logits.argmax_rows()?;
    let n = valid.min(labels.len()).min(preds.len());
    if n == 0 {
        return Ok(0.0);
    }
    let correct = (0..n).filter(|&i| preds[i] as i32 == labels[i]).count();
    Ok(correct as f64 / n as f64)
}

/// A streaming (optionally weighted) mean. The weight sum is tracked as
/// `f64`: truncating it to an integer would let a fractional weight (a
/// staleness discount of 0.5, say) inflate or zero the denominator.
#[derive(Debug, Clone, Default)]
pub struct Mean {
    sum: f64,
    w: f64,
    n: usize,
}

impl Mean {
    pub fn add(&mut self, x: f64) {
        self.weighted_add(x, 1.0);
    }

    pub fn weighted_add(&mut self, x: f64, w: f64) {
        self.sum += x * w;
        self.w += w;
        self.n += 1;
    }

    pub fn get(&self) -> f64 {
        if self.w == 0.0 {
            0.0
        } else {
            self.sum / self.w
        }
    }

    /// Number of observations (not the weight sum).
    pub fn count(&self) -> usize {
        self.n
    }
}

/// One training round's record.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundLog {
    pub round: usize,
    /// "setskel" | "updateskel" | "full"
    pub phase: String,
    pub mean_loss: f64,
    pub new_acc: Option<f64>,
    pub local_acc: Option<f64>,
    pub comm_params: u64,
    /// Measured bytes-on-the-wire this round (encoded frames, both
    /// directions, all clients).
    pub comm_wire_bytes: u64,
    /// Virtual-clock duration of the round under the configured
    /// scheduling policy (sync: the slowest client; deadline: capped at
    /// the deadline; async: the K-th arrival).
    pub sim_round_secs: f64,
    /// Per-client `(id, virtual seconds)` for every client that trained
    /// this round — the straggler distribution the scheduler consumed
    /// (compute under the client's core budget ÷ capability + its
    /// measured frame bytes over its link).
    pub client_secs: Vec<(usize, f64)>,
    /// Updates discarded at the round deadline (DeadlineDrop only).
    pub dropped: usize,
    /// Stale updates (trained in an earlier round) aggregated this round
    /// (AsyncBuffer only).
    pub stale: usize,
    pub wall_secs: f64,
}

/// Full run log; serializes to JSON/CSV for EXPERIMENTS.md plots.
#[derive(Debug, Default)]
pub struct RunLog {
    pub rounds: Vec<RoundLog>,
}

impl RunLog {
    pub fn push(&mut self, r: RoundLog) {
        self.rounds.push(r);
    }

    pub fn last_new_acc(&self) -> Option<f64> {
        self.rounds.iter().rev().find_map(|r| r.new_acc)
    }

    pub fn last_local_acc(&self) -> Option<f64> {
        self.rounds.iter().rev().find_map(|r| r.local_acc)
    }

    pub fn total_comm_params(&self) -> u64 {
        self.rounds.iter().map(|r| r.comm_params).sum()
    }

    pub fn total_comm_wire_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.comm_wire_bytes).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rounds
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("round", Json::num(r.round as f64)),
                        ("phase", Json::str(r.phase.clone())),
                        ("mean_loss", Json::num(r.mean_loss)),
                        (
                            "new_acc",
                            r.new_acc.map(Json::num).unwrap_or(Json::Null),
                        ),
                        (
                            "local_acc",
                            r.local_acc.map(Json::num).unwrap_or(Json::Null),
                        ),
                        ("comm_params", Json::num(r.comm_params as f64)),
                        ("comm_wire_bytes", Json::num(r.comm_wire_bytes as f64)),
                        ("sim_round_secs", Json::num(r.sim_round_secs)),
                        ("dropped", Json::num(r.dropped as f64)),
                        ("stale", Json::num(r.stale as f64)),
                        (
                            "client_secs",
                            Json::Arr(
                                r.client_secs
                                    .iter()
                                    .map(|&(id, s)| {
                                        Json::obj(vec![
                                            ("client", Json::num(id as f64)),
                                            ("secs", Json::num(s)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        ("wall_secs", Json::num(r.wall_secs)),
                    ])
                })
                .collect(),
        )
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,phase,mean_loss,new_acc,local_acc,comm_params,comm_wire_bytes,sim_round_secs,dropped,stale,client_secs,wall_secs\n",
        );
        for r in &self.rounds {
            // one CSV cell: `id:secs` pairs joined by ';' so the
            // per-client distribution survives a flat-file export
            let secs: Vec<String> =
                r.client_secs.iter().map(|&(id, t)| format!("{id}:{t:.6}")).collect();
            let _ = writeln!(
                s,
                "{},{},{:.6},{},{},{},{},{:.6},{},{},{},{:.3}",
                r.round,
                r.phase,
                r.mean_loss,
                r.new_acc.map(|a| format!("{a:.4}")).unwrap_or_default(),
                r.local_acc.map(|a| format!("{a:.4}")).unwrap_or_default(),
                r.comm_params,
                r.comm_wire_bytes,
                r.sim_round_secs,
                r.dropped,
                r.stale,
                secs.join(";"),
                r.wall_secs
            );
        }
        s
    }

    pub fn save_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let line = |s: &mut String, cells: &[String], widths: &[usize]| {
            let _ = write!(s, "|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {:<w$} |", c, w = w);
            }
            let _ = writeln!(s);
        };
        line(&mut s, &self.header, &widths);
        let _ = write!(s, "|");
        for w in &widths {
            let _ = write!(s, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(s);
        for row in &self.rows {
            line(&mut s, row, &widths);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_valid_rows_only() {
        let logits = Tensor::from_vec(
            &[3, 2],
            vec![
                1.0, 0.0, // -> 0
                0.0, 1.0, // -> 1
                1.0, 0.0, // -> 0 (padding row)
            ],
        )
        .unwrap();
        let labels = vec![0, 1, 1];
        assert_eq!(accuracy(&logits, &labels, 3).unwrap(), 2.0 / 3.0);
        assert_eq!(accuracy(&logits, &labels, 2).unwrap(), 1.0);
        assert_eq!(accuracy(&logits, &labels, 0).unwrap(), 0.0);
    }

    #[test]
    fn mean_works() {
        let mut m = Mean::default();
        m.add(1.0);
        m.add(3.0);
        assert_eq!(m.get(), 2.0);
        assert_eq!(m.count(), 2);
        assert_eq!(Mean::default().get(), 0.0);
    }

    #[test]
    fn weighted_mean_keeps_fractional_weights() {
        // regression: the old `n += w as usize` truncated 0.5 to 0, so
        // two half-weight observations divided by zero-ish and returned 0
        let mut m = Mean::default();
        m.weighted_add(1.0, 0.5);
        m.weighted_add(3.0, 0.5);
        assert_eq!(m.get(), 2.0);
        assert_eq!(m.count(), 2);
        // mixed weights: (1*2 + 4*0.25) / 2.25
        let mut m = Mean::default();
        m.weighted_add(1.0, 2.0);
        m.weighted_add(4.0, 0.25);
        assert!((m.get() - 3.0 / 2.25).abs() < 1e-12);
        // integer weights still behave like repeated adds
        let mut a = Mean::default();
        a.weighted_add(0.25, 3.0);
        a.weighted_add(0.75, 1.0);
        let mut b = Mean::default();
        for x in [0.25, 0.25, 0.25, 0.75] {
            b.add(x);
        }
        assert_eq!(a.get(), b.get());
    }

    #[test]
    fn runlog_roundtrip() {
        let mut log = RunLog::default();
        log.push(RoundLog {
            round: 0,
            phase: "setskel".into(),
            mean_loss: 1.5,
            new_acc: Some(0.5),
            local_acc: None,
            comm_params: 100,
            comm_wire_bytes: 450,
            sim_round_secs: 0.25,
            client_secs: vec![(0, 0.25), (1, 0.1)],
            dropped: 0,
            stale: 0,
            wall_secs: 1.0,
        });
        log.push(RoundLog {
            round: 1,
            phase: "updateskel".into(),
            mean_loss: 1.2,
            new_acc: None,
            local_acc: Some(0.75),
            comm_params: 40,
            comm_wire_bytes: 200,
            sim_round_secs: 0.1,
            client_secs: vec![(1, 0.1)],
            dropped: 1,
            stale: 2,
            wall_secs: 0.8,
        });
        assert_eq!(log.last_new_acc(), Some(0.5));
        assert_eq!(log.last_local_acc(), Some(0.75));
        assert_eq!(log.total_comm_params(), 140);
        assert_eq!(log.total_comm_wire_bytes(), 650);
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().next().unwrap().contains("dropped,stale,client_secs"));
        // per-client cell: `id:secs` pairs joined by ';'
        assert!(csv.contains("0:0.250000;1:0.100000"), "{csv}");
        assert!(csv.contains(",1,2,1:0.100000,"), "{csv}");
        let j = log.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 2);
        let s = j.to_string();
        assert!(s.contains("\"client_secs\""), "{s}");
        assert!(s.contains("\"stale\":2"), "{s}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "acc"]);
        t.row(vec!["FedSkel".into(), "92.60".into()]);
        t.row(vec!["FedAvg".into(), "59.03".into()]);
        let s = t.render();
        assert!(s.contains("| FedSkel | 92.60 |"));
        assert_eq!(s.lines().count(), 4);
    }
}
