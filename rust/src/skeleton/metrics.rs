//! Alternative skeleton-selection metrics — the paper's §5 future work
//! ("better metrics of selecting skeleton networks") made concrete, plus
//! controls for the ablation bench (examples/ablation.rs).
//!
//! All metrics produce per-layer, per-channel scores; selection is always
//! top-k over the scores, so they slot into the same SetSkel machinery.

use anyhow::{bail, Result};

use crate::model::{Params, PrunableSpec};
use crate::util::Rng;

/// How a client scores its channels at SetSkel time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionMetric {
    /// Paper Eq. 2: accumulated mean |activation| per channel.
    Activation,
    /// Structured-pruning classic: L1 norm of the channel's weight column
    /// (computable host-side from the client's parameters, no activation
    /// statistics needed — cheaper SetSkel, the natural alternative).
    WeightNorm,
    /// Uniform-random scores (control: how much does the metric matter?).
    Random,
    /// Negated Eq. 2 (adversarial control: deliberately keep the *least*
    /// important channels).
    LeastImportant,
}

impl SelectionMetric {
    pub fn parse(s: &str) -> Result<SelectionMetric> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "activation" => SelectionMetric::Activation,
            "weightnorm" | "weight-norm" => SelectionMetric::WeightNorm,
            "random" => SelectionMetric::Random,
            "least" | "leastimportant" => SelectionMetric::LeastImportant,
            _ => bail!("unknown metric '{s}' (activation|weightnorm|random|least)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SelectionMetric::Activation => "activation",
            SelectionMetric::WeightNorm => "weightnorm",
            SelectionMetric::Random => "random",
            SelectionMetric::LeastImportant => "least",
        }
    }
}

/// Score all prunable layers' channels under `metric`.
///
/// * `importance_means` — the accumulated Eq. 2 statistics (used by
///   Activation / LeastImportant).
/// * `params` — the client's current parameters (used by WeightNorm).
pub fn score_channels(
    metric: SelectionMetric,
    importance_means: &[Vec<f64>],
    params: &Params,
    prunable: &[PrunableSpec],
    rng: &mut Rng,
) -> Result<Vec<Vec<f64>>> {
    match metric {
        SelectionMetric::Activation => Ok(importance_means.to_vec()),
        SelectionMetric::LeastImportant => Ok(importance_means
            .iter()
            .map(|layer| layer.iter().map(|&v| -v).collect())
            .collect()),
        SelectionMetric::Random => Ok(prunable
            .iter()
            .map(|p| (0..p.channels).map(|_| rng.uniform() as f64).collect())
            .collect()),
        SelectionMetric::WeightNorm => prunable
            .iter()
            .map(|p| {
                let w = &params[p.weight_param];
                let channels = p.channels;
                if w.len() % channels != 0 {
                    bail!("weight len {} not divisible by channels {channels}", w.len());
                }
                let rows = w.len() / channels;
                let mut scores = vec![0.0f64; channels];
                let data = w.data();
                for r in 0..rows {
                    for c in 0..channels {
                        scores[c] += data[r * channels + c].abs() as f64;
                    }
                }
                Ok(scores)
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn prun() -> Vec<PrunableSpec> {
        vec![PrunableSpec { name: "l0".into(), channels: 3, weight_param: 0, bias_param: 1 }]
    }

    fn params() -> Params {
        vec![
            Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 0.5, 1.0, 2.0, 0.5]).unwrap(),
            Tensor::zeros(&[3]),
        ]
    }

    #[test]
    fn parse_roundtrip() {
        for name in ["activation", "weightnorm", "random", "least"] {
            assert_eq!(SelectionMetric::parse(name).unwrap().name(), name);
        }
        assert!(SelectionMetric::parse("magic").is_err());
    }

    #[test]
    fn activation_passthrough_and_negation() {
        let means = vec![vec![0.1, 0.9, 0.5]];
        let mut rng = Rng::new(0);
        let s = score_channels(SelectionMetric::Activation, &means, &params(), &prun(), &mut rng).unwrap();
        assert_eq!(s[0], vec![0.1, 0.9, 0.5]);
        let s = score_channels(SelectionMetric::LeastImportant, &means, &params(), &prun(), &mut rng).unwrap();
        assert_eq!(s[0], vec![-0.1, -0.9, -0.5]);
    }

    #[test]
    fn weight_norm_is_column_l1() {
        let means = vec![vec![0.0; 3]];
        let mut rng = Rng::new(0);
        let s = score_channels(SelectionMetric::WeightNorm, &means, &params(), &prun(), &mut rng).unwrap();
        assert_eq!(s[0], vec![2.0, 4.0, 1.0]);
    }

    #[test]
    fn random_is_seeded_and_in_range() {
        let means = vec![vec![0.0; 3]];
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = score_channels(SelectionMetric::Random, &means, &params(), &prun(), &mut r1).unwrap();
        let b = score_channels(SelectionMetric::Random, &means, &params(), &prun(), &mut r2).unwrap();
        assert_eq!(a, b);
        assert!(a[0].iter().all(|&v| (0.0..1.0).contains(&v)));
    }
}
