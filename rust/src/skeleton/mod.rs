//! Skeleton selection — the paper's §3.1/§3.2 logic.
//!
//! * [`ImportanceAccumulator`] integrates the per-channel importance metric
//!   `M_i^l = mean |A_i^l|` (Eq. 2) that each train-step artifact emits,
//!   across the batches of a SetSkel process.
//! * [`select_skeleton`] picks the top-k channels per prunable layer.
//! * [`RatioPolicy`] maps client compute capabilities `c_i` to skeleton
//!   ratios `r_i` (the paper's linear rule `r_i ∝ c_i / c_max`, plus
//!   uniform/fixed alternatives for ablations).

pub mod metrics;

pub use metrics::{score_channels, SelectionMetric};

use anyhow::{bail, Result};

/// Running per-layer channel-importance sums.
#[derive(Debug, Clone)]
pub struct ImportanceAccumulator {
    /// per prunable layer: per-channel accumulated importance
    sums: Vec<Vec<f64>>,
    batches: usize,
}

impl ImportanceAccumulator {
    /// `channels[l]` = channel count of prunable layer l.
    pub fn new(channels: &[usize]) -> Self {
        ImportanceAccumulator {
            sums: channels.iter().map(|&c| vec![0.0; c]).collect(),
            batches: 0,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.sums.len()
    }

    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Add one train step's importance outputs (one f32 slice per layer).
    pub fn accumulate(&mut self, per_layer: &[&[f32]]) -> Result<()> {
        if per_layer.len() != self.sums.len() {
            bail!("importance layer count {} != {}", per_layer.len(), self.sums.len());
        }
        for (sum, imp) in self.sums.iter_mut().zip(per_layer) {
            if sum.len() != imp.len() {
                bail!("importance channel count {} != {}", imp.len(), sum.len());
            }
            for (s, &v) in sum.iter_mut().zip(imp.iter()) {
                *s += v as f64;
            }
        }
        self.batches += 1;
        Ok(())
    }

    /// Add importance *sums* covering `batches` train steps at once — what
    /// a pool worker returns after running a whole local round. Equivalent
    /// to `batches` individual [`ImportanceAccumulator::accumulate`] calls
    /// whose per-step values add up to `per_layer`.
    pub fn accumulate_summed(&mut self, per_layer: &[&[f32]], batches: usize) -> Result<()> {
        if batches == 0 {
            return Ok(());
        }
        if per_layer.len() != self.sums.len() {
            bail!("importance layer count {} != {}", per_layer.len(), self.sums.len());
        }
        for (sum, imp) in self.sums.iter_mut().zip(per_layer) {
            if sum.len() != imp.len() {
                bail!("importance channel count {} != {}", imp.len(), sum.len());
            }
            for (s, &v) in sum.iter_mut().zip(imp.iter()) {
                *s += v as f64;
            }
        }
        self.batches += batches;
        Ok(())
    }

    /// Mean importance per channel per layer.
    pub fn means(&self) -> Vec<Vec<f64>> {
        let n = self.batches.max(1) as f64;
        self.sums
            .iter()
            .map(|layer| layer.iter().map(|&s| s / n).collect())
            .collect()
    }

    /// Raw accumulated sums (not the lossy [`ImportanceAccumulator::means`]
    /// view) — checkpoint view, paired with [`ImportanceAccumulator::batches`].
    pub fn raw_sums(&self) -> &[Vec<f64>] {
        &self.sums
    }

    /// Rebuild mid-SetSkel state from [`ImportanceAccumulator::raw_sums`] +
    /// [`ImportanceAccumulator::batches`] output, bitwise.
    pub fn restore(sums: Vec<Vec<f64>>, batches: usize) -> Self {
        ImportanceAccumulator { sums, batches }
    }

    /// Reset for the next SetSkel process (importance is re-estimated each
    /// time so skeletons track the training dynamics).
    pub fn reset(&mut self) {
        for layer in &mut self.sums {
            layer.iter_mut().for_each(|s| *s = 0.0);
        }
        self.batches = 0;
    }
}

/// Top-k channel selection for one layer: returns the `k` most important
/// channel indices, ascending (the artifacts' gather wants sorted i32).
/// Ties break toward the lower channel index for determinism.
pub fn top_k_channels(importance: &[f64], k: usize) -> Vec<i32> {
    let k = k.min(importance.len()).max(1);
    let mut order: Vec<usize> = (0..importance.len()).collect();
    // sort by importance desc, index asc on ties
    order.sort_by(|&a, &b| {
        importance[b]
            .partial_cmp(&importance[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut out: Vec<i32> = order[..k].iter().map(|&i| i as i32).collect();
    out.sort_unstable();
    out
}

/// Select the full skeleton: per layer, top-k_l channels.
pub fn select_skeleton(means: &[Vec<f64>], k_sizes: &[usize]) -> Result<Vec<Vec<i32>>> {
    if means.len() != k_sizes.len() {
        bail!("layer count mismatch {} vs {}", means.len(), k_sizes.len());
    }
    Ok(means
        .iter()
        .zip(k_sizes)
        .map(|(m, &k)| top_k_channels(m, k))
        .collect())
}

/// Identity skeleton (r = 100%): every channel, per layer.
pub fn identity_skeleton(channels: &[usize]) -> Vec<Vec<i32>> {
    channels.iter().map(|&c| (0..c as i32).collect()).collect()
}

/// How the server maps client capabilities to skeleton ratios (§3.2
/// "Server sets skeleton ratios r").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RatioPolicy {
    /// Paper's rule: r_i = clamp(c_i / c_max, min_ratio, 1).
    LinearCapability { min_ratio: f64 },
    /// Everyone gets the same ratio (used for Table 1 single-device runs
    /// and the FedAvg baseline at 1.0).
    Fixed(f64),
    /// Equidistant ratios from lo..hi across clients in id order (the
    /// paper's Tables 3–4 heterogeneous setting).
    Equidistant { lo: f64, hi: f64 },
}

impl RatioPolicy {
    /// Compute every client's ratio (in [0,1]) from their capabilities.
    pub fn assign(&self, capabilities: &[f64]) -> Result<Vec<f64>> {
        let n = capabilities.len();
        if n == 0 {
            bail!("no clients");
        }
        match *self {
            RatioPolicy::LinearCapability { min_ratio } => {
                let cmax = capabilities.iter().cloned().fold(f64::MIN, f64::max);
                if cmax <= 0.0 {
                    bail!("capabilities must be positive");
                }
                Ok(capabilities
                    .iter()
                    .map(|&c| (c / cmax).clamp(min_ratio, 1.0))
                    .collect())
            }
            RatioPolicy::Fixed(r) => {
                if !(0.0..=1.0).contains(&r) {
                    bail!("fixed ratio {r} out of [0,1]");
                }
                Ok(vec![r; n])
            }
            RatioPolicy::Equidistant { lo, hi } => {
                if n == 1 {
                    return Ok(vec![hi]);
                }
                Ok((0..n)
                    .map(|i| (lo + (hi - lo) * i as f64 / (n - 1) as f64).clamp(lo.min(hi), hi.max(lo)))
                    .collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_and_mean() {
        let mut acc = ImportanceAccumulator::new(&[3, 2]);
        acc.accumulate(&[&[1.0, 2.0, 3.0], &[0.5, 0.1]]).unwrap();
        acc.accumulate(&[&[3.0, 2.0, 1.0], &[0.5, 0.3]]).unwrap();
        let m = acc.means();
        assert_eq!(m[0], vec![2.0, 2.0, 2.0]);
        assert!((m[1][1] - 0.2).abs() < 1e-6); // f32→f64 rounding
        assert_eq!(acc.batches(), 2);
        acc.reset();
        assert_eq!(acc.batches(), 0);
        assert_eq!(acc.means()[0], vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn accumulate_summed_equals_stepwise() {
        let mut a = ImportanceAccumulator::new(&[2]);
        a.accumulate(&[&[1.0, 2.0]]).unwrap();
        a.accumulate(&[&[3.0, 4.0]]).unwrap();
        let mut b = ImportanceAccumulator::new(&[2]);
        b.accumulate_summed(&[&[4.0, 6.0]], 2).unwrap();
        assert_eq!(a.means(), b.means());
        assert_eq!(a.batches(), b.batches());
        // zero batches is a no-op
        b.accumulate_summed(&[&[9.0, 9.0]], 0).unwrap();
        assert_eq!(a.means(), b.means());
        assert!(b.accumulate_summed(&[&[1.0]], 1).is_err());
    }

    #[test]
    fn restore_round_trips_raw_sums() {
        let mut acc = ImportanceAccumulator::new(&[3, 2]);
        acc.accumulate(&[&[1.0, 2.0, 3.0], &[0.5, 0.1]]).unwrap();
        let copy = ImportanceAccumulator::restore(acc.raw_sums().to_vec(), acc.batches());
        assert_eq!(copy.raw_sums(), acc.raw_sums());
        assert_eq!(copy.batches(), acc.batches());
        assert_eq!(copy.means(), acc.means());
    }

    #[test]
    fn accumulate_shape_errors() {
        let mut acc = ImportanceAccumulator::new(&[3]);
        assert!(acc.accumulate(&[&[1.0, 2.0]]).is_err());
        assert!(acc.accumulate(&[&[1.0, 2.0, 3.0], &[1.0]]).is_err());
    }

    #[test]
    fn top_k_picks_largest_sorted() {
        let imp = vec![0.1, 5.0, 3.0, 4.0, 0.2];
        assert_eq!(top_k_channels(&imp, 3), vec![1, 2, 3]);
        assert_eq!(top_k_channels(&imp, 1), vec![1]);
        // k larger than channels clamps
        assert_eq!(top_k_channels(&imp, 10), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn top_k_deterministic_ties() {
        let imp = vec![1.0, 1.0, 1.0, 1.0];
        assert_eq!(top_k_channels(&imp, 2), vec![0, 1]);
    }

    #[test]
    fn select_skeleton_per_layer() {
        let means = vec![vec![0.3, 0.9, 0.1], vec![1.0, 2.0]];
        let sk = select_skeleton(&means, &[2, 1]).unwrap();
        assert_eq!(sk[0], vec![0, 1]);
        assert_eq!(sk[1], vec![1]);
        assert!(select_skeleton(&means, &[1]).is_err());
    }

    #[test]
    fn identity_skeleton_full() {
        let sk = identity_skeleton(&[3, 2]);
        assert_eq!(sk[0], vec![0, 1, 2]);
        assert_eq!(sk[1], vec![0, 1]);
    }

    #[test]
    fn linear_capability_policy() {
        let p = RatioPolicy::LinearCapability { min_ratio: 0.1 };
        let r = p.assign(&[1.0, 2.0, 4.0]).unwrap();
        assert_eq!(r, vec![0.25, 0.5, 1.0]);
        // clamping at min
        let r = p.assign(&[0.01, 4.0]).unwrap();
        assert_eq!(r[0], 0.1);
    }

    #[test]
    fn equidistant_policy() {
        let p = RatioPolicy::Equidistant { lo: 0.1, hi: 1.0 };
        let r = p.assign(&[0.0; 10]).unwrap();
        assert!((r[0] - 0.1).abs() < 1e-9);
        assert!((r[9] - 1.0).abs() < 1e-9);
        assert!((r[1] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn fixed_policy_validates() {
        assert!(RatioPolicy::Fixed(1.5).assign(&[1.0]).is_err());
        assert_eq!(RatioPolicy::Fixed(0.4).assign(&[1.0, 2.0]).unwrap(), vec![0.4, 0.4]);
    }

    #[test]
    fn ratio_monotone_in_capability() {
        // property: higher capability never gets a smaller ratio
        let p = RatioPolicy::LinearCapability { min_ratio: 0.05 };
        let caps: Vec<f64> = (1..=20).map(|i| i as f64 * 0.37).collect();
        let r = p.assign(&caps).unwrap();
        for w in r.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
    }
}
