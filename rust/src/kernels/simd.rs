//! Packed, register-blocked microkernels — the `simd` tier of
//! [`KernelTier`](super::KernelTier).
//!
//! Stable-toolchain, dependency-free Rust: instead of `core::simd` these
//! kernels are written so LLVM's autovectorizer maps them onto whatever
//! vector ISA the target has — fixed-width accumulator arrays held in
//! registers, contiguous packed panels, and inner loops with no
//! cross-lane dependencies.
//!
//! ## Bitwise contract (load-bearing)
//!
//! Every kernel here produces output *bitwise identical* to its scalar
//! counterpart in [`super::gemm`] / [`super::conv`]. The trick is that
//! all blocking happens across **output** elements (column panels of C,
//! k-axis blocks of dWᵀ) while each individual output element still
//! accumulates its reduction terms in exactly the scalar kernel's
//! ascending order — Rust never contracts `a*b + c` into an FMA on its
//! own, so identical per-element operation order implies identical bits.
//! Keeping partial sums in registers instead of re-loading them from the
//! output buffer each step changes *where* the value lives, not what it
//! is: an f32 register spill round-trips exactly.
//!
//! What makes this tier faster than the scalar loops:
//!
//! * [`gemm_simd`] packs each `KC × NR` panel of B once per k-tile
//!   (zero-padded ragged tail) and keeps an `NR`-wide accumulator row in
//!   registers across the whole tile — the scalar kernel re-reads and
//!   re-writes the C row from memory on every reduction step.
//! * [`gemm_bt_a_cols_simd`] holds a `KB`-wide slice of one dWᵀ row in
//!   registers across all `m` reduction rows — the scalar kernel streams
//!   the whole row through memory once per reduction row.
//! * [`im2col_simd`] hoists the `ky` loop above `ox` so one input row is
//!   reused across every horizontal patch position (pure copies — parity
//!   is trivial).

use super::conv::Conv2d;
use super::gemm::KC;

/// C-panel width (f32 lanes) for [`gemm_simd`] — two 128-bit or one
/// 256-bit vector register per accumulator row.
pub const NR: usize = 8;

/// dWᵀ-row block width (f32 lanes) for [`gemm_bt_a_cols_simd`].
pub const KB: usize = 16;

/// `out[m×n] += a[m×k] · b[k×n]` — bitwise identical to
/// [`gemm`](super::gemm::gemm), via packed B panels and register
/// accumulation.
///
/// Per k-tile (same [`KC`] tiling as the scalar kernel) B is repacked
/// into `[n_blocks][kc][NR]` column panels; each output element then
/// accumulates `kk` ascending within ascending tiles — the scalar order
/// exactly.
pub fn gemm_simd(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let n_blocks = n.div_ceil(NR);
    let mut panel = vec![0.0f32; n_blocks * KC * NR];
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        let kc = k1 - k0;
        // pack B[k0..k1, :] into zero-padded NR-wide column panels
        for jb in 0..n_blocks {
            let j0 = jb * NR;
            let w = NR.min(n - j0);
            let pb = &mut panel[jb * KC * NR..jb * KC * NR + kc * NR];
            for (kk, dst) in pb.chunks_exact_mut(NR).enumerate() {
                let src = &b[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + w];
                dst[..w].copy_from_slice(src);
                dst[w..].fill(0.0);
            }
        }
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k1];
            let orow = &mut out[i * n..(i + 1) * n];
            for jb in 0..n_blocks {
                let j0 = jb * NR;
                let w = NR.min(n - j0);
                let pb = &panel[jb * KC * NR..jb * KC * NR + kc * NR];
                // load the current C values; padding lanes accumulate
                // `alpha * 0` and are never written back
                let mut acc = [0.0f32; NR];
                acc[..w].copy_from_slice(&orow[j0..j0 + w]);
                for (&alpha, bv) in arow.iter().zip(pb.chunks_exact(NR)) {
                    for (av, &x) in acc.iter_mut().zip(bv) {
                        *av += alpha * x;
                    }
                }
                orow[j0..j0 + w].copy_from_slice(&acc[..w]);
            }
        }
    }
}

/// Column-range slice of the weight-gradient GEMM `out[n×k] += bᵀ·a` —
/// bitwise identical to [`gemm_bt_a_cols`](super::gemm::gemm_bt_a_cols)
/// (same signature, same `j0` semantics).
///
/// Blocks each output row into [`KB`]-wide register accumulators that
/// persist across all `m` reduction rows; every element still sums its
/// rows in ascending order, exactly like the scalar kernel.
pub fn gemm_bt_a_cols_simd(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    j0: usize,
    out: &mut [f32],
) {
    if k == 0 {
        return;
    }
    let jn = out.len() / k;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), jn * k);
    debug_assert!(j0 + jn <= n);
    for (j, orow) in out.chunks_exact_mut(k).enumerate() {
        for kb in (0..k).step_by(KB) {
            let bw = KB.min(k - kb);
            let mut acc = [0.0f32; KB];
            acc[..bw].copy_from_slice(&orow[kb..kb + bw]);
            for row in 0..m {
                let alpha = b[row * n + j0 + j];
                let arow = &a[row * k + kb..row * k + kb + bw];
                for (av, &x) in acc[..bw].iter_mut().zip(arow) {
                    *av += alpha * x;
                }
            }
            orow[kb..kb + bw].copy_from_slice(&acc[..bw]);
        }
    }
}

/// [`Conv2d::im2col`] with the `ky` loop hoisted above `ox`, so each
/// input row stays hot while every horizontal patch position copies from
/// it. Pure gathers — the patch matrix is bitwise identical to the
/// scalar pass by construction.
pub fn im2col_simd(conv: &Conv2d, batch: usize, x: &[f32], patches: &mut [f32]) {
    let (oh, ow, k) = (conv.out_h(), conv.out_w(), conv.patch_len());
    debug_assert_eq!(x.len(), batch * conv.in_numel());
    debug_assert_eq!(patches.len(), conv.rows(batch) * k);
    let row_elems = conv.kw * conv.cin;
    let in_row = conv.in_w * conv.cin;
    for b in 0..batch {
        let xs = &x[b * conv.in_numel()..(b + 1) * conv.in_numel()];
        for oy in 0..oh {
            let prow = &mut patches[(b * oh + oy) * ow * k..(b * oh + oy + 1) * ow * k];
            for ky in 0..conv.kh {
                let src_row = &xs[(oy + ky) * in_row..(oy + ky) * in_row + in_row];
                for (ox, dst) in prow.chunks_exact_mut(k).enumerate() {
                    let src = &src_row[ox * conv.cin..ox * conv.cin + row_elems];
                    dst[ky * row_elems..(ky + 1) * row_elems].copy_from_slice(src);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::{gemm, gemm_bt_a_cols};

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..n).map(|_| rng.normal() * 0.5).collect()
    }

    #[test]
    fn gemm_simd_bitwise_matches_scalar_on_ragged_shapes() {
        // widths straddle the NR panel (4, 9) and the KC tile (257, 300)
        for (m, k, n) in [(3, 5, 4), (7, 300, 2), (1, 1, 1), (4, 257, 9), (37, 150, 96)] {
            let a = data(m * k, 1);
            let b = data(k * n, 2);
            let mut want = data(m * n, 3); // nonzero: += semantics must match
            let mut got = want.clone();
            gemm(m, k, n, &a, &b, &mut want);
            gemm_simd(m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_bt_a_cols_simd_bitwise_matches_scalar_incl_offsets() {
        // k values straddle the KB block (1, 10, 50, 64)
        for (m, k, n, j0, jn) in
            [(6, 10, 3, 0, 3), (37, 50, 8, 1, 2), (640, 64, 13, 5, 8), (9, 1, 4, 3, 1)]
        {
            let a = data(m * k, 4);
            let b = data(m * n, 5);
            let mut want = data(jn * k, 6);
            let mut got = want.clone();
            gemm_bt_a_cols(m, k, n, &a, &b, j0, &mut want);
            gemm_bt_a_cols_simd(m, k, n, &a, &b, j0, &mut got);
            assert_eq!(got, want, "({m},{k},{n}) j0={j0}");
        }
    }

    #[test]
    fn im2col_simd_bitwise_matches_scalar() {
        for conv in [
            Conv2d { in_h: 5, in_w: 6, cin: 2, cout: 3, kh: 3, kw: 2 },
            Conv2d { in_h: 16, in_w: 16, cin: 8, cout: 1, kh: 3, kw: 3 },
            Conv2d { in_h: 4, in_w: 4, cin: 1, cout: 1, kh: 4, kw: 4 },
        ] {
            let batch = 3;
            let x = data(batch * conv.in_numel(), 7);
            let len = conv.rows(batch) * conv.patch_len();
            let mut want = vec![0.0f32; len];
            let mut got = vec![0.0f32; len];
            conv.im2col(batch, &x, &mut want);
            im2col_simd(&conv, batch, &x, &mut got);
            assert_eq!(got, want, "{conv:?}");
        }
    }
}
