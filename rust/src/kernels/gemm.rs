//! Dense f32 GEMM primitives for the native CPU backend.
//!
//! Three loop orders, one per use site, each chosen so the *innermost*
//! loop runs contiguously over the longest row-major axis (the compiler
//! auto-vectorizes a contiguous `axpy`):
//!
//! * [`gemm`] (`C += A·B`) — forward conv/dense and the `dA` back-prop
//!   GEMM. `i`/`kk`/`j` order: the inner loop streams a row of B.
//! * [`gemm_bt_a`] (`C += Bᵀ·A`) — the weight-gradient GEMM, produced
//!   *transposed* (`[N, K]` instead of `[K, N]`) so the inner loop streams
//!   a row of A even when the skeleton width `N = k` is tiny. The caller
//!   scatters rows back to weight columns ([`scatter_cols_add`]).
//! * [`col_sums`] — bias gradients.
//!
//! The reduction axis is always walked in ascending order, so any output
//! element accumulates in the same floating-point order regardless of
//! which *other* columns are computed. That is what makes the
//! skeleton-sliced backward bitwise-equal to the full backward on the
//! selected channels (see `rust/tests/native_backend.rs`).
//!
//! Cache blocking: the reduction dim is tiled at [`KC`] so the active
//! panel of B stays in L1/L2 while every row of A streams through it.

/// Reduction-dimension tile (f32 elements). 256 keeps a `KC × n` panel of
/// B under 32 KiB for every layer width this crate uses.
pub const KC: usize = 256;

/// `out[m×n] += a[m×k] · b[k×n]` (all row-major, contiguous).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &alpha) in arow.iter().enumerate().take(k1).skip(k0) {
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += alpha * bv;
                }
            }
        }
    }
}

/// `out[n×k] += bᵀ[n×m] · a[m×k]` — i.e. `(Aᵀ·B)ᵀ` with `a: [m×k]`,
/// `b: [m×n]`.
///
/// This is the skeleton weight-gradient GEMM `dWᵀ = dZ_sᵀ · patches`: the
/// inner loop is over `k` (a full patch row, long and contiguous) rather
/// than over the skeleton width `n`, so throughput does not collapse when
/// only a couple of channels are selected.
pub fn gemm_bt_a(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), n * k);
    gemm_bt_a_cols(m, k, n, a, b, 0, out);
}

/// Column-range slice of [`gemm_bt_a`]: computes output rows
/// `j0 .. j0 + out.len()/k` (b-columns `j0..`) into `out`, walking the
/// `m` reduction rows in the same ascending order as the full kernel —
/// each output element is therefore bitwise identical to the full call.
/// This is the shard body of [`super::parallel::pgemm_bt_a`].
pub fn gemm_bt_a_cols(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], j0: usize, out: &mut [f32]) {
    let jn = out.len() / k;
    debug_assert_eq!(out.len(), jn * k);
    debug_assert!(j0 + jn <= n);
    for row in 0..m {
        let arow = &a[row * k..(row + 1) * k];
        let brow = &b[row * n + j0..row * n + j0 + jn];
        for (j, &alpha) in brow.iter().enumerate() {
            let orow = &mut out[j * k..(j + 1) * k];
            for (o, &av) in orow.iter_mut().zip(arow) {
                *o += alpha * av;
            }
        }
    }
}

/// `out[j] += Σ_m b[m×n][m, j]` — column sums (bias gradients).
pub fn col_sums(m: usize, n: usize, b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), n);
    col_sums_cols(m, n, b, 0, out);
}

/// Column-range slice of [`col_sums`]: sums b-columns
/// `j0 .. j0 + out.len()` into `out`, rows ascending — the shard body of
/// [`super::parallel::pcol_sums`], bitwise identical to the full call.
pub fn col_sums_cols(m: usize, n: usize, b: &[f32], j0: usize, out: &mut [f32]) {
    let jn = out.len();
    debug_assert!(j0 + jn <= n);
    for row in 0..m {
        let brow = &b[row * n + j0..row * n + j0 + jn];
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += bv;
        }
    }
}

/// Gather columns `idx` of `src[m×n]` into dense `dst[m×idx.len()]`
/// (the skeleton gather `dz_s = dz[:, idx]`).
pub fn gather_cols(m: usize, n: usize, src: &[f32], idx: &[i32], dst: &mut [f32]) {
    let k = idx.len();
    debug_assert_eq!(src.len(), m * n);
    debug_assert_eq!(dst.len(), m * k);
    for row in 0..m {
        let srow = &src[row * n..(row + 1) * n];
        let drow = &mut dst[row * k..(row + 1) * k];
        for (d, &c) in drow.iter_mut().zip(idx) {
            *d = srow[c as usize];
        }
    }
}

/// Gather columns `idx` of `src[m×n]` *transposed* into `dst[idx.len()×m]`
/// — row `j` of `dst` is column `idx[j]` of `src`. Used to stage the
/// skeleton slice `W[:, idx]ᵀ` for the `dA = dZ_s · W_sᵀ` GEMM.
pub fn gather_cols_t(m: usize, n: usize, src: &[f32], idx: &[i32], dst: &mut [f32]) {
    let k = idx.len();
    debug_assert_eq!(src.len(), m * n);
    debug_assert_eq!(dst.len(), k * m);
    for (j, &c) in idx.iter().enumerate() {
        let c = c as usize;
        let drow = &mut dst[j * m..(j + 1) * m];
        for (row, d) in drow.iter_mut().enumerate() {
            *d = src[row * n + c];
        }
    }
}

/// Scatter-add the transposed gradient rows back into weight columns:
/// `dst[k×n][:, idx[j]] += src[j·k .. (j+1)·k]` for every `j`.
pub fn scatter_cols_add(k: usize, n: usize, src: &[f32], idx: &[i32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), idx.len() * k);
    debug_assert_eq!(dst.len(), k * n);
    for (j, &c) in idx.iter().enumerate() {
        let c = c as usize;
        let srow = &src[j * k..(j + 1) * k];
        for (i, &sv) in srow.iter().enumerate() {
            dst[i * n + c] += sv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 7 % 13) as f32 - 6.0) * scale).collect()
    }

    #[test]
    fn gemm_matches_naive() {
        // odd sizes straddle the KC tile boundary when KC is lowered by k
        for (m, k, n) in [(3, 5, 4), (7, 300, 2), (1, 1, 1), (4, 257, 9)] {
            let a = seq(m * k, 0.25);
            let b = seq(k * n, 0.5);
            let mut c = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut c);
            let want = naive_gemm(m, k, n, &a, &b);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_bt_a_is_transposed_at_b() {
        let (m, k, n) = (6, 10, 3);
        let a = seq(m * k, 0.3);
        let b = seq(m * n, 0.7);
        let mut out_t = vec![0.0f32; n * k];
        gemm_bt_a(m, k, n, &a, &b, &mut out_t);
        // reference: Aᵀ·B is [k×n]; out_t[j,i] must equal (AᵀB)[i,j]
        for i in 0..k {
            for j in 0..n {
                let mut s = 0.0f32;
                for row in 0..m {
                    s += a[row * k + i] * b[row * n + j];
                }
                assert!((out_t[j * k + i] - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn col_sums_adds_rows() {
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let mut s = vec![0.0f32; 3];
        col_sums(2, 3, &b, &mut s);
        assert_eq!(s, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let src = vec![0., 1., 2., 3., 10., 11., 12., 13.]; // 2x4
        let idx = [1i32, 3];
        let mut g = vec![0.0f32; 2 * 2];
        gather_cols(2, 4, &src, &idx, &mut g);
        assert_eq!(g, vec![1., 3., 11., 13.]);

        let mut gt = vec![0.0f32; 2 * 2];
        gather_cols_t(2, 4, &src, &idx, &mut gt);
        assert_eq!(gt, vec![1., 11., 3., 13.]);

        // scatter the transposed form back into a zeroed 2x4
        let mut dst = vec![0.0f32; 2 * 4];
        scatter_cols_add(2, 4, &gt, &idx, &mut dst);
        assert_eq!(dst, vec![0., 1., 0., 3., 0., 11., 0., 13.]);
    }

    #[test]
    fn cols_variants_match_full_kernels() {
        let (m, k, n) = (9, 7, 5);
        let a = seq(m * k, 0.2);
        let b = seq(m * n, 0.4);
        let mut full = vec![0.0f32; n * k];
        gemm_bt_a(m, k, n, &a, &b, &mut full);
        let mut mid = vec![0.0f32; 2 * k]; // columns 1..3
        gemm_bt_a_cols(m, k, n, &a, &b, 1, &mut mid);
        assert_eq!(&mid[..], &full[k..3 * k]);

        let mut sums = vec![0.0f32; n];
        col_sums(m, n, &b, &mut sums);
        let mut tail = vec![0.0f32; 2]; // columns 3..5
        col_sums_cols(m, n, &b, 3, &mut tail);
        assert_eq!(&tail[..], &sums[3..5]);
    }

    #[test]
    fn reduction_order_is_subset_invariant() {
        // the property the skeleton parity test relies on: computing a
        // column alone gives bitwise the same value as computing it among
        // all columns.
        let (m, k, n) = (37, 50, 8);
        let a = seq(m * k, 0.013);
        let b = seq(m * n, 0.029);
        let mut full = vec![0.0f32; n * k];
        gemm_bt_a(m, k, n, &a, &b, &mut full);
        let idx = [5i32];
        let mut bs = vec![0.0f32; m];
        gather_cols(m, n, &b, &idx, &mut bs);
        let mut one = vec![0.0f32; k];
        gemm_bt_a(m, k, 1, &a, &bs, &mut one);
        assert_eq!(&full[5 * k..6 * k], &one[..]);
    }
}
