//! im2col convolution for NHWC tensors (stride 1, valid padding).
//!
//! A conv layer `z = conv(x, w) + b` is lowered exactly like the AOT
//! Pallas path (python/compile/kernels/): patches are gathered once into
//! an `[M, K]` matrix (`M = B·OH·OW`, `K = KH·KW·CIN`) whose column order
//! `(ky, kx, c)` matches the row-major flattening of the `[KH,KW,CIN,COUT]`
//! weight tensor, so forward is one GEMM and both backward GEMMs reuse the
//! cached patches.
//!
//! The backward entry points take the *skeleton* channel indices and do
//! gathered small GEMMs (`dW_s`, `dA` through only the selected output
//! channels) — FLOPs scale with `k/C` exactly as in FedSkel §3.2.

use super::gemm::{gather_cols, gather_cols_t};
use super::parallel::{pcol_sums, pgemm, pgemm_bt_a, Parallelism};

/// Geometry of one stride-1 valid conv layer over NHWC input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2d {
    pub in_h: usize,
    pub in_w: usize,
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
}

impl Conv2d {
    pub fn out_h(&self) -> usize {
        self.in_h - self.kh + 1
    }

    pub fn out_w(&self) -> usize {
        self.in_w - self.kw + 1
    }

    /// Patch length `K = KH·KW·CIN`.
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// GEMM rows `M = batch·OH·OW`.
    pub fn rows(&self, batch: usize) -> usize {
        batch * self.out_h() * self.out_w()
    }

    /// Input elements per sample.
    pub fn in_numel(&self) -> usize {
        self.in_h * self.in_w * self.cin
    }

    /// Gather `x[B,H,W,CIN]` into `patches[M,K]` (row `(b,oy,ox)`, column
    /// `(ky,kx,c)`).
    pub fn im2col(&self, batch: usize, x: &[f32], patches: &mut [f32]) {
        let (oh, ow, k) = (self.out_h(), self.out_w(), self.patch_len());
        debug_assert_eq!(x.len(), batch * self.in_numel());
        debug_assert_eq!(patches.len(), self.rows(batch) * k);
        let row_elems = self.kw * self.cin; // one (ky) slab of a patch
        let in_row = self.in_w * self.cin;
        for b in 0..batch {
            let xs = &x[b * self.in_numel()..(b + 1) * self.in_numel()];
            for oy in 0..oh {
                for ox in 0..ow {
                    let m = (b * oh + oy) * ow + ox;
                    let dst = &mut patches[m * k..(m + 1) * k];
                    for ky in 0..self.kh {
                        let src_off = (oy + ky) * in_row + ox * self.cin;
                        dst[ky * row_elems..(ky + 1) * row_elems]
                            .copy_from_slice(&xs[src_off..src_off + row_elems]);
                    }
                }
            }
        }
    }

    /// Scatter-add patch gradients `d_patches[M,K]` back to the input
    /// gradient `dx[B,H,W,CIN]` (the transpose of [`Conv2d::im2col`]).
    pub fn col2im_add(&self, batch: usize, d_patches: &[f32], dx: &mut [f32]) {
        let (oh, ow, k) = (self.out_h(), self.out_w(), self.patch_len());
        debug_assert_eq!(dx.len(), batch * self.in_numel());
        debug_assert_eq!(d_patches.len(), self.rows(batch) * k);
        let row_elems = self.kw * self.cin;
        let in_row = self.in_w * self.cin;
        for b in 0..batch {
            let xs = &mut dx[b * self.in_numel()..(b + 1) * self.in_numel()];
            for oy in 0..oh {
                for ox in 0..ow {
                    let m = (b * oh + oy) * ow + ox;
                    let src = &d_patches[m * k..(m + 1) * k];
                    for ky in 0..self.kh {
                        let dst_off = (oy + ky) * in_row + ox * self.cin;
                        let srow = &src[ky * row_elems..(ky + 1) * row_elems];
                        for (d, &s) in xs[dst_off..dst_off + row_elems].iter_mut().zip(srow) {
                            *d += s;
                        }
                    }
                }
            }
        }
    }

    /// Forward: `z[M,COUT] = patches · w_mat + bias` (`w_mat` is the
    /// `[KH,KW,CIN,COUT]` weight viewed as `[K,COUT]`).
    pub fn forward(&self, batch: usize, patches: &[f32], w_mat: &[f32], bias: &[f32], z: &mut [f32]) {
        self.forward_par(Parallelism::serial(), batch, patches, w_mat, bias, z);
    }

    /// [`Conv2d::forward`] under a thread budget: the GEMM is row-sharded
    /// by [`pgemm`], bitwise identical to the serial call.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_par(
        &self,
        par: Parallelism,
        batch: usize,
        patches: &[f32],
        w_mat: &[f32],
        bias: &[f32],
        z: &mut [f32],
    ) {
        let m = self.rows(batch);
        debug_assert_eq!(bias.len(), self.cout);
        debug_assert_eq!(z.len(), m * self.cout);
        for chunk in z.chunks_exact_mut(self.cout) {
            chunk.copy_from_slice(bias);
        }
        pgemm(par, m, self.patch_len(), self.cout, patches, w_mat, z);
    }
}

/// Skeleton-sliced backward for one GEMM-lowered layer (conv via patches,
/// dense via its input activations): given the full-width pre-activation
/// gradient `dz[M,N]`, the layer input `a[M,K]`, and the skeleton channel
/// indices `idx` (identity for a full update), computes
///
/// * `dw_t[k_s, K]`  — weight gradient rows for the selected channels
///   (transposed; scatter back with [`scatter_cols_add`][sc]),
/// * `db_s[k_s]`     — bias gradient for the selected channels,
/// * `da[M,K] += dZ_s · W_sᵀ` — input gradient through only the selected
///   channels (skipped when `da` is `None`, e.g. the first layer).
///
/// Scratch buffers (`dz_s`, `w_t`) are caller-provided so the hot loop
/// never allocates. All GEMM work is `O(M·K·k_s)` — proportional to the
/// skeleton ratio — and runs under the `par` thread budget: the weight
/// gradient is channel-sharded, `dA` row-sharded, both bitwise identical
/// to the serial kernels (`Parallelism::serial()` reproduces the old
/// behaviour exactly).
///
/// [sc]: super::gemm::scatter_cols_add
#[allow(clippy::too_many_arguments)]
pub fn sliced_backward(
    par: Parallelism,
    m: usize,
    k: usize,
    n: usize,
    dz: &[f32],
    a: &[f32],
    w_mat: &[f32],
    idx: &[i32],
    dz_s: &mut Vec<f32>,
    w_t: &mut Vec<f32>,
    dw_t: &mut [f32],
    db_s: &mut [f32],
    da: Option<&mut [f32]>,
) {
    let ks = idx.len();
    debug_assert_eq!(dz.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(w_mat.len(), k * n);
    debug_assert_eq!(dw_t.len(), ks * k);
    debug_assert_eq!(db_s.len(), ks);
    dz_s.resize(m * ks, 0.0);
    gather_cols(m, n, dz, idx, dz_s);
    // dWᵀ = dZ_sᵀ · a   (inner loop over K, see gemm_bt_a)
    pgemm_bt_a(par, m, k, ks, a, dz_s, dw_t);
    pcol_sums(par, m, ks, dz_s, db_s);
    if let Some(da) = da {
        debug_assert_eq!(da.len(), m * k);
        w_t.resize(ks * k, 0.0);
        gather_cols_t(k, n, w_mat, idx, w_t);
        // dA += dZ_s[M,ks] · W_sᵀ[ks,K]
        pgemm(par, m, ks, k, dz_s, w_t, da);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_conv(c: &Conv2d, batch: usize, x: &[f32], w: &[f32], b: &[f32]) -> Vec<f32> {
        let (oh, ow) = (c.out_h(), c.out_w());
        let mut z = vec![0.0f32; batch * oh * ow * c.cout];
        for bi in 0..batch {
            for oy in 0..oh {
                for ox in 0..ow {
                    for co in 0..c.cout {
                        let mut s = b[co];
                        for ky in 0..c.kh {
                            for kx in 0..c.kw {
                                for ci in 0..c.cin {
                                    let xv = x[((bi * c.in_h + oy + ky) * c.in_w + ox + kx)
                                        * c.cin
                                        + ci];
                                    let wv = w[((ky * c.kw + kx) * c.cin + ci) * c.cout + co];
                                    s += xv * wv;
                                }
                            }
                        }
                        z[((bi * oh + oy) * ow + ox) * c.cout + co] = s;
                    }
                }
            }
        }
        z
    }

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..n).map(|_| rng.normal() * 0.5).collect()
    }

    #[test]
    fn im2col_forward_matches_naive_conv() {
        let c = Conv2d { in_h: 5, in_w: 6, cin: 2, cout: 3, kh: 3, kw: 2 };
        let batch = 2;
        let x = data(batch * c.in_numel(), 1);
        let w = data(c.patch_len() * c.cout, 2);
        let b = data(c.cout, 3);
        let mut patches = vec![0.0f32; c.rows(batch) * c.patch_len()];
        c.im2col(batch, &x, &mut patches);
        let mut z = vec![0.0f32; c.rows(batch) * c.cout];
        c.forward(batch, &patches, &w, &b, &mut z);
        let want = naive_conv(&c, batch, &x, &w, &b);
        for (a, e) in z.iter().zip(&want) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
    }

    #[test]
    fn col2im_is_im2col_transpose() {
        // <im2col(x), p> == <x, col2im(p)> for arbitrary x, p
        let c = Conv2d { in_h: 4, in_w: 4, cin: 2, cout: 1, kh: 2, kw: 3 };
        let batch = 2;
        let x = data(batch * c.in_numel(), 4);
        let p = data(c.rows(batch) * c.patch_len(), 5);
        let mut px = vec![0.0f32; c.rows(batch) * c.patch_len()];
        c.im2col(batch, &x, &mut px);
        let lhs: f64 = px.iter().zip(&p).map(|(a, b)| (a * b) as f64).sum();
        let mut dx = vec![0.0f32; batch * c.in_numel()];
        c.col2im_add(batch, &p, &mut dx);
        let rhs: f64 = x.iter().zip(&dx).map(|(a, b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn sliced_backward_shapes_and_subset_parity() {
        let (m, k, n) = (12, 10, 6);
        let dz = data(m * n, 7);
        let a = data(m * k, 8);
        let w = data(k * n, 9);
        let full_idx: Vec<i32> = (0..n as i32).collect();
        let (mut s1, mut s2) = (Vec::new(), Vec::new());
        let mut dw_full = vec![0.0f32; n * k];
        let mut db_full = vec![0.0f32; n];
        let mut da_full = vec![0.0f32; m * k];
        sliced_backward(
            Parallelism::serial(), m, k, n, &dz, &a, &w, &full_idx, &mut s1, &mut s2,
            &mut dw_full, &mut db_full, Some(&mut da_full),
        );
        let idx = [1i32, 4];
        let mut dw_s = vec![0.0f32; 2 * k];
        let mut db_s = vec![0.0f32; 2];
        let mut da_s = vec![0.0f32; m * k];
        sliced_backward(
            Parallelism::serial(), m, k, n, &dz, &a, &w, &idx, &mut s1, &mut s2, &mut dw_s,
            &mut db_s, Some(&mut da_s),
        );
        // selected channels bitwise equal to the full run
        assert_eq!(&dw_s[..k], &dw_full[k..2 * k]);
        assert_eq!(&dw_s[k..], &dw_full[4 * k..5 * k]);
        assert_eq!(db_s[0], db_full[1]);
        assert_eq!(db_s[1], db_full[4]);
        // da through 2 of 6 channels is a partial sum, not the full one
        let n2: f32 = da_s.iter().map(|v| v * v).sum();
        let nf: f32 = da_full.iter().map(|v| v * v).sum();
        assert!(n2 > 0.0 && n2 < nf);
    }
}
