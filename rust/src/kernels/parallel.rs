//! Scoped multi-threaded wrappers around the serial kernels — the native
//! backend's parallel execution layer (paper Table 1 / Fig. 5 substrate:
//! per-client core budgets make compute heterogeneity *emergent* instead
//! of a sleep-based latency knob).
//!
//! Everything here is dependency-free `std::thread::scope` fan-out; there
//! is no persistent pool and no unsafe. Each wrapper splits its output
//! into at most [`Parallelism::threads`] disjoint contiguous shards and
//! runs a *serial* kernel on every shard — the scalar reference or its
//! bitwise-identical `simd`-tier twin, chosen by [`Parallelism::tier`]
//! (see [`super::tier::KernelTier`] and [`super::simd`]).
//!
//! ## Determinism contract (load-bearing)
//!
//! Every parallel kernel must produce output *bitwise identical* to its
//! serial counterpart at any thread count *and any kernel tier*. The
//! sharding axes are chosen
//! so each output element is still accumulated by exactly one thread,
//! walking the reduction axis in the same ascending order as the serial
//! kernel:
//!
//! * [`pgemm`] — row-shards `C += A·B`: a thread owns whole output rows
//!   and reduces over `k` ascending (identical [`super::gemm::KC`]
//!   tiling per row).
//! * [`pgemm_bt_a`] — channel-shards the (skeleton) weight-gradient GEMM:
//!   a thread owns whole output rows `j` (= selected channels) and
//!   reduces over `m` ascending.
//! * [`pcol_sums`] — column-shards the bias-gradient reduction, `m`
//!   ascending per column.
//! * [`pim2col`] / [`pmaxpool2_fwd`] — batch-shard pure gather passes
//!   (samples are independent; the pool shard rebases its argmax indices
//!   to the global input so backward scatters stay correct).
//!
//! This is what keeps the skeleton-parity and FD-gradient tests
//! (`rust/tests/native_backend.rs`) green at every thread count, and what
//! lets CI assert identical model digests for 1- vs 2-thread training.
//!
//! Tiny problems skip the fan-out entirely ([`PAR_MIN_FLOPS`],
//! [`PAR_MIN_ELEMS`]): spawning costs more than the loop.

use super::conv::Conv2d;
use super::gemm::{col_sums, col_sums_cols, gemm, gemm_bt_a_cols};
use super::pool::maxpool2_fwd;
use super::simd::{gemm_bt_a_cols_simd, gemm_simd, im2col_simd};
use super::tier::KernelTier;
use crate::prof;

/// Per-tier profiler span name for one kernel family. Spans are opened
/// on the *caller* thread around the whole fork/join (never inside the
/// spawned shard closures), so a kernel span includes its spawn/join
/// overhead and the per-thread timing tree stays single-rooted.
fn tier_span(tier: KernelTier, scalar: &'static str, simd: &'static str) -> &'static str {
    match tier {
        KernelTier::Scalar => scalar,
        KernelTier::Simd => simd,
    }
}

/// A compute-thread budget (a simulated client's core count) plus the
/// [`KernelTier`] its shards dispatch to. `1` thread means fully serial —
/// no threads are ever spawned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
    tier: KernelTier,
}

impl Parallelism {
    /// Budget of `threads` compute threads (clamped to ≥ 1), scalar tier.
    pub fn new(threads: usize) -> Parallelism {
        Parallelism { threads: threads.max(1), tier: KernelTier::Scalar }
    }

    /// The single-threaded scalar budget — bitwise the reference
    /// behaviour (every other (threads, tier) combination must reproduce
    /// it exactly).
    pub fn serial() -> Parallelism {
        Parallelism::new(1)
    }

    /// Same thread budget, dispatching to `tier` kernels.
    pub fn with_tier(mut self, tier: KernelTier) -> Parallelism {
        self.tier = tier;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// Shards to split `items` work units into: never more than the
    /// budget, never more than the items.
    fn shards(&self, items: usize) -> usize {
        self.threads.min(items).max(1)
    }
}

impl Default for Parallelism {
    fn default() -> Parallelism {
        Parallelism::serial()
    }
}

/// Below this many multiply-adds a GEMM stays serial. Spawning + joining
/// a scoped thread costs tens of µs; ~512K MACs is ~100µs+ of serial GEMM
/// work on these kernels, so fan-out only starts where shards amortize
/// their spawn. LeNet's conv layers and fc1 (~1M–5M MACs) parallelize;
/// the small fc2/head GEMMs (~200–300K) rightly stay serial.
pub const PAR_MIN_FLOPS: usize = 512 * 1024;

/// Below this many moved elements a gather/copy pass stays serial — the
/// same spawn-amortization argument for memory-bound passes (~0.4 MB of
/// traffic before threads pay off). Sized so LeNet's conv1 im2col and
/// pool-argmax passes (at batch 32) clear it while the tiny CI model
/// stays serial.
pub const PAR_MIN_ELEMS: usize = 96 * 1024;

// ---- tier dispatch: one shard body per kernel, chosen by
// [`Parallelism::tier`]. Both arms are bitwise identical (see
// `super::simd`), so the choice affects throughput only.

fn run_gemm(tier: KernelTier, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    match tier {
        KernelTier::Scalar => gemm(m, k, n, a, b, out),
        KernelTier::Simd => gemm_simd(m, k, n, a, b, out),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_gemm_bt_a_cols(
    tier: KernelTier,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    j0: usize,
    out: &mut [f32],
) {
    match tier {
        KernelTier::Scalar => gemm_bt_a_cols(m, k, n, a, b, j0, out),
        KernelTier::Simd => gemm_bt_a_cols_simd(m, k, n, a, b, j0, out),
    }
}

fn run_im2col(tier: KernelTier, conv: &Conv2d, batch: usize, x: &[f32], patches: &mut [f32]) {
    match tier {
        KernelTier::Scalar => conv.im2col(batch, x, patches),
        KernelTier::Simd => im2col_simd(conv, batch, x, patches),
    }
}

/// Parallel `out[m×n] += a[m×k] · b[k×n]` — row-sharded [`gemm`].
///
/// Each shard owns `out` rows `[r0, r1)` and the matching rows of `a`;
/// per row the serial kernel runs unchanged, so the result is bitwise
/// equal to `gemm(m, k, n, a, b, out)` at any thread count.
pub fn pgemm(par: Parallelism, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let _span = prof::scope(tier_span(par.tier, "gemm:scalar", "gemm:simd"));
    let shards = par.shards(m);
    if shards <= 1 || m * k * n < PAR_MIN_FLOPS {
        run_gemm(par.tier, m, k, n, a, b, out);
        return;
    }
    let rows_per = m.div_ceil(shards);
    let tier = par.tier;
    std::thread::scope(|s| {
        for (a_chunk, o_chunk) in a.chunks(rows_per * k).zip(out.chunks_mut(rows_per * n)) {
            let rows = o_chunk.len() / n;
            s.spawn(move || run_gemm(tier, rows, k, n, a_chunk, b, o_chunk));
        }
    });
}

/// Parallel `out[n×k] += bᵀ[n×m] · a[m×k]` — channel-sharded
/// [`gemm_bt_a`] (the skeleton weight-gradient GEMM).
///
/// Each shard owns a contiguous range of output rows `j` (= b-columns =
/// selected channels) and walks all `m` reduction rows ascending, exactly
/// like the serial kernel — bitwise equal at any thread count. With a
/// tiny skeleton (`n < 2`) this degrades gracefully to the serial path.
pub fn pgemm_bt_a(
    par: Parallelism,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), n * k);
    let _span = prof::scope(tier_span(par.tier, "gemm_bt_a:scalar", "gemm_bt_a:simd"));
    let shards = par.shards(n);
    if shards <= 1 || m * k * n < PAR_MIN_FLOPS {
        run_gemm_bt_a_cols(par.tier, m, k, n, a, b, 0, out);
        return;
    }
    let cols_per = n.div_ceil(shards);
    let tier = par.tier;
    std::thread::scope(|s| {
        for (i, o_chunk) in out.chunks_mut(cols_per * k).enumerate() {
            let j0 = i * cols_per;
            s.spawn(move || run_gemm_bt_a_cols(tier, m, k, n, a, b, j0, o_chunk));
        }
    });
}

/// Parallel column sums (bias gradients) — column-sharded [`col_sums`],
/// bitwise equal at any thread count.
pub fn pcol_sums(par: Parallelism, m: usize, n: usize, b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), n);
    let _span = prof::scope("col_sums");
    let shards = par.shards(n);
    if shards <= 1 || m * n < PAR_MIN_ELEMS {
        col_sums(m, n, b, out);
        return;
    }
    let cols_per = n.div_ceil(shards);
    std::thread::scope(|s| {
        for (i, o_chunk) in out.chunks_mut(cols_per).enumerate() {
            let j0 = i * cols_per;
            s.spawn(move || col_sums_cols(m, n, b, j0, o_chunk));
        }
    });
}

/// Parallel [`Conv2d::im2col`] — batch-sharded. Samples are independent
/// and the patch matrix is batch-major, so each shard is a plain serial
/// `im2col` over a sub-batch writing its own rows. Pure copies: trivially
/// bitwise equal.
pub fn pim2col(par: Parallelism, conv: &Conv2d, batch: usize, x: &[f32], patches: &mut [f32]) {
    let in1 = conv.in_numel();
    let rows1 = conv.rows(1) * conv.patch_len();
    debug_assert_eq!(x.len(), batch * in1);
    debug_assert_eq!(patches.len(), batch * rows1);
    let _span = prof::scope(tier_span(par.tier, "im2col:scalar", "im2col:simd"));
    let shards = par.shards(batch);
    if shards <= 1 || patches.len() < PAR_MIN_ELEMS {
        run_im2col(par.tier, conv, batch, x, patches);
        return;
    }
    let per = batch.div_ceil(shards);
    let tier = par.tier;
    std::thread::scope(|s| {
        for (x_chunk, p_chunk) in x.chunks(per * in1).zip(patches.chunks_mut(per * rows1)) {
            let b = x_chunk.len() / in1;
            s.spawn(move || run_im2col(tier, conv, b, x_chunk, p_chunk));
        }
    });
}

/// One batch-shard of the parallel max pool: serial pool over the
/// sub-batch, then rebase the recorded argmax indices from shard-local to
/// the global input so [`super::pool::maxpool2_bwd`] scatters into the
/// full tensor.
fn pool_shard(base: usize, h: usize, w: usize, c: usize, x: &[f32], out: &mut [f32], am: &mut [u32]) {
    let in1 = h * w * c;
    maxpool2_fwd(x.len() / in1, h, w, c, x, out, am);
    if base > 0 {
        for a in am.iter_mut() {
            *a += base as u32;
        }
    }
}

/// Parallel [`maxpool2_fwd`] — batch-sharded argmax pass. Values and
/// (rebased) argmax indices are bitwise equal to the serial kernel at any
/// thread count.
#[allow(clippy::too_many_arguments)]
pub fn pmaxpool2_fwd(
    par: Parallelism,
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
    x: &[f32],
    out: &mut [f32],
    argmax: &mut [u32],
) {
    let in1 = h * w * c;
    let out1 = (h / 2) * (w / 2) * c;
    debug_assert_eq!(x.len(), batch * in1);
    debug_assert_eq!(out.len(), batch * out1);
    debug_assert_eq!(argmax.len(), out.len());
    let _span = prof::scope("maxpool_fwd");
    let shards = par.shards(batch);
    if shards <= 1 || x.len() < PAR_MIN_ELEMS {
        maxpool2_fwd(batch, h, w, c, x, out, argmax);
        return;
    }
    let per = batch.div_ceil(shards);
    std::thread::scope(|s| {
        for (i, ((x_chunk, o_chunk), a_chunk)) in x
            .chunks(per * in1)
            .zip(out.chunks_mut(per * out1))
            .zip(argmax.chunks_mut(per * out1))
            .enumerate()
        {
            let base = i * per * in1;
            s.spawn(move || pool_shard(base, h, w, c, x_chunk, o_chunk, a_chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::gemm_bt_a;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..n).map(|_| rng.normal() * 0.5).collect()
    }

    // Thread counts the parity tests sweep: serial, even split, and a
    // prime that forces a ragged tail shard on every size used below.
    const SWEEP: [usize; 3] = [1, 2, 7];

    #[test]
    fn pgemm_bitwise_matches_serial_incl_ragged_tail() {
        // m = 37 rows over 7 threads → ceil = 6-row shards + 1-row tail
        let (m, k, n) = (37, 150, 96); // 532800 MACs ≥ PAR_MIN_FLOPS
        assert!(m * k * n >= PAR_MIN_FLOPS);
        let a = data(m * k, 1);
        let b = data(k * n, 2);
        let mut want = data(m * n, 3); // nonzero: += semantics must match
        let base = want.clone();
        gemm(m, k, n, &a, &b, &mut want);
        for t in SWEEP {
            let mut got = base.clone();
            pgemm(Parallelism::new(t), m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "{t} threads");
        }
    }

    #[test]
    fn pgemm_bt_a_bitwise_matches_serial_incl_ragged_tail() {
        // n = 13 channels over 7 threads → 2-col shards + 1-col tail
        let (m, k, n) = (640, 64, 13); // 532480 MACs
        assert!(m * k * n >= PAR_MIN_FLOPS);
        let a = data(m * k, 4);
        let b = data(m * n, 5);
        let mut want = vec![0.0f32; n * k];
        gemm_bt_a(m, k, n, &a, &b, &mut want);
        for t in SWEEP {
            let mut got = vec![0.0f32; n * k];
            pgemm_bt_a(Parallelism::new(t), m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "{t} threads");
        }
        // a 1-channel skeleton degrades to the serial path and still agrees
        let mut one_want = vec![0.0f32; k];
        gemm_bt_a(m, k, 1, &a, &b[..m], &mut one_want);
        let mut one_got = vec![0.0f32; k];
        pgemm_bt_a(Parallelism::new(7), m, k, 1, &a, &b[..m], &mut one_got);
        assert_eq!(one_got, one_want);
    }

    #[test]
    fn pcol_sums_bitwise_matches_serial() {
        let (m, n) = (7700, 13); // 100100 elems ≥ PAR_MIN_ELEMS
        assert!(m * n >= PAR_MIN_ELEMS);
        let b = data(m * n, 6);
        let mut want = vec![0.0f32; n];
        col_sums(m, n, &b, &mut want);
        for t in SWEEP {
            let mut got = vec![0.0f32; n];
            pcol_sums(Parallelism::new(t), m, n, &b, &mut got);
            assert_eq!(got, want, "{t} threads");
        }
    }

    #[test]
    fn pim2col_bitwise_matches_serial() {
        let conv = Conv2d { in_h: 16, in_w: 16, cin: 8, cout: 1, kh: 3, kw: 3 };
        let batch = 11; // 11 samples over 7 threads → 2-sample shards + 1-sample tail
        let x = data(batch * conv.in_numel(), 7);
        let len = conv.rows(batch) * conv.patch_len(); // 11·196·72 = 155232
        assert!(len >= PAR_MIN_ELEMS);
        let mut want = vec![0.0f32; len];
        conv.im2col(batch, &x, &mut want);
        for t in SWEEP {
            let mut got = vec![0.0f32; len];
            pim2col(Parallelism::new(t), &conv, batch, &x, &mut got);
            assert_eq!(got, want, "{t} threads");
        }
    }

    #[test]
    fn pmaxpool2_fwd_bitwise_matches_serial_with_global_argmax() {
        let (batch, h, w, c) = (11, 16, 16, 64); // 180224 elems; ragged tail at 7 threads
        let x = data(batch * h * w * c, 8);
        assert!(x.len() >= PAR_MIN_ELEMS);
        let out_len = batch * (h / 2) * (w / 2) * c;
        let mut want = vec![0.0f32; out_len];
        let mut want_am = vec![0u32; out_len];
        maxpool2_fwd(batch, h, w, c, &x, &mut want, &mut want_am);
        for t in SWEEP {
            let mut got = vec![0.0f32; out_len];
            let mut got_am = vec![0u32; out_len];
            pmaxpool2_fwd(Parallelism::new(t), batch, h, w, c, &x, &mut got, &mut got_am);
            assert_eq!(got, want, "{t} threads");
            assert_eq!(got_am, want_am, "{t} threads (argmax must be global)");
        }
    }

    #[test]
    fn tiny_problems_stay_serial_and_correct() {
        // below the spawn thresholds the wrappers are the serial kernels
        let (m, k, n) = (3, 4, 2);
        let a = data(m * k, 9);
        let b = data(k * n, 10);
        let mut want = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut want);
        let mut got = vec![0.0f32; m * n];
        pgemm(Parallelism::new(8), m, k, n, &a, &b, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn parallelism_clamps_and_defaults() {
        assert_eq!(Parallelism::new(0).threads(), 1);
        assert_eq!(Parallelism::default(), Parallelism::serial());
        assert_eq!(Parallelism::new(4).shards(2), 2);
        assert_eq!(Parallelism::new(4).shards(100), 4);
        assert_eq!(Parallelism::new(4).tier(), KernelTier::Scalar);
        assert_eq!(Parallelism::new(4).with_tier(KernelTier::Simd).tier(), KernelTier::Simd);
        assert_eq!(Parallelism::new(4).with_tier(KernelTier::Simd).threads(), 4);
    }

    #[test]
    fn simd_tier_wrappers_bitwise_match_serial_scalar() {
        // the tier axis of the determinism contract: every (threads, simd)
        // combination reproduces the serial scalar reference exactly
        let (m, k, n) = (37, 150, 96);
        let a = data(m * k, 11);
        let b = data(k * n, 12);
        let mut want = data(m * n, 13);
        let base = want.clone();
        gemm(m, k, n, &a, &b, &mut want);
        for t in SWEEP {
            let par = Parallelism::new(t).with_tier(KernelTier::Simd);
            let mut got = base.clone();
            pgemm(par, m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "pgemm simd {t} threads");
        }

        let (m2, k2, n2) = (640, 64, 13);
        let a2 = data(m2 * k2, 14);
        let b2 = data(m2 * n2, 15);
        let mut want2 = vec![0.0f32; n2 * k2];
        gemm_bt_a(m2, k2, n2, &a2, &b2, &mut want2);
        for t in SWEEP {
            let par = Parallelism::new(t).with_tier(KernelTier::Simd);
            let mut got = vec![0.0f32; n2 * k2];
            pgemm_bt_a(par, m2, k2, n2, &a2, &b2, &mut got);
            assert_eq!(got, want2, "pgemm_bt_a simd {t} threads");
        }

        let conv = Conv2d { in_h: 16, in_w: 16, cin: 8, cout: 1, kh: 3, kw: 3 };
        let batch = 11;
        let x = data(batch * conv.in_numel(), 16);
        let len = conv.rows(batch) * conv.patch_len();
        let mut wantp = vec![0.0f32; len];
        conv.im2col(batch, &x, &mut wantp);
        for t in SWEEP {
            let par = Parallelism::new(t).with_tier(KernelTier::Simd);
            let mut got = vec![0.0f32; len];
            pim2col(par, &conv, batch, &x, &mut got);
            assert_eq!(got, wantp, "pim2col simd {t} threads");
        }
    }
}
