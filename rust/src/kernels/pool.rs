//! 2×2 max-pooling over NHWC activations (stride 2), with argmax indices
//! recorded on the forward pass so backward is a pure scatter.

/// Forward 2×2/stride-2 max pool: `x[B,H,W,C] → out[B,H/2,W/2,C]`.
/// `argmax[i]` records the flat index into `x` that won output element
/// `i`, for [`maxpool2_bwd`]. `h` and `w` must be even.
pub fn maxpool2_fwd(
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
    x: &[f32],
    out: &mut [f32],
    argmax: &mut [u32],
) {
    debug_assert_eq!(h % 2, 0);
    debug_assert_eq!(w % 2, 0);
    debug_assert_eq!(x.len(), batch * h * w * c);
    let (oh, ow) = (h / 2, w / 2);
    debug_assert_eq!(out.len(), batch * oh * ow * c);
    debug_assert_eq!(argmax.len(), out.len());
    let row = w * c;
    for b in 0..batch {
        let base = b * h * w * c;
        for oy in 0..oh {
            for ox in 0..ow {
                let tl = base + (2 * oy) * row + (2 * ox) * c;
                let o = ((b * oh + oy) * ow + ox) * c;
                for ch in 0..c {
                    let cands = [tl + ch, tl + c + ch, tl + row + ch, tl + row + c + ch];
                    let mut best = cands[0];
                    for &cand in &cands[1..] {
                        if x[cand] > x[best] {
                            best = cand;
                        }
                    }
                    out[o + ch] = x[best];
                    argmax[o + ch] = best as u32;
                }
            }
        }
    }
}

/// Backward: route each output gradient to its argmax input position.
/// `dx` is fully overwritten (zeros elsewhere).
pub fn maxpool2_bwd(dout: &[f32], argmax: &[u32], dx: &mut [f32]) {
    debug_assert_eq!(dout.len(), argmax.len());
    dx.iter_mut().for_each(|v| *v = 0.0);
    for (&g, &i) in dout.iter().zip(argmax) {
        dx[i as usize] += g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_picks_max_per_channel() {
        // 1 sample, 2x2 spatial, 2 channels: one window
        let x = vec![
            1.0, 8.0, // (0,0) c0,c1
            3.0, 2.0, // (0,1)
            4.0, -1.0, // (1,0)
            2.0, 5.0, // (1,1)
        ];
        let mut out = vec![0.0f32; 2];
        let mut am = vec![0u32; 2];
        maxpool2_fwd(1, 2, 2, 2, &x, &mut out, &mut am);
        assert_eq!(out, vec![4.0, 8.0]);
        assert_eq!(am, vec![4, 1]);

        let mut dx = vec![9.0f32; x.len()];
        maxpool2_bwd(&[0.5, 0.25], &am, &mut dx);
        let mut want = vec![0.0f32; x.len()];
        want[4] = 0.5;
        want[1] = 0.25;
        assert_eq!(dx, want);
    }

    #[test]
    fn pool_shapes_multi_window() {
        let (b, h, w, c) = (2, 4, 6, 3);
        let x: Vec<f32> = (0..b * h * w * c).map(|i| (i % 17) as f32).collect();
        let mut out = vec![0.0f32; b * (h / 2) * (w / 2) * c];
        let mut am = vec![0u32; out.len()];
        maxpool2_fwd(b, h, w, c, &x, &mut out, &mut am);
        // every argmax points at a value equal to its output
        for (o, &i) in out.iter().zip(&am) {
            assert_eq!(*o, x[i as usize]);
        }
        // gradient mass is conserved
        let dout = vec![1.0f32; out.len()];
        let mut dx = vec![0.0f32; x.len()];
        maxpool2_bwd(&dout, &am, &mut dx);
        let total: f32 = dx.iter().sum();
        assert_eq!(total, out.len() as f32);
    }
}
