//! Quantized `i8×i8→i32` forward GEMM — the [`Precision::Int8`] client
//! compute path.
//!
//! FedSkel targets capability-starved edge devices; PR 5 already ships
//! int8 on the *wire* (`transport::wire` / `compress`). This module
//! reuses those exact symmetric quantizers
//! ([`int8_scale`](crate::transport::wire::int8_scale),
//! [`int8_quantize`](crate::transport::wire::int8_quantize)) on the
//! *compute* side: activations get one per-tensor scale, weights one
//! scale per output channel, and the dot products accumulate exactly in
//! `i32` (`127·127·k` fits for every layer width this crate uses —
//! overflow needs `k > 2^31/127² ≈ 133k`, far above our largest
//! `K = 1600`).
//!
//! ## Determinism
//!
//! Integer accumulation is exact, so the result is independent of
//! reduction order — [`pgemm_int8`] is bitwise identical at any thread
//! count *for free*, keeping the digest contract intact under int8 too.
//! There is **no** bitwise contract *across* precisions: int8 is an
//! approximation of the f32 forward (bounded by the quantization step),
//! which is why the server eval path always forces f32
//! (`runtime::native`).

use super::parallel::Parallelism;
use crate::transport::wire::{int8_quantize, int8_scale};

/// Quantized forward layer: `out[m×n] = bias[n] + dequant(qa · qb)`.
///
/// `a[m×k]` is quantized with one per-tensor scale; each column `j` of
/// the row-major weight matrix `b[k×n]` (an output channel) gets its own
/// scale and is packed column-major so the inner dot runs over two
/// contiguous `i8` slices. Unlike the f32 [`pgemm`](super::pgemm) this
/// *overwrites* `out` (bias included in the dequant), since mixing
/// precisions in a `+=` would be meaningless.
pub fn pgemm_int8(
    par: Parallelism,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let _span = crate::prof::scope("gemm:int8");
    // per-tensor activation scale, per-channel weight scales
    let sa = int8_scale(a);
    let qa: Vec<i8> = a.iter().map(|&v| int8_quantize(v, sa)).collect();
    let mut sw = vec![0.0f32; n];
    let mut qbt = vec![0i8; n * k]; // column-major (channel-major) weights
    let mut col = vec![0.0f32; k];
    for j in 0..n {
        for (kk, c) in col.iter_mut().enumerate() {
            *c = b[kk * n + j];
        }
        let s = int8_scale(&col);
        sw[j] = s * sa;
        for (q, &v) in qbt[j * k..(j + 1) * k].iter_mut().zip(&col) {
            *q = int8_quantize(v, s);
        }
    }

    let shards = par.threads().min(m).max(1);
    if shards <= 1 || m * k * n < super::parallel::PAR_MIN_FLOPS {
        int8_rows(k, n, &qa, &qbt, &sw, bias, out);
        return;
    }
    let rows_per = m.div_ceil(shards);
    let (qa, qbt, sw) = (&qa[..], &qbt[..], &sw[..]);
    std::thread::scope(|s| {
        for (a_chunk, o_chunk) in qa.chunks(rows_per * k).zip(out.chunks_mut(rows_per * n)) {
            s.spawn(move || int8_rows(k, n, a_chunk, qbt, sw, bias, o_chunk));
        }
    });
}

/// Row-shard body of [`pgemm_int8`]: exact `i32` dot per (row, channel),
/// then one dequant multiply-add. `sw` already folds in the activation
/// scale.
fn int8_rows(k: usize, n: usize, qa: &[i8], qbt: &[i8], sw: &[f32], bias: &[f32], out: &mut [f32]) {
    for (arow, orow) in qa.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        for ((o, brow), (&s, &bi)) in
            orow.iter_mut().zip(qbt.chunks_exact(k)).zip(sw.iter().zip(bias))
        {
            let mut acc = 0i32;
            for (&x, &w) in arow.iter().zip(brow) {
                acc += x as i32 * w as i32;
            }
            *o = acc as f32 * s + bi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::gemm;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..n).map(|_| rng.normal() * 0.5).collect()
    }

    fn f32_forward(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], bias: &[f32]) -> Vec<f32> {
        let mut z = vec![0.0f32; m * n];
        for chunk in z.chunks_exact_mut(n) {
            chunk.copy_from_slice(bias);
        }
        gemm(m, k, n, a, b, &mut z);
        z
    }

    #[test]
    fn int8_forward_is_bounded_error_vs_f32() {
        let (m, k, n) = (13, 75, 9);
        let a = data(m * k, 1);
        let b = data(k * n, 2);
        let bias = data(n, 3);
        let want = f32_forward(m, k, n, &a, &b, &bias);
        let mut got = vec![0.0f32; m * n];
        pgemm_int8(Parallelism::serial(), m, k, n, &a, &b, &bias, &mut got);
        // worst-case per-term quantization error is half a step per
        // operand; k terms give a loose but safe additive bound
        let sa = crate::transport::wire::int8_scale(&a);
        let max_b = b.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
        let max_a = a.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
        let bound = (k as f32) * 0.5 * (sa * max_b + (max_b / 127.0) * max_a + sa * max_b / 127.0);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= bound, "{g} vs {w} (bound {bound})");
        }
    }

    #[test]
    fn int8_is_thread_invariant_bitwise() {
        let (m, k, n) = (37, 150, 96); // clears PAR_MIN_FLOPS
        let a = data(m * k, 4);
        let b = data(k * n, 5);
        let bias = data(n, 6);
        let mut want = vec![0.0f32; m * n];
        pgemm_int8(Parallelism::serial(), m, k, n, &a, &b, &bias, &mut want);
        for t in [2, 7] {
            let mut got = vec![7.0f32; m * n]; // overwritten, not accumulated
            pgemm_int8(Parallelism::new(t), m, k, n, &a, &b, &bias, &mut got);
            assert_eq!(got, want, "{t} threads");
        }
    }

    #[test]
    fn all_zero_tensors_stay_zero() {
        let (m, k, n) = (3, 4, 2);
        let a = vec![0.0f32; m * k];
        let b = vec![0.0f32; k * n];
        let bias = vec![0.5f32, -0.5];
        let mut out = vec![9.0f32; m * n];
        pgemm_int8(Parallelism::serial(), m, k, n, &a, &b, &bias, &mut out);
        assert_eq!(out, vec![0.5, -0.5, 0.5, -0.5, 0.5, -0.5]);
    }
}
