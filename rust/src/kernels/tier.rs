//! Kernel-tier and client-precision selectors for the native backend.
//!
//! [`KernelTier`] picks which microkernel implementation the parallel
//! wrappers dispatch to ([`Scalar`](KernelTier::Scalar) — the PR-2
//! cache-blocked loops in [`super::gemm`]; [`Simd`](KernelTier::Simd) —
//! the packed, register-blocked, autovectorization-friendly kernels in
//! [`super::simd`]). Both tiers accumulate every output element in the
//! same ascending reduction order, so training digests are bitwise
//! identical across tiers *and* thread counts — the PR-3 determinism
//! contract extended by one axis.
//!
//! [`Precision`] picks the client *forward-pass* arithmetic:
//! [`F32`](Precision::F32), or [`Int8`](Precision::Int8) — the
//! `i8×i8→i32` quantized GEMM in [`super::int8`] that FedSkel's
//! capability-starved simulated edge devices use (companion to the int8
//! *wire* codecs of `transport::wire` / `compress`, whose quantizers it
//! reuses). Int8 is an approximation: it trades bitwise parity with f32
//! for cheap compute, so the server-side eval path always stays f32.

use anyhow::{bail, Result};

/// Which microkernel implementation the native backend runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum KernelTier {
    /// Cache-blocked scalar loops (`kernels::gemm`) — the reference tier.
    #[default]
    Scalar,
    /// Packed-panel, register-blocked kernels (`kernels::simd`) — bitwise
    /// identical to [`KernelTier::Scalar`], faster on wide layers.
    Simd,
}

impl KernelTier {
    /// Parse a `--kernel-tier` CLI/config value.
    pub fn parse(s: &str) -> Result<KernelTier> {
        match s {
            "scalar" => Ok(KernelTier::Scalar),
            "simd" => Ok(KernelTier::Simd),
            _ => bail!("unknown kernel tier '{s}' — valid tiers: scalar|simd"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Simd => "simd",
        }
    }
}

/// Client forward-pass arithmetic precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Precision {
    /// Full f32 forward — bitwise reference.
    #[default]
    F32,
    /// Quantized `i8×i8→i32` forward (`kernels::int8`) with per-channel
    /// weight scales; backward stays f32 on the traced activations.
    Int8,
}

impl Precision {
    /// Parse a `--client-precision` CLI/config value.
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "int8" => Ok(Precision::Int8),
            _ => bail!("unknown precision '{s}' — valid precisions: f32|int8"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_names_roundtrip() {
        for t in [KernelTier::Scalar, KernelTier::Simd] {
            assert_eq!(KernelTier::parse(t.name()).unwrap(), t);
        }
        for p in [Precision::F32, Precision::Int8] {
            assert_eq!(Precision::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn rejects_unknown_with_enumerated_choices() {
        let e = KernelTier::parse("avx512").unwrap_err().to_string();
        assert!(e.contains("scalar|simd"), "{e}");
        let e = Precision::parse("f16").unwrap_err().to_string();
        assert!(e.contains("f32|int8"), "{e}");
    }

    #[test]
    fn defaults_are_the_reference_pair() {
        assert_eq!(KernelTier::default(), KernelTier::Scalar);
        assert_eq!(Precision::default(), Precision::F32);
    }
}
