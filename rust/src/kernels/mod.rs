//! Native CPU compute kernels for the dependency-free training backend.
//!
//! The AOT/PJRT path (`crate::runtime::pjrt`, behind the `pjrt` feature)
//! executes Pallas-lowered HLO; this module is its default-build twin: the
//! same im2col + GEMM lowering (python/compile/kernels/) hand-written in
//! portable Rust so `benches/hotpath.rs` and the Table-1 bench measure a
//! *real* skeleton-sliced backward on every machine.
//!
//! | module | role |
//! |---|---|
//! | [`gemm`] | cache-blocked scalar f32 GEMM, skeleton gather/scatter |
//! | [`simd`] | packed register-blocked microkernels (the `simd` [`KernelTier`]) |
//! | [`int8`] | quantized `i8×i8→i32` forward GEMM ([`Precision::Int8`]) |
//! | [`conv`] | im2col conv forward + skeleton-sliced GEMM backward |
//! | [`pool`] | 2×2 max pool with argmax backward |
//! | [`parallel`] | scoped multi-threaded wrappers ([`Parallelism`] core budgets + tier dispatch) |
//! | [`tier`] | [`KernelTier`] / [`Precision`] selectors |
//!
//! Paper: Table 1 (backward FLOPs ∝ skeleton ratio) is measured on these
//! kernels; Fig. 5's per-device compute heterogeneity is realized by
//! running them under per-client [`Parallelism`] budgets.
//!
//! Design invariant, load-bearing for the parity tests: every GEMM walks
//! its reduction axis in ascending order, so an output channel's value is
//! bitwise identical whether it is computed inside a full backward or a
//! gathered skeleton backward — *and* identical at any thread count and
//! any kernel tier (see `parallel`'s determinism contract and `simd`'s
//! bitwise contract). The int8 path is the one deliberate exception:
//! exact integer accumulation keeps it thread- and tier-invariant, but it
//! approximates the f32 forward rather than reproducing it.

pub mod conv;
pub mod gemm;
pub mod int8;
pub mod parallel;
pub mod pool;
pub mod simd;
pub mod tier;

pub use conv::{sliced_backward, Conv2d};
pub use gemm::{col_sums, gather_cols, gather_cols_t, gemm, gemm_bt_a, scatter_cols_add};
pub use int8::pgemm_int8;
pub use parallel::{pcol_sums, pgemm, pgemm_bt_a, pim2col, pmaxpool2_fwd, Parallelism};
pub use pool::{maxpool2_bwd, maxpool2_fwd};
pub use tier::{KernelTier, Precision};

/// In-place ReLU.
pub fn relu(z: &mut [f32]) {
    for v in z {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: zero the gradient wherever the forward activation was
/// clamped (`act` is the *post*-ReLU activation, so the mask is `act > 0`).
pub fn relu_bwd(act: &[f32], grad: &mut [f32]) {
    debug_assert_eq!(act.len(), grad.len());
    for (g, &a) in grad.iter_mut().zip(act) {
        if a <= 0.0 {
            *g = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_and_mask() {
        let mut z = vec![-1.0, 0.0, 2.0];
        relu(&mut z);
        assert_eq!(z, vec![0.0, 0.0, 2.0]);
        let mut g = vec![5.0, 5.0, 5.0];
        relu_bwd(&z, &mut g);
        assert_eq!(g, vec![0.0, 0.0, 5.0]);
    }
}
