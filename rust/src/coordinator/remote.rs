//! Server-side roster of remote `fedskel client` worker processes.
//!
//! [`RemoteFleet`] is the multi-process analogue of the in-process
//! [`WorkerPool`](crate::transport::pool::WorkerPool): the coordinator
//! hands it a round's [`TrainJob`]s and gets back [`TrainOutcome`]s in
//! submission order — but the jobs execute in *other processes*, reached
//! over a listen-mode [`TcpTransport`] speaking the
//! [`proto`](crate::transport::proto) control plane.
//!
//! All federation state stays on the server (sampling, skeletons,
//! aggregation, the virtual clock, checkpoints). Remote workers are
//! stateless: each job carries everything local training needs, each
//! outcome everything the server aggregates. Because the proto codec
//! round-trips jobs and outcomes bitwise and
//! [`run_local_steps`](crate::transport::pool::run_local_steps) is the
//! same function the in-process pool runs, a multi-process run's param
//! digest is bitwise equal to the in-process run's — the acceptance
//! criterion `tests/e2e_multiprocess.rs` locks in.
//!
//! ## Fault model
//!
//! * **Worker joins** (any time, including mid-round): a proto `Hello`
//!   is validated against the server's wire version and determinism key,
//!   answered with `Welcome {slot}` (or `Reject`), and the worker starts
//!   pulling jobs immediately.
//! * **Worker dies**: the TCP reader observes the disconnect, the
//!   in-flight job is requeued to the next idle worker, and the slot's
//!   departure surfaces as a [`RunEvent::ClientLeave`].
//! * **Duplicate outcomes** (a worker completed, its connection died
//!   before the server's ack-by-next-job, and the job was re-run
//!   elsewhere): outcomes dedup by their globally unique `seq` —
//!   first-wins, so a job can never aggregate twice. Re-running is safe
//!   because jobs are pure: identical job, identical outcome, bitwise.
//!
//! The round loop therefore makes progress as long as *some* worker is
//! alive, and stalls (then errors, after
//! [`RemoteFleet::with_stall_timeout`]) rather than hangs when none is.

use std::collections::{BTreeMap, VecDeque};
use std::net::SocketAddr;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::model::ModelSpec;
use crate::trace::RunEvent;
use crate::transport::pool::{TrainJob, TrainOutcome};
use crate::transport::proto::{self, CtrlMsg};
use crate::transport::tcp::{LinkEvent, TcpTransport};
use crate::transport::wire;
use crate::transport::{Envelope, Peer, Transport};

/// The server end of the split-process deployment: a TCP listener, the
/// roster of welcomed workers, and the dispatch/collect loop.
pub struct RemoteFleet {
    transport: TcpTransport,
    spec: ModelSpec,
    model: String,
    key: String,
    /// Welcomed workers: connection peer → slot. A reconnecting worker
    /// re-handshakes and gets a fresh slot.
    roster: BTreeMap<Peer, u32>,
    /// Slot → the worker name its `Hello` announced.
    names: BTreeMap<u32, String>,
    next_slot: u32,
    /// Globally unique job sequence — the outcome-dedup key.
    next_seq: u64,
    stall_timeout: Duration,
    /// Join/leave transitions since the last [`RemoteFleet::take_events`]
    /// drain: `(joined, slot)`.
    events: Vec<(bool, u32)>,
}

impl RemoteFleet {
    /// Bind `listen` (port 0 lets the OS pick) and start accepting
    /// worker connections. `model` and `determinism_key` are what
    /// `Welcome` hands each worker.
    pub fn new(
        listen: &str,
        spec: ModelSpec,
        model: &str,
        determinism_key: &str,
    ) -> Result<RemoteFleet> {
        Ok(RemoteFleet {
            transport: TcpTransport::listen(listen)?,
            spec,
            model: model.to_string(),
            key: determinism_key.to_string(),
            roster: BTreeMap::new(),
            names: BTreeMap::new(),
            next_slot: 0,
            next_seq: 0,
            stall_timeout: Duration::from_secs(120),
            events: Vec::new(),
        })
    }

    /// Error (instead of waiting forever) when a round makes no progress
    /// — no outcome, no join — for this long. Default 120 s.
    pub fn with_stall_timeout(mut self, d: Duration) -> RemoteFleet {
        self.stall_timeout = d;
        self
    }

    /// The bound listen address.
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.transport.local_addr()
    }

    /// Workers currently welcomed.
    pub fn workers(&self) -> usize {
        self.roster.len()
    }

    /// `(slot, worker name)` of every welcomed worker, in slot order.
    pub fn roster(&self) -> Vec<(u32, String)> {
        let mut v: Vec<(u32, String)> = self
            .roster
            .values()
            .map(|&s| (s, self.names.get(&s).cloned().unwrap_or_default()))
            .collect();
        v.sort();
        v
    }

    /// Block until at least `min` workers have been welcomed (handling
    /// handshakes as they arrive) or `timeout` elapses.
    pub fn wait_for_workers(&mut self, min: usize, timeout: Duration) -> Result<usize> {
        let deadline = Instant::now() + timeout;
        while self.roster.len() < min {
            self.drain_leaves();
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                bail!("only {}/{min} workers joined within {timeout:?}", self.roster.len());
            }
            let Some(env) = self
                .transport
                .recv_wait(Peer::Server, left.min(Duration::from_millis(200)))?
            else {
                continue;
            };
            if let Ok(CtrlMsg::Hello { wire_version, determinism_key, worker }) =
                proto::decode(&env.frame, Some(&self.spec))
            {
                self.handle_hello(env.from, wire_version, &determinism_key, &worker)?;
            }
        }
        Ok(self.roster.len())
    }

    /// Execute one round's jobs on the fleet and return their outcomes
    /// in submission order — the same contract as
    /// [`WorkerPool::run`](crate::transport::pool::WorkerPool::run).
    pub fn run(&mut self, jobs: Vec<TrainJob>) -> Result<Vec<TrainOutcome>> {
        let n = jobs.len();
        let mut frames = Vec::with_capacity(n);
        let mut seq_idx: BTreeMap<u64, usize> = BTreeMap::new();
        for (i, job) in jobs.into_iter().enumerate() {
            let seq = self.next_seq;
            self.next_seq += 1;
            seq_idx.insert(seq, i);
            // encode once; requeues resend the identical bytes
            frames.push(proto::encode(&CtrlMsg::Job { seq, job }));
        }
        let mut queue: VecDeque<usize> = (0..n).collect();
        let mut inflight: BTreeMap<Peer, usize> = BTreeMap::new();
        let mut done: Vec<Option<TrainOutcome>> = (0..n).map(|_| None).collect();
        let mut done_count = 0usize;
        let mut last_progress = Instant::now();

        while done_count < n {
            // a dead worker's in-flight job goes back to the front of
            // the queue (unless its outcome already landed)
            for ev in self.transport.drain_link_events() {
                if let LinkEvent::Left(p) = ev {
                    if let Some(idx) = inflight.remove(&p) {
                        if done[idx].is_none() {
                            queue.push_front(idx);
                        }
                    }
                    if let Some(slot) = self.roster.remove(&p) {
                        self.events.push((false, slot));
                    }
                }
            }
            let idle: Vec<Peer> = self
                .roster
                .keys()
                .filter(|p| !inflight.contains_key(p))
                .copied()
                .collect();
            for p in idle {
                Self::dispatch(&mut self.transport, &mut queue, &frames, &mut inflight, p);
            }

            let Some(env) = self
                .transport
                .recv_wait(Peer::Server, Duration::from_millis(100))?
            else {
                if last_progress.elapsed() > self.stall_timeout {
                    bail!(
                        "remote fleet stalled: {done_count}/{n} outcomes, {} workers \
                         connected, no progress for {:?}",
                        self.roster.len(),
                        self.stall_timeout
                    );
                }
                continue;
            };
            // a corrupt control frame is that connection's problem, not
            // the run's
            let Ok(msg) = proto::decode(&env.frame, Some(&self.spec)) else { continue };
            match msg {
                CtrlMsg::Hello { wire_version, determinism_key, worker } => {
                    // mid-round join: welcome and put it to work
                    if self.handle_hello(env.from, wire_version, &determinism_key, &worker)? {
                        last_progress = Instant::now();
                        Self::dispatch(
                            &mut self.transport,
                            &mut queue,
                            &frames,
                            &mut inflight,
                            env.from,
                        );
                    }
                }
                CtrlMsg::Outcome { seq, outcome } => {
                    // dedup by seq, first-wins: an unknown seq is a
                    // duplicate from a re-run job (or a stale worker) and
                    // must not aggregate
                    if let Some(&idx) = seq_idx.get(&seq) {
                        if done[idx].is_none() {
                            done[idx] = Some(outcome);
                            done_count += 1;
                            last_progress = Instant::now();
                        }
                    }
                    if let Some(idx) = inflight.remove(&env.from) {
                        if done[idx].is_none() {
                            // it answered something else — its assigned
                            // job is still owed
                            queue.push_front(idx);
                        }
                    }
                    Self::dispatch(
                        &mut self.transport,
                        &mut queue,
                        &frames,
                        &mut inflight,
                        env.from,
                    );
                }
                // workers never legitimately send these
                CtrlMsg::Welcome { .. }
                | CtrlMsg::Reject { .. }
                | CtrlMsg::Job { .. }
                | CtrlMsg::Shutdown { .. } => {}
            }
        }
        Ok(done.into_iter().map(|o| o.expect("all outcomes collected")).collect())
    }

    /// Join/leave transitions since the last drain, stamped with `round`
    /// — the coordinator emits these into the run's event stream.
    pub fn take_events(&mut self, round: usize) -> Vec<RunEvent> {
        std::mem::take(&mut self.events)
            .into_iter()
            .map(|(joined, slot)| {
                if joined {
                    RunEvent::ClientJoin { round, client: slot as usize }
                } else {
                    RunEvent::ClientLeave { round, client: slot as usize }
                }
            })
            .collect()
    }

    /// Tell every connected worker the run is over.
    pub fn shutdown(&mut self, reason: &str) {
        let frame = proto::encode(&CtrlMsg::Shutdown { reason: reason.to_string() });
        let peers: Vec<Peer> = self.roster.keys().copied().collect();
        for p in peers {
            let _ = self.transport.send(Envelope {
                from: Peer::Server,
                to: p,
                frame: frame.clone(),
            });
        }
    }

    /// Validate a `Hello`, answer `Welcome` or `Reject`, update the
    /// roster. Returns whether the worker was welcomed.
    fn handle_hello(
        &mut self,
        from: Peer,
        wire_version: u16,
        key: &str,
        worker: &str,
    ) -> Result<bool> {
        let reject = if wire_version != wire::VERSION {
            Some(format!(
                "wire version {wire_version} does not match server version {}",
                wire::VERSION
            ))
        } else if !key.is_empty() && key != self.key {
            Some("determinism key mismatch: this worker belongs to a different run".to_string())
        } else {
            None
        };
        if let Some(reason) = reject {
            let frame = proto::encode(&CtrlMsg::Reject { reason });
            let _ = self.transport.send(Envelope { from: Peer::Server, to: from, frame });
            return Ok(false);
        }
        let slot = match self.roster.get(&from) {
            Some(&s) => s,
            None => {
                let s = self.next_slot;
                self.next_slot += 1;
                self.roster.insert(from, s);
                self.names.insert(s, worker.to_string());
                self.events.push((true, s));
                s
            }
        };
        let frame = proto::encode(&CtrlMsg::Welcome {
            slot,
            model: self.model.clone(),
            determinism_key: self.key.clone(),
        });
        if self.transport.send(Envelope { from: Peer::Server, to: from, frame }).is_err() {
            // died between hello and welcome; the Left event cleans up
            return Ok(false);
        }
        Ok(true)
    }

    /// Record leaves observed outside `run` (e.g. between rounds).
    fn drain_leaves(&mut self) {
        for ev in self.transport.drain_link_events() {
            if let LinkEvent::Left(p) = ev {
                if let Some(slot) = self.roster.remove(&p) {
                    self.events.push((false, slot));
                }
            }
        }
    }

    /// Hand the front queued job to `p` (no-op if `p` is busy or the
    /// queue is empty). A send failure requeues — the Left event that
    /// follows will drop `p` from the roster.
    fn dispatch(
        transport: &mut TcpTransport,
        queue: &mut VecDeque<usize>,
        frames: &[Vec<u8>],
        inflight: &mut BTreeMap<Peer, usize>,
        p: Peer,
    ) {
        if inflight.contains_key(&p) {
            return;
        }
        let Some(idx) = queue.pop_front() else { return };
        let env = Envelope { from: Peer::Server, to: p, frame: frames[idx].clone() };
        match transport.send(env) {
            Ok(_) => {
                inflight.insert(p, idx);
            }
            Err(_) => queue.push_front(idx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread::JoinHandle;

    use crate::config::{Method, RunConfig};
    use crate::coordinator::Coordinator;
    use crate::kernels::{Parallelism, Precision};
    use crate::model::init_params;
    use crate::runtime::mock::{toy_spec, MockBackend};
    use crate::snapshot;
    use crate::transport::pool::run_local_steps;
    use crate::transport::TransportKind;

    const T: Duration = Duration::from_secs(20);

    /// A faithful worker process in a thread: hello, then serve jobs
    /// with `run_local_steps` on its own MockBackend until Shutdown.
    fn worker(addr: String, raw_id: usize) -> JoinHandle<()> {
        std::thread::spawn(move || {
            let me = Peer::Client(raw_id);
            let mut t = TcpTransport::connect(&addr, me).unwrap();
            let hello = proto::encode(&CtrlMsg::Hello {
                wire_version: wire::VERSION,
                determinism_key: String::new(),
                worker: format!("w{raw_id}"),
            });
            t.send(Envelope { from: me, to: Peer::Server, frame: hello }).unwrap();
            let spec = toy_spec();
            let mut backend = MockBackend::toy();
            loop {
                let Some(env) = t.recv_wait(me, T).unwrap() else { break };
                match proto::decode(&env.frame, Some(&spec)).unwrap() {
                    CtrlMsg::Welcome { .. } => {}
                    CtrlMsg::Job { seq, job } => {
                        let outcome = run_local_steps(&mut backend, job).unwrap();
                        let frame = proto::encode(&CtrlMsg::Outcome { seq, outcome });
                        t.send(Envelope { from: me, to: Peer::Server, frame }).unwrap();
                    }
                    CtrlMsg::Shutdown { .. } => break,
                    CtrlMsg::Reject { reason } => panic!("rejected: {reason}"),
                    other => panic!("unexpected {:?}", other.name()),
                }
            }
        })
    }

    /// A worker that dies holding its first job (no outcome, no goodbye).
    fn dying_worker(addr: String, raw_id: usize) -> JoinHandle<()> {
        std::thread::spawn(move || {
            let me = Peer::Client(raw_id);
            let mut t = TcpTransport::connect(&addr, me).unwrap();
            let hello = proto::encode(&CtrlMsg::Hello {
                wire_version: wire::VERSION,
                determinism_key: String::new(),
                worker: format!("w{raw_id}"),
            });
            t.send(Envelope { from: me, to: Peer::Server, frame: hello }).unwrap();
            let spec = toy_spec();
            loop {
                let Some(env) = t.recv_wait(me, T).unwrap() else { break };
                match proto::decode(&env.frame, Some(&spec)).unwrap() {
                    CtrlMsg::Welcome { .. } => {}
                    _ => break, // first job (or shutdown): vanish
                }
            }
        })
    }

    fn job(i: usize) -> TrainJob {
        let spec = toy_spec();
        let params = init_params(&spec, i as u64);
        let numel: usize = spec.input_shape.iter().product();
        TrainJob {
            client: i,
            bucket: 100,
            skeleton: vec![vec![0, 1, 2, 3], vec![0, 1, 2, 3]],
            local: params.clone(),
            global: Arc::new(params),
            batches: vec![(vec![0.5f32; spec.train_batch * numel], vec![0i32; spec.train_batch])],
            lr: 0.05,
            mu: 0.0,
            want_importance: false,
            par: Parallelism::serial(),
            precision: Precision::F32,
        }
    }

    fn cfg(method: Method) -> RunConfig {
        RunConfig {
            method,
            model: "toy".into(),
            num_clients: 4,
            shards_per_client: 2,
            dataset_size: 400,
            new_test_size: 64,
            rounds: 4,
            local_steps: 2,
            updateskel_per_setskel: 3,
            eval_every: 0,
            transport: TransportKind::Loopback,
            ..RunConfig::default()
        }
    }

    #[test]
    fn remote_fleet_matches_the_inline_run_bitwise() {
        let run_cfg = cfg(Method::FedSkel);
        let mut inline = Coordinator::new(run_cfg.clone(), MockBackend::toy()).unwrap();
        inline.run().unwrap();

        let key = snapshot::determinism_key(&run_cfg);
        let fleet = RemoteFleet::new("127.0.0.1:0", toy_spec(), "toy", &key).unwrap();
        let addr = fleet.local_addr().unwrap().to_string();
        let h1 = worker(addr.clone(), 101);
        let h2 = worker(addr, 202);
        let mut c = Coordinator::with_remote(run_cfg, MockBackend::toy(), fleet).unwrap();
        c.remote.as_mut().unwrap().wait_for_workers(2, T).unwrap();
        c.run().unwrap();

        assert_eq!(inline.global, c.global, "remote execution must be bitwise transparent");
        assert_eq!(inline.ledger.total_wire_bytes(), c.ledger.total_wire_bytes());
        let fleet = c.remote.as_mut().unwrap();
        assert_eq!(fleet.workers(), 2);
        let names: Vec<String> = fleet.roster().into_iter().map(|(_, n)| n).collect();
        assert_eq!(names, vec!["w101", "w202"]);
        fleet.shutdown("done");
        h1.join().unwrap();
        h2.join().unwrap();
    }

    #[test]
    fn dead_workers_jobs_requeue_to_the_living() {
        let mut fleet = RemoteFleet::new("127.0.0.1:0", toy_spec(), "toy", "k").unwrap();
        let addr = fleet.local_addr().unwrap().to_string();
        let hbad = dying_worker(addr.clone(), 7);
        let hgood = worker(addr, 8);
        fleet.wait_for_workers(2, T).unwrap();

        let jobs: Vec<TrainJob> = (0..4).map(job).collect();
        let outs = fleet.run(jobs).unwrap();
        assert_eq!(outs.len(), 4, "every job completes despite the death");
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.client, i, "submission order preserved");
        }
        // outcomes are bitwise what inline execution produces
        let mut b = MockBackend::toy();
        let want = run_local_steps(&mut b, job(0)).unwrap();
        assert_eq!(outs[0].params, want.params);
        assert_eq!(outs[0].mean_loss.to_bits(), want.mean_loss.to_bits());

        hbad.join().unwrap();
        let evs = fleet.take_events(3);
        assert!(
            evs.iter().any(|e| matches!(e, RunEvent::ClientJoin { round: 3, .. })),
            "joins recorded: {evs:?}"
        );
        assert!(
            evs.iter().any(|e| matches!(e, RunEvent::ClientLeave { round: 3, .. })),
            "the death surfaced as a leave: {evs:?}"
        );
        fleet.shutdown("done");
        hgood.join().unwrap();
    }

    #[test]
    fn hello_rejects_wrong_wire_version_and_key() {
        let mut fleet = RemoteFleet::new("127.0.0.1:0", toy_spec(), "toy", "the-run-key").unwrap();
        let addr = fleet.local_addr().unwrap().to_string();
        let me = Peer::Client(50);
        let mut t = TcpTransport::connect(&addr, me).unwrap();

        // wrong wire version → Reject naming versions
        let bad = proto::encode(&CtrlMsg::Hello {
            wire_version: wire::VERSION + 1,
            determinism_key: String::new(),
            worker: "w".into(),
        });
        t.send(Envelope { from: me, to: Peer::Server, frame: bad }).unwrap();
        // wrong determinism key (a worker from another run) → Reject
        let stale = proto::encode(&CtrlMsg::Hello {
            wire_version: wire::VERSION,
            determinism_key: "some-other-run".into(),
            worker: "w".into(),
        });
        t.send(Envelope { from: me, to: Peer::Server, frame: stale }).unwrap();
        // a correct hello still gets in on the same connection
        let good = proto::encode(&CtrlMsg::Hello {
            wire_version: wire::VERSION,
            determinism_key: "the-run-key".into(),
            worker: "w".into(),
        });
        t.send(Envelope { from: me, to: Peer::Server, frame: good }).unwrap();

        fleet.wait_for_workers(1, T).unwrap();
        assert_eq!(fleet.workers(), 1);
        let mut seen = Vec::new();
        let deadline = Instant::now() + T;
        while seen.len() < 3 {
            assert!(Instant::now() < deadline, "answers never arrived: {seen:?}");
            if let Some(env) = t.recv_wait(me, Duration::from_millis(200)).unwrap() {
                seen.push(proto::decode(&env.frame, None).unwrap());
            }
        }
        assert!(
            matches!(&seen[0], CtrlMsg::Reject { reason } if reason.contains("wire version")),
            "{:?}",
            seen[0].name()
        );
        assert!(
            matches!(&seen[1], CtrlMsg::Reject { reason } if reason.contains("determinism key")),
            "{:?}",
            seen[1].name()
        );
        assert!(matches!(&seen[2], CtrlMsg::Welcome { slot: 0, .. }), "{:?}", seen[2].name());
    }
}
