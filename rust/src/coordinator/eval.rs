//! Evaluation protocols (LG-FedAvg's, which the paper follows — Table 3
//! footnote):
//!
//! * **New Test** — "new predictions on new devices": the *global* model on
//!   IID held-out data drawn from the whole-dataset distribution.
//! * **Local Test** — "new predictions on an existing device": each
//!   client's *personalized* model on held-out data from that client's own
//!   (non-IID) distribution; we report the sample-weighted mean.
//!
//! Which parameters count as "the global model" / "personalized" differs
//! per method — see the match in [`Coordinator::new_test_params`].

use anyhow::Result;

use crate::aggregate::{fedavg, Update};
use crate::config::Method;
use crate::coordinator::Coordinator;
use crate::data::synthetic::Dataset;
use crate::metrics::{accuracy, Mean};
use crate::model::Params;
use crate::runtime::step::Backend;

impl<B: Backend> Coordinator<B> {
    /// Parameters the New Test evaluates for the configured method.
    pub fn new_test_params(&self) -> Result<Params> {
        match self.cfg.method {
            // FedAvg / FedSkel / FedMTL: the server model (for FedMTL this
            // is the anchor — the paper's characteristic near-random New
            // Test numbers for FedMTL come from exactly this).
            Method::FedAvg | Method::FedSkel | Method::FedMtl => Ok(self.global.clone()),
            // LG-FedAvg: average of client representations + global head
            // (the paper's new-device protocol averages local models).
            Method::LgFedAvg => {
                let updates: Vec<Update> = self
                    .clients
                    .iter()
                    .map(|c| Update {
                        client: c.id,
                        weight: c.weight(),
                        params: c.local_params.clone(),
                        skeleton: vec![],
                    })
                    .collect();
                let mut avg = fedavg(&self.global, &updates)?;
                let prefixes: Vec<&str> =
                    self.cfg.lg_global_prefixes.iter().map(|s| s.as_str()).collect();
                for &pi in
                    &crate::coordinator::lg_global_ids_of(&self.backend.spec().params, &prefixes)
                {
                    avg[pi] = self.global[pi].clone();
                }
                Ok(avg)
            }
        }
    }

    /// New Test accuracy (global model, IID held-out set).
    pub fn evaluate_new(&mut self) -> Result<f64> {
        let params = self.new_test_params()?;
        let new_test = self.new_test.clone();
        let ids: Vec<usize> = (0..new_test.len()).collect();
        self.eval_on(&params, &new_test, &ids)
    }

    /// Local Test accuracy: personalized params on each client's own test
    /// shard, sample-weighted mean across clients.
    pub fn evaluate_local(&mut self) -> Result<f64> {
        let mut mean = Mean::default();
        let data = self.data.clone();
        for ci in 0..self.clients.len() {
            let ids = self.clients[ci].split.test.clone();
            if ids.is_empty() {
                continue;
            }
            let params = self.clients[ci].local_params.clone();
            let acc = self.eval_on(&params, &data, &ids)?;
            mean.weighted_add(acc, ids.len() as f64);
        }
        Ok(mean.get())
    }

    /// Accuracy of `params` on `ids` into `data`, batched at the eval
    /// artifact's static batch size (tail padded, padding excluded).
    pub fn eval_on(&mut self, params: &Params, data: &Dataset, ids: &[usize]) -> Result<f64> {
        let spec = self.backend.spec().clone();
        let b = spec.eval_batch;
        let numel: usize = spec.input_shape.iter().product();
        let mut x = vec![0.0f32; b * numel];
        let mut labels = vec![0i32; b];
        let mut correct_mean = Mean::default();

        for chunk in ids.chunks(b) {
            x.iter_mut().for_each(|v| *v = 0.0);
            for (bi, &i) in chunk.iter().enumerate() {
                data.copy_image(i, &mut x[bi * numel..(bi + 1) * numel]);
                labels[bi] = data.labels[i] as i32;
            }
            let logits = self.backend.eval_logits(params, &x)?;
            let acc = accuracy(&logits, &labels, chunk.len())?;
            correct_mean.weighted_add(acc, chunk.len() as f64);
        }
        Ok(correct_mean.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::runtime::mock::MockBackend;

    fn coord(method: Method) -> Coordinator<MockBackend> {
        let cfg = RunConfig {
            method,
            model: "toy".into(),
            num_clients: 4,
            shards_per_client: 2,
            dataset_size: 400,
            new_test_size: 64,
            rounds: 4,
            local_steps: 1,
            eval_every: 0,
            ..RunConfig::default()
        };
        Coordinator::new(cfg, MockBackend::toy()).unwrap()
    }

    #[test]
    fn eval_runs_and_is_in_range() {
        let mut c = coord(Method::FedSkel);
        let acc = c.evaluate_new().unwrap();
        assert!((0.0..=1.0).contains(&acc));
        let acc = c.evaluate_local().unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn eval_handles_non_multiple_batches() {
        let mut c = coord(Method::FedAvg);
        let params = c.global.clone();
        let data = c.data.clone();
        // 7 samples with eval_batch 4 → one full + one padded batch
        let ids: Vec<usize> = (0..7).collect();
        let acc = c.eval_on(&params, &data, &ids).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert_eq!(c.backend.eval_calls, 2);
    }

    #[test]
    fn lg_new_test_uses_averaged_reps() {
        let c = coord(Method::LgFedAvg);
        let p = c.new_test_params().unwrap();
        // head comes from global
        assert_eq!(p[2], c.global[2]);
        assert_eq!(p.len(), c.global.len());
    }
}
