//! The federated training loop — FedSkel's SetSkel/UpdateSkel state
//! machine plus the three baselines, over any [`Backend`].
//!
//! One [`Coordinator`] owns the server state (global params), the client
//! fleet, the data, and the ledgers. `run()` drives `cfg.rounds` rounds:
//!
//! * **FedSkel** (§3.2): rounds alternate — one *SetSkel* round (full
//!   exchange; clients accumulate the importance metric; afterwards each
//!   client re-selects its skeleton at its assigned ratio) followed by
//!   `updateskel_per_setskel` *UpdateSkel* rounds (skeleton-only train +
//!   exchange, partial aggregation).
//! * **FedAvg**: every round is a full round.
//! * **LG-FedAvg**: clients keep representation layers local; only the
//!   head tensors are exchanged/averaged.
//! * **FedMTL**: clients train personalized models with a proximal pull
//!   toward the server anchor (mu > 0); the anchor is FedAvg-maintained;
//!   clients never overwrite their local models from the server.
//!
//! Every round payload — full params, sparse skeleton channels, or a
//! param subset — is *encoded to wire frames* and moved through the
//! configured [`Transport`] ([`crate::transport`]): server encodes the
//! download, the client decodes and applies it, trains, encodes its
//! upload, and the server decodes it back before aggregating. The
//! [`CommLedger`] therefore records both logical parameter counts
//! (Table 2's unit) and the exact bytes the encoder put on the wire —
//! split into raw (dense-f32) and achieved bytes so compression is
//! measured, not assumed. Uploads optionally travel through the
//! [`crate::compress`] pipeline (`--compress f16|int8|topk`): the
//! client ships its *update delta* vs the round anchor with per-client
//! error-feedback residuals, and the server adds the decoded delta back
//! onto the same anchor. Full downloads optionally delta-encode against
//! each client's recorded anchor (`--delta-down`, lossless).
//! Local training runs either inline on the coordinator's backend or
//! concurrently on a [`WorkerPool`] (see [`Coordinator::with_pool`]);
//! either way each client's job carries its device profile's core budget
//! ([`TrainJob::par`]), so compute heterogeneity is *executed* by the
//! parallel kernels, not just charged as simulated seconds.
//!
//! Rounds are *scheduled*, not just looped: every client's completion is
//! an event on a virtual clock ([`crate::sched`]) at its simulated
//! round time (compute + link seconds), and the configured
//! [`crate::sched::RoundPolicy`] decides when the round ends and which
//! arrivals aggregate — the sync barrier (bitwise the classic loop), a
//! deadline that drops stragglers (their frames become
//! [`CommLedger::wasted_wire_bytes`]), or FedBuff-style async buffering
//! where stragglers' updates stay in flight and land in later rounds
//! with staleness-discounted weights. Accepted updates always aggregate
//! in `(origin round, submission order)` — never arrival order — so
//! results are independent of everything but the policy itself.
//!
//! The run is *event-sourced* ([`crate::trace`]): every mutation of the
//! [`RunLog`], the [`CommLedger`], and the metrics registry goes through
//! [`RunEvent`]s and the shared fold, and the same events fan out to any
//! attached trace sinks (`--trace`), so a recorded trace replays into
//! exactly the tables this module produced live.

pub mod eval;
pub mod remote;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::aggregate::{self, Update};
use crate::clients::ClientState;
use crate::comm::{params_moved, CommLedger, ExchangeKind};
use crate::compress::{compress_update, Compressor};
use crate::config::{Method, RatioAssignment, RunConfig};
use crate::data::shard::{non_iid_shards, Batcher};
use crate::data::synthetic::Dataset;
use crate::hetero::{
    assign_precision, equidistant_fleet_with_cores, simulate_round_wire, DeviceProfile,
};
use crate::kernels::Parallelism;
use crate::metrics::{Mean, RunLog};
use crate::model::{init_params, ModelSpec, Params};
use crate::prof;
use crate::runtime::step::Backend;
use crate::sched::{staleness_weight, RoundScheduler};
use crate::skeleton::{identity_skeleton, select_skeleton, ImportanceAccumulator, RatioPolicy};
use crate::snapshot::{self, ClientSnap, DeviceSnap, PendingSnap, Snapshot, SnapshotError};
use crate::tensor::Tensor;
use crate::trace::{self, registry::Registry, RunEvent, Trace, TraceSink};
use crate::transport::fault::FaultInjector;
use crate::transport::pool::{run_local_steps, TrainJob, WorkerPool};
use crate::transport::wire::{self, FrameOpts, Quant, RoundMsg, WirePayload};
use crate::transport::{Envelope, Peer, Receipt, Transport};
use crate::util::timer::Timer;
use crate::util::Rng;

/// Phase of a round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Full exchange + importance accumulation (FedSkel only).
    SetSkel,
    /// Skeleton-only train/exchange (FedSkel only).
    UpdateSkel,
    /// Baseline full round.
    Full,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::SetSkel => "setskel",
            Phase::UpdateSkel => "updateskel",
            Phase::Full => "full",
        }
    }
}

/// The federated server + simulated fleet.
pub struct Coordinator<B: Backend> {
    pub cfg: RunConfig,
    pub backend: B,
    pub global: Params,
    pub clients: Vec<ClientState>,
    pub data: Dataset,
    pub new_test: Dataset,
    pub ledger: CommLedger,
    pub fleet: Vec<DeviceProfile>,
    pub log: RunLog,
    /// Moves every round payload as encoded wire frames.
    pub transport: Box<dyn Transport>,
    /// Virtual clock + round policy deciding when rounds end and which
    /// arrivals aggregate ([`crate::sched`]).
    pub sched: RoundScheduler,
    /// Counters/gauges/histograms folded from the same event stream as
    /// `log` and `ledger` ([`crate::trace::registry`]).
    pub registry: Registry,
    /// Attached trace sinks; every run event fans out here after the
    /// fold ([`crate::trace`]). Empty by default (zero cost).
    trace: Trace,
    rng: Rng,
    /// param ids LG-FedAvg treats as global.
    lg_global_ids: Vec<usize>,
    /// Parallel client workers; `None` trains inline on `backend`.
    pool: Option<WorkerPool<B>>,
    /// Remote worker processes ([`remote::RemoteFleet`]); `None` trains
    /// inline or on the pool. Like the pool, remote execution changes
    /// scheduling only, never results — jobs and outcomes round-trip the
    /// proto codec bitwise.
    remote: Option<remote::RemoteFleet>,
    /// Upload update compressor ([`crate::compress`]); `None` = identity
    /// compression = the plain pre-compression wire path, byte for byte.
    compressor: Option<Box<dyn Compressor>>,
    /// Per-client download anchor for `--delta-down`: the last full
    /// model copy both ends know the client holds. Updated from the
    /// *decoded* form of every Full-kind download, so server and client
    /// agree bitwise even under lossy `--quant`.
    down_anchor: Vec<Option<Params>>,
    /// Decoded updates awaiting aggregation, keyed by
    /// `(origin round, submission seq)` — the same key their completion
    /// events carry on the scheduler's clock. Under the sync barrier the
    /// buffer drains every round; under async buffering entries survive
    /// until their arrival event is accepted.
    pending: BTreeMap<(usize, usize), Update>,
    /// The decoded delta payload of each in-flight compressed upload
    /// (same keys as `pending`; populated only under `--error-feedback`,
    /// by *moving* the already-decoded payload — no extra work on the
    /// common no-drop path). A deadline drop refolds its exact values
    /// into the client's residual — recomputing them as
    /// `(global + delta) − global` in f32 would cancel sub-ulp values,
    /// quietly violating "deferred, never lost".
    pending_deltas: BTreeMap<(usize, usize), WirePayload>,
    round_idx: usize,
}

impl<B: Backend> Coordinator<B> {
    /// Build the full system: synthesize data, shard it non-IID, create
    /// clients with capabilities + ratios + buckets, init global params.
    pub fn new(cfg: RunConfig, backend: B) -> Result<Coordinator<B>> {
        cfg.validate()?;
        if cfg.workers > 0 {
            // Refuse rather than silently train inline: a worker pool
            // needs one backend per thread (B: Send), which this
            // constructor cannot conjure. The PJRT backend is not Send;
            // pool-capable callers construct via `with_pool` (see
            // examples/transport_demo.rs).
            bail!(
                "config asks for {} workers, but Coordinator::new always trains inline — \
                 build the pool explicitly with Coordinator::with_pool",
                cfg.workers
            );
        }
        let spec = backend.spec().clone();
        let mut rng = Rng::new(cfg.seed);

        // ---- data
        let total = cfg.dataset_size + cfg.new_test_size;
        let full = Dataset::generate(cfg.dataset, total, cfg.seed ^ 0xD5);
        let data = full.subset(0, cfg.dataset_size);
        let new_test = full.subset(cfg.dataset_size, total);
        let splits = non_iid_shards(&data, cfg.num_clients, cfg.shards_per_client, 0.2, cfg.seed)?;

        // ---- capabilities & fleet (equidistant like the paper's Fig. 5,
        // spread by cfg.fleet_skew: the slowest device runs at
        // 1/fleet_skew of the fastest); core budgets scale with
        // capability up to cfg.threads, so with --threads 8 the fastest
        // client trains on 8 threads while the slowest stays a 1-core
        // straggler. At --threads > 1 capability acts as the *per-core*
        // speed class (hetero module docs): total device speed =
        // capability × measured thread scaling.
        let mut fleet = equidistant_fleet_with_cores(
            cfg.num_clients,
            1.0 / cfg.fleet_skew.max(1.0),
            1.0,
            100.0,
            cfg.threads.max(1),
        );
        // under --client-precision int8 the capability-starved half of
        // the fleet trains its forward pass quantized (hetero policy)
        assign_precision(&mut fleet, cfg.client_precision);
        let capabilities: Vec<f64> = fleet.iter().map(|d| d.capability).collect();

        // ---- ratios
        let policy = match cfg.ratio_assignment {
            RatioAssignment::Linear => RatioPolicy::LinearCapability { min_ratio: 0.1 },
            RatioAssignment::Equidistant { lo, hi } => RatioPolicy::Equidistant { lo, hi },
            RatioAssignment::Fixed(r) => RatioPolicy::Fixed(r),
        };
        let ratios = policy.assign(&capabilities)?;

        // ---- clients
        let global = init_params(&spec, cfg.seed ^ 0x91);
        let prunable_channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
        let mut clients = Vec::with_capacity(cfg.num_clients);
        for (i, split) in splits.into_iter().enumerate() {
            let mut c = ClientState::new(
                i,
                split,
                capabilities[i],
                global.clone(),
                &prunable_channels,
                spec.train_batch,
                rng.fork(i as u64).next_u64(),
            );
            c.ratio = ratios[i];
            c.bucket = if cfg.method == Method::FedSkel {
                spec.quantize_ratio(ratios[i] * 100.0)?
            } else {
                spec.quantize_ratio(100.0)?
            };
            clients.push(c);
        }

        // --fault wraps the built transport in the seeded chaos layer.
        // The retry loops in ship_download/ship_upload recover every
        // injected loss, so the training trajectory (and its digest) is
        // identical to the clean run — faults only add FaultRetry events
        // and wasted bytes. That is why `fault` stays out of the
        // snapshot determinism key.
        let mut transport = cfg.transport.build(&fleet);
        if let Some(plan) = &cfg.fault {
            transport = Box::new(FaultInjector::new(transport, plan.clone()));
        }
        let sched = RoundScheduler::new(cfg.sched.build(
            cfg.deadline_secs,
            cfg.buffer_k,
            cfg.staleness_alpha,
        ));
        let compressor = if cfg.compress.is_identity() {
            None
        } else {
            Some(cfg.compress.build(cfg.topk_ratio))
        };
        let down_anchor: Vec<Option<Params>> = vec![None; cfg.num_clients];
        let mut tracer = Trace::null();
        if let Some(path) = &cfg.trace {
            let sink =
                trace::JsonlSink::create(Path::new(path), &cfg.to_json(), cfg.trace_level)?;
            tracer.add_sink(Box::new(sink));
        }
        let cfg2 = cfg.lg_global_prefixes.clone();
        Ok(Coordinator {
            cfg,
            backend,
            global,
            clients,
            data,
            new_test,
            ledger: CommLedger::new(),
            fleet,
            log: RunLog::default(),
            transport,
            sched,
            registry: Registry::new(),
            trace: tracer,
            rng,
            lg_global_ids: {
                let prefixes: Vec<&str> = cfg2.iter().map(|s| s.as_str()).collect();
                lg_global_ids_of(&spec.params, &prefixes)
            },
            pool: None,
            remote: None,
            compressor,
            down_anchor,
            pending: BTreeMap::new(),
            pending_deltas: BTreeMap::new(),
            round_idx: 0,
        })
    }

    /// Like [`Coordinator::new`], but local training runs on a
    /// [`WorkerPool`] — one thread per backend in `worker_backends` — so
    /// clients within a round execute concurrently instead of
    /// sequentially. The coordinator's own `backend` still serves
    /// evaluation and batch-time measurement.
    pub fn with_pool(
        mut cfg: RunConfig,
        backend: B,
        worker_backends: Vec<B>,
    ) -> Result<Coordinator<B>>
    where
        B: Send + 'static,
    {
        let pool = WorkerPool::new(worker_backends)?;
        let workers = pool.workers();
        cfg.workers = 0; // pass the inline-constructor guard
        let mut c = Coordinator::new(cfg, backend)?;
        c.cfg.workers = workers; // the pool, not the flag, is authoritative
        c.pool = Some(pool);
        Ok(c)
    }

    /// Resume a run from a snapshot file ([`crate::snapshot`]): build the
    /// system normally from `cfg` (data, shards, fleet, transport are all
    /// deterministic functions of the config), then overwrite every piece
    /// of primary state the snapshot carries. The continuation is bitwise
    /// identical to never having stopped — `cfg` must describe the same
    /// run (checked via [`snapshot::determinism_key`]); only `rounds` and
    /// observer knobs (trace, checkpointing, workers) may differ.
    pub fn restore(cfg: RunConfig, backend: B, path: &Path) -> Result<Coordinator<B>> {
        let mut c = Coordinator::new(cfg, backend)?;
        c.apply_snapshot(path)?;
        Ok(c)
    }

    /// [`Coordinator::restore`] with a worker pool ([`Coordinator::with_pool`]).
    pub fn restore_with_pool(
        cfg: RunConfig,
        backend: B,
        worker_backends: Vec<B>,
        path: &Path,
    ) -> Result<Coordinator<B>>
    where
        B: Send + 'static,
    {
        let mut c = Coordinator::with_pool(cfg, backend, worker_backends)?;
        c.apply_snapshot(path)?;
        Ok(c)
    }

    /// Like [`Coordinator::new`], but local training executes on remote
    /// `fedskel client` processes via a [`remote::RemoteFleet`]. The
    /// coordinator's own `backend` still serves evaluation and
    /// batch-time measurement; jobs and outcomes cross process
    /// boundaries bitwise, so results equal the inline run's.
    pub fn with_remote(
        mut cfg: RunConfig,
        backend: B,
        fleet: remote::RemoteFleet,
    ) -> Result<Coordinator<B>> {
        cfg.workers = 0; // pass the inline-constructor guard; the fleet is dynamic
        let mut c = Coordinator::new(cfg, backend)?;
        c.remote = Some(fleet);
        Ok(c)
    }

    /// [`Coordinator::restore`] with a [`remote::RemoteFleet`]
    /// (see [`Coordinator::with_remote`]).
    pub fn restore_with_remote(
        cfg: RunConfig,
        backend: B,
        fleet: remote::RemoteFleet,
        path: &Path,
    ) -> Result<Coordinator<B>> {
        let mut c = Coordinator::with_remote(cfg, backend, fleet)?;
        c.apply_snapshot(path)?;
        Ok(c)
    }

    /// The remote worker fleet, when training runs multi-process
    /// (`fedskel serve` drives joins/waits/shutdown through this).
    pub fn remote_mut(&mut self) -> Option<&mut remote::RemoteFleet> {
        self.remote.as_mut()
    }

    /// Worker threads training clients (0 = inline).
    pub fn workers(&self) -> usize {
        self.pool.as_ref().map(|p| p.workers()).unwrap_or(0)
    }

    /// Rounds completed so far (the next [`Coordinator::step_round`] runs
    /// this round index).
    pub fn round_idx(&self) -> usize {
        self.round_idx
    }

    /// Serialize all primary run state to a snapshot file and return the
    /// bytes written. A pure read of the coordinator — taking a
    /// checkpoint never perturbs training state, so `--checkpoint-every 1`
    /// cannot change any digest.
    pub fn checkpoint(&self, path: &Path) -> Result<u64> {
        let spec = self.backend.spec();
        let (rng_state, rng_spare) = self.rng.state_parts();
        let (clock_now, in_flight) = self.sched.clock_state();
        let clients = self
            .clients
            .iter()
            .map(|c| {
                let (batcher_rng_state, batcher_rng_spare) = c.batcher.rng_parts();
                ClientSnap {
                    id: c.id as u32,
                    capability: c.capability,
                    ratio: c.ratio,
                    bucket: c.bucket as u32,
                    last_loss_bits: c.last_loss.to_bits(),
                    skeleton: c.skeleton.clone(),
                    local_params: c.local_params.clone(),
                    importance_sums: c.importance.raw_sums().to_vec(),
                    importance_batches: c.importance.batches() as u64,
                    batcher_indices: c.batcher.indices().iter().map(|&i| i as u32).collect(),
                    batcher_batch: spec.train_batch as u32,
                    batcher_cursor: c.batcher.cursor() as u64,
                    batcher_rng_state,
                    batcher_rng_spare,
                    ef_residual: c.ef_residual.clone(),
                }
            })
            .collect();
        let fleet = self
            .fleet
            .iter()
            .map(|d| DeviceSnap {
                name: d.name.clone(),
                capability: d.capability,
                bandwidth_mbps: d.bandwidth_mbps,
                latency_s: d.latency_s,
                cores: d.cores as u32,
                precision: d.precision,
            })
            .collect();
        let pending = self
            .pending
            .iter()
            .map(|(&(round, seq), u)| PendingSnap {
                round: round as u64,
                seq: seq as u64,
                client: u.client as u32,
                weight: u.weight,
                params: u.params.clone(),
                skeleton: u.skeleton.clone(),
                delta: self.pending_deltas.get(&(round, seq)).cloned(),
            })
            .collect();
        let snap = Snapshot {
            determinism_key: snapshot::determinism_key(&self.cfg),
            round_idx: self.round_idx as u64,
            rng_state,
            rng_spare,
            global: self.global.clone(),
            clients,
            fleet,
            clock_now,
            in_flight,
            pending,
            anchors: self.down_anchor.clone(),
            ledger: self.ledger.clone(),
            rounds_log: self.log.rounds.clone(),
        };
        snap.save(path)
    }

    /// Load a snapshot and install its state over this freshly built
    /// coordinator (see [`Coordinator::restore`]).
    fn apply_snapshot(&mut self, path: &Path) -> Result<()> {
        let spec = self.backend.spec().clone();
        let snap = Snapshot::load(&spec, path)?;
        let run_key = snapshot::determinism_key(&self.cfg);
        if snap.determinism_key != run_key {
            return Err(SnapshotError::ConfigMismatch {
                snapshot: snap.determinism_key,
                run: run_key,
            }
            .into());
        }
        if snap.clients.len() != self.clients.len()
            || snap.fleet.len() != self.fleet.len()
            || snap.anchors.len() != self.down_anchor.len()
        {
            bail!(
                "snapshot population mismatch: {} clients / {} devices / {} anchors \
                 vs this run's {} / {} / {}",
                snap.clients.len(),
                snap.fleet.len(),
                snap.anchors.len(),
                self.clients.len(),
                self.fleet.len(),
                self.down_anchor.len()
            );
        }
        self.global = snap.global;
        self.rng = Rng::from_parts(snap.rng_state, snap.rng_spare);
        self.round_idx = snap.round_idx as usize;
        for (cl, cs) in self.clients.iter_mut().zip(snap.clients) {
            if cl.id != cs.id as usize {
                bail!("snapshot client id {} does not match slot {}", cs.id, cl.id);
            }
            if cs.batcher_batch == 0 {
                bail!("snapshot client {} has a zero batch size", cs.id);
            }
            cl.capability = cs.capability;
            cl.ratio = cs.ratio;
            cl.bucket = cs.bucket as usize;
            cl.last_loss = f32::from_bits(cs.last_loss_bits);
            cl.skeleton = cs.skeleton;
            cl.local_params = cs.local_params;
            cl.importance = ImportanceAccumulator::restore(
                cs.importance_sums,
                cs.importance_batches as usize,
            );
            cl.batcher = Batcher::restore(
                cs.batcher_indices.iter().map(|&i| i as usize).collect(),
                cs.batcher_batch as usize,
                cs.batcher_cursor as usize,
                cs.batcher_rng_state,
                cs.batcher_rng_spare,
            );
            cl.ef_residual = cs.ef_residual;
        }
        for (d, ds) in self.fleet.iter_mut().zip(snap.fleet) {
            d.name = ds.name;
            d.capability = ds.capability;
            d.bandwidth_mbps = ds.bandwidth_mbps;
            d.latency_s = ds.latency_s;
            d.cores = ds.cores as usize;
            d.precision = ds.precision;
        }
        self.down_anchor = snap.anchors;
        self.pending.clear();
        self.pending_deltas.clear();
        for p in snap.pending {
            let key = (p.round as usize, p.seq as usize);
            if let Some(d) = p.delta {
                self.pending_deltas.insert(key, d);
            }
            self.pending.insert(
                key,
                Update {
                    client: p.client as usize,
                    weight: p.weight,
                    params: p.params,
                    skeleton: p.skeleton,
                },
            );
        }
        self.ledger = snap.ledger;
        self.log.rounds = snap.rounds_log;
        // now BEFORE events: in-flight stragglers keep their absolute
        // arrival times on the restored clock, so their staleness
        // weights match the uninterrupted run ([`crate::sched`]).
        let in_flight = snap.in_flight.len();
        self.sched.restore_clock(snap.clock_now, snap.in_flight)?;
        self.emit(RunEvent::Resume {
            round: self.round_idx,
            path: path.display().to_string(),
            clock: snap.clock_now,
            in_flight,
        });
        Ok(())
    }

    /// Attach an additional trace sink (e.g. a [`crate::trace::RingSink`]
    /// for an embedded dashboard) on top of any `--trace` file sink.
    pub fn add_trace_sink(&mut self, sink: Box<dyn TraceSink>) {
        self.trace.add_sink(sink);
    }

    /// Emit one run event: fold it into the derived tables (log, ledger,
    /// registry — the only way they are ever written), then fan it out to
    /// the attached sinks. Replay uses the identical fold, which is what
    /// makes `fedskel report` byte-for-byte faithful.
    fn emit(&mut self, ev: RunEvent) {
        trace::fold::apply(&mut self.log, &mut self.ledger, &mut self.registry, &ev);
        self.trace.emit(&ev);
    }

    /// Phase of round `r` under the configured method.
    pub fn phase_of(&self, r: usize) -> Phase {
        if self.cfg.method != Method::FedSkel {
            return Phase::Full;
        }
        if r % (1 + self.cfg.updateskel_per_setskel) == 0 {
            Phase::SetSkel
        } else {
            Phase::UpdateSkel
        }
    }

    /// Run all configured rounds (from the restored round when resuming).
    pub fn run(&mut self) -> Result<()> {
        while self.round_idx < self.cfg.rounds {
            self.step_round()?;
        }
        // final eval if the cadence missed the last round
        if self
            .log
            .rounds
            .last()
            .map(|r| r.new_acc.is_none())
            .unwrap_or(true)
        {
            let new_acc = self.evaluate_new()?;
            let local_acc = self.evaluate_local()?;
            if let Some(round) = self.log.rounds.last().map(|l| l.round) {
                self.emit(RunEvent::Eval { round, new_acc, local_acc });
            }
        }
        self.trace.flush();
        Ok(())
    }

    /// Execute exactly one federated round: encode + ship downloads, run
    /// local training (pool or inline), ship + decode uploads, queue each
    /// client's completion on the virtual clock, let the round policy
    /// decide which arrivals aggregate, aggregate them.
    pub fn step_round(&mut self) -> Result<()> {
        // Round phases open short-lived child spans (`round/select`,
        // `round/download`, …) under this guard; see `docs/OBSERVABILITY.md`
        // for the vocabulary.
        let _round_span = prof::scope("round");
        let r = self.round_idx;
        let phase = self.phase_of(r);
        let wall = Timer::start();
        let method = self.cfg.method;
        let spec = self.backend.spec().clone();
        let round_start = self.sched.now();
        self.emit(RunEvent::RoundOpen {
            round: r,
            phase: phase.name().to_string(),
            clock: round_start,
        });

        // --- participant sampling + failure injection. The dropout
        // draws stay here (one per sampled participant, in sampling
        // order) but the drop itself is applied *after* the download
        // ships: a device that dies mid-round has already cost its
        // download frames, which the ledger books as wasted bytes.
        let select_span = prof::scope("select");
        let participants = self.sample_participants();
        let mut dropped_mid = vec![false; participants.len()];
        if self.cfg.dropout > 0.0 {
            let p = self.cfg.dropout;
            for slot in dropped_mid.iter_mut() {
                *slot = (self.rng.uniform() as f64) < p;
            }
        }
        drop(select_span);

        let comm_before = self.ledger.total_params();
        let wire_before = self.ledger.total_wire_bytes();

        // --- downloads + job construction. Batches are pre-filled from
        // each client's deterministic batcher so the jobs are
        // self-contained and scheduling-independent. The round's anchor
        // is shared (`Arc`) rather than cloned per participant, and on
        // the inline path each job runs as soon as it is built so only
        // one job's buffers are alive at a time. `seq` (= index into
        // `trained`) is the submission slot everything downstream keys
        // on: job routing, pending updates, completion events.
        let round_global: Arc<Params> = Arc::new(self.global.clone());
        let pooled = self.pool.is_some() || self.remote.is_some();
        let mut jobs: Vec<TrainJob> = Vec::new();
        let mut outcomes = Vec::with_capacity(participants.len());
        let mut down_info: Vec<(ExchangeKind, Receipt)> = Vec::with_capacity(participants.len());
        let mut meta: Vec<(usize, Vec<Vec<i32>>)> = Vec::with_capacity(participants.len());
        let mut trained: Vec<usize> = Vec::with_capacity(participants.len());
        for (i, &ci) in participants.iter().enumerate() {
            let down_kind = self.down_kind(ci, phase);
            let (receipt, anchor) = {
                let _span = prof::scope("download");
                self.ship_download(r, ci, &down_kind, &spec)?
            };
            self.emit(RunEvent::Download {
                round: r,
                client: ci,
                wire_bytes: receipt.bytes as u64,
                raw_bytes: wire::encoded_len(&spec, &down_kind, Quant::F32) as u64,
            });
            if dropped_mid[i] {
                // mid-round failure: the download was already on the wire
                // (and applied — the device received it before dying);
                // no training, no upload, frames wasted.
                self.emit(RunEvent::MidroundDrop {
                    round: r,
                    client: ci,
                    wasted_bytes: receipt.bytes as u64,
                });
                continue;
            }
            let (bucket, skeleton) = self.train_setup(ci, phase, &spec)?;
            self.emit(RunEvent::Dispatch { round: r, seq: trained.len(), client: ci, bucket });
            // covers batch fill + job build + (inline mode) the local
            // training itself, so train_step spans nest under dispatch
            let _dispatch_span = prof::scope("dispatch");

            let b = spec.train_batch;
            let numel: usize = spec.input_shape.iter().product();
            let mut batches = Vec::with_capacity(self.cfg.local_steps);
            for _ in 0..self.cfg.local_steps {
                let mut x = vec![0.0f32; b * numel];
                let mut y = vec![0i32; b];
                self.clients[ci].batcher.fill_batch(&self.data, &mut x, &mut y);
                batches.push((x, y));
            }
            let mu = if method == Method::FedMtl { self.cfg.mu.max(0.01) } else { 0.0 };
            let job = TrainJob {
                client: ci,
                bucket,
                skeleton: skeleton.clone(),
                local: self.clients[ci].local_params.clone(),
                // FedMTL pulls toward the anchor it actually received on
                // the wire (which differs from the server copy under
                // lossy quantization); everyone else shares the round's
                // server anchor.
                global: match anchor {
                    Some(a) => Arc::new(a),
                    None => Arc::clone(&round_global),
                },
                batches,
                lr: self.cfg.lr,
                mu,
                want_importance: method == Method::FedSkel && phase == Phase::SetSkel,
                par: self.client_parallelism(ci),
                precision: self.fleet[ci].precision,
            };
            if pooled {
                jobs.push(job);
            } else {
                outcomes.push(run_local_steps(&mut self.backend, job)?);
            }
            down_info.push((down_kind, receipt));
            meta.push((bucket, skeleton));
            trained.push(ci);
        }

        // --- pool/remote mode: dispatch the whole round and wait;
        // outcomes come back in submission order, so all paths see the
        // same sequence. Remote worker joins/leaves observed during the
        // round surface as run events after the outcomes land.
        if pooled {
            let _span = prof::scope("dispatch");
            let mut remote_events = Vec::new();
            outcomes = if let Some(fleet) = self.remote.as_mut() {
                let out = fleet.run(jobs)?;
                remote_events = fleet.take_events(r);
                out
            } else {
                self.pool.as_ref().unwrap().run(jobs)?
            };
            for ev in remote_events {
                self.emit(ev);
            }
        }

        // --- uploads: encode each client's payload, move it over the
        // transport, decode server-side, reconstruct full tensors, and
        // queue the client's completion event at its virtual arrival
        // time. The decoded update waits in `pending` until the policy
        // accepts its event — possibly in a later round.
        let mut loss_mean = Mean::default();
        let mut client_secs: Vec<(usize, f64)> = Vec::with_capacity(outcomes.len());
        let mut up_info: Vec<(ExchangeKind, Receipt)> = Vec::with_capacity(outcomes.len());
        let comp_name = self.cfg.compress.name();
        for (seq, out) in outcomes.into_iter().enumerate() {
            let ci = out.client;
            let (bucket, skeleton) = &meta[seq];
            loss_mean.add(out.mean_loss as f64);
            self.clients[ci].last_loss = out.mean_loss;
            self.clients[ci].local_params = out.params.clone();
            if !out.importance_sums.is_empty() {
                let refs: Vec<&[f32]> = out.importance_sums.iter().map(|v| v.as_slice()).collect();
                self.clients[ci].importance.accumulate_summed(&refs, out.steps)?;
            }

            let up_kind = self.up_kind(phase, skeleton);
            let (update, up_receipt, refold) = {
                let _span = prof::scope("upload");
                self.ship_upload(r, ci, &up_kind, skeleton, &out.params, &spec, phase)?
            };
            if let Some(d) = refold {
                self.pending_deltas.insert((r, seq), d);
            }
            self.emit(RunEvent::Upload {
                round: r,
                seq,
                client: ci,
                wire_bytes: up_receipt.bytes as u64,
                raw_bytes: wire::encoded_len(&spec, &up_kind, Quant::F32) as u64,
                compressor: comp_name.to_string(),
            });

            // simulated heterogeneous wall-clock: compute + the *measured*
            // frame bytes over this client's simulated link. Batch time is
            // measured under the client's own core budget (the backend
            // caches per (bucket, threads)) and then divided by its
            // *per-core* capability inside simulate_round_wire — the core
            // axis is measured, the per-core axis simulated, and the two
            // compose without double-counting (see hetero's module docs).
            self.backend.set_parallelism(self.client_parallelism(ci));
            self.backend.set_precision(self.fleet[ci].precision);
            let batch_s = self.backend.batch_time_secs(*bucket)?;
            let profile = &self.fleet[ci];
            let secs = simulate_round_wire(
                profile,
                batch_s,
                self.cfg.local_steps,
                down_info[seq].1.sim_secs + up_receipt.sim_secs,
            )
            .total();
            self.emit(RunEvent::Complete {
                round: r,
                seq,
                client: ci,
                loss: out.mean_loss as f64,
                secs,
            });
            self.sched.submit(ci, r, seq, secs);
            self.pending.insert((r, seq), update);
            client_secs.push((ci, secs));
            up_info.push((up_kind, up_receipt));
        }

        // --- the policy decides the round from the event queue: which
        // arrivals aggregate, which are dropped, when the round ends.
        let outcome = self.sched.run_round(r);

        // comm accounting for this round's exchanges. An update the
        // policy discarded at the deadline wasted both its frames; every
        // other exchange counts as useful traffic at the round it
        // happened (async stragglers' bytes were spent now even though
        // their update aggregates later).
        let dropped_seqs: Vec<usize> =
            outcome.dropped.iter().filter(|c| c.round == r).map(|c| c.seq).collect();
        for (seq, ((down_kind, down_receipt), (up_kind, up_receipt))) in
            down_info.iter().zip(&up_info).enumerate()
        {
            if dropped_seqs.contains(&seq) {
                self.emit(RunEvent::DeadlineDrop {
                    round: r,
                    seq,
                    client: trained[seq],
                    wasted_bytes: up_receipt.bytes as u64 + down_receipt.bytes as u64,
                });
            } else {
                // the raw sides of the raw-vs-compressed split are what
                // the same exchange costs as plain dense-f32 frames
                self.emit(RunEvent::Exchange {
                    round: r,
                    seq,
                    client: trained[seq],
                    up_params: params_moved(&spec, up_kind) as u64,
                    down_params: params_moved(&spec, down_kind) as u64,
                    up_wire: up_receipt.bytes as u64,
                    down_wire: down_receipt.bytes as u64,
                    up_raw: wire::encoded_len(&spec, up_kind, Quant::F32) as u64,
                    down_raw: wire::encoded_len(&spec, down_kind, Quant::F32) as u64,
                });
            }
        }
        for c in &outcome.dropped {
            debug_assert_eq!(c.round, r, "only the current round's arrivals can be dropped");
            let Some(update) = self.pending.remove(&(c.round, c.seq)) else { continue };
            // Error-feedback contract under deadline drops: the client's
            // residual was reset at upload time as if the update had been
            // delivered, but the policy just discarded it. Refold the
            // exact decoded delta (recorded at submission; zero outside
            // carried coordinates) into the residual, so the next upload
            // re-carries what the server threw away — "deferred, never
            // lost" survives drops.
            if let Some(payload) = self.pending_deltas.remove(&(c.round, c.seq)) {
                if !self.clients[update.client].ef_residual.is_empty() {
                    let mut delta: Params =
                        spec.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
                    payload.add_into(&spec, &mut delta)?;
                    let res = &mut self.clients[update.client].ef_residual;
                    for (pi, t) in delta.iter().enumerate() {
                        for (j, v) in t.data().iter().enumerate() {
                            if *v != 0.0 {
                                res[pi][j] += v;
                            }
                        }
                    }
                }
            }
        }

        // --- aggregation over the accepted arrivals, in (origin round,
        // submission seq) order — bitwise the pre-scheduler order under
        // the sync barrier. Stale arrivals (async buffering) contribute
        // with staleness-discounted weights.
        let mut updates: Vec<Update> = Vec::with_capacity(outcome.accepted.len());
        let mut stale = 0usize;
        for c in &outcome.accepted {
            let Some(mut update) = self.pending.remove(&(c.round, c.seq)) else {
                bail!("scheduler accepted unknown update (round {}, seq {})", c.round, c.seq);
            };
            self.pending_deltas.remove(&(c.round, c.seq));
            let staleness = r - c.round;
            if staleness > 0 {
                stale += 1;
                let w = staleness_weight(staleness, self.sched.staleness_alpha());
                update.weight *= w;
                self.emit(RunEvent::StaleLand {
                    round: r,
                    origin_round: c.round,
                    seq: c.seq,
                    client: update.client,
                    staleness,
                    weight_scale: w,
                });
            }
            updates.push(update);
        }
        let aggregate_span = prof::scope("aggregate");
        self.global = match (method, phase) {
            // Stale FedSkel arrivals (async buffering) may mix origin
            // phases: an UpdateSkel-trained update only carries real
            // values on its skeleton channels, so it must aggregate
            // partially even when it lands in a SetSkel round. Every
            // FedSkel update records its own skeleton (identity for
            // SetSkel origins), so the partial aggregator is correct for
            // any mix — and with no stale arrivals (every Sync round)
            // this branch is never taken, preserving bitwise parity.
            (Method::FedSkel, _) if stale > 0 => {
                aggregate::fedskel_aggregate(&self.global, &updates, &spec.prunable)?
            }
            (Method::FedAvg, _) | (Method::FedMtl, _) | (Method::FedSkel, Phase::SetSkel) => {
                aggregate::fedavg(&self.global, &updates)?
            }
            (Method::FedSkel, _) => {
                aggregate::fedskel_aggregate(&self.global, &updates, &spec.prunable)?
            }
            (Method::LgFedAvg, _) => {
                aggregate::lg_fedavg_aggregate(&self.global, &updates, &self.lg_global_ids)?
            }
        };
        drop(aggregate_span);

        // --- after a SetSkel round, every client that trained re-selects
        // its skeleton (a client-local step — it happens even if the
        // server dropped or deferred the client's upload).
        if method == Method::FedSkel && phase == Phase::SetSkel {
            for &ci in &trained {
                self.reselect_skeleton(ci)?;
                self.emit(RunEvent::Reselect {
                    round: r,
                    client: ci,
                    bucket: self.clients[ci].bucket,
                    k: self.clients[ci].skeleton.iter().map(|s| s.len()).collect(),
                });
            }
        }

        self.round_idx += 1;

        // --- eval cadence
        let do_eval = self.cfg.eval_every > 0 && (r + 1) % self.cfg.eval_every == 0;
        let (new_acc, local_acc) = if do_eval {
            let _span = prof::scope("eval");
            (Some(self.evaluate_new()?), Some(self.evaluate_local()?))
        } else {
            (None, None)
        };

        // the digest makes the trace checkpoint-ready (and lets replay
        // cross-check state); computing it is pure reading, skipped when
        // no sink is listening.
        let digest = if self.trace.active() {
            Some(crate::model::params_digest(&self.global))
        } else {
            None
        };
        self.emit(RunEvent::RoundClose {
            round: r,
            phase: phase.name().to_string(),
            mean_loss: loss_mean.get(),
            new_acc,
            local_acc,
            comm_params: self.ledger.total_params() - comm_before,
            comm_wire_bytes: self.ledger.total_wire_bytes() - wire_before,
            sim_secs: outcome.round_end - round_start,
            client_secs,
            dropped: outcome.dropped.len(),
            stale,
            wall_secs: wall.elapsed_secs(),
            digest,
        });
        if let (Some(new_acc), Some(local_acc)) = (new_acc, local_acc) {
            self.emit(RunEvent::Eval { round: r, new_acc, local_acc });
        }

        // --- checkpoint hook: after the round's events so the snapshot
        // sees exactly the closed-round state. Writing is a pure read of
        // the coordinator ([`Coordinator::checkpoint`]), so
        // `--checkpoint-every 1` never changes a digest.
        if self.cfg.checkpoint_every > 0 && self.round_idx % self.cfg.checkpoint_every == 0 {
            if let Some(dir) = self.cfg.checkpoint_dir.clone() {
                let _span = prof::scope("checkpoint");
                let path = Path::new(&dir).join(format!("snap_round_{}.fsnap", self.round_idx));
                let bytes = self.checkpoint(&path)?;
                self.emit(RunEvent::CheckpointWrite {
                    round: r,
                    path: path.display().to_string(),
                    bytes,
                });
            }
        }
        Ok(())
    }

    /// What the server sends client `ci` this round.
    fn down_kind(&self, ci: usize, phase: Phase) -> ExchangeKind {
        // FedMTL still *downloads* the anchor every round (the prox term
        // needs it) but never adopts it into the personal model.
        match (self.cfg.method, phase) {
            (Method::FedMtl, _) => ExchangeKind::Full,
            (Method::LgFedAvg, _) => ExchangeKind::ParamSubset(self.lg_global_ids.clone()),
            (Method::FedSkel, Phase::UpdateSkel) => {
                ExchangeKind::Skeleton(self.clients[ci].skeleton.iter().map(|s| s.len()).collect())
            }
            _ => ExchangeKind::Full,
        }
    }

    /// What a client uploads after training with `skeleton`.
    fn up_kind(&self, phase: Phase, skeleton: &[Vec<i32>]) -> ExchangeKind {
        match (self.cfg.method, phase) {
            (Method::LgFedAvg, _) => ExchangeKind::ParamSubset(self.lg_global_ids.clone()),
            (Method::FedSkel, Phase::UpdateSkel) => {
                ExchangeKind::Skeleton(skeleton.iter().map(|s| s.len()).collect())
            }
            _ => ExchangeKind::Full,
        }
    }

    /// Bucket + training skeleton for one client this round.
    fn train_setup(&self, ci: usize, phase: Phase, spec: &ModelSpec) -> Result<(usize, Vec<Vec<i32>>)> {
        match (self.cfg.method, phase) {
            (Method::FedSkel, Phase::UpdateSkel) => {
                let bucket = self.clients[ci].bucket;
                let ks = spec.train_artifact(bucket)?.k.clone();
                let mut skel = self.clients[ci].skeleton.clone();
                // A client sampled into UpdateSkel before its first SetSkel
                // (participation < 1 or dropout) still carries the identity
                // skeleton — truncate to the bucket's k_l channels until a
                // SetSkel round gives it importance-ranked ones.
                for (s, &k) in skel.iter_mut().zip(&ks) {
                    if s.len() != k {
                        *s = (0..k as i32).collect(); // identity prefix
                    }
                }
                Ok((bucket, skel))
            }
            _ => {
                let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
                Ok((spec.quantize_ratio(100.0)?, identity_skeleton(&channels)))
            }
        }
    }

    /// Encode the server's payload for `ci`, move it through the
    /// transport, decode it client-side, and apply it to the client's
    /// local params. FedMTL never adopts the download into its personal
    /// model; instead the decoded anchor is returned so training pulls
    /// toward what the wire delivered (not the server-side copy, which
    /// differs under lossy quantization).
    ///
    /// With `--delta-down`, Full downloads after the client's first are
    /// shipped as [`WirePayload::anchor_delta`] frames vs the client's
    /// recorded anchor — bitwise-unchanged parameters (FedSkel channels
    /// no participant covered, frozen parts) cost ~0 bytes, and the
    /// client reconstructs the identical full model, so results are
    /// bit-for-bit those of the plain path.
    fn ship_download(
        &mut self,
        round: usize,
        ci: usize,
        kind: &ExchangeKind,
        spec: &ModelSpec,
    ) -> Result<(Receipt, Option<Params>)> {
        if *kind == ExchangeKind::None {
            return Ok((Receipt { bytes: 0, sim_secs: 0.0 }, None));
        }
        let track_anchor = self.cfg.delta_down && *kind == ExchangeKind::Full;
        let payload = match (track_anchor, self.down_anchor[ci].as_ref()) {
            (true, Some(anchor)) => {
                WirePayload::anchor_delta(spec, anchor, &self.global, self.cfg.quant)?
            }
            _ => match kind {
                ExchangeKind::Full => WirePayload::full(&self.global),
                ExchangeKind::Skeleton(_) => {
                    WirePayload::skeleton(spec, &self.global, &self.clients[ci].skeleton)?
                }
                ExchangeKind::ParamSubset(ids) => WirePayload::subset(spec, &self.global, ids)?,
                ExchangeKind::None => unreachable!(),
            },
        };
        let msg = RoundMsg { round: round as u32, client: ci as u32, weight: 0.0, payload };
        let frame = wire::encode(&msg, self.cfg.quant);
        let (receipt, decoded, _) =
            self.reliable_exchange(round, ci, Peer::Client(ci), frame, true, spec)?;
        if track_anchor {
            if let WirePayload::Full(ps) = &decoded.payload {
                self.down_anchor[ci] = Some(ps.clone());
            }
        }
        if self.cfg.method == Method::FedMtl {
            let mut anchor = self.global.clone();
            decoded.payload.overlay_into(spec, &mut anchor)?;
            return Ok((receipt, Some(anchor)));
        }
        decoded
            .payload
            .overlay_into(spec, &mut self.clients[ci].local_params)?;
        Ok((receipt, None))
    }

    /// Encode a client's post-training payload, move it through the
    /// transport, decode it server-side, and reconstruct full tensors for
    /// the aggregators by overlaying the (possibly sparse) payload on the
    /// current global — the aggregators only ever read the channels and
    /// tensors the payload actually carried.
    ///
    /// With a non-identity `--compress`, the payload instead carries the
    /// client's *update delta* vs this round's global anchor (with the
    /// error-feedback residual folded in when `--error-feedback` is on),
    /// encoded per the compressor's block plans and `DELTA`-flagged; the
    /// server reconstructs by *adding* the decoded delta onto the same
    /// anchor. Because encode and decode both happen here — at
    /// submission time, with the origin round's global in hand — a stale
    /// async arrival ([`crate::sched`]) is always compressed and
    /// reconstructed against its own recorded anchor, never a later
    /// round's.
    #[allow(clippy::too_many_arguments)]
    fn ship_upload(
        &mut self,
        round: usize,
        ci: usize,
        kind: &ExchangeKind,
        skeleton: &[Vec<i32>],
        trained: &Params,
        spec: &ModelSpec,
        phase: Phase,
    ) -> Result<(Update, Receipt, Option<WirePayload>)> {
        let (payload, plans) = if let Some(comp) = &self.compressor {
            let residual = if self.cfg.error_feedback {
                Some(&mut self.clients[ci].ef_residual)
            } else {
                None
            };
            let anchor = &self.global;
            let (payload, plans) =
                compress_update(comp.as_ref(), spec, kind, skeleton, anchor, trained, residual)?;
            (payload, Some(plans))
        } else {
            let payload = match kind {
                ExchangeKind::Full => WirePayload::full(trained),
                ExchangeKind::Skeleton(_) => WirePayload::skeleton(spec, trained, skeleton)?,
                ExchangeKind::ParamSubset(ids) => WirePayload::subset(spec, trained, ids)?,
                ExchangeKind::None => bail!("client {ci} cannot upload ExchangeKind::None"),
            };
            (payload, None)
        };
        let msg = RoundMsg {
            round: round as u32,
            client: ci as u32,
            weight: self.clients[ci].weight(),
            payload,
        };
        let frame = match &plans {
            Some(p) => wire::encode_opts(
                &msg,
                &FrameOpts { quant: self.cfg.quant, delta: true, plans: Some(p) },
            )?,
            None => wire::encode(&msg, self.cfg.quant),
        };
        let (receipt, decoded, is_delta) =
            self.reliable_exchange(round, ci, Peer::Server, frame, false, spec)?;
        let mut full = self.global.clone();
        if is_delta {
            decoded.payload.add_into(spec, &mut full)?;
        } else {
            decoded.payload.overlay_into(spec, &mut full)?;
        }
        let update = Update {
            client: ci,
            weight: decoded.weight,
            params: full,
            skeleton: if self.cfg.method == Method::FedSkel && phase == Phase::UpdateSkel {
                skeleton.to_vec()
            } else if self.cfg.method == Method::FedSkel {
                // SetSkel rounds aggregate fully; identity skeleton recorded
                let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
                identity_skeleton(&channels)
            } else {
                vec![]
            },
        };
        // hand the decoded delta payload back for the drop-refold store
        // (a move of an existing allocation — free on the no-drop path)
        let refold = (is_delta && self.cfg.error_feedback).then_some(decoded.payload);
        Ok((update, receipt, refold))
    }

    /// One reliable request/response exchange over the (possibly
    /// fault-injected) transport: send `frame` toward `to`, then receive
    /// and decode the frame carrying `(round, ci)` in its header. Under
    /// `--fault` the loop retransmits when the queue runs dry (the frame
    /// was dropped or is still held by the injector), discards stray
    /// frames — released duplicates of *earlier* exchanges' retransmitted
    /// attempts, recognized by their header ids ([`wire::peek_ids`])
    /// without decoding — and retries frames that fail to decode
    /// (truncated mid-body: length/checksum checks reject them typed,
    /// never a panic). Every wasted attempt is emitted as
    /// [`RunEvent::FaultRetry`], so retransmission bytes land in
    /// [`CommLedger::wasted_wire_bytes`] — never in the useful counters.
    /// Without `--fault` the first loss or decode failure is a hard
    /// error (exactly one attempt, the pre-fault behavior).
    ///
    /// The returned receipt is the final send's: same frame bytes as the
    /// clean run, so the simulated link seconds fed to the scheduler —
    /// and therefore every digest — are unchanged by injected faults.
    /// (Sole caveat, simnet only: if the final *delivered* copy is one
    /// the injector had held, its receipt charged 0 link-seconds; on
    /// loopback/tcp all receipts are 0 and neutrality is exact.)
    fn reliable_exchange(
        &mut self,
        round: usize,
        ci: usize,
        to: Peer,
        frame: Vec<u8>,
        with_anchor: bool,
        spec: &ModelSpec,
    ) -> Result<(Receipt, RoundMsg, bool)> {
        let from = match to {
            Peer::Server => Peer::Client(ci),
            Peer::Client(_) => Peer::Server,
        };
        let max_attempts: usize = if self.cfg.fault.is_some() { 32 } else { 1 };
        let mut receipt = self.transport.send(Envelope { from, to, frame: frame.clone() })?;
        let mut attempts = 1usize;
        loop {
            let env = match self.transport.recv(to)? {
                Some(env) => env,
                None => {
                    if attempts >= max_attempts {
                        bail!(
                            "frame for client {ci} (round {round}) lost after {attempts} attempt(s)"
                        );
                    }
                    self.emit(RunEvent::FaultRetry {
                        round,
                        client: ci,
                        wasted_bytes: receipt.bytes as u64,
                    });
                    receipt = self.transport.send(Envelope { from, to, frame: frame.clone() })?;
                    attempts += 1;
                    continue;
                }
            };
            if wire::peek_ids(&env.frame) != Some((round as u32, ci as u32)) {
                // A stray: some earlier exchange resent after its first
                // attempt was held, and the injector has now released the
                // duplicate. Discard without resending — this exchange's
                // own frame is still in flight. (Also the no-double-
                // aggregation guarantee: a stale duplicate can never
                // reach decode, so it can never become a second Update.)
                self.emit(RunEvent::FaultRetry {
                    round,
                    client: ci,
                    wasted_bytes: env.frame.len() as u64,
                });
                continue;
            }
            let anchor = if with_anchor { self.down_anchor[ci].as_ref() } else { None };
            match wire::decode_frame(spec, &env.frame, anchor) {
                Ok((decoded, is_delta)) => return Ok((receipt, decoded, is_delta)),
                Err(e) => {
                    if attempts >= max_attempts {
                        return Err(e);
                    }
                    self.emit(RunEvent::FaultRetry {
                        round,
                        client: ci,
                        wasted_bytes: env.frame.len() as u64,
                    });
                    receipt = self.transport.send(Envelope { from, to, frame: frame.clone() })?;
                    attempts += 1;
                }
            }
        }
    }

    /// Post-SetSkel skeleton re-selection for one client (§3.1: top-k by
    /// the configured channel metric at the client's bucket size).
    fn reselect_skeleton(&mut self, ci: usize) -> Result<()> {
        let spec = self.backend.spec().clone();
        let bucket = self.clients[ci].bucket;
        let ks = spec.train_artifact(bucket)?.k.clone();
        let means = self.clients[ci].importance.means();
        if self.clients[ci].importance.batches() == 0 {
            bail!("client {ci} has no accumulated importance");
        }
        let mut rng = self.rng.fork(ci as u64 ^ 0x5E1EC7);
        let scores = crate::skeleton::score_channels(
            self.cfg.selection_metric,
            &means,
            &self.clients[ci].local_params,
            &spec.prunable,
            &mut rng,
        )?;
        self.clients[ci].skeleton = select_skeleton(&scores, &ks)?;
        self.clients[ci].importance.reset();
        Ok(())
    }

    /// Thread budget of client `ci`'s simulated device: its profile's
    /// core count, capped by the host-wide `--threads` budget, running
    /// the configured kernel tier.
    fn client_parallelism(&self, ci: usize) -> Parallelism {
        Parallelism::new(self.fleet[ci].cores.min(self.cfg.threads.max(1)))
            .with_tier(self.cfg.kernel_tier)
    }

    /// Sample this round's participants. Clients whose previous update
    /// is still in flight on the scheduler's clock (async buffering) are
    /// unavailable; the policy may over-select from the rest
    /// (DeadlineDrop). With nothing in flight and no over-selection this
    /// is exactly the classic participation sampler, RNG call for RNG
    /// call.
    fn sample_participants(&mut self) -> Vec<usize> {
        let n = self.clients.len();
        let busy = self.sched.busy_clients();
        if busy.is_empty() {
            let target = ((n as f64) * self.cfg.participation).round().max(1.0) as usize;
            let k = self.sched.select_count(target, n);
            if k >= n {
                (0..n).collect()
            } else {
                self.rng.choose_k(n, k)
            }
        } else {
            let avail: Vec<usize> = (0..n).filter(|i| busy.binary_search(i).is_err()).collect();
            let na = avail.len();
            if na == 0 {
                return Vec::new();
            }
            let target = ((na as f64) * self.cfg.participation).round().max(1.0) as usize;
            let k = self.sched.select_count(target, na);
            if k >= na {
                avail
            } else {
                self.rng.choose_k(na, k).into_iter().map(|i| avail[i]).collect()
            }
        }
    }
}

/// Param ids whose names match any of the prefixes (LG-FedAvg global set).
pub fn lg_global_ids_of(params: &[crate::model::ParamSpec], prefixes: &[&str]) -> Vec<usize> {
    params
        .iter()
        .enumerate()
        .filter(|(_, p)| prefixes.iter().any(|pre| p.name.starts_with(pre)))
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::MockBackend;
    use crate::transport::TransportKind;

    fn cfg(method: Method) -> RunConfig {
        RunConfig {
            method,
            model: "toy".into(),
            num_clients: 4,
            shards_per_client: 2,
            dataset_size: 400,
            new_test_size: 64,
            rounds: 8,
            local_steps: 2,
            updateskel_per_setskel: 3,
            eval_every: 0,
            ..RunConfig::default()
        }
    }

    fn coord(method: Method) -> Coordinator<MockBackend> {
        Coordinator::new(cfg(method), MockBackend::toy()).unwrap()
    }

    #[test]
    fn phases_alternate_for_fedskel() {
        let c = coord(Method::FedSkel);
        let phases: Vec<Phase> = (0..8).map(|r| c.phase_of(r)).collect();
        assert_eq!(phases[0], Phase::SetSkel);
        assert_eq!(phases[1], Phase::UpdateSkel);
        assert_eq!(phases[3], Phase::UpdateSkel);
        assert_eq!(phases[4], Phase::SetSkel);
        let c = coord(Method::FedAvg);
        assert!(c.clients.iter().all(|cl| cl.bucket == 100));
        assert_eq!(c.phase_of(0), Phase::Full);
    }

    #[test]
    fn fedskel_buckets_follow_ratios() {
        let c = coord(Method::FedSkel);
        // equidistant ratios 0.1..1.0 over 4 clients → buckets 25/50/100-ish
        let buckets: Vec<usize> = c.clients.iter().map(|cl| cl.bucket).collect();
        assert!(buckets.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(*buckets.last().unwrap(), 100);
        assert!(buckets[0] < 100);
    }

    #[test]
    fn setskel_then_updateskel_trains_selected_skeleton() {
        let mut c = coord(Method::FedSkel);
        c.step_round().unwrap(); // SetSkel
        // mock importance is increasing in channel id → top-k must be the
        // highest channels
        for cl in &c.clients {
            let k = cl.skeleton[0].len();
            let expect: Vec<i32> = ((4 - k) as i32..4).collect();
            assert_eq!(cl.skeleton[0], expect, "client {} bucket {}", cl.id, cl.bucket);
        }
        c.step_round().unwrap(); // UpdateSkel
        let b = &c.backend;
        // last 4 recorded trainings used each client's bucket + skeleton
        let recent = &b.trained_skeletons[b.trained_skeletons.len() - 8..];
        for (bucket, skel) in recent {
            let k = c.backend.spec().train_artifact(*bucket).unwrap().k[0];
            assert_eq!(skel[0].len(), k);
        }
    }

    #[test]
    fn fedskel_communicates_less_than_fedavg() {
        let mut avg = coord(Method::FedAvg);
        avg.run().unwrap();
        let mut skel = coord(Method::FedSkel);
        skel.run().unwrap();
        assert!(
            skel.ledger.total_params() < avg.ledger.total_params(),
            "fedskel {} !< fedavg {}",
            skel.ledger.total_params(),
            avg.ledger.total_params()
        );
        // the measured wire bytes agree with the logical accounting
        assert!(
            skel.ledger.total_wire_bytes() < avg.ledger.total_wire_bytes(),
            "fedskel wire {} !< fedavg wire {}",
            skel.ledger.total_wire_bytes(),
            avg.ledger.total_wire_bytes()
        );
    }

    #[test]
    fn wire_bytes_measured_every_round() {
        let mut c = coord(Method::FedSkel);
        c.run().unwrap();
        assert!(c.log.rounds.iter().all(|r| r.comm_wire_bytes > 0));
        assert_eq!(c.log.total_comm_wire_bytes(), c.ledger.total_wire_bytes());
        // at f32, wire bytes exceed the 4-bytes-per-param floor only by
        // frame + index overhead. The toy model is tiny (51 params) so the
        // relative overhead is large; bound it loosely — at LeNet scale it
        // is well under 1%.
        let nominal = c.ledger.total_params() * 4;
        let wire = c.ledger.total_wire_bytes();
        assert!(wire > nominal);
        assert!((wire as f64) < nominal as f64 * 1.5, "overhead too large: {wire} vs {nominal}");
    }

    #[test]
    fn lg_fedavg_only_moves_head() {
        let mut c = coord(Method::LgFedAvg);
        let head_before = c.global[0].clone(); // representation param
        c.run().unwrap();
        // representation tensors never aggregated server-side
        assert_eq!(c.global[0], head_before);
        // head was aggregated (mock adds +lr each step so it moves)
        assert!(c.global[2].max_abs() > 0.0);
        // comm strictly less than full
        let mut avg = coord(Method::FedAvg);
        avg.run().unwrap();
        assert!(c.ledger.total_params() < avg.ledger.total_params());
    }

    #[test]
    fn fedmtl_clients_keep_personal_models() {
        let mut c = coord(Method::FedMtl);
        c.step_round().unwrap();
        let locals_after_r1: Vec<_> = c.clients.iter().map(|cl| cl.local_params[0].clone()).collect();
        c.step_round().unwrap();
        // no download: local params evolve from their own previous values
        for (cl, before) in c.clients.iter().zip(&locals_after_r1) {
            let moved = cl.local_params[0].sub(before).unwrap().max_abs();
            assert!(moved > 0.0);
        }
    }

    #[test]
    fn run_produces_log_and_final_eval() {
        let mut c = coord(Method::FedSkel);
        c.run().unwrap();
        assert_eq!(c.log.rounds.len(), 8);
        assert!(c.log.last_new_acc().is_some());
        assert!(c.log.last_local_acc().is_some());
        assert!(c.log.rounds.iter().all(|r| r.sim_round_secs > 0.0));
    }

    #[test]
    fn sync_round_log_exposes_straggler_distribution() {
        let mut c = coord(Method::FedAvg);
        c.run().unwrap();
        for r in &c.log.rounds {
            // every participant's virtual seconds are logged...
            assert_eq!(r.client_secs.len(), 4);
            assert!(r.client_secs.iter().all(|&(id, s)| id < 4 && s > 0.0));
            // ...and the barrier round lasts exactly as long as the
            // slowest of them
            let max = r.client_secs.iter().map(|&(_, s)| s).fold(0.0f64, f64::max);
            assert!((max - r.sim_round_secs).abs() < 1e-9, "{max} vs {}", r.sim_round_secs);
            // the barrier never drops or defers
            assert_eq!(r.dropped, 0);
            assert_eq!(r.stale, 0);
        }
        // the slowest device (capability 1/8) dominates every round
        for r in &c.log.rounds {
            let slowest = r.client_secs.iter().max_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
            assert_eq!(slowest, 0, "round {}", r.round);
        }
    }

    #[test]
    fn participation_sampling() {
        let mut cfg = cfg(Method::FedAvg);
        cfg.participation = 0.5;
        let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
        let p = c.sample_participants();
        assert_eq!(p.len(), 2);
        assert!(p.iter().all(|&i| i < 4));
    }

    #[test]
    fn dropout_shrinks_participation_but_run_survives() {
        let mut cfg = cfg(Method::FedSkel);
        cfg.dropout = 0.6;
        cfg.rounds = 10;
        let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
        c.run().unwrap();
        // rounds completed despite random client losses
        assert_eq!(c.log.rounds.len(), 10);
        // strictly fewer train calls than the no-dropout schedule
        assert!(c.backend.calls < 10 * 4 * 2);
        // a client that dropped mid-round had already been shipped its
        // download frames — those are ledgered as wasted, not folded
        // into the useful byte counters
        assert!(c.ledger.wasted_wire_bytes > 0, "mid-round drops must waste download bytes");
        assert_eq!(
            c.log.total_comm_wire_bytes(),
            c.ledger.total_wire_bytes(),
            "per-round useful bytes exclude wasted frames"
        );
        // only trained clients appear in the straggler distribution
        for r in &c.log.rounds {
            assert!(r.client_secs.len() <= 4);
        }
    }

    #[test]
    fn partial_participation_updateskel_uses_identity_prefix_fallback() {
        let mut cfg = cfg(Method::FedSkel);
        cfg.participation = 0.5; // some clients miss the SetSkel round
        cfg.rounds = 4;
        let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
        c.run().unwrap(); // must not error on skeleton-size mismatch
        for (bucket, skel) in &c.backend.trained_skeletons {
            let k = c.backend.spec().train_artifact(*bucket).unwrap().k[0];
            assert_eq!(skel[0].len(), k);
            // distinct, in-range channels
            let mut v = skel[0].clone();
            v.dedup();
            assert_eq!(v.len(), k);
        }
    }

    #[test]
    fn selection_metric_least_flips_topk() {
        let mut cfg_a = cfg(Method::FedSkel);
        cfg_a.rounds = 1;
        let mut c = Coordinator::new(cfg_a, MockBackend::toy()).unwrap();
        c.step_round().unwrap(); // SetSkel with Activation
        let top: Vec<Vec<i32>> = c.clients.iter().map(|cl| cl.skeleton[0].clone()).collect();

        let mut cfg_b = cfg(Method::FedSkel);
        cfg_b.rounds = 1;
        cfg_b.selection_metric = crate::skeleton::SelectionMetric::LeastImportant;
        let mut c2 = Coordinator::new(cfg_b, MockBackend::toy()).unwrap();
        c2.step_round().unwrap();
        // mock importance is increasing in channel id: Activation picks the
        // top channels, LeastImportant the bottom ones.
        for (cl, t) in c2.clients.iter().zip(&top) {
            let k = cl.skeleton[0].len();
            let expect: Vec<i32> = (0..k as i32).collect();
            assert_eq!(cl.skeleton[0], expect);
            if k < 4 {
                assert_ne!(&cl.skeleton[0], t);
            }
        }
    }

    #[test]
    fn lg_global_ids_match_prefixes() {
        let spec = crate::runtime::mock::toy_spec();
        let ids = lg_global_ids_of(&spec.params, &["head."]);
        assert_eq!(ids, vec![2, 3]);
    }

    // ------------------------------------------------ transport + pool

    #[test]
    fn loopback_pool_round_end_to_end() {
        // the acceptance path: a full run through the loopback transport
        // with clients training concurrently on the worker pool.
        let mut cfg = cfg(Method::FedSkel);
        cfg.transport = TransportKind::Loopback;
        let workers: Vec<MockBackend> = (0..3).map(|_| MockBackend::toy()).collect();
        let mut c = Coordinator::with_pool(cfg, MockBackend::toy(), workers).unwrap();
        assert_eq!(c.workers(), 3);
        assert_eq!(c.transport.name(), "loopback");
        c.run().unwrap();
        assert_eq!(c.log.rounds.len(), 8);
        assert!(c.log.last_new_acc().is_some());
        assert!(c.ledger.total_wire_bytes() > 0);
        // no messages stranded in the transport
        assert_eq!(c.transport.pending(Peer::Server), 0);
    }

    #[test]
    fn inline_constructor_rejects_workers_flag() {
        // cfg.workers is consumed by with_pool; new() must refuse it
        // loudly instead of silently training inline.
        let mut cfg = cfg(Method::FedAvg);
        cfg.workers = 4;
        let err = Coordinator::new(cfg.clone(), MockBackend::toy()).unwrap_err();
        assert!(format!("{err:#}").contains("with_pool"), "{err:#}");
        // with_pool accepts the same config and reports the real pool size
        let c = Coordinator::with_pool(cfg, MockBackend::toy(), vec![MockBackend::toy()]).unwrap();
        assert_eq!(c.workers(), 1);
        assert_eq!(c.cfg.workers, 1);
    }

    #[test]
    fn pool_and_inline_runs_agree_bitwise() {
        // the pool changes scheduling, never semantics: global params
        // after a run must be identical to the sequential path.
        for method in [Method::FedSkel, Method::FedAvg, Method::LgFedAvg, Method::FedMtl] {
            let mut inline = Coordinator::new(cfg(method), MockBackend::toy()).unwrap();
            inline.run().unwrap();
            let workers: Vec<MockBackend> = (0..2).map(|_| MockBackend::toy()).collect();
            let mut pooled =
                Coordinator::with_pool(cfg(method), MockBackend::toy(), workers).unwrap();
            pooled.run().unwrap();
            assert_eq!(inline.global, pooled.global, "{method:?}");
            assert_eq!(
                inline.ledger.total_wire_bytes(),
                pooled.ledger.total_wire_bytes(),
                "{method:?}"
            );
        }
    }

    #[test]
    fn simnet_rounds_charge_link_time() {
        // default transport is the simulated network: comm seconds come
        // from measured frame bytes over each client's 100 Mbit/s link.
        let mut c = coord(Method::FedAvg);
        assert_eq!(c.transport.name(), "simnet");
        c.step_round().unwrap();
        let log = &c.log.rounds[0];
        // the slowest client's round includes a nonzero comm component:
        // sim time strictly exceeds its pure-compute time
        let batch_s = c.backend.batch_time_secs(100).unwrap();
        let pure_compute = (0..4)
            .map(|i| batch_s * 2.0 / c.fleet[i].capability)
            .fold(0.0f64, f64::max);
        assert!(log.sim_round_secs > pure_compute);
    }

    #[test]
    fn uncompressed_f32_runs_report_ratio_one() {
        // with no compression and f32 quant, the encoder emits exactly
        // the dense-f32 frames the raw counter charges for
        let mut c = coord(Method::FedAvg);
        c.run().unwrap();
        assert_eq!(c.ledger.total_raw_bytes(), c.ledger.total_wire_bytes());
        assert!((c.ledger.compression_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn compressed_uploads_shrink_wire_bytes_and_report_ratio() {
        let mut plain_cfg = cfg(Method::FedSkel);
        plain_cfg.rounds = 4;
        let mut plain = Coordinator::new(plain_cfg, MockBackend::toy()).unwrap();
        plain.run().unwrap();

        let mut ccfg = cfg(Method::FedSkel);
        ccfg.rounds = 4;
        ccfg.compress = crate::compress::CompressKind::TopK;
        ccfg.topk_ratio = 0.25;
        ccfg.error_feedback = true;
        let mut comp = Coordinator::new(ccfg, MockBackend::toy()).unwrap();
        comp.run().unwrap();

        assert!(
            comp.ledger.upload_wire_bytes < plain.ledger.upload_wire_bytes,
            "top-k uploads must shrink: {} !< {}",
            comp.ledger.upload_wire_bytes,
            plain.ledger.upload_wire_bytes
        );
        // logical parameter accounting (Table 2) is compression-independent
        assert_eq!(comp.ledger.total_params(), plain.ledger.total_params());
        assert_eq!(comp.ledger.total_raw_bytes(), plain.ledger.total_raw_bytes());
        assert!(comp.ledger.compression_ratio() > 1.0);
        // error feedback left per-client residual state behind
        assert!(comp.clients.iter().any(|cl| !cl.ef_residual.is_empty()));
        // and the model stayed finite through sparse aggregation
        for t in &comp.global {
            assert!(t.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn delta_down_is_bitwise_lossless() {
        for method in [Method::FedSkel, Method::FedAvg, Method::FedMtl] {
            let mut plain = coord(method);
            plain.run().unwrap();
            let mut dcfg = cfg(method);
            dcfg.delta_down = true;
            let mut delta = Coordinator::new(dcfg, MockBackend::toy()).unwrap();
            delta.run().unwrap();
            // anchor-delta downloads reconstruct the identical model:
            // training results are bit-for-bit unchanged
            assert_eq!(plain.global, delta.global, "{method:?}");
            assert_eq!(plain.ledger.total_params(), delta.ledger.total_params());
            // anchors are tracked for every client after a Full round
            assert!(delta.down_anchor.iter().all(|a| a.is_some()), "{method:?}");
        }
    }

    #[test]
    fn quantized_wire_shrinks_bytes() {
        let mut cfg_f16 = cfg(Method::FedAvg);
        cfg_f16.quant = crate::transport::wire::Quant::F16;
        cfg_f16.rounds = 2;
        let mut a = Coordinator::new(cfg_f16, MockBackend::toy()).unwrap();
        a.run().unwrap();
        let mut cfg_f32 = cfg(Method::FedAvg);
        cfg_f32.rounds = 2;
        let mut b = Coordinator::new(cfg_f32, MockBackend::toy()).unwrap();
        b.run().unwrap();
        assert!(a.ledger.total_wire_bytes() < b.ledger.total_wire_bytes());
        // logical param accounting is quantization-independent
        assert_eq!(a.ledger.total_params(), b.ledger.total_params());
    }

    #[test]
    fn fault_injection_never_changes_the_trajectory() {
        // --fault only adds retransmissions (ledgered as waste): global
        // params, useful wire bytes, and logical param counts must be
        // bitwise those of the clean run on the same transport.
        for method in [Method::FedSkel, Method::FedAvg] {
            let mut clean_cfg = cfg(method);
            clean_cfg.transport = TransportKind::Loopback;
            let mut clean = Coordinator::new(clean_cfg, MockBackend::toy()).unwrap();
            clean.run().unwrap();

            let mut fcfg = cfg(method);
            fcfg.transport = TransportKind::Loopback;
            fcfg.fault = Some(
                crate::transport::fault::FaultPlan::parse(
                    "drop=0.1,delay=0.1,reorder=0.1,truncate=0.1,seed=11",
                )
                .unwrap(),
            );
            let mut faulty = Coordinator::new(fcfg, MockBackend::toy()).unwrap();
            assert_eq!(faulty.transport.name(), "fault");
            faulty.run().unwrap();

            assert_eq!(clean.global, faulty.global, "{method:?}");
            assert_eq!(
                clean.ledger.total_wire_bytes(),
                faulty.ledger.total_wire_bytes(),
                "{method:?}: useful bytes exclude retransmissions"
            );
            assert_eq!(clean.ledger.total_params(), faulty.ledger.total_params());
            assert!(
                faulty.ledger.wasted_wire_bytes > 0,
                "{method:?}: at these probabilities the seeded plan must waste bytes"
            );
            assert_eq!(clean.ledger.wasted_wire_bytes, 0, "{method:?}");
        }
    }
}
