//! The federated training loop — FedSkel's SetSkel/UpdateSkel state
//! machine plus the three baselines, over any [`Backend`].
//!
//! One [`Coordinator`] owns the server state (global params), the client
//! fleet, the data, and the ledgers. `run()` drives `cfg.rounds` rounds:
//!
//! * **FedSkel** (§3.2): rounds alternate — one *SetSkel* round (full
//!   exchange; clients accumulate the importance metric; afterwards each
//!   client re-selects its skeleton at its assigned ratio) followed by
//!   `updateskel_per_setskel` *UpdateSkel* rounds (skeleton-only train +
//!   exchange, partial aggregation).
//! * **FedAvg**: every round is a full round.
//! * **LG-FedAvg**: clients keep representation layers local; only the
//!   head tensors are exchanged/averaged.
//! * **FedMTL**: clients train personalized models with a proximal pull
//!   toward the server anchor (mu > 0); the anchor is FedAvg-maintained;
//!   clients never overwrite their local models from the server.

pub mod eval;

use anyhow::{bail, Result};

use crate::aggregate::{self, Update};
use crate::clients::ClientState;
use crate::comm::{CommLedger, ExchangeKind};
use crate::config::{Method, RatioAssignment, RunConfig};
use crate::data::shard::non_iid_shards;
use crate::data::synthetic::Dataset;
use crate::hetero::{equidistant_fleet, simulate_round, system_round_time, DeviceProfile};
use crate::metrics::{Mean, RoundLog, RunLog};
use crate::model::{init_params, Params};
use crate::runtime::step::Backend;
use crate::skeleton::{identity_skeleton, select_skeleton, RatioPolicy};
use crate::util::timer::Timer;
use crate::util::Rng;

/// Phase of a round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Full exchange + importance accumulation (FedSkel only).
    SetSkel,
    /// Skeleton-only train/exchange (FedSkel only).
    UpdateSkel,
    /// Baseline full round.
    Full,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::SetSkel => "setskel",
            Phase::UpdateSkel => "updateskel",
            Phase::Full => "full",
        }
    }
}

/// The federated server + simulated fleet.
pub struct Coordinator<B: Backend> {
    pub cfg: RunConfig,
    pub backend: B,
    pub global: Params,
    pub clients: Vec<ClientState>,
    pub data: Dataset,
    pub new_test: Dataset,
    pub ledger: CommLedger,
    pub fleet: Vec<DeviceProfile>,
    pub log: RunLog,
    rng: Rng,
    /// param ids LG-FedAvg treats as global.
    lg_global_ids: Vec<usize>,
    round_idx: usize,
}

impl<B: Backend> Coordinator<B> {
    /// Build the full system: synthesize data, shard it non-IID, create
    /// clients with capabilities + ratios + buckets, init global params.
    pub fn new(cfg: RunConfig, backend: B) -> Result<Coordinator<B>> {
        cfg.validate()?;
        let spec = backend.spec().clone();
        let mut rng = Rng::new(cfg.seed);

        // ---- data
        let total = cfg.dataset_size + cfg.new_test_size;
        let full = Dataset::generate(cfg.dataset, total, cfg.seed ^ 0xD5);
        let data = full.subset(0, cfg.dataset_size);
        let new_test = full.subset(cfg.dataset_size, total);
        let splits = non_iid_shards(&data, cfg.num_clients, cfg.shards_per_client, 0.2, cfg.seed)?;

        // ---- capabilities & fleet (equidistant like the paper's Fig. 5)
        let fleet = equidistant_fleet(cfg.num_clients, 0.125, 1.0, 100.0);
        let capabilities: Vec<f64> = fleet.iter().map(|d| d.capability).collect();

        // ---- ratios
        let policy = match cfg.ratio_assignment {
            RatioAssignment::Linear => RatioPolicy::LinearCapability { min_ratio: 0.1 },
            RatioAssignment::Equidistant { lo, hi } => RatioPolicy::Equidistant { lo, hi },
            RatioAssignment::Fixed(r) => RatioPolicy::Fixed(r),
        };
        let ratios = policy.assign(&capabilities)?;

        // ---- clients
        let global = init_params(&spec, cfg.seed ^ 0x91);
        let prunable_channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
        let mut clients = Vec::with_capacity(cfg.num_clients);
        for (i, split) in splits.into_iter().enumerate() {
            let mut c = ClientState::new(
                i,
                split,
                capabilities[i],
                global.clone(),
                &prunable_channels,
                spec.train_batch,
                rng.fork(i as u64).next_u64(),
            );
            c.ratio = ratios[i];
            c.bucket = if cfg.method == Method::FedSkel {
                spec.quantize_ratio(ratios[i] * 100.0)?
            } else {
                spec.quantize_ratio(100.0)?
            };
            clients.push(c);
        }

        let cfg2 = cfg.lg_global_prefixes.clone();
        Ok(Coordinator {
            cfg,
            backend,
            global,
            clients,
            data,
            new_test,
            ledger: CommLedger::new(),
            fleet,
            log: RunLog::default(),
            rng,
            lg_global_ids: {
                let prefixes: Vec<&str> = cfg2.iter().map(|s| s.as_str()).collect();
                lg_global_ids_of(&spec.params, &prefixes)
            },
            round_idx: 0,
        })
    }

    /// Phase of round `r` under the configured method.
    pub fn phase_of(&self, r: usize) -> Phase {
        if self.cfg.method != Method::FedSkel {
            return Phase::Full;
        }
        if r % (1 + self.cfg.updateskel_per_setskel) == 0 {
            Phase::SetSkel
        } else {
            Phase::UpdateSkel
        }
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Result<()> {
        for _ in 0..self.cfg.rounds {
            self.step_round()?;
        }
        // final eval if the cadence missed the last round
        if self
            .log
            .rounds
            .last()
            .map(|r| r.new_acc.is_none())
            .unwrap_or(true)
        {
            let new_acc = self.evaluate_new()?;
            let local_acc = self.evaluate_local()?;
            if let Some(last) = self.log.rounds.last_mut() {
                last.new_acc = Some(new_acc);
                last.local_acc = Some(local_acc);
            }
        }
        Ok(())
    }

    /// Execute exactly one federated round.
    pub fn step_round(&mut self) -> Result<()> {
        let r = self.round_idx;
        let phase = self.phase_of(r);
        let wall = Timer::start();
        let method = self.cfg.method;

        // --- participant sampling + failure injection: dropped clients
        // contribute nothing this round (the aggregators tolerate any
        // subset, including the empty one).
        let mut participants = self.sample_participants();
        if self.cfg.dropout > 0.0 {
            let p = self.cfg.dropout;
            participants.retain(|_| self.rng.uniform() as f64 >= p);
        }

        // --- local training
        let mut updates: Vec<Update> = Vec::with_capacity(participants.len());
        let mut loss_mean = Mean::default();
        let mut round_times = Vec::with_capacity(participants.len());
        let comm_before = self.ledger.total_params();

        for &ci in &participants {
            let (update, loss, bucket, exchanged) = self.client_round(ci, phase)?;
            loss_mean.add(loss as f64);
            updates.push(update);

            // simulated heterogeneous wall-clock for this client's round
            let batch_s = self.backend.batch_time_secs(bucket)?;
            let profile = &self.fleet[ci];
            round_times.push(simulate_round(profile, batch_s, self.cfg.local_steps, exchanged));
        }

        // --- aggregation
        let spec = self.backend.spec().clone();
        self.global = match (method, phase) {
            (Method::FedAvg, _) | (Method::FedMtl, _) | (Method::FedSkel, Phase::SetSkel) => {
                aggregate::fedavg(&self.global, &updates)?
            }
            (Method::FedSkel, _) => {
                aggregate::fedskel_aggregate(&self.global, &updates, &spec.prunable)?
            }
            (Method::LgFedAvg, _) => {
                aggregate::lg_fedavg_aggregate(&self.global, &updates, &self.lg_global_ids)?
            }
        };

        // --- after a SetSkel round, clients re-select skeletons
        if method == Method::FedSkel && phase == Phase::SetSkel {
            for &ci in &participants {
                self.reselect_skeleton(ci)?;
            }
        }

        self.ledger.end_round();
        self.round_idx += 1;

        // --- eval cadence
        let do_eval = self.cfg.eval_every > 0 && (r + 1) % self.cfg.eval_every == 0;
        let (new_acc, local_acc) = if do_eval {
            (Some(self.evaluate_new()?), Some(self.evaluate_local()?))
        } else {
            (None, None)
        };

        self.log.push(RoundLog {
            round: r,
            phase: phase.name().into(),
            mean_loss: loss_mean.get(),
            new_acc,
            local_acc,
            comm_params: self.ledger.total_params() - comm_before,
            sim_round_secs: system_round_time(&round_times),
            wall_secs: wall.elapsed_secs(),
        });
        Ok(())
    }

    /// One client's full round: download → local steps → produce update.
    /// Returns (update, mean loss, bucket used, params exchanged).
    fn client_round(&mut self, ci: usize, phase: Phase) -> Result<(Update, f32, usize, usize)> {
        let method = self.cfg.method;
        let spec = self.backend.spec().clone();

        // ---- download
        // FedMTL still *downloads* the anchor every round (the prox term
        // needs it) but never adopts it into the personal model.
        let down_kind = match (method, phase) {
            (Method::FedMtl, _) => ExchangeKind::Full,
            (Method::LgFedAvg, _) => ExchangeKind::ParamSubset(self.lg_global_ids.clone()),
            (Method::FedSkel, Phase::UpdateSkel) => {
                ExchangeKind::Skeleton(self.clients[ci].skeleton.iter().map(|s| s.len()).collect())
            }
            _ => ExchangeKind::Full,
        };
        {
            let c = &mut self.clients[ci];
            match &down_kind {
                ExchangeKind::Full if method == Method::FedMtl => {} // anchor only
                ExchangeKind::Full => {
                    aggregate::apply_download(&mut c.local_params, &self.global, &spec.prunable, &[], None)?
                }
                ExchangeKind::Skeleton(_) => aggregate::apply_download(
                    &mut c.local_params,
                    &self.global,
                    &spec.prunable,
                    &c.skeleton.clone(),
                    None,
                )?,
                ExchangeKind::ParamSubset(ids) => aggregate::apply_download(
                    &mut c.local_params,
                    &self.global,
                    &spec.prunable,
                    &[],
                    Some(ids),
                )?,
                ExchangeKind::None => {}
            }
        }

        // ---- local training
        let (bucket, skeleton) = match (method, phase) {
            (Method::FedSkel, Phase::UpdateSkel) => {
                let bucket = self.clients[ci].bucket;
                let ks = spec.train_artifact(bucket)?.k.clone();
                let mut skel = self.clients[ci].skeleton.clone();
                // A client sampled into UpdateSkel before its first SetSkel
                // (participation < 1 or dropout) still carries the identity
                // skeleton — truncate to the bucket's k_l channels until a
                // SetSkel round gives it importance-ranked ones.
                for (s, &k) in skel.iter_mut().zip(&ks) {
                    if s.len() != k {
                        *s = (0..k as i32).collect(); // identity prefix
                    }
                }
                (bucket, skel)
            }
            _ => {
                let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
                (spec.quantize_ratio(100.0)?, identity_skeleton(&channels))
            }
        };
        let mu = if method == Method::FedMtl { self.cfg.mu.max(0.01) } else { 0.0 };

        let b = spec.train_batch;
        let numel: usize = spec.input_shape.iter().product();
        let mut x = vec![0.0f32; b * numel];
        let mut y = vec![0i32; b];
        let mut loss_mean = Mean::default();
        let accumulate_importance = method == Method::FedSkel && phase == Phase::SetSkel;

        let mut local = self.clients[ci].local_params.clone();
        for _ in 0..self.cfg.local_steps {
            self.clients[ci].batcher.fill_batch(&self.data, &mut x, &mut y);
            let out = self.backend.train_step(
                bucket,
                &local,
                &self.global,
                &x,
                &y,
                &skeleton,
                self.cfg.lr,
                mu,
            )?;
            local = out.params;
            loss_mean.add(out.loss as f64);
            if accumulate_importance {
                let refs: Vec<&[f32]> = out.importance.iter().map(|v| v.as_slice()).collect();
                self.clients[ci].importance.accumulate(&refs)?;
            }
        }
        let loss = loss_mean.get() as f32;
        self.clients[ci].last_loss = loss;
        self.clients[ci].local_params = local.clone();

        // ---- upload
        let up_kind = match (method, phase) {
            (Method::LgFedAvg, _) => ExchangeKind::ParamSubset(self.lg_global_ids.clone()),
            (Method::FedSkel, Phase::UpdateSkel) => {
                ExchangeKind::Skeleton(skeleton.iter().map(|s| s.len()).collect())
            }
            _ => ExchangeKind::Full,
        };
        let exchanged = crate::comm::params_moved(&spec, &up_kind)
            + crate::comm::params_moved(&spec, &down_kind);
        self.ledger.record(&spec, &up_kind, &down_kind);

        let update = Update {
            client: ci,
            weight: self.clients[ci].weight(),
            params: local,
            skeleton: if method == Method::FedSkel && phase == Phase::UpdateSkel {
                skeleton
            } else if method == Method::FedSkel {
                // SetSkel rounds aggregate fully; identity skeleton recorded
                let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
                identity_skeleton(&channels)
            } else {
                vec![]
            },
        };
        Ok((update, loss, bucket, exchanged))
    }

    /// Post-SetSkel skeleton re-selection for one client (§3.1: top-k by
    /// the configured channel metric at the client's bucket size).
    fn reselect_skeleton(&mut self, ci: usize) -> Result<()> {
        let spec = self.backend.spec().clone();
        let bucket = self.clients[ci].bucket;
        let ks = spec.train_artifact(bucket)?.k.clone();
        let means = self.clients[ci].importance.means();
        if self.clients[ci].importance.batches() == 0 {
            bail!("client {ci} has no accumulated importance");
        }
        let mut rng = self.rng.fork(ci as u64 ^ 0x5E1EC7);
        let scores = crate::skeleton::score_channels(
            self.cfg.selection_metric,
            &means,
            &self.clients[ci].local_params,
            &spec.prunable,
            &mut rng,
        )?;
        self.clients[ci].skeleton = select_skeleton(&scores, &ks)?;
        self.clients[ci].importance.reset();
        Ok(())
    }

    fn sample_participants(&mut self) -> Vec<usize> {
        let n = self.clients.len();
        let k = ((n as f64) * self.cfg.participation).round().max(1.0) as usize;
        if k >= n {
            (0..n).collect()
        } else {
            self.rng.choose_k(n, k)
        }
    }
}

/// Param ids whose names match any of the prefixes (LG-FedAvg global set).
pub fn lg_global_ids_of(params: &[crate::model::ParamSpec], prefixes: &[&str]) -> Vec<usize> {
    params
        .iter()
        .enumerate()
        .filter(|(_, p)| prefixes.iter().any(|pre| p.name.starts_with(pre)))
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::MockBackend;

    fn cfg(method: Method) -> RunConfig {
        RunConfig {
            method,
            model: "toy".into(),
            num_clients: 4,
            shards_per_client: 2,
            dataset_size: 400,
            new_test_size: 64,
            rounds: 8,
            local_steps: 2,
            updateskel_per_setskel: 3,
            eval_every: 0,
            ..RunConfig::default()
        }
    }

    fn coord(method: Method) -> Coordinator<MockBackend> {
        Coordinator::new(cfg(method), MockBackend::toy()).unwrap()
    }

    #[test]
    fn phases_alternate_for_fedskel() {
        let c = coord(Method::FedSkel);
        let phases: Vec<Phase> = (0..8).map(|r| c.phase_of(r)).collect();
        assert_eq!(phases[0], Phase::SetSkel);
        assert_eq!(phases[1], Phase::UpdateSkel);
        assert_eq!(phases[3], Phase::UpdateSkel);
        assert_eq!(phases[4], Phase::SetSkel);
        let c = coord(Method::FedAvg);
        assert!(c.clients.iter().all(|cl| cl.bucket == 100));
        assert_eq!(c.phase_of(0), Phase::Full);
    }

    #[test]
    fn fedskel_buckets_follow_ratios() {
        let c = coord(Method::FedSkel);
        // equidistant ratios 0.1..1.0 over 4 clients → buckets 25/50/100-ish
        let buckets: Vec<usize> = c.clients.iter().map(|cl| cl.bucket).collect();
        assert!(buckets.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(*buckets.last().unwrap(), 100);
        assert!(buckets[0] < 100);
    }

    #[test]
    fn setskel_then_updateskel_trains_selected_skeleton() {
        let mut c = coord(Method::FedSkel);
        c.step_round().unwrap(); // SetSkel
        // mock importance is increasing in channel id → top-k must be the
        // highest channels
        for cl in &c.clients {
            let k = cl.skeleton[0].len();
            let expect: Vec<i32> = ((4 - k) as i32..4).collect();
            assert_eq!(cl.skeleton[0], expect, "client {} bucket {}", cl.id, cl.bucket);
        }
        c.step_round().unwrap(); // UpdateSkel
        let b = &c.backend;
        // last 4 recorded trainings used each client's bucket + skeleton
        let recent = &b.trained_skeletons[b.trained_skeletons.len() - 8..];
        for (bucket, skel) in recent {
            let k = c.backend.spec().train_artifact(*bucket).unwrap().k[0];
            assert_eq!(skel[0].len(), k);
        }
    }

    #[test]
    fn fedskel_communicates_less_than_fedavg() {
        let mut avg = coord(Method::FedAvg);
        avg.run().unwrap();
        let mut skel = coord(Method::FedSkel);
        skel.run().unwrap();
        assert!(
            skel.ledger.total_params() < avg.ledger.total_params(),
            "fedskel {} !< fedavg {}",
            skel.ledger.total_params(),
            avg.ledger.total_params()
        );
    }

    #[test]
    fn lg_fedavg_only_moves_head() {
        let mut c = coord(Method::LgFedAvg);
        let head_before = c.global[0].clone(); // representation param
        c.run().unwrap();
        // representation tensors never aggregated server-side
        assert_eq!(c.global[0], head_before);
        // head was aggregated (mock adds +lr each step so it moves)
        assert!(c.global[2].max_abs() > 0.0);
        // comm strictly less than full
        let mut avg = coord(Method::FedAvg);
        avg.run().unwrap();
        assert!(c.ledger.total_params() < avg.ledger.total_params());
    }

    #[test]
    fn fedmtl_clients_keep_personal_models() {
        let mut c = coord(Method::FedMtl);
        c.step_round().unwrap();
        let locals_after_r1: Vec<_> = c.clients.iter().map(|cl| cl.local_params[0].clone()).collect();
        c.step_round().unwrap();
        // no download: local params evolve from their own previous values
        for (cl, before) in c.clients.iter().zip(&locals_after_r1) {
            let moved = cl.local_params[0].sub(before).unwrap().max_abs();
            assert!(moved > 0.0);
        }
    }

    #[test]
    fn run_produces_log_and_final_eval() {
        let mut c = coord(Method::FedSkel);
        c.run().unwrap();
        assert_eq!(c.log.rounds.len(), 8);
        assert!(c.log.last_new_acc().is_some());
        assert!(c.log.last_local_acc().is_some());
        assert!(c.log.rounds.iter().all(|r| r.sim_round_secs > 0.0));
    }

    #[test]
    fn participation_sampling() {
        let mut cfg = cfg(Method::FedAvg);
        cfg.participation = 0.5;
        let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
        let p = c.sample_participants();
        assert_eq!(p.len(), 2);
        assert!(p.iter().all(|&i| i < 4));
    }

    #[test]
    fn dropout_shrinks_participation_but_run_survives() {
        let mut cfg = cfg(Method::FedSkel);
        cfg.dropout = 0.6;
        cfg.rounds = 10;
        let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
        c.run().unwrap();
        // rounds completed despite random client losses
        assert_eq!(c.log.rounds.len(), 10);
        // strictly fewer train calls than the no-dropout schedule
        assert!(c.backend.calls < 10 * 4 * 2);
    }

    #[test]
    fn partial_participation_updateskel_uses_identity_prefix_fallback() {
        let mut cfg = cfg(Method::FedSkel);
        cfg.participation = 0.5; // some clients miss the SetSkel round
        cfg.rounds = 4;
        let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
        c.run().unwrap(); // must not error on skeleton-size mismatch
        for (bucket, skel) in &c.backend.trained_skeletons {
            let k = c.backend.spec().train_artifact(*bucket).unwrap().k[0];
            assert_eq!(skel[0].len(), k);
            // distinct, in-range channels
            let mut v = skel[0].clone();
            v.dedup();
            assert_eq!(v.len(), k);
        }
    }

    #[test]
    fn selection_metric_least_flips_topk() {
        let mut cfg_a = cfg(Method::FedSkel);
        cfg_a.rounds = 1;
        let mut c = Coordinator::new(cfg_a, MockBackend::toy()).unwrap();
        c.step_round().unwrap(); // SetSkel with Activation
        let top: Vec<Vec<i32>> = c.clients.iter().map(|cl| cl.skeleton[0].clone()).collect();

        let mut cfg_b = cfg(Method::FedSkel);
        cfg_b.rounds = 1;
        cfg_b.selection_metric = crate::skeleton::SelectionMetric::LeastImportant;
        let mut c2 = Coordinator::new(cfg_b, MockBackend::toy()).unwrap();
        c2.step_round().unwrap();
        // mock importance is increasing in channel id: Activation picks the
        // top channels, LeastImportant the bottom ones.
        for (cl, t) in c2.clients.iter().zip(&top) {
            let k = cl.skeleton[0].len();
            let expect: Vec<i32> = (0..k as i32).collect();
            assert_eq!(cl.skeleton[0], expect);
            if k < 4 {
                assert_ne!(&cl.skeleton[0], t);
            }
        }
    }

    #[test]
    fn lg_global_ids_match_prefixes() {
        let spec = crate::runtime::mock::toy_spec();
        let ids = lg_global_ids_of(&spec.params, &["head."]);
        assert_eq!(ids, vec![2, 3]);
    }
}
