//! Run configuration: defaults, JSON config files, CLI overrides.
//!
//! A [`RunConfig`] fully determines a federated training run (with the
//! artifact manifest). Configs load from a JSON file (`--config run.json`)
//! and/or CLI flags; flags win.
//!
//! Paper: encodes the Table 3/4 experiment grid (method, non-IID shards,
//! UpdateSkel cadence, ratio assignment) plus the systems knobs
//! (`workers` = concurrent clients, `threads` = per-client core budget)
//! behind Fig. 5. Invariant: [`RunConfig::validate`] runs after every
//! override source, so an invalid run can never start.

use anyhow::{bail, Result};

use crate::data::DatasetKind;
use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// The four methods of the paper's evaluation (Tables 2–4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    FedAvg,
    FedSkel,
    LgFedAvg,
    FedMtl,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fedavg" => Method::FedAvg,
            "fedskel" => Method::FedSkel,
            "lgfedavg" | "lg-fedavg" | "lg_fedavg" => Method::LgFedAvg,
            "fedmtl" => Method::FedMtl,
            _ => bail!("unknown method '{s}' (fedavg|fedskel|lgfedavg|fedmtl)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::FedAvg => "fedavg",
            Method::FedSkel => "fedskel",
            Method::LgFedAvg => "lgfedavg",
            Method::FedMtl => "fedmtl",
        }
    }
}

/// How client skeleton ratios are assigned (mirrors skeleton::RatioPolicy
/// plus the string form used in configs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RatioAssignment {
    /// r_i = c_i / c_max (paper §3.2).
    Linear,
    /// equidistant in [lo, hi] by client id (paper Tables 3–4 setting).
    Equidistant { lo: f64, hi: f64 },
    /// same fixed r for everyone.
    Fixed(f64),
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub method: Method,
    pub dataset: DatasetKind,
    /// manifest model name, e.g. "lenet_smnist".
    pub model: String,
    pub num_clients: usize,
    pub shards_per_client: usize,
    /// total dataset size to synthesize (train+local-test pool).
    pub dataset_size: usize,
    /// extra IID samples for the New Test.
    pub new_test_size: usize,
    pub rounds: usize,
    /// local SGD batches per client per round.
    pub local_steps: usize,
    /// 1 SetSkel : N UpdateSkel (paper: 3–5).
    pub updateskel_per_setskel: usize,
    pub lr: f32,
    /// FedProx/FedMTL proximal coefficient.
    pub mu: f32,
    pub ratio_assignment: RatioAssignment,
    /// fraction of clients participating per round.
    pub participation: f64,
    /// probability a sampled client drops mid-round (failure injection).
    pub dropout: f64,
    /// skeleton-selection metric (paper Eq. 2 = Activation; others are
    /// the §5-future-work alternatives benchmarked by examples/ablation).
    pub selection_metric: crate::skeleton::SelectionMetric,
    pub seed: u64,
    /// evaluate every k rounds (0 = only at end).
    pub eval_every: usize,
    pub artifacts_dir: String,
    /// LG-FedAvg: parameter names treated as global (averaged) — matched
    /// by prefix against manifest param names. Default: the fc head.
    pub lg_global_prefixes: Vec<String>,
    /// Which transport moves round payloads (loopback|simnet).
    pub transport: crate::transport::TransportKind,
    /// Wire-codec value quantization (f32|f16|int8).
    pub quant: crate::transport::wire::Quant,
    /// Upload update compression ([`crate::compress`]):
    /// identity|f16|int8|topk. Identity is byte-for-byte the
    /// pre-compression wire path; the others ship error-feedback-aware
    /// update deltas vs the round anchor.
    pub compress: crate::compress::CompressKind,
    /// TopK compressor: fraction of each value block's entries kept,
    /// in (0, 1].
    pub topk_ratio: f64,
    /// Accumulate each client's compression error and fold it into its
    /// next round's update before compressing (no effect under
    /// `identity`).
    pub error_feedback: bool,
    /// Delta-encode full server→client downloads against each client's
    /// recorded anchor: bitwise-unchanged parameters (and elements) cost
    /// ~0 wire bytes. Lossless under f32/f16 wire quant (elementwise
    /// codecs — training results are bit-identical with or without it);
    /// the int8 combination is rejected by [`RunConfig::validate`]
    /// because int8's per-block scale would depend on which elements
    /// ship.
    pub delta_down: bool,
    /// Round-scheduling policy driving the virtual clock
    /// ([`crate::sched`]): sync barrier, deadline-drop, or FedBuff-style
    /// async buffering.
    pub sched: crate::sched::SchedKind,
    /// DeadlineDrop: per-round deadline in simulated seconds; arrivals
    /// past it are discarded. `f64::INFINITY` (the default) never drops,
    /// which makes the policy identical to sync.
    pub deadline_secs: f64,
    /// AsyncBuffer: aggregate the first K arrivals per round. `0` (the
    /// default) means "all of this round's participants", which leaves
    /// nothing in flight.
    pub buffer_k: usize,
    /// AsyncBuffer: staleness-discount exponent — a stale update's
    /// weight is scaled by `(1 + staleness)^-alpha`
    /// ([`crate::sched::staleness_weight`]). `0` disables the discount.
    pub staleness_alpha: f64,
    /// Fleet capability skew: fastest/slowest device speed ratio of the
    /// equidistant fleet (paper Fig. 5 uses 8). The slowest device gets
    /// capability `1 / fleet_skew`; 1.0 = homogeneous fleet.
    pub fleet_skew: f64,
    /// Client worker threads (0 = train clients inline on the
    /// coordinator's backend). Non-zero values are consumed by
    /// `Coordinator::with_pool`; the plain constructor rejects them so
    /// the flag can never be silently ignored.
    pub workers: usize,
    /// Max compute threads a single client's kernels may use (native
    /// backend). Each client's actual budget is
    /// `min(threads, its DeviceProfile::cores)`; the fleet's core budgets
    /// scale with capability up to this value. 1 (the default) keeps
    /// every kernel serial. Orthogonal to `workers`: `workers` is how
    /// many clients train concurrently, `threads` is how many cores one
    /// client's training may occupy.
    pub threads: usize,
    /// Compute kernel tier for native backends (scalar|simd). Both
    /// tiers honor the bitwise determinism contract, so param digests
    /// are identical at either setting — `simd` is purely a speed knob.
    pub kernel_tier: crate::kernels::KernelTier,
    /// Client forward-pass precision (f32|int8). Under `int8`, the
    /// lower-capability half of the fleet runs quantized forward GEMMs
    /// ([`crate::hetero::assign_precision`]); server eval always stays
    /// f32.
    pub client_precision: crate::kernels::Precision,
    /// Record the run's event stream to this `trace.jsonl` path
    /// ([`crate::trace`]); `None` (the default) attaches no sink.
    pub trace: Option<String>,
    /// How much of the stream the trace file records: round (coarsest),
    /// client, or frame (everything — the only level `fedskel report`
    /// can rebuild the comm ledger from).
    pub trace_level: crate::trace::TraceLevel,
    /// Enable the [`crate::prof`] span profiler for the run and export a
    /// Chrome-trace JSON profile to this path when training finishes.
    /// `None` (the default) leaves profiling disabled. Pure observer: it
    /// only reads clocks, so param digests are bitwise identical either
    /// way — and like `trace`, it is excluded from the snapshot
    /// determinism key.
    pub profile: Option<String>,
    /// Write [`crate::snapshot`] checkpoints (`snap_round_N.fsnap`) into
    /// this directory; `None` (the default) never checkpoints.
    pub checkpoint_dir: Option<String>,
    /// Checkpoint cadence in rounds (`0` = never). Snapshot writes are
    /// pure reads of run state, so any cadence leaves the training
    /// trajectory — and the param digest — bit-for-bit unchanged.
    pub checkpoint_every: usize,
    /// Seeded fault injection over the transport
    /// ([`crate::transport::fault::FaultInjector`]):
    /// `drop=0.1,delay=0.05,reorder=0.05,truncate=0.01,seed=7`. `None`
    /// (the default) injects nothing. The coordinator's reliable-exchange
    /// loop retries through faults, so the trajectory — and the param
    /// digest — is bitwise identical with or without a plan; only the
    /// wasted-bytes ledger and `fault_retry` trace events differ. It is
    /// therefore excluded from the snapshot determinism key.
    pub fault: Option<crate::transport::fault::FaultPlan>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            method: Method::FedSkel,
            dataset: DatasetKind::Smnist,
            model: "lenet_smnist".into(),
            num_clients: 10,
            shards_per_client: 2,
            dataset_size: 2000,
            new_test_size: 512,
            rounds: 20,
            local_steps: 4,
            updateskel_per_setskel: 3,
            lr: 0.05,
            mu: 0.0,
            ratio_assignment: RatioAssignment::Equidistant { lo: 0.1, hi: 1.0 },
            participation: 1.0,
            dropout: 0.0,
            selection_metric: crate::skeleton::SelectionMetric::Activation,
            seed: 42,
            eval_every: 5,
            artifacts_dir: "artifacts".into(),
            // LG-FedAvg's standard CNN split: conv features are the local
            // representation; dense layers (incl. head) are global.
            lg_global_prefixes: vec!["fc1.".into(), "fc2.".into(), "fc3.".into(), "fc.".into(), "head.".into()],
            transport: crate::transport::TransportKind::SimNet,
            quant: crate::transport::wire::Quant::F32,
            compress: crate::compress::CompressKind::Identity,
            topk_ratio: 0.1,
            error_feedback: false,
            delta_down: false,
            sched: crate::sched::SchedKind::Sync,
            deadline_secs: f64::INFINITY,
            buffer_k: 0,
            staleness_alpha: 0.5,
            fleet_skew: 8.0,
            workers: 0,
            threads: 1,
            kernel_tier: crate::kernels::KernelTier::Scalar,
            client_precision: crate::kernels::Precision::F32,
            trace: None,
            trace_level: crate::trace::TraceLevel::Frame,
            profile: None,
            checkpoint_dir: None,
            checkpoint_every: 0,
            fault: None,
        }
    }
}

impl RunConfig {
    /// Apply CLI flag overrides (flags declared by `standard_flags`).
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(v) = a.get("method") {
            self.method = Method::parse(v)?;
        }
        if let Some(v) = a.get("dataset") {
            self.dataset = DatasetKind::parse(v)?;
            // keep model consistent unless explicitly overridden below
            self.model = self.dataset.lenet_model().to_string();
        }
        if let Some(v) = a.get("model") {
            self.model = v.to_string();
        }
        for (field, key) in [
            (&mut self.num_clients, "clients"),
            (&mut self.shards_per_client, "shards-per-client"),
            (&mut self.dataset_size, "dataset-size"),
            (&mut self.new_test_size, "new-test-size"),
            (&mut self.rounds, "rounds"),
            (&mut self.local_steps, "local-steps"),
            (&mut self.updateskel_per_setskel, "updateskel-per-setskel"),
            (&mut self.eval_every, "eval-every"),
        ] {
            if let Some(v) = a.get(key) {
                *field = v.parse()?;
            }
        }
        if let Some(v) = a.get("lr") {
            self.lr = v.parse()?;
        }
        if let Some(v) = a.get("mu") {
            self.mu = v.parse()?;
        }
        if let Some(v) = a.get("participation") {
            self.participation = v.parse()?;
        }
        if let Some(v) = a.get("dropout") {
            self.dropout = v.parse()?;
        }
        if let Some(v) = a.get("metric") {
            self.selection_metric = crate::skeleton::SelectionMetric::parse(v)?;
        }
        if let Some(v) = a.get("seed") {
            self.seed = v.parse()?;
        }
        if let Some(v) = a.get("artifacts") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = a.get("transport") {
            self.transport = crate::transport::TransportKind::parse(v)?;
        }
        if let Some(v) = a.get("quant") {
            self.quant = crate::transport::wire::Quant::parse(v)?;
        }
        if let Some(v) = a.get("compress") {
            self.compress = crate::compress::CompressKind::parse(v)?;
        }
        if let Some(v) = a.get("topk-ratio") {
            self.topk_ratio = v.parse()?;
        }
        if a.bool("error-feedback") {
            self.error_feedback = true;
        }
        if a.bool("delta-down") {
            self.delta_down = true;
        }
        if let Some(v) = a.get("sched") {
            self.sched = crate::sched::SchedKind::parse(v)?;
        }
        if let Some(v) = a.get("deadline-secs") {
            self.deadline_secs = v.parse()?;
        }
        if let Some(v) = a.get("buffer-k") {
            self.buffer_k = v.parse()?;
        }
        if let Some(v) = a.get("staleness-alpha") {
            self.staleness_alpha = v.parse()?;
        }
        if let Some(v) = a.get("fleet-skew") {
            self.fleet_skew = v.parse()?;
        }
        if let Some(v) = a.get("workers") {
            self.workers = v.parse()?;
        }
        if let Some(v) = a.get("threads") {
            self.threads = v.parse()?;
        }
        if let Some(v) = a.get("kernel-tier") {
            self.kernel_tier = crate::kernels::KernelTier::parse(v)?;
        }
        if let Some(v) = a.get("client-precision") {
            self.client_precision = crate::kernels::Precision::parse(v)?;
        }
        if let Some(v) = a.get("trace") {
            self.trace = Some(v.to_string());
        }
        if let Some(v) = a.get("trace-level") {
            self.trace_level = crate::trace::TraceLevel::parse(v)?;
        }
        if let Some(v) = a.get("profile") {
            self.profile = Some(v.to_string());
        }
        if let Some(v) = a.get("checkpoint-dir") {
            self.checkpoint_dir = Some(v.to_string());
        }
        if let Some(v) = a.get("checkpoint-every") {
            self.checkpoint_every = v.parse()?;
        }
        if let Some(v) = a.get("fault") {
            self.fault = Some(crate::transport::fault::FaultPlan::parse(v)?);
        }
        if let Some(v) = a.get("ratio") {
            self.ratio_assignment = match v {
                "linear" => RatioAssignment::Linear,
                "equidistant" => RatioAssignment::Equidistant { lo: 0.1, hi: 1.0 },
                other => {
                    let r: f64 = other
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--ratio wants linear|equidistant|<float>"))?;
                    RatioAssignment::Fixed(r)
                }
            };
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.num_clients == 0 || self.rounds == 0 || self.local_steps == 0 {
            bail!("clients, rounds, local_steps must be positive");
        }
        if !(0.0..=1.0).contains(&self.participation) || self.participation == 0.0 {
            bail!("participation must be in (0,1]");
        }
        if !(0.0..1.0).contains(&self.dropout) {
            bail!("dropout must be in [0,1)");
        }
        if self.updateskel_per_setskel == 0 {
            bail!("updateskel_per_setskel must be ≥ 1");
        }
        if self.threads == 0 {
            bail!("threads must be ≥ 1 (1 = serial kernels)");
        }
        if !(self.topk_ratio > 0.0 && self.topk_ratio <= 1.0) {
            bail!("topk_ratio must be in (0,1]");
        }
        if self.delta_down && self.quant == crate::transport::wire::Quant::Int8 {
            // f32/f16 are elementwise codecs, so a delta-down download
            // delivers bit-for-bit what a plain download would; int8's
            // per-block scale would depend on *which* elements ship,
            // breaking that parity — refuse rather than silently drift.
            bail!("delta_down requires --quant f32|f16 (int8's block scale is subset-dependent)");
        }
        if self.deadline_secs.is_nan() || self.deadline_secs <= 0.0 {
            bail!("deadline_secs must be > 0 (inf = never drop)");
        }
        if !self.staleness_alpha.is_finite() || self.staleness_alpha < 0.0 {
            bail!("staleness_alpha must be a finite value ≥ 0");
        }
        if !self.fleet_skew.is_finite() || self.fleet_skew < 1.0 {
            bail!("fleet_skew must be a finite value ≥ 1 (1 = homogeneous)");
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_none() {
            bail!("checkpoint_every > 0 needs --checkpoint-dir");
        }
        Ok(())
    }

    /// Load overrides from a JSON config file (same keys as CLI flags).
    pub fn apply_json_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let j = json::parse(&text)?;
        let obj = j.as_obj()?;
        for (k, v) in obj {
            match k.as_str() {
                "method" => self.method = Method::parse(v.as_str()?)?,
                "dataset" => {
                    self.dataset = DatasetKind::parse(v.as_str()?)?;
                    self.model = self.dataset.lenet_model().to_string();
                }
                "model" => self.model = v.as_str()?.to_string(),
                "clients" => self.num_clients = v.as_usize()?,
                "shards_per_client" => self.shards_per_client = v.as_usize()?,
                "dataset_size" => self.dataset_size = v.as_usize()?,
                "new_test_size" => self.new_test_size = v.as_usize()?,
                "rounds" => self.rounds = v.as_usize()?,
                "local_steps" => self.local_steps = v.as_usize()?,
                "updateskel_per_setskel" => self.updateskel_per_setskel = v.as_usize()?,
                "lr" => self.lr = v.as_f64()? as f32,
                "mu" => self.mu = v.as_f64()? as f32,
                "participation" => self.participation = v.as_f64()?,
                "seed" => self.seed = v.as_usize()? as u64,
                "eval_every" => self.eval_every = v.as_usize()?,
                "artifacts_dir" => self.artifacts_dir = v.as_str()?.to_string(),
                "transport" => self.transport = crate::transport::TransportKind::parse(v.as_str()?)?,
                "quant" => self.quant = crate::transport::wire::Quant::parse(v.as_str()?)?,
                "compress" => self.compress = crate::compress::CompressKind::parse(v.as_str()?)?,
                "topk_ratio" => self.topk_ratio = v.as_f64()?,
                "error_feedback" => self.error_feedback = v.as_bool()?,
                "delta_down" => self.delta_down = v.as_bool()?,
                "sched" => self.sched = crate::sched::SchedKind::parse(v.as_str()?)?,
                "deadline_secs" => self.deadline_secs = v.as_f64()?,
                "buffer_k" => self.buffer_k = v.as_usize()?,
                "staleness_alpha" => self.staleness_alpha = v.as_f64()?,
                "fleet_skew" => self.fleet_skew = v.as_f64()?,
                "workers" => self.workers = v.as_usize()?,
                "threads" => self.threads = v.as_usize()?,
                "kernel_tier" => {
                    self.kernel_tier = crate::kernels::KernelTier::parse(v.as_str()?)?
                }
                "client_precision" => {
                    self.client_precision = crate::kernels::Precision::parse(v.as_str()?)?
                }
                "trace" => self.trace = Some(v.as_str()?.to_string()),
                "trace_level" => {
                    self.trace_level = crate::trace::TraceLevel::parse(v.as_str()?)?
                }
                "profile" => self.profile = Some(v.as_str()?.to_string()),
                "checkpoint_dir" => self.checkpoint_dir = Some(v.as_str()?.to_string()),
                "checkpoint_every" => self.checkpoint_every = v.as_usize()?,
                "fault" => {
                    self.fault = Some(crate::transport::fault::FaultPlan::parse(v.as_str()?)?)
                }
                other => bail!("unknown config key '{other}'"),
            }
        }
        self.validate()
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("method", Json::str(self.method.name())),
            ("dataset", Json::str(self.dataset.name())),
            ("model", Json::str(self.model.clone())),
            ("clients", Json::num(self.num_clients as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("local_steps", Json::num(self.local_steps as f64)),
            ("updateskel_per_setskel", Json::num(self.updateskel_per_setskel as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("mu", Json::num(self.mu as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("compress", Json::str(self.compress.name())),
            ("topk_ratio", Json::num(self.topk_ratio)),
            ("error_feedback", Json::Bool(self.error_feedback)),
            ("delta_down", Json::Bool(self.delta_down)),
            ("sched", Json::str(self.sched.name())),
            ("buffer_k", Json::num(self.buffer_k as f64)),
            ("staleness_alpha", Json::num(self.staleness_alpha)),
            ("fleet_skew", Json::num(self.fleet_skew)),
            ("workers", Json::num(self.workers as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("kernel_tier", Json::str(self.kernel_tier.name())),
            ("client_precision", Json::str(self.client_precision.name())),
            ("trace_level", Json::str(self.trace_level.name())),
        ];
        // infinity has no JSON literal; the absence of the key means
        // "no deadline" (the default)
        if self.deadline_secs.is_finite() {
            fields.push(("deadline_secs", Json::num(self.deadline_secs)));
        }
        if let Some(t) = &self.trace {
            fields.push(("trace", Json::str(t.clone())));
        }
        if let Some(p) = &self.profile {
            fields.push(("profile", Json::str(p.clone())));
        }
        if let Some(d) = &self.checkpoint_dir {
            fields.push(("checkpoint_dir", Json::str(d.clone())));
            fields.push(("checkpoint_every", Json::num(self.checkpoint_every as f64)));
        }
        if let Some(f) = &self.fault {
            fields.push(("fault", Json::str(f.spec())));
        }
        Json::obj(fields)
    }
}

/// Declare the standard run flags on a [`crate::util::cli::Cli`].
pub fn standard_flags(cli: crate::util::cli::Cli) -> crate::util::cli::Cli {
    cli.flag("method", None, "fedavg|fedskel|lgfedavg|fedmtl")
        .flag("dataset", None, "smnist|sfemnist|scifar10|scifar100")
        .flag("model", None, "manifest model name (default: lenet for dataset)")
        .flag("clients", None, "number of clients")
        .flag("shards-per-client", None, "non-IID shards per client")
        .flag("dataset-size", None, "synthesized samples")
        .flag("new-test-size", None, "IID New-Test samples")
        .flag("rounds", None, "federated rounds")
        .flag("local-steps", None, "local batches per round")
        .flag("updateskel-per-setskel", None, "UpdateSkel rounds per SetSkel")
        .flag("lr", None, "learning rate")
        .flag("mu", None, "FedProx/FedMTL proximal coefficient")
        .flag("participation", None, "fraction of clients per round")
        .flag("dropout", None, "per-round client dropout probability")
        .flag("metric", None, "skeleton metric: activation|weightnorm|random|least")
        .flag("transport", None, "round-payload transport: loopback|simnet")
        .flag("quant", None, "wire quantization: f32|f16|int8")
        .flag("compress", None, "upload update compression: identity|f16|int8|topk")
        .flag("topk-ratio", None, "topk compressor: fraction of update values kept, (0,1]")
        .switch("error-feedback", "fold each client's compression error into its next update")
        .switch("delta-down", "delta-encode full downloads vs each client's anchor (lossless)")
        .flag("sched", None, "round scheduler: sync|deadline|async")
        .flag("deadline-secs", None, "deadline sched: round deadline in sim secs (inf = never)")
        .flag("buffer-k", None, "async sched: aggregate first K arrivals (0 = all)")
        .flag("staleness-alpha", None, "async sched: stale weight = (1+staleness)^-alpha")
        .flag("fleet-skew", None, "fleet capability skew max/min (default 8, 1 = homogeneous)")
        .flag("workers", None, "client worker threads (0 = inline)")
        .flag("threads", None, "max compute threads per client's kernels (1 = serial)")
        .flag("kernel-tier", None, "compute kernel tier: scalar|simd (digests identical)")
        .flag("client-precision", None, "client forward precision: f32|int8 (eval stays f32)")
        .flag("trace", None, "record the run's event stream to this trace.jsonl path")
        .flag("trace-level", None, "trace granularity: round|client|frame (default frame)")
        .flag("profile", None, "enable the span profiler; export a Chrome-trace JSON here")
        .flag("checkpoint-dir", None, "write snap_round_N.fsnap checkpoints into this directory")
        .flag("checkpoint-every", None, "checkpoint cadence in rounds (0 = never)")
        .flag("fault", None, "inject transport faults: drop=P,delay=P,reorder=P,truncate=P,seed=N")
        .switch("quiet", "suppress human progress lines; only tables/JSON/digests print")
        .flag("ratio", None, "linear|equidistant|<fixed float>")
        .flag("seed", None, "run seed")
        .flag("eval-every", None, "evaluate every k rounds")
        .flag("artifacts", None, "artifacts directory")
        .flag("config", None, "JSON config file")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Cli;

    fn parse(args: &[&str]) -> RunConfig {
        let cli = standard_flags(Cli::new("t", "t"));
        let a = cli.parse_from(args.iter().map(|s| s.to_string())).unwrap();
        let mut c = RunConfig::default();
        c.apply_args(&a).unwrap();
        c
    }

    #[test]
    fn defaults_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("FedSkel").unwrap(), Method::FedSkel);
        assert_eq!(Method::parse("lg-fedavg").unwrap(), Method::LgFedAvg);
        assert!(Method::parse("sgd").is_err());
    }

    #[test]
    fn cli_overrides() {
        let c = parse(&["--method", "fedavg", "--clients", "7", "--lr", "0.1", "--ratio", "0.4"]);
        assert_eq!(c.method, Method::FedAvg);
        assert_eq!(c.num_clients, 7);
        assert_eq!(c.lr, 0.1);
        assert_eq!(c.ratio_assignment, RatioAssignment::Fixed(0.4));
    }

    #[test]
    fn dataset_sets_model() {
        let c = parse(&["--dataset", "scifar10"]);
        assert_eq!(c.model, "lenet_scifar10");
        let c = parse(&["--dataset", "scifar10", "--model", "resnet18_scifar10"]);
        assert_eq!(c.model, "resnet18_scifar10");
    }

    #[test]
    fn transport_and_quant_flags() {
        let c = parse(&["--transport", "loopback", "--quant", "f16", "--workers", "4", "--threads", "8"]);
        assert_eq!(c.transport, crate::transport::TransportKind::Loopback);
        assert_eq!(c.quant, crate::transport::wire::Quant::F16);
        assert_eq!(c.workers, 4);
        assert_eq!(c.threads, 8);
        let d = RunConfig::default();
        assert_eq!(d.transport, crate::transport::TransportKind::SimNet);
        assert_eq!(d.quant, crate::transport::wire::Quant::F32);
        assert_eq!(d.workers, 0);
        assert_eq!(d.threads, 1);
    }

    #[test]
    fn compress_flags() {
        use crate::compress::CompressKind;
        let c = parse(&["--compress", "int8", "--topk-ratio", "0.25", "--error-feedback", "--delta-down"]);
        assert_eq!(c.compress, CompressKind::Int8);
        assert_eq!(c.topk_ratio, 0.25);
        assert!(c.error_feedback);
        assert!(c.delta_down);
        let d = RunConfig::default();
        assert_eq!(d.compress, CompressKind::Identity);
        assert_eq!(d.topk_ratio, 0.1);
        assert!(!d.error_feedback);
        assert!(!d.delta_down);
        // the parse error enumerates the valid modes, exactly like the
        // quant flag's does
        let err = format!("{:#}", CompressKind::parse("gzip").unwrap_err());
        assert!(err.contains("identity|f16|int8|topk"), "{err}");
        let err = format!("{:#}", crate::transport::wire::Quant::parse("f64").unwrap_err());
        assert!(err.contains("f32|f16|int8"), "{err}");
    }

    #[test]
    fn kernel_tier_and_precision_flags() {
        use crate::kernels::{KernelTier, Precision};
        let c = parse(&["--kernel-tier", "simd", "--client-precision", "int8"]);
        assert_eq!(c.kernel_tier, KernelTier::Simd);
        assert_eq!(c.client_precision, Precision::Int8);
        let d = RunConfig::default();
        assert_eq!(d.kernel_tier, KernelTier::Scalar);
        assert_eq!(d.client_precision, Precision::F32);
        // parse errors enumerate the valid choices, like the quant flag
        let err = format!("{:#}", KernelTier::parse("avx512").unwrap_err());
        assert!(err.contains("scalar|simd"), "{err}");
        let err = format!("{:#}", Precision::parse("f64").unwrap_err());
        assert!(err.contains("f32|int8"), "{err}");
        let s = c.to_json().to_string();
        assert!(s.contains("\"kernel_tier\":\"simd\""), "{s}");
        assert!(s.contains("\"client_precision\":\"int8\""), "{s}");
    }

    #[test]
    fn kernel_tier_json_keys() {
        let dir = std::env::temp_dir().join(format!("fedskel_tier_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"kernel_tier":"simd","client_precision":"int8"}"#).unwrap();
        let mut c = RunConfig::default();
        c.apply_json_file(p.to_str().unwrap()).unwrap();
        assert_eq!(c.kernel_tier, crate::kernels::KernelTier::Simd);
        assert_eq!(c.client_precision, crate::kernels::Precision::Int8);
    }

    #[test]
    fn topk_ratio_validation() {
        let mut c = RunConfig::default();
        c.topk_ratio = 0.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.topk_ratio = 1.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.topk_ratio = 1.0;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn compress_json_keys() {
        let dir = std::env::temp_dir().join(format!("fedskel_cmp_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"compress":"topk","topk_ratio":0.05,"error_feedback":true,"delta_down":true}"#,
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_json_file(p.to_str().unwrap()).unwrap();
        assert_eq!(c.compress, crate::compress::CompressKind::TopK);
        assert_eq!(c.topk_ratio, 0.05);
        assert!(c.error_feedback);
        assert!(c.delta_down);
        let s = c.to_json().to_string();
        assert!(s.contains("\"compress\":\"topk\""), "{s}");
        assert!(s.contains("\"error_feedback\":true"), "{s}");
    }

    #[test]
    fn sched_flags() {
        let c = parse(&["--sched", "deadline", "--deadline-secs", "2.5", "--buffer-k", "3"]);
        assert_eq!(c.sched, crate::sched::SchedKind::DeadlineDrop);
        assert_eq!(c.deadline_secs, 2.5);
        assert_eq!(c.buffer_k, 3);
        let c = parse(&["--staleness-alpha", "0.75", "--fleet-skew", "4"]);
        assert_eq!(c.staleness_alpha, 0.75);
        assert_eq!(c.fleet_skew, 4.0);
        // "inf" is a valid f64 literal for --deadline-secs
        let c = parse(&["--sched", "async", "--deadline-secs", "inf"]);
        assert_eq!(c.sched, crate::sched::SchedKind::AsyncBuffer);
        assert!(c.deadline_secs.is_infinite());
        let d = RunConfig::default();
        assert_eq!(d.sched, crate::sched::SchedKind::Sync);
        assert!(d.deadline_secs.is_infinite());
        assert_eq!(d.buffer_k, 0);
        assert_eq!(d.fleet_skew, 8.0);
    }

    #[test]
    fn sched_validation_rejects_bad_knobs() {
        let mut c = RunConfig::default();
        c.deadline_secs = 0.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.staleness_alpha = -0.1;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.staleness_alpha = f64::INFINITY;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.fleet_skew = 0.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn to_json_omits_infinite_deadline() {
        let mut c = RunConfig::default();
        let s = c.to_json().to_string();
        assert!(s.contains("\"sched\":\"sync\""), "{s}");
        assert!(!s.contains("deadline_secs"), "{s}");
        c.deadline_secs = 3.0;
        assert!(c.to_json().to_string().contains("\"deadline_secs\":3"));
    }

    #[test]
    fn checkpoint_flags_and_validation() {
        let c = parse(&["--checkpoint-dir", "ckpt", "--checkpoint-every", "2"]);
        assert_eq!(c.checkpoint_dir.as_deref(), Some("ckpt"));
        assert_eq!(c.checkpoint_every, 2);
        let d = RunConfig::default();
        assert_eq!(d.checkpoint_dir, None);
        assert_eq!(d.checkpoint_every, 0);
        // a cadence with nowhere to write is a config error
        let mut c = RunConfig::default();
        c.checkpoint_every = 1;
        assert!(c.validate().is_err());
        c.checkpoint_dir = Some("ckpt".into());
        assert!(c.validate().is_ok());
        // JSON keys round-trip and to_json only emits them when set
        let s = RunConfig::default().to_json().to_string();
        assert!(!s.contains("checkpoint_dir"), "{s}");
        let s = c.to_json().to_string();
        assert!(s.contains("\"checkpoint_dir\":\"ckpt\""), "{s}");
        assert!(s.contains("\"checkpoint_every\":1"), "{s}");
        let dir = std::env::temp_dir().join(format!("fedskel_ckpt_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"checkpoint_dir":"snaps","checkpoint_every":3}"#).unwrap();
        let mut c = RunConfig::default();
        c.apply_json_file(p.to_str().unwrap()).unwrap();
        assert_eq!(c.checkpoint_dir.as_deref(), Some("snaps"));
        assert_eq!(c.checkpoint_every, 3);
    }

    #[test]
    fn profile_flag_and_json_key() {
        let c = parse(&["--profile", "prof.json"]);
        assert_eq!(c.profile.as_deref(), Some("prof.json"));
        assert_eq!(RunConfig::default().profile, None);
        // to_json only emits the key when set
        let s = RunConfig::default().to_json().to_string();
        assert!(!s.contains("profile"), "{s}");
        let s = c.to_json().to_string();
        assert!(s.contains("\"profile\":\"prof.json\""), "{s}");
        let dir = std::env::temp_dir().join(format!("fedskel_prof_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"profile":"out.json"}"#).unwrap();
        let mut c = RunConfig::default();
        c.apply_json_file(p.to_str().unwrap()).unwrap();
        assert_eq!(c.profile.as_deref(), Some("out.json"));
    }

    #[test]
    fn fault_flag_and_json_key() {
        let c = parse(&["--fault", "drop=0.1,seed=9"]);
        let plan = c.fault.clone().unwrap();
        assert_eq!(plan.drop, 0.1);
        assert_eq!(plan.seed, 9);
        assert_eq!(RunConfig::default().fault, None);
        // to_json only emits the key when set, in canonical spec form
        let s = RunConfig::default().to_json().to_string();
        assert!(!s.contains("\"fault\""), "{s}");
        let s = c.to_json().to_string();
        assert!(s.contains("\"fault\":\"drop=0.1,delay=0,reorder=0,truncate=0,seed=9\""), "{s}");
        let dir = std::env::temp_dir().join(format!("fedskel_fault_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"fault":"delay=0.2,reorder=0.1,seed=3"}"#).unwrap();
        let mut c = RunConfig::default();
        c.apply_json_file(p.to_str().unwrap()).unwrap();
        let plan = c.fault.unwrap();
        assert_eq!(plan.delay, 0.2);
        assert_eq!(plan.reorder, 0.1);
        assert_eq!(plan.seed, 3);
    }

    #[test]
    fn zero_threads_rejected() {
        let mut c = RunConfig::default();
        c.threads = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = RunConfig::default();
        c.num_clients = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.participation = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("fedskel_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"method":"fedmtl","rounds":5,"mu":0.5}"#).unwrap();
        let mut c = RunConfig::default();
        c.apply_json_file(p.to_str().unwrap()).unwrap();
        assert_eq!(c.method, Method::FedMtl);
        assert_eq!(c.rounds, 5);
        assert_eq!(c.mu, 0.5);
        std::fs::write(&p, r#"{"bogus":1}"#).unwrap();
        assert!(c.apply_json_file(p.to_str().unwrap()).is_err());
    }
}
