//! The single fold from events to run tables.
//!
//! [`apply`] is the only code in the repo that turns a [`RunEvent`] into
//! mutations of the [`RunLog`], the [`CommLedger`], and the metrics
//! [`Registry`]. The live coordinator calls it on every event it emits;
//! [`crate::trace::replay`] calls it on every event it parses back from
//! a `trace.jsonl`. Byte-for-byte replay parity is therefore structural:
//! there is no second bookkeeping path to drift.
//!
//! The ledger-affecting events are `Frame`-level (`exchange`) and
//! `Client`-level (the drops), so only a `Frame`-level trace replays
//! into a complete [`CommLedger`]; the [`RunLog`] folds entirely from
//! `round_close`/`eval` and survives any level.

use crate::comm::CommLedger;
use crate::metrics::{RoundLog, RunLog};

use super::event::RunEvent;
use super::registry::Registry;

/// Fold one event into the three derived tables.
pub fn apply(log: &mut RunLog, ledger: &mut CommLedger, registry: &mut Registry, ev: &RunEvent) {
    registry.update(ev);
    match ev {
        RunEvent::MidroundDrop { wasted_bytes, .. }
        | RunEvent::DeadlineDrop { wasted_bytes, .. }
        | RunEvent::FaultRetry { wasted_bytes, .. } => {
            ledger.record_wasted(*wasted_bytes);
        }
        RunEvent::Exchange { up_params, down_params, up_wire, down_wire, up_raw, down_raw, .. } => {
            ledger.record_params(*up_params, *down_params);
            ledger.record_wire(*up_wire, *down_wire);
            ledger.record_raw(*up_raw, *down_raw);
        }
        RunEvent::Eval { round, new_acc, local_acc } => {
            // idempotent against the same values already being on the
            // round's close record: eval points both stamp the row and
            // stream as their own event
            if let Some(row) = log.rounds.iter_mut().rev().find(|r| r.round == *round) {
                row.new_acc = Some(*new_acc);
                row.local_acc = Some(*local_acc);
            }
        }
        RunEvent::RoundClose {
            round,
            phase,
            mean_loss,
            new_acc,
            local_acc,
            comm_params,
            comm_wire_bytes,
            sim_secs,
            client_secs,
            dropped,
            stale,
            wall_secs,
            digest: _,
        } => {
            log.push(RoundLog {
                round: *round,
                phase: phase.clone(),
                mean_loss: *mean_loss,
                new_acc: *new_acc,
                local_acc: *local_acc,
                comm_params: *comm_params,
                comm_wire_bytes: *comm_wire_bytes,
                sim_round_secs: *sim_secs,
                client_secs: client_secs.clone(),
                dropped: *dropped,
                stale: *stale,
                wall_secs: *wall_secs,
            });
            ledger.end_round();
        }
        RunEvent::RoundOpen { .. }
        | RunEvent::Download { .. }
        | RunEvent::Dispatch { .. }
        | RunEvent::Complete { .. }
        | RunEvent::Upload { .. }
        | RunEvent::StaleLand { .. }
        | RunEvent::Reselect { .. }
        | RunEvent::CheckpointWrite { .. }
        | RunEvent::Resume { .. }
        | RunEvent::ClientJoin { .. }
        | RunEvent::ClientLeave { .. } => {}
    }
}

/// The three derived tables plus the shared fold, bundled for replay.
#[derive(Default)]
pub struct Folder {
    pub log: RunLog,
    pub ledger: CommLedger,
    pub registry: Registry,
}

impl Folder {
    pub fn new() -> Folder {
        Folder::default()
    }

    pub fn apply(&mut self, ev: &RunEvent) {
        apply(&mut self.log, &mut self.ledger, &mut self.registry, ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(round: usize) -> RunEvent {
        RunEvent::RoundClose {
            round,
            phase: "updateskel".into(),
            mean_loss: 1.0,
            new_acc: None,
            local_acc: None,
            comm_params: 30,
            comm_wire_bytes: 120,
            sim_secs: 0.5,
            client_secs: vec![(0, 0.5)],
            dropped: 0,
            stale: 0,
            wall_secs: 0.01,
            digest: None,
        }
    }

    #[test]
    fn exchange_and_drops_rebuild_the_ledger() {
        let mut f = Folder::new();
        f.apply(&RunEvent::Exchange {
            round: 0,
            seq: 0,
            client: 0,
            up_params: 17,
            down_params: 38,
            up_wire: 100,
            down_wire: 300,
            up_raw: 152,
            down_raw: 152,
        });
        f.apply(&RunEvent::MidroundDrop { round: 0, client: 1, wasted_bytes: 300 });
        f.apply(&RunEvent::DeadlineDrop { round: 0, seq: 1, client: 2, wasted_bytes: 400 });
        f.apply(&RunEvent::FaultRetry { round: 0, client: 0, wasted_bytes: 100 });
        f.apply(&close(0));
        assert_eq!(f.ledger.upload_params, 17);
        assert_eq!(f.ledger.download_params, 38);
        assert_eq!(f.ledger.total_wire_bytes(), 400);
        assert_eq!(f.ledger.total_raw_bytes(), 304);
        assert_eq!(f.ledger.wasted_wire_bytes, 800);
        assert_eq!(f.ledger.rounds, 1);
        assert_eq!(f.log.rounds.len(), 1);
    }

    #[test]
    fn eval_stamps_the_matching_round_row() {
        let mut f = Folder::new();
        f.apply(&close(0));
        f.apply(&close(1));
        f.apply(&RunEvent::Eval { round: 1, new_acc: 0.5, local_acc: 0.75 });
        assert_eq!(f.log.rounds[0].new_acc, None);
        assert_eq!(f.log.rounds[1].new_acc, Some(0.5));
        assert_eq!(f.log.rounds[1].local_acc, Some(0.75));
        // an eval for an unknown round is ignored, not a panic
        f.apply(&RunEvent::Eval { round: 9, new_acc: 0.1, local_acc: 0.1 });
        assert_eq!(f.log.rounds.len(), 2);
    }
}
