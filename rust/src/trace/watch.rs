//! `fedskel watch`: a terminal dashboard over a live or recorded trace.
//!
//! The dashboard is a pure function of a folded trace ([`render`]), so
//! watching a finished recording (`--replay`) and tailing a live run
//! (`--follow`) share every line of rendering code. Follow mode re-reads
//! the file on an interval and folds only the complete prefix — the
//! trailing partial line a live [`super::JsonlSink`] may be mid-writing
//! is held back until its newline arrives.

use std::path::Path;

use anyhow::Result;

use crate::hetero;

use super::replay::{self, Replay};

/// Unicode block sparkline of a series, normalized to its own min/max.
pub fn sparkline(xs: &[f64]) -> String {
    const BLOCKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if xs.is_empty() {
        return String::new();
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let span = hi - lo;
    xs.iter()
        .map(|&x| {
            let t = if span > 0.0 { (x - lo) / span } else { 0.5 };
            BLOCKS[((t * 7.0).round() as usize).min(7)]
        })
        .collect()
}

/// A `[####....]`-style horizontal bar for a fraction in `[0, 1]`.
pub fn bar(frac: f64, width: usize) -> String {
    let f = frac.clamp(0.0, 1.0);
    let filled = (f * width as f64).round() as usize;
    format!("[{}{}]", "#".repeat(filled), ".".repeat(width - filled))
}

/// `12.3 KiB`-style rendering of a byte count.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = bytes as f64;
    let mut unit = 0;
    while x >= 1024.0 && unit < UNITS.len() - 1 {
        x /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{:.1} {}", x, UNITS[unit])
    }
}

/// Render the dashboard for a folded trace.
pub fn render(replay: &Replay) -> String {
    let log = &replay.folder.log;
    let ledger = &replay.folder.ledger;
    let reg = &replay.folder.registry;
    let cfg = &replay.config;
    let method = cfg.opt("method").and_then(|m| m.as_str().ok()).unwrap_or("?");
    let model = cfg.opt("model").and_then(|m| m.as_str().ok()).unwrap_or("?");
    let sched = cfg.opt("sched").and_then(|m| m.as_str().ok()).unwrap_or("?");

    let mut out = String::new();
    out.push_str(&format!(
        "fedskel watch · method {method} · model {model} · sched {sched} · {} events\n\n",
        replay.events
    ));

    let (last_round, phase, loss) = match log.rounds.last() {
        Some(r) => (r.round, r.phase.clone(), r.mean_loss),
        None => {
            out.push_str("waiting for the first round_close…\n");
            return out;
        }
    };
    out.push_str(&format!(
        "round {last_round} ({phase})   mean loss {loss:.4}   virtual clock {:.2}s\n",
        reg.gauge("clock/virtual_secs").unwrap_or(0.0)
    ));

    let accs: Vec<f64> = log.rounds.iter().filter_map(|r| r.new_acc).collect();
    let acc_line = match (log.last_new_acc(), log.last_local_acc()) {
        (Some(n), Some(l)) => format!("new {:.2}%  local {:.2}%", n * 100.0, l * 100.0),
        (Some(n), None) => format!("new {:.2}%", n * 100.0),
        _ => "no eval yet".to_string(),
    };
    out.push_str(&format!("accuracy  {}  {acc_line}\n", sparkline(&accs)));

    out.push_str(&format!(
        "wire      up {}  down {}  (raw {}  ratio {:.2}x  wasted {})\n",
        human_bytes(ledger.upload_wire_bytes),
        human_bytes(ledger.download_wire_bytes),
        human_bytes(ledger.total_raw_bytes()),
        ledger.compression_ratio(),
        human_bytes(ledger.wasted_wire_bytes),
    ));

    // mean fleet utilization over the recorded rounds
    let mut util_sum = 0.0;
    let mut util_n = 0usize;
    for r in &log.rounds {
        if !r.client_secs.is_empty() {
            let busy: Vec<f64> = r.client_secs.iter().map(|&(_, s)| s).collect();
            util_sum += hetero::utilization(&busy, r.sim_round_secs, busy.len());
            util_n += 1;
        }
    }
    if util_n > 0 {
        let util = util_sum / util_n as f64;
        out.push_str(&format!("fleet     {} {:.1}% utilized\n", bar(util, 24), util * 100.0));
    }

    out.push_str(&format!(
        "sched     drops {} mid-round / {} deadline   stale landings {}   reselects {}\n",
        reg.counter("sched/drops_midround"),
        reg.counter("sched/drops_deadline"),
        reg.counter("sched/stale_landings"),
        reg.counter("skeleton/reselects"),
    ));
    out
}

/// Render a trace file once (replay mode).
pub fn render_file(path: &Path) -> Result<String> {
    Ok(render(&replay::read_trace(path)?))
}

/// Fold the complete prefix of a possibly-mid-write trace: everything up
/// to (and including) the last newline. A live [`super::JsonlSink`] may
/// be halfway through a line; that tail is held back until its newline
/// arrives, and the next fold re-reads the whole file from scratch — so
/// repeated folds of a growing file never double-count an event.
pub fn fold_tail(text: &str) -> Result<Replay> {
    let complete = match text.rfind('\n') {
        Some(i) => &text[..=i],
        None => "",
    };
    replay::parse_trace(complete)
}

/// Watch a trace file: render once, or re-render every `interval_ms` in
/// follow mode (runs until interrupted). Follow mode folds only the
/// complete prefix of the file ([`fold_tail`]). When `profile` names an
/// exported Chrome-trace profile, its self-time attribution table is
/// appended below the dashboard.
pub fn watch(path: &Path, follow: bool, interval_ms: u64, profile: Option<&Path>) -> Result<()> {
    let attribution = match profile {
        Some(p) => Some(crate::prof::report_from_chrome(p)?),
        None => None,
    };
    if !follow {
        print!("{}", render_file(path)?);
        if let Some(a) = &attribution {
            print!("\n{a}");
        }
        return Ok(());
    }
    loop {
        let text = std::fs::read_to_string(path).unwrap_or_default();
        // ANSI clear + home, then the dashboard
        print!("\x1b[2J\x1b[H");
        match fold_tail(&text) {
            Ok(r) => print!("{}", render(&r)),
            Err(e) => println!("waiting for a readable trace at {} ({e:#})", path.display()),
        }
        if let Some(a) = &attribution {
            print!("\n{a}");
        }
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        std::thread::sleep(std::time::Duration::from_millis(interval_ms.max(50)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::{RunEvent, TRACE_SCHEMA, TRACE_VERSION};
    use crate::util::json::Json;

    fn header_line() -> String {
        let header = Json::obj(vec![
            ("schema", Json::str(TRACE_SCHEMA)),
            ("version", Json::num(TRACE_VERSION as f64)),
            ("config", Json::obj(vec![("method", Json::str("fedskel"))])),
        ]);
        let mut s = header.to_string();
        s.push('\n');
        s
    }

    fn round_events(round: usize) -> Vec<RunEvent> {
        vec![
            RunEvent::RoundOpen { round, phase: "updateskel".into(), clock: round as f64 },
            RunEvent::Exchange {
                round,
                seq: 0,
                client: 0,
                up_params: 17,
                down_params: 38,
                up_wire: 100,
                down_wire: 300,
                up_raw: 200,
                down_raw: 600,
            },
            RunEvent::RoundClose {
                round,
                phase: "updateskel".into(),
                mean_loss: 1.0,
                new_acc: Some(0.5),
                local_acc: Some(0.5),
                comm_params: 55,
                comm_wire_bytes: 400,
                sim_secs: 1.0,
                client_secs: vec![(0, 0.5)],
                dropped: 0,
                stale: 0,
                wall_secs: 0.01,
                digest: None,
            },
        ]
    }

    #[test]
    fn follow_fold_holds_back_partial_tail_and_never_double_counts() {
        // Simulate a live JsonlSink appending to the file while follow
        // mode re-folds it: after every append (including mid-line
        // partial writes), fold_tail must see exactly the complete
        // prefix, and fold counters must match a from-scratch fold of
        // those same lines — i.e. repeated folds never double-count.
        let mut text = header_line();
        let mut complete_rounds = 0usize;
        let mut complete_exchanges = 0u64;
        for round in 0..3 {
            for ev in round_events(round) {
                let line = ev.to_json().to_string();

                // append the first half of the line: a mid-write tail
                let half = line.len() / 2;
                text.push_str(&line[..half]);
                let r = fold_tail(&text).unwrap();
                assert_eq!(r.folder.log.rounds.len(), complete_rounds, "partial tail folded");
                assert_eq!(
                    r.folder.ledger.upload_wire_bytes,
                    100 * complete_exchanges,
                    "partial tail changed the ledger"
                );

                // complete the line; only now does the event fold in
                text.push_str(&line[half..]);
                text.push('\n');
                match ev {
                    RunEvent::RoundClose { .. } => complete_rounds += 1,
                    RunEvent::Exchange { .. } => complete_exchanges += 1,
                    _ => {}
                }
                let r = fold_tail(&text).unwrap();
                assert_eq!(r.folder.log.rounds.len(), complete_rounds);
                // each Exchange contributes exactly once per fold
                assert_eq!(r.folder.ledger.upload_wire_bytes, 100 * complete_exchanges);
            }
        }
        // final fold over the finished file: exactly 3 rounds' worth,
        // byte-identical to what a one-shot replay would derive
        let r = fold_tail(&text).unwrap();
        assert_eq!(r.events, 9);
        assert_eq!(r.folder.log.rounds.len(), 3);
        assert_eq!(r.folder.ledger.upload_wire_bytes, 300);
        assert_eq!(r.folder.ledger.download_wire_bytes, 900);
        let oneshot = replay::parse_trace(&text).unwrap();
        assert_eq!(render(&r), render(&oneshot));
    }

    #[test]
    fn fold_tail_without_any_newline_is_an_error_not_a_panic() {
        // A file caught before even the header's newline lands folds to
        // the empty prefix, which parse_trace rejects (no header) — the
        // follow loop renders its "waiting" line instead of crashing.
        assert!(fold_tail("").is_err());
        let partial_header = &header_line()[..10];
        assert!(fold_tail(partial_header).is_err());
    }

    #[test]
    fn sparkline_normalizes_and_handles_edges() {
        assert_eq!(sparkline(&[]), "");
        assert_eq!(sparkline(&[1.0]), "▅"); // flat series sits mid-scale
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
        assert!(s.starts_with('▁') && s.ends_with('█'), "{s}");
    }

    #[test]
    fn bar_clamps_and_fills() {
        assert_eq!(bar(0.0, 4), "[....]");
        assert_eq!(bar(0.5, 4), "[##..]");
        assert_eq!(bar(1.0, 4), "[####]");
        assert_eq!(bar(7.0, 4), "[####]");
        assert_eq!(bar(-1.0, 4), "[....]");
    }

    #[test]
    fn human_bytes_scales_units() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.0 MiB");
    }
}
