//! A dependency-free metrics registry derived from the event stream.
//!
//! Counters, gauges, and fixed-bucket histograms keyed by `area/name`
//! strings, stored in `BTreeMap`s so iteration (and therefore JSON
//! output) is deterministic. The registry never gets written directly by
//! run code: [`Registry::update`] folds each [`RunEvent`] into it, so a
//! live run and a trace replay produce identical registries.
//!
//! Metric names (schema v1):
//!
//! | kind      | name                       | source event                |
//! |-----------|----------------------------|-----------------------------|
//! | counter   | `comm/upload_params`       | `exchange`                  |
//! | counter   | `comm/download_params`     | `exchange`                  |
//! | counter   | `comm/upload_wire_bytes`   | `exchange`                  |
//! | counter   | `comm/download_wire_bytes` | `exchange`                  |
//! | counter   | `comm/upload_raw_bytes`    | `exchange`                  |
//! | counter   | `comm/download_raw_bytes`  | `exchange`                  |
//! | counter   | `comm/wasted_wire_bytes`   | `midround_drop`, `deadline_drop`, `fault_retry` |
//! | counter   | `sched/drops_midround`     | `midround_drop`             |
//! | counter   | `sched/drops_deadline`     | `deadline_drop`             |
//! | counter   | `sched/stale_landings`     | `stale_land`                |
//! | counter   | `net/fault_retries`        | `fault_retry`               |
//! | counter   | `net/client_joins`         | `client_join`               |
//! | counter   | `net/client_leaves`        | `client_leave`              |
//! | counter   | `skeleton/reselects`       | `reselect`                  |
//! | counter   | `run/rounds`               | `round_close`               |
//! | counter   | `run/dispatches`           | `dispatch`                  |
//! | counter   | `run/evals`                | `eval`                      |
//! | counter   | `run/checkpoints`          | `checkpoint_write`          |
//! | counter   | `run/resumes`              | `resume`                    |
//! | gauge     | `run/mean_loss`            | `round_close`               |
//! | gauge     | `acc/new`, `acc/local`     | `eval`, `round_close`       |
//! | gauge     | `run/utilization`          | `round_close` (via [`crate::hetero::utilization`]) |
//! | gauge     | `clock/virtual_secs`       | `round_open`                |
//! | histogram | `client/secs`              | `complete`                  |
//! | histogram | `round/sim_secs`           | `round_close`               |

use std::collections::BTreeMap;

use crate::hetero;
use crate::util::json::Json;

use super::event::RunEvent;

/// Histogram bucket upper bounds (seconds-ish scales); observations above
/// the last bound land in the overflow bucket. The sub-millisecond decades
/// exist for profiler span durations ([`crate::prof`]), where a single
/// GEMM call is micro- to milliseconds.
pub const HIST_BOUNDS: [f64; 10] =
    [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0, 1e3];

/// A fixed-bucket histogram with count/sum/min/max summary stats.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    /// One count per [`HIST_BOUNDS`] entry, plus a final overflow bucket.
    pub buckets: [u64; HIST_BOUNDS.len() + 1],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; HIST_BOUNDS.len() + 1],
        }
    }
}

impl Histogram {
    pub fn observe(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        let idx = HIST_BOUNDS.iter().position(|&b| x <= b).unwrap_or(HIST_BOUNDS.len());
        self.buckets[idx] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Fold another histogram into this one (used by [`crate::prof`] to
    /// merge per-thread span histograms into one registry entry).
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
    }

    /// Estimated `q`-quantile (`0.0..=1.0`) over the fixed buckets:
    /// linear interpolation between a bucket's lower and upper bound,
    /// clamped to the observed `[min, max]` so exact-boundary
    /// observations report exactly. Ranks landing in the overflow bucket
    /// (above [`HIST_BOUNDS`]'s last bound) report `max` — the bucket
    /// has no upper bound to interpolate toward. Returns 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                if i >= HIST_BOUNDS.len() {
                    return self.max;
                }
                let lo = if i == 0 { 0.0 } else { HIST_BOUNDS[i - 1] };
                let hi = HIST_BOUNDS[i];
                let frac = (rank - seen) as f64 / c as f64;
                return (lo + frac * (hi - lo)).clamp(self.min, self.max);
            }
            seen += c;
        }
        self.max
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum)),
            ("min", Json::num(if self.count == 0 { 0.0 } else { self.min })),
            ("max", Json::num(if self.count == 0 { 0.0 } else { self.max })),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.quantile(0.50))),
            ("p95", Json::num(self.quantile(0.95))),
            ("p99", Json::num(self.quantile(0.99))),
            (
                "buckets",
                Json::Arr(self.buckets.iter().map(|&c| Json::num(c as f64)).collect()),
            ),
        ])
    }
}

/// Counters, gauges, and histograms with deterministic iteration order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    pub fn observe(&mut self, name: &str, value: f64) {
        self.histograms.entry(name.to_string()).or_default().observe(value);
    }

    /// Current counter value (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Last gauge value, if ever set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// All histograms in deterministic (sorted-name) order.
    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.histograms.iter().map(|(k, h)| (k.as_str(), h))
    }

    /// Fold a whole pre-aggregated histogram into `name` (creating it if
    /// absent) — the bulk counterpart of [`Registry::observe`].
    pub fn merge_histogram(&mut self, name: &str, h: &Histogram) {
        self.histograms.entry(name.to_string()).or_default().merge(h);
    }

    /// Fold one event into the registry (see the module table for the
    /// event → metric mapping).
    pub fn update(&mut self, ev: &RunEvent) {
        match ev {
            RunEvent::RoundOpen { clock, .. } => {
                self.set_gauge("clock/virtual_secs", *clock);
            }
            RunEvent::Download { .. } | RunEvent::Upload { .. } => {}
            RunEvent::MidroundDrop { wasted_bytes, .. } => {
                self.inc("sched/drops_midround", 1);
                self.inc("comm/wasted_wire_bytes", *wasted_bytes);
            }
            RunEvent::Dispatch { .. } => {
                self.inc("run/dispatches", 1);
            }
            RunEvent::Complete { secs, .. } => {
                self.observe("client/secs", *secs);
            }
            RunEvent::Exchange {
                up_params, down_params, up_wire, down_wire, up_raw, down_raw, ..
            } => {
                self.inc("comm/upload_params", *up_params);
                self.inc("comm/download_params", *down_params);
                self.inc("comm/upload_wire_bytes", *up_wire);
                self.inc("comm/download_wire_bytes", *down_wire);
                self.inc("comm/upload_raw_bytes", *up_raw);
                self.inc("comm/download_raw_bytes", *down_raw);
            }
            RunEvent::DeadlineDrop { wasted_bytes, .. } => {
                self.inc("sched/drops_deadline", 1);
                self.inc("comm/wasted_wire_bytes", *wasted_bytes);
            }
            RunEvent::StaleLand { .. } => {
                self.inc("sched/stale_landings", 1);
            }
            RunEvent::Reselect { .. } => {
                self.inc("skeleton/reselects", 1);
            }
            RunEvent::Eval { new_acc, local_acc, .. } => {
                self.inc("run/evals", 1);
                self.set_gauge("acc/new", *new_acc);
                self.set_gauge("acc/local", *local_acc);
            }
            RunEvent::RoundClose {
                mean_loss, new_acc, local_acc, sim_secs, client_secs, ..
            } => {
                self.inc("run/rounds", 1);
                self.set_gauge("run/mean_loss", *mean_loss);
                if let Some(a) = new_acc {
                    self.set_gauge("acc/new", *a);
                }
                if let Some(a) = local_acc {
                    self.set_gauge("acc/local", *a);
                }
                self.observe("round/sim_secs", *sim_secs);
                if !client_secs.is_empty() {
                    let busy: Vec<f64> = client_secs.iter().map(|&(_, s)| s).collect();
                    let util = hetero::utilization(&busy, *sim_secs, busy.len());
                    self.set_gauge("run/utilization", util);
                }
            }
            RunEvent::CheckpointWrite { .. } => {
                self.inc("run/checkpoints", 1);
            }
            RunEvent::Resume { .. } => {
                self.inc("run/resumes", 1);
            }
            RunEvent::FaultRetry { wasted_bytes, .. } => {
                self.inc("net/fault_retries", 1);
                self.inc("comm/wasted_wire_bytes", *wasted_bytes);
            }
            RunEvent::ClientJoin { .. } => {
                self.inc("net/client_joins", 1);
            }
            RunEvent::ClientLeave { .. } => {
                self.inc("net/client_leaves", 1);
            }
        }
    }

    /// Deterministic JSON dump: `{"counters":{...},"gauges":{...},"histograms":{...}}`.
    pub fn to_json(&self) -> Json {
        let counters =
            self.counters.iter().map(|(k, &v)| (k.as_str(), Json::num(v as f64))).collect();
        let gauges = self.gauges.iter().map(|(k, &v)| (k.as_str(), Json::num(v))).collect();
        let hists = self.histograms.iter().map(|(k, h)| (k.as_str(), h.to_json())).collect();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_basics() {
        let mut r = Registry::new();
        assert_eq!(r.counter("comm/upload_params"), 0);
        r.inc("comm/upload_params", 3);
        r.inc("comm/upload_params", 4);
        assert_eq!(r.counter("comm/upload_params"), 7);
        r.set_gauge("acc/new", 0.5);
        r.set_gauge("acc/new", 0.75);
        assert_eq!(r.gauge("acc/new"), Some(0.75));
        assert_eq!(r.gauge("acc/local"), None);
        r.observe("client/secs", 0.05);
        r.observe("client/secs", 5.0);
        r.observe("client/secs", 5000.0);
        let h = r.histogram("client/secs").unwrap();
        assert_eq!(h.count, 3);
        assert_eq!(h.min, 0.05);
        assert_eq!(h.max, 5000.0);
        assert_eq!(h.buckets[5], 1); // 0.05 <= 1e-1
        assert_eq!(h.buckets[7], 1); // 5.0 <= 10
        assert_eq!(h.buckets[HIST_BOUNDS.len()], 1); // overflow
        assert!((h.mean() - (0.05 + 5.0 + 5000.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates_within_buckets() {
        let mut h = Histogram::default();
        assert_eq!(h.quantile(0.5), 0.0); // empty
        // 100 observations spread across (1e-2, 1e-1]: ranks interpolate.
        for i in 0..100 {
            h.observe(0.011 + 0.00089 * i as f64);
        }
        let p50 = h.quantile(0.50);
        // All mass in one bucket: p50 sits mid-bucket under linear
        // interpolation, inside the bucket's bound range.
        assert!(p50 > 1e-2 && p50 <= 1e-1, "{p50}");
        assert!(h.quantile(0.95) > p50);
        assert!(h.quantile(0.0) >= h.min);
        assert!(h.quantile(1.0) <= h.max);
    }

    #[test]
    fn quantile_pins_bucket_boundary_observations() {
        // Observations exactly on a bucket bound: clamping to [min, max]
        // makes every quantile report the exact value.
        let mut h = Histogram::default();
        for _ in 0..4 {
            h.observe(1e-3);
        }
        assert_eq!(h.quantile(0.50), 1e-3);
        assert_eq!(h.quantile(0.99), 1e-3);
    }

    #[test]
    fn quantile_above_top_bucket_reports_max() {
        let mut h = Histogram::default();
        h.observe(0.5);
        h.observe(5e4); // above the last bound → overflow bucket
        h.observe(7e4);
        assert_eq!(h.quantile(0.99), 7e4);
        // The median rank (2 of 3) falls in the overflow bucket too.
        assert_eq!(h.quantile(0.50), 7e4);
        // Rank 1 is the in-range bucket: interpolated, never above max.
        let p33 = h.quantile(0.33);
        assert!((0.5..=1.0).contains(&p33), "{p33}");
    }

    #[test]
    fn histogram_merge_matches_sequential_observes() {
        let (mut a, mut b, mut all) = (Histogram::default(), Histogram::default(), Histogram::default());
        for (i, &x) in [1e-5, 3e-4, 0.02, 0.9, 12.0, 4e3].iter().enumerate() {
            if i % 2 == 0 { a.observe(x) } else { b.observe(x) }
            all.observe(x);
        }
        a.merge(&b);
        assert_eq!(a, all);
        let empty = Histogram::default();
        a.merge(&empty);
        assert_eq!(a, all); // merging empty is a no-op
        let mut r = Registry::new();
        r.merge_histogram("prof/x", &all);
        assert_eq!(r.histogram("prof/x"), Some(&all));
    }

    #[test]
    fn json_dump_carries_percentiles() {
        let mut r = Registry::new();
        r.observe("client/secs", 0.05);
        let s = r.to_json().to_string();
        assert!(s.contains("\"p50\""), "{s}");
        assert!(s.contains("\"p95\""), "{s}");
        assert!(s.contains("\"p99\""), "{s}");
    }

    #[test]
    fn update_folds_events_into_named_metrics() {
        let mut r = Registry::new();
        r.update(&RunEvent::Dispatch { round: 0, seq: 0, client: 1, bucket: 50 });
        r.update(&RunEvent::Exchange {
            round: 0,
            seq: 0,
            client: 1,
            up_params: 10,
            down_params: 20,
            up_wire: 40,
            down_wire: 80,
            up_raw: 40,
            down_raw: 80,
        });
        r.update(&RunEvent::DeadlineDrop { round: 0, seq: 1, client: 2, wasted_bytes: 99 });
        r.update(&RunEvent::RoundClose {
            round: 0,
            phase: "updateskel".into(),
            mean_loss: 1.5,
            new_acc: None,
            local_acc: None,
            comm_params: 30,
            comm_wire_bytes: 120,
            sim_secs: 2.0,
            client_secs: vec![(1, 1.0), (2, 2.0)],
            dropped: 1,
            stale: 0,
            wall_secs: 0.01,
            digest: None,
        });
        assert_eq!(r.counter("run/dispatches"), 1);
        assert_eq!(r.counter("comm/upload_params"), 10);
        assert_eq!(r.counter("comm/download_wire_bytes"), 80);
        r.update(&RunEvent::FaultRetry { round: 0, client: 1, wasted_bytes: 11 });
        r.update(&RunEvent::ClientJoin { round: 0, client: 4 });
        r.update(&RunEvent::ClientLeave { round: 0, client: 4 });
        assert_eq!(r.counter("sched/drops_deadline"), 1);
        assert_eq!(r.counter("comm/wasted_wire_bytes"), 110);
        assert_eq!(r.counter("net/fault_retries"), 1);
        assert_eq!(r.counter("net/client_joins"), 1);
        assert_eq!(r.counter("net/client_leaves"), 1);
        assert_eq!(r.counter("run/rounds"), 1);
        assert_eq!(r.gauge("run/mean_loss"), Some(1.5));
        // (1.0 + 2.0) busy over 2 slots × 2.0 s makespan = 0.75
        assert_eq!(r.gauge("run/utilization"), Some(0.75));
    }

    #[test]
    fn json_dump_is_deterministic() {
        let mut r = Registry::new();
        r.inc("b/z", 1);
        r.inc("a/y", 2);
        r.set_gauge("m/g", 0.5);
        let a = r.to_json().to_string();
        let b = r.clone().to_json().to_string();
        assert_eq!(a, b);
        let ia = a.find("a/y").unwrap();
        let ib = a.find("b/z").unwrap();
        assert!(ia < ib, "counters not sorted: {a}");
    }
}
