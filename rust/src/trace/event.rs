//! The run-event vocabulary and its versioned JSON form.
//!
//! A [`RunEvent`] is one fact about a federated run: a round opened or
//! closed, a frame shipped, a client was dispatched / completed / dropped,
//! a stale update landed, a skeleton was re-selected, an eval happened.
//! The coordinator emits these as they occur; everything downstream —
//! the [`crate::metrics::RunLog`], the [`crate::comm::CommLedger`], the
//! metrics registry, the `fedskel watch` dashboard — is a *fold* over the
//! stream ([`crate::trace::fold`]), so a recorded trace replays into
//! exactly the tables a live run produced.
//!
//! ## Wire form (`trace.jsonl`, schema v1)
//!
//! One JSON object per line. The first line is the header record:
//!
//! ```text
//! {"config":{...},"schema":"fedskel.trace","version":1}
//! ```
//!
//! every following line is an event tagged by its `"ev"` field (see
//! `docs/OBSERVABILITY.md` for the field tables). Revision policy mirrors
//! `docs/WIRE_FORMAT.md`: additive changes (new event kinds, new fields)
//! keep `version`; anything that changes the meaning of an existing
//! field bumps it, and readers refuse traces newer than they are.
//! Floats are written in Rust's shortest-roundtrip form, so a
//! parse → fold of a recorded trace reproduces the live run's CSV/JSON
//! tables byte for byte. `u64` state digests don't survive an `f64`
//! JSON number (53-bit mantissa), so they travel as `0x…` hex strings.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Schema name in every trace header record.
pub const TRACE_SCHEMA: &str = "fedskel.trace";
/// Current trace schema version (see the revision policy above).
pub const TRACE_VERSION: u64 = 1;

/// How much of the stream a sink wants: each event carries the coarsest
/// level that includes it, and a sink records events with
/// `event.level() <= sink.level()`. Ordered `Round < Client < Frame`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// Round opens/closes and eval points only.
    Round,
    /// Plus per-client lifecycle: dispatch, completion, drops, stale
    /// landings, skeleton re-selections.
    Client,
    /// Plus per-frame traffic: uploads, downloads, exchange accounting.
    /// The only level [`crate::trace::replay`] can rebuild the
    /// [`crate::comm::CommLedger`] from.
    Frame,
}

impl TraceLevel {
    pub fn parse(s: &str) -> Result<TraceLevel> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "round" => TraceLevel::Round,
            "client" => TraceLevel::Client,
            "frame" => TraceLevel::Frame,
            _ => bail!("unknown trace level '{s}' (round|client|frame)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TraceLevel::Round => "round",
            TraceLevel::Client => "client",
            TraceLevel::Frame => "frame",
        }
    }
}

/// One fact about a federated run. Byte counts are `u64` (what the
/// ledger books); virtual times are `f64` seconds on the scheduler's
/// clock ([`crate::sched`]).
#[derive(Debug, Clone, PartialEq)]
pub enum RunEvent {
    /// A round began at virtual time `clock`.
    RoundOpen { round: usize, phase: String, clock: f64 },
    /// A server→client frame shipped: measured wire bytes vs what the
    /// same payload costs as dense-f32 frames.
    Download { round: usize, client: usize, wire_bytes: u64, raw_bytes: u64 },
    /// A sampled client died mid-round after its download was already on
    /// the wire; those frames are wasted.
    MidroundDrop { round: usize, client: usize, wasted_bytes: u64 },
    /// A client started local training in submission slot `seq` at
    /// skeleton bucket `bucket`.
    Dispatch { round: usize, seq: usize, client: usize, bucket: usize },
    /// A client finished local training; its completion is queued on the
    /// virtual clock at `secs` into the round.
    Complete { round: usize, seq: usize, client: usize, loss: f64, secs: f64 },
    /// A client→server frame shipped, tagged with the configured
    /// compressor id ([`crate::compress`]).
    Upload {
        round: usize,
        seq: usize,
        client: usize,
        wire_bytes: u64,
        raw_bytes: u64,
        compressor: String,
    },
    /// The ledger booking for one *useful* exchange (the round policy
    /// accepted or deferred it): logical params, measured wire bytes,
    /// and dense-f32 raw bytes, both directions. The fold rebuilds the
    /// [`crate::comm::CommLedger`] from exactly these.
    Exchange {
        round: usize,
        seq: usize,
        client: usize,
        up_params: u64,
        down_params: u64,
        up_wire: u64,
        down_wire: u64,
        up_raw: u64,
        down_raw: u64,
    },
    /// The round policy discarded this arrival at the deadline; both
    /// directions of its exchange are wasted.
    DeadlineDrop { round: usize, seq: usize, client: usize, wasted_bytes: u64 },
    /// An update trained in `origin_round` aggregated `staleness` rounds
    /// late with its weight scaled by `weight_scale` (async buffering).
    StaleLand {
        round: usize,
        origin_round: usize,
        seq: usize,
        client: usize,
        staleness: usize,
        weight_scale: f64,
    },
    /// A client re-selected its skeleton after a SetSkel round: `k` is
    /// the per-prunable-layer channel count it kept.
    Reselect { round: usize, client: usize, bucket: usize, k: Vec<usize> },
    /// An evaluation point (in-round cadence or the post-run final eval).
    Eval { round: usize, new_acc: f64, local_acc: f64 },
    /// A round ended: the complete per-round record the
    /// [`crate::metrics::RoundLog`] is folded from, plus an optional
    /// checkpoint-ready FNV digest of the post-aggregation global model.
    RoundClose {
        round: usize,
        phase: String,
        mean_loss: f64,
        new_acc: Option<f64>,
        local_acc: Option<f64>,
        comm_params: u64,
        comm_wire_bytes: u64,
        sim_secs: f64,
        client_secs: Vec<(usize, f64)>,
        dropped: usize,
        stale: usize,
        wall_secs: f64,
        digest: Option<u64>,
    },
    /// A checkpoint snapshot was written after this round closed
    /// ([`crate::snapshot`]); `bytes` is the encoded file size.
    CheckpointWrite { round: usize, path: String, bytes: u64 },
    /// This run resumed from a snapshot taken after `round` rounds: the
    /// virtual clock restarts at `clock` with `in_flight` straggler
    /// completions still pending.
    Resume { round: usize, path: String, clock: f64, in_flight: usize },
    /// The reliable-exchange loop retransmitted (the expected frame never
    /// arrived) or discarded a stray/duplicate frame under fault
    /// injection; `wasted_bytes` crossed the wire for nothing and are
    /// booked as waste. Retries never move the virtual clock, so a
    /// faulted run's trajectory matches its fault-free twin.
    FaultRetry { round: usize, client: usize, wasted_bytes: u64 },
    /// A remote worker process connected to `fedskel serve` and passed
    /// the handshake during `round`.
    ClientJoin { round: usize, client: usize },
    /// A remote worker's connection dropped during `round`; its in-flight
    /// jobs are re-dispatched to surviving workers.
    ClientLeave { round: usize, client: usize },
}

impl RunEvent {
    /// The `"ev"` tag this event serializes under.
    pub fn name(&self) -> &'static str {
        match self {
            RunEvent::RoundOpen { .. } => "round_open",
            RunEvent::Download { .. } => "download",
            RunEvent::MidroundDrop { .. } => "midround_drop",
            RunEvent::Dispatch { .. } => "dispatch",
            RunEvent::Complete { .. } => "complete",
            RunEvent::Upload { .. } => "upload",
            RunEvent::Exchange { .. } => "exchange",
            RunEvent::DeadlineDrop { .. } => "deadline_drop",
            RunEvent::StaleLand { .. } => "stale_land",
            RunEvent::Reselect { .. } => "reselect",
            RunEvent::Eval { .. } => "eval",
            RunEvent::RoundClose { .. } => "round_close",
            RunEvent::CheckpointWrite { .. } => "checkpoint_write",
            RunEvent::Resume { .. } => "resume",
            RunEvent::FaultRetry { .. } => "fault_retry",
            RunEvent::ClientJoin { .. } => "client_join",
            RunEvent::ClientLeave { .. } => "client_leave",
        }
    }

    /// The coarsest [`TraceLevel`] that includes this event.
    pub fn level(&self) -> TraceLevel {
        match self {
            RunEvent::RoundOpen { .. }
            | RunEvent::Eval { .. }
            | RunEvent::RoundClose { .. }
            | RunEvent::CheckpointWrite { .. }
            | RunEvent::Resume { .. } => TraceLevel::Round,
            RunEvent::MidroundDrop { .. }
            | RunEvent::Dispatch { .. }
            | RunEvent::Complete { .. }
            | RunEvent::DeadlineDrop { .. }
            | RunEvent::StaleLand { .. }
            | RunEvent::Reselect { .. }
            | RunEvent::ClientJoin { .. }
            | RunEvent::ClientLeave { .. } => TraceLevel::Client,
            RunEvent::Download { .. }
            | RunEvent::Upload { .. }
            | RunEvent::Exchange { .. }
            | RunEvent::FaultRetry { .. } => TraceLevel::Frame,
        }
    }

    /// Serialize to the schema-v1 JSON object (one `trace.jsonl` line).
    pub fn to_json(&self) -> Json {
        let u = |x: usize| Json::num(x as f64);
        let b = |x: u64| Json::num(x as f64);
        let mut fields: Vec<(&str, Json)> = vec![("ev", Json::str(self.name()))];
        match self {
            RunEvent::RoundOpen { round, phase, clock } => {
                fields.push(("round", u(*round)));
                fields.push(("phase", Json::str(phase.clone())));
                fields.push(("clock", Json::num(*clock)));
            }
            RunEvent::Download { round, client, wire_bytes, raw_bytes } => {
                fields.push(("round", u(*round)));
                fields.push(("client", u(*client)));
                fields.push(("wire_bytes", b(*wire_bytes)));
                fields.push(("raw_bytes", b(*raw_bytes)));
            }
            RunEvent::MidroundDrop { round, client, wasted_bytes } => {
                fields.push(("round", u(*round)));
                fields.push(("client", u(*client)));
                fields.push(("wasted_bytes", b(*wasted_bytes)));
            }
            RunEvent::Dispatch { round, seq, client, bucket } => {
                fields.push(("round", u(*round)));
                fields.push(("seq", u(*seq)));
                fields.push(("client", u(*client)));
                fields.push(("bucket", u(*bucket)));
            }
            RunEvent::Complete { round, seq, client, loss, secs } => {
                fields.push(("round", u(*round)));
                fields.push(("seq", u(*seq)));
                fields.push(("client", u(*client)));
                fields.push(("loss", Json::num(*loss)));
                fields.push(("secs", Json::num(*secs)));
            }
            RunEvent::Upload { round, seq, client, wire_bytes, raw_bytes, compressor } => {
                fields.push(("round", u(*round)));
                fields.push(("seq", u(*seq)));
                fields.push(("client", u(*client)));
                fields.push(("wire_bytes", b(*wire_bytes)));
                fields.push(("raw_bytes", b(*raw_bytes)));
                fields.push(("compressor", Json::str(compressor.clone())));
            }
            RunEvent::Exchange {
                round,
                seq,
                client,
                up_params,
                down_params,
                up_wire,
                down_wire,
                up_raw,
                down_raw,
            } => {
                fields.push(("round", u(*round)));
                fields.push(("seq", u(*seq)));
                fields.push(("client", u(*client)));
                fields.push(("up_params", b(*up_params)));
                fields.push(("down_params", b(*down_params)));
                fields.push(("up_wire", b(*up_wire)));
                fields.push(("down_wire", b(*down_wire)));
                fields.push(("up_raw", b(*up_raw)));
                fields.push(("down_raw", b(*down_raw)));
            }
            RunEvent::DeadlineDrop { round, seq, client, wasted_bytes } => {
                fields.push(("round", u(*round)));
                fields.push(("seq", u(*seq)));
                fields.push(("client", u(*client)));
                fields.push(("wasted_bytes", b(*wasted_bytes)));
            }
            RunEvent::StaleLand { round, origin_round, seq, client, staleness, weight_scale } => {
                fields.push(("round", u(*round)));
                fields.push(("origin_round", u(*origin_round)));
                fields.push(("seq", u(*seq)));
                fields.push(("client", u(*client)));
                fields.push(("staleness", u(*staleness)));
                fields.push(("weight_scale", Json::num(*weight_scale)));
            }
            RunEvent::Reselect { round, client, bucket, k } => {
                fields.push(("round", u(*round)));
                fields.push(("client", u(*client)));
                fields.push(("bucket", u(*bucket)));
                fields.push(("k", Json::arr_usize(k)));
            }
            RunEvent::Eval { round, new_acc, local_acc } => {
                fields.push(("round", u(*round)));
                fields.push(("new_acc", Json::num(*new_acc)));
                fields.push(("local_acc", Json::num(*local_acc)));
            }
            RunEvent::RoundClose {
                round,
                phase,
                mean_loss,
                new_acc,
                local_acc,
                comm_params,
                comm_wire_bytes,
                sim_secs,
                client_secs,
                dropped,
                stale,
                wall_secs,
                digest,
            } => {
                fields.push(("round", u(*round)));
                fields.push(("phase", Json::str(phase.clone())));
                fields.push(("mean_loss", Json::num(*mean_loss)));
                fields.push(("new_acc", opt_num(*new_acc)));
                fields.push(("local_acc", opt_num(*local_acc)));
                fields.push(("comm_params", b(*comm_params)));
                fields.push(("comm_wire_bytes", b(*comm_wire_bytes)));
                fields.push(("sim_secs", Json::num(*sim_secs)));
                fields.push((
                    "client_secs",
                    Json::Arr(
                        client_secs
                            .iter()
                            .map(|&(id, s)| Json::Arr(vec![u(id), Json::num(s)]))
                            .collect(),
                    ),
                ));
                fields.push(("dropped", u(*dropped)));
                fields.push(("stale", u(*stale)));
                fields.push(("wall_secs", Json::num(*wall_secs)));
                fields.push((
                    "digest",
                    match digest {
                        Some(d) => Json::str(format!("{d:#018x}")),
                        None => Json::Null,
                    },
                ));
            }
            RunEvent::CheckpointWrite { round, path, bytes } => {
                fields.push(("round", u(*round)));
                fields.push(("path", Json::str(path.clone())));
                fields.push(("bytes", b(*bytes)));
            }
            RunEvent::Resume { round, path, clock, in_flight } => {
                fields.push(("round", u(*round)));
                fields.push(("path", Json::str(path.clone())));
                fields.push(("clock", Json::num(*clock)));
                fields.push(("in_flight", u(*in_flight)));
            }
            RunEvent::FaultRetry { round, client, wasted_bytes } => {
                fields.push(("round", u(*round)));
                fields.push(("client", u(*client)));
                fields.push(("wasted_bytes", b(*wasted_bytes)));
            }
            RunEvent::ClientJoin { round, client } | RunEvent::ClientLeave { round, client } => {
                fields.push(("round", u(*round)));
                fields.push(("client", u(*client)));
            }
        }
        Json::obj(fields)
    }

    /// Parse a schema-v1 event object. Strict: an unknown `"ev"` tag or
    /// a missing/ill-typed field is an error, so a full parse doubles as
    /// schema validation of a recorded trace.
    pub fn from_json(j: &Json) -> Result<RunEvent> {
        let ev = j.get("ev")?.as_str()?;
        let us = |k: &str| -> Result<usize> { j.get(k)?.as_usize() };
        let u64of = |k: &str| -> Result<u64> { Ok(j.get(k)?.as_usize()? as u64) };
        let f = |k: &str| -> Result<f64> { j.get(k)?.as_f64() };
        let s = |k: &str| -> Result<String> { Ok(j.get(k)?.as_str()?.to_string()) };
        Ok(match ev {
            "round_open" => {
                RunEvent::RoundOpen { round: us("round")?, phase: s("phase")?, clock: f("clock")? }
            }
            "download" => RunEvent::Download {
                round: us("round")?,
                client: us("client")?,
                wire_bytes: u64of("wire_bytes")?,
                raw_bytes: u64of("raw_bytes")?,
            },
            "midround_drop" => RunEvent::MidroundDrop {
                round: us("round")?,
                client: us("client")?,
                wasted_bytes: u64of("wasted_bytes")?,
            },
            "dispatch" => RunEvent::Dispatch {
                round: us("round")?,
                seq: us("seq")?,
                client: us("client")?,
                bucket: us("bucket")?,
            },
            "complete" => RunEvent::Complete {
                round: us("round")?,
                seq: us("seq")?,
                client: us("client")?,
                loss: f("loss")?,
                secs: f("secs")?,
            },
            "upload" => RunEvent::Upload {
                round: us("round")?,
                seq: us("seq")?,
                client: us("client")?,
                wire_bytes: u64of("wire_bytes")?,
                raw_bytes: u64of("raw_bytes")?,
                compressor: s("compressor")?,
            },
            "exchange" => RunEvent::Exchange {
                round: us("round")?,
                seq: us("seq")?,
                client: us("client")?,
                up_params: u64of("up_params")?,
                down_params: u64of("down_params")?,
                up_wire: u64of("up_wire")?,
                down_wire: u64of("down_wire")?,
                up_raw: u64of("up_raw")?,
                down_raw: u64of("down_raw")?,
            },
            "deadline_drop" => RunEvent::DeadlineDrop {
                round: us("round")?,
                seq: us("seq")?,
                client: us("client")?,
                wasted_bytes: u64of("wasted_bytes")?,
            },
            "stale_land" => RunEvent::StaleLand {
                round: us("round")?,
                origin_round: us("origin_round")?,
                seq: us("seq")?,
                client: us("client")?,
                staleness: us("staleness")?,
                weight_scale: f("weight_scale")?,
            },
            "reselect" => RunEvent::Reselect {
                round: us("round")?,
                client: us("client")?,
                bucket: us("bucket")?,
                k: j.get("k")?.as_usize_vec()?,
            },
            "eval" => RunEvent::Eval {
                round: us("round")?,
                new_acc: f("new_acc")?,
                local_acc: f("local_acc")?,
            },
            "round_close" => RunEvent::RoundClose {
                round: us("round")?,
                phase: s("phase")?,
                mean_loss: f("mean_loss")?,
                new_acc: opt_f64(j.get("new_acc")?)?,
                local_acc: opt_f64(j.get("local_acc")?)?,
                comm_params: u64of("comm_params")?,
                comm_wire_bytes: u64of("comm_wire_bytes")?,
                sim_secs: f("sim_secs")?,
                client_secs: client_secs_of(j.get("client_secs")?)?,
                dropped: us("dropped")?,
                stale: us("stale")?,
                wall_secs: f("wall_secs")?,
                digest: digest_of(j.get("digest")?)?,
            },
            "checkpoint_write" => RunEvent::CheckpointWrite {
                round: us("round")?,
                path: s("path")?,
                bytes: u64of("bytes")?,
            },
            "resume" => RunEvent::Resume {
                round: us("round")?,
                path: s("path")?,
                clock: f("clock")?,
                in_flight: us("in_flight")?,
            },
            "fault_retry" => RunEvent::FaultRetry {
                round: us("round")?,
                client: us("client")?,
                wasted_bytes: u64of("wasted_bytes")?,
            },
            "client_join" => RunEvent::ClientJoin { round: us("round")?, client: us("client")? },
            "client_leave" => RunEvent::ClientLeave { round: us("round")?, client: us("client")? },
            other => bail!("unknown trace event '{other}'"),
        })
    }
}

fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) => Json::num(v),
        None => Json::Null,
    }
}

fn opt_f64(j: &Json) -> Result<Option<f64>> {
    match j {
        Json::Null => Ok(None),
        other => Ok(Some(other.as_f64()?)),
    }
}

fn client_secs_of(j: &Json) -> Result<Vec<(usize, f64)>> {
    j.as_arr()?
        .iter()
        .map(|pair| {
            let p = pair.as_arr()?;
            if p.len() != 2 {
                bail!("client_secs entry must be a [client, secs] pair");
            }
            Ok((p[0].as_usize()?, p[1].as_f64()?))
        })
        .collect()
}

fn digest_of(j: &Json) -> Result<Option<u64>> {
    match j {
        Json::Null => Ok(None),
        other => {
            let s = other.as_str()?;
            let hex = s
                .strip_prefix("0x")
                .ok_or_else(|| anyhow::anyhow!("digest must be a 0x… hex string, got '{s}'"))?;
            Ok(Some(u64::from_str_radix(hex, 16)?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn samples() -> Vec<RunEvent> {
        vec![
            RunEvent::RoundOpen { round: 0, phase: "setskel".into(), clock: 0.0 },
            RunEvent::Download { round: 0, client: 2, wire_bytes: 321, raw_bytes: 400 },
            RunEvent::MidroundDrop { round: 0, client: 3, wasted_bytes: 321 },
            RunEvent::Dispatch { round: 0, seq: 0, client: 2, bucket: 50 },
            RunEvent::Complete { round: 0, seq: 0, client: 2, loss: 1.25, secs: 0.125 },
            RunEvent::Upload {
                round: 0,
                seq: 0,
                client: 2,
                wire_bytes: 100,
                raw_bytes: 400,
                compressor: "topk".into(),
            },
            RunEvent::Exchange {
                round: 0,
                seq: 0,
                client: 2,
                up_params: 17,
                down_params: 38,
                up_wire: 100,
                down_wire: 321,
                up_raw: 400,
                down_raw: 400,
            },
            RunEvent::DeadlineDrop { round: 1, seq: 1, client: 0, wasted_bytes: 421 },
            RunEvent::StaleLand {
                round: 2,
                origin_round: 1,
                seq: 0,
                client: 1,
                staleness: 1,
                weight_scale: 0.7071067811865476,
            },
            RunEvent::Reselect { round: 0, client: 2, bucket: 50, k: vec![2, 8] },
            RunEvent::Eval { round: 1, new_acc: 0.625, local_acc: 0.71875 },
            RunEvent::RoundClose {
                round: 1,
                phase: "updateskel".into(),
                mean_loss: 0.8125,
                new_acc: Some(0.625),
                local_acc: None,
                comm_params: 140,
                comm_wire_bytes: 842,
                sim_secs: 0.3333333333333333,
                client_secs: vec![(2, 0.125), (0, 0.3333333333333333)],
                dropped: 1,
                stale: 0,
                wall_secs: 0.012,
                digest: Some(0xdead_beef_f00d_cafe),
            },
            RunEvent::CheckpointWrite {
                round: 2,
                path: "ckpt/snap_round_2.fsnap".into(),
                bytes: 4096,
            },
            RunEvent::Resume {
                round: 2,
                path: "ckpt/snap_round_2.fsnap".into(),
                clock: 1.5,
                in_flight: 1,
            },
            RunEvent::FaultRetry { round: 1, client: 2, wasted_bytes: 321 },
            RunEvent::ClientJoin { round: 0, client: 5 },
            RunEvent::ClientLeave { round: 3, client: 5 },
        ]
    }

    #[test]
    fn every_event_roundtrips_through_json_text() {
        for ev in samples() {
            let line = ev.to_json().to_string();
            let back = RunEvent::from_json(&json::parse(&line).unwrap()).unwrap();
            assert_eq!(ev, back, "{line}");
        }
    }

    #[test]
    fn digest_survives_as_hex_not_f64() {
        // 0xdeadbeeff00dcafe > 2^53: a JSON number would silently round
        let ev = samples()
            .into_iter()
            .find(|e| e.name() == "round_close")
            .unwrap();
        let line = ev.to_json().to_string();
        assert!(line.contains("\"digest\":\"0xdeadbeeff00dcafe\""), "{line}");
        match RunEvent::from_json(&json::parse(&line).unwrap()).unwrap() {
            RunEvent::RoundClose { digest, .. } => assert_eq!(digest, Some(0xdead_beef_f00d_cafe)),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn levels_are_ordered_and_assigned() {
        assert!(TraceLevel::Round < TraceLevel::Client);
        assert!(TraceLevel::Client < TraceLevel::Frame);
        for ev in samples() {
            match ev.name() {
                "round_open" | "round_close" | "eval" | "checkpoint_write" | "resume" => {
                    assert_eq!(ev.level(), TraceLevel::Round)
                }
                "download" | "upload" | "exchange" | "fault_retry" => {
                    assert_eq!(ev.level(), TraceLevel::Frame)
                }
                _ => assert_eq!(ev.level(), TraceLevel::Client),
            }
        }
        assert_eq!(TraceLevel::parse("CLIENT").unwrap(), TraceLevel::Client);
        assert!(TraceLevel::parse("verbose").is_err());
        assert_eq!(TraceLevel::Frame.name(), "frame");
    }

    #[test]
    fn strict_parse_rejects_unknown_and_missing() {
        let j = json::parse(r#"{"ev":"warp_drive","round":0}"#).unwrap();
        assert!(RunEvent::from_json(&j).is_err());
        let j = json::parse(r#"{"ev":"dispatch","round":0,"seq":1}"#).unwrap();
        let err = format!("{:#}", RunEvent::from_json(&j).unwrap_err());
        assert!(err.contains("client"), "{err}");
        // fractional where an integer is required
        let j = json::parse(r#"{"ev":"dispatch","round":0.5,"seq":1,"client":0,"bucket":50}"#)
            .unwrap();
        assert!(RunEvent::from_json(&j).is_err());
    }
}
