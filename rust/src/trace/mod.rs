//! Event-sourced run tracing, metrics registry, and replay.
//!
//! The coordinator narrates a run as a stream of [`RunEvent`]s instead of
//! mutating its logs in place. The stream has exactly one write path and
//! two kinds of consumer:
//!
//! * **sinks** ([`TraceSink`]) persist or buffer the events — [`NullSink`]
//!   (default, zero cost), [`JsonlSink`] (versioned append-only
//!   `trace.jsonl`), [`RingSink`] (bounded in-process buffer);
//! * **the fold** ([`fold`]) derives the run's tables — the
//!   [`crate::metrics::RunLog`], the [`crate::comm::CommLedger`], and the
//!   metrics [`registry::Registry`] — from the same events, both live in
//!   the coordinator and offline in [`replay`].
//!
//! Because live tables and replayed tables come from the same fold over
//! the same events, `fedskel report` reproduces a live run's CSV/JSON
//! byte for byte, and `fedskel watch` can render its dashboard from a
//! live tail or a recording with no second code path.
//!
//! Sinks are best-effort by design: a full disk mid-run degrades the
//! trace, never the training — write errors are swallowed after an
//! `eprintln!` warning (once) rather than propagated into `step_round`.

pub mod event;
pub mod fold;
pub mod registry;
pub mod replay;
pub mod watch;

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

pub use event::{RunEvent, TraceLevel, TRACE_SCHEMA, TRACE_VERSION};

use crate::util::json::Json;

/// A consumer of the event stream. `record` must be cheap and must not
/// fail: observability never aborts a run.
pub trait TraceSink {
    /// The coarsest level this sink wants; events above it are filtered
    /// out before `record` is called.
    fn level(&self) -> TraceLevel {
        TraceLevel::Frame
    }
    fn record(&mut self, ev: &RunEvent);
    /// Flush buffered output (called at run end and on round closes).
    fn flush(&mut self) {}
}

/// The zero-cost default: every event is dropped on the floor.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn record(&mut self, _ev: &RunEvent) {}
}

/// Appends the stream to a `trace.jsonl` file: one header record (schema
/// name, version, run config), then one JSON object per event. Buffered,
/// flushed on every `round_close` so a live `fedskel watch` tail sees
/// whole rounds.
pub struct JsonlSink {
    out: BufWriter<File>,
    level: TraceLevel,
    warned: bool,
}

impl JsonlSink {
    /// Create (truncate) `path` and write the schema header record.
    pub fn create(path: &Path, config: &Json, level: TraceLevel) -> Result<JsonlSink> {
        let file = File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        let mut sink = JsonlSink { out: BufWriter::new(file), level, warned: false };
        let header = Json::obj(vec![
            ("schema", Json::str(TRACE_SCHEMA)),
            ("version", Json::num(TRACE_VERSION as f64)),
            ("config", config.clone()),
        ]);
        sink.write_line(&header);
        Ok(sink)
    }

    fn write_line(&mut self, j: &Json) {
        let res = writeln!(self.out, "{}", j.to_string());
        if res.is_err() && !self.warned {
            self.warned = true;
            eprintln!("warning: trace write failed; trace will be incomplete");
        }
    }
}

impl TraceSink for JsonlSink {
    fn level(&self) -> TraceLevel {
        self.level
    }

    fn record(&mut self, ev: &RunEvent) {
        self.write_line(&ev.to_json());
        // Flush on checkpoint writes as well as round closes: a crash
        // right after a checkpoint must leave the trace and the `.fsnap`
        // consistent (the resume path replays the trace up to the
        // snapshot's round).
        if matches!(ev, RunEvent::RoundClose { .. } | RunEvent::CheckpointWrite { .. }) {
            self.flush();
        }
    }

    fn flush(&mut self) {
        let _ = self.out.flush();
    }
}

/// Bounded in-process buffer holding the most recent events, shared with
/// readers through a cloneable [`RingHandle`] — the hook an embedded
/// dashboard polls without touching the filesystem.
pub struct RingSink {
    buf: Arc<Mutex<VecDeque<RunEvent>>>,
    cap: usize,
    level: TraceLevel,
}

impl RingSink {
    pub fn new(cap: usize, level: TraceLevel) -> RingSink {
        RingSink { buf: Arc::new(Mutex::new(VecDeque::new())), cap: cap.max(1), level }
    }

    /// A cloneable reader for this sink's buffer.
    pub fn handle(&self) -> RingHandle {
        RingHandle { buf: Arc::clone(&self.buf) }
    }
}

impl TraceSink for RingSink {
    fn level(&self) -> TraceLevel {
        self.level
    }

    fn record(&mut self, ev: &RunEvent) {
        let mut buf = self.buf.lock().unwrap();
        if buf.len() == self.cap {
            buf.pop_front();
        }
        buf.push_back(ev.clone());
    }
}

/// Reader side of a [`RingSink`].
#[derive(Clone)]
pub struct RingHandle {
    buf: Arc<Mutex<VecDeque<RunEvent>>>,
}

impl RingHandle {
    /// Copy out the buffered events, oldest first.
    pub fn snapshot(&self) -> Vec<RunEvent> {
        self.buf.lock().unwrap().iter().cloned().collect()
    }
}

/// The coordinator's emission point: a fan-out over zero or more sinks.
/// With no sinks attached (`Trace::null()`), emission is a no-op and the
/// coordinator skips optional work like per-round digests.
#[derive(Default)]
pub struct Trace {
    sinks: Vec<Box<dyn TraceSink>>,
}

impl Trace {
    /// No sinks: the zero-cost default.
    pub fn null() -> Trace {
        Trace::default()
    }

    /// Whether any sink is attached (gates optional per-event work).
    pub fn active(&self) -> bool {
        !self.sinks.is_empty()
    }

    pub fn add_sink(&mut self, sink: Box<dyn TraceSink>) {
        self.sinks.push(sink);
    }

    /// Offer an event to every sink whose level includes it.
    pub fn emit(&mut self, ev: &RunEvent) {
        for sink in &mut self.sinks {
            if ev.level() <= sink.level() {
                sink.record(ev);
            }
        }
    }

    pub fn flush(&mut self) {
        for sink in &mut self.sinks {
            sink.flush();
        }
    }
}

static QUIET: AtomicBool = AtomicBool::new(false);

/// Suppress (`true`) or restore (`false`) human-oriented progress lines.
pub fn set_quiet(quiet: bool) {
    QUIET.store(quiet, Ordering::Relaxed);
}

/// Whether human-oriented progress output is currently suppressed.
pub fn quiet() -> bool {
    QUIET.load(Ordering::Relaxed)
}

/// Print a human-oriented progress line unless `--quiet` is in effect.
///
/// This is the single chokepoint for narrative output (config echoes,
/// fleet banners, per-round progress). Machine-read output — tables,
/// JSON, the `param digest:` line CI greps — never goes through here and
/// always prints.
pub fn human(line: &str) {
    if !quiet() {
        println!("{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev_round_open(round: usize) -> RunEvent {
        RunEvent::RoundOpen { round, phase: "updateskel".into(), clock: 0.0 }
    }

    fn ev_upload(round: usize) -> RunEvent {
        RunEvent::Upload {
            round,
            seq: 0,
            client: 0,
            wire_bytes: 10,
            raw_bytes: 40,
            compressor: "none".into(),
        }
    }

    #[test]
    fn null_trace_is_inactive_and_emits_nothing() {
        let mut t = Trace::null();
        assert!(!t.active());
        t.emit(&ev_round_open(0)); // must not panic
        t.flush();
    }

    #[test]
    fn ring_sink_caps_and_snapshots_in_order() {
        let ring = RingSink::new(3, TraceLevel::Frame);
        let handle = ring.handle();
        let mut t = Trace::null();
        t.add_sink(Box::new(ring));
        assert!(t.active());
        for r in 0..5 {
            t.emit(&ev_round_open(r));
        }
        let snap = handle.snapshot();
        assert_eq!(snap.len(), 3);
        match (&snap[0], &snap[2]) {
            (RunEvent::RoundOpen { round: a, .. }, RunEvent::RoundOpen { round: b, .. }) => {
                assert_eq!((*a, *b), (2, 4));
            }
            other => panic!("wrong events {other:?}"),
        }
    }

    #[test]
    fn level_filter_drops_finer_events() {
        let ring = RingSink::new(16, TraceLevel::Round);
        let handle = ring.handle();
        let mut t = Trace::null();
        t.add_sink(Box::new(ring));
        t.emit(&ev_round_open(0));
        t.emit(&ev_upload(0)); // Frame > Round: filtered
        assert_eq!(handle.snapshot().len(), 1);
    }

    #[test]
    fn jsonl_sink_writes_header_and_events() {
        let dir = std::env::temp_dir().join("fedskel-trace-sink-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let cfg = Json::obj(vec![("rounds", Json::num(2.0))]);
        let mut sink = JsonlSink::create(&path, &cfg, TraceLevel::Frame).unwrap();
        sink.record(&ev_round_open(0));
        sink.record(&ev_upload(0));
        sink.flush();
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"schema\":\"fedskel.trace\""), "{}", lines[0]);
        assert!(lines[0].contains("\"version\":1"), "{}", lines[0]);
        assert!(lines[1].contains("\"ev\":\"round_open\""), "{}", lines[1]);
        assert!(lines[2].contains("\"ev\":\"upload\""), "{}", lines[2]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn jsonl_sink_flushes_on_checkpoint_write_without_drop() {
        // Regression: checkpoint_write must hit the disk immediately (not
        // wait for the next round_close or the sink's drop), so a crash
        // right after a checkpoint leaves trace and .fsnap consistent.
        let dir = std::env::temp_dir().join("fedskel-trace-ckpt-flush-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let cfg = Json::obj(vec![("rounds", Json::num(1.0))]);
        let mut sink = JsonlSink::create(&path, &cfg, TraceLevel::Frame).unwrap();
        sink.record(&RunEvent::CheckpointWrite {
            round: 0,
            path: "snap_round_1.fsnap".into(),
            bytes: 123,
        });
        // read while the sink is still alive: only a flush makes the
        // event visible
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"ev\":\"checkpoint_write\""), "{text}");
        drop(sink);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quiet_gates_human_lines() {
        // no capture of stdout here; just exercise the toggle round-trip
        set_quiet(true);
        assert!(quiet());
        human("suppressed");
        set_quiet(false);
        assert!(!quiet());
    }
}
