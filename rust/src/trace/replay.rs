//! Replay a recorded `trace.jsonl` into the run's derived tables.
//!
//! Parsing is strict on purpose: every line must be valid JSON, the
//! header must carry the expected schema name and a version we know, and
//! every event must satisfy [`RunEvent::from_json`]'s field checks — so
//! `fedskel report` doubles as a schema validator for CI. Errors carry
//! the 1-based line number of the offending record.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::Table;
use crate::util::json::{self, Json};

use super::event::{RunEvent, TRACE_SCHEMA, TRACE_VERSION};
use super::fold::Folder;

/// A fully folded trace: the header metadata plus the derived tables.
pub struct Replay {
    /// Schema version the trace was recorded under.
    pub version: u64,
    /// The recording run's config summary (the header's `config` object).
    pub config: Json,
    /// The tables folded from the event stream.
    pub folder: Folder,
    /// Number of events folded (header excluded).
    pub events: usize,
}

/// Read and fold a trace file. See [`parse_trace`].
pub fn read_trace(path: &Path) -> Result<Replay> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    parse_trace(&text).with_context(|| format!("parsing trace {}", path.display()))
}

/// Strictly parse and fold a trace: header line first, then one event
/// per line. Partial trailing lines (a live file mid-write) are an
/// error here — [`super::watch`] trims to the last newline before
/// calling this.
pub fn parse_trace(text: &str) -> Result<Replay> {
    let mut lines = text.lines().enumerate();
    let (_, header_line) = match lines.next() {
        Some(first) => first,
        None => bail!("empty trace (no header record)"),
    };
    let header = json::parse(header_line).context("line 1: bad header JSON")?;
    let schema = header.get("schema")?.as_str()?;
    if schema != TRACE_SCHEMA {
        bail!("line 1: schema '{schema}' is not '{TRACE_SCHEMA}'");
    }
    let version = header.get("version")?.as_usize()? as u64;
    if version > TRACE_VERSION {
        bail!("line 1: trace version {version} is newer than supported {TRACE_VERSION}");
    }
    let config = header.get("config")?.clone();

    let mut folder = Folder::new();
    let mut events = 0usize;
    for (idx, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        let lineno = idx + 1;
        let j = json::parse(line).with_context(|| format!("line {lineno}: bad JSON"))?;
        let ev = RunEvent::from_json(&j).with_context(|| format!("line {lineno}: bad event"))?;
        folder.apply(&ev);
        events += 1;
    }
    Ok(Replay { version, config, folder, events })
}

/// The `fedskel report` summary table: run outcome, traffic accounting
/// (including wasted wire bytes), and scheduler health, all derived from
/// the folded tables and registry.
pub fn summary_table(replay: &Replay) -> String {
    let log = &replay.folder.log;
    let ledger = &replay.folder.ledger;
    let reg = &replay.folder.registry;
    let acc = |x: Option<f64>| match x {
        Some(a) => format!("{:.2}%", a * 100.0),
        None => "-".to_string(),
    };
    let method = replay
        .config
        .opt("method")
        .and_then(|m| m.as_str().ok())
        .unwrap_or("?")
        .to_string();
    let util = match reg.gauge("run/utilization") {
        Some(u) => format!("{:.1}%", u * 100.0),
        None => "-".to_string(),
    };
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["method".into(), method]);
    t.row(vec!["rounds".into(), log.rounds.len().to_string()]);
    t.row(vec!["final new acc".into(), acc(log.last_new_acc())]);
    t.row(vec!["final local acc".into(), acc(log.last_local_acc())]);
    t.row(vec!["comm params".into(), ledger.total_params().to_string()]);
    t.row(vec!["upload wire bytes".into(), ledger.upload_wire_bytes.to_string()]);
    t.row(vec!["download wire bytes".into(), ledger.download_wire_bytes.to_string()]);
    t.row(vec!["raw bytes (dense f32)".into(), ledger.total_raw_bytes().to_string()]);
    t.row(vec!["compression ratio".into(), format!("{:.2}x", ledger.compression_ratio())]);
    t.row(vec!["wasted wire bytes".into(), ledger.wasted_wire_bytes.to_string()]);
    t.row(vec!["fleet utilization (last round)".into(), util]);
    t.row(vec![
        "drops (mid-round / deadline)".into(),
        format!(
            "{} / {}",
            reg.counter("sched/drops_midround"),
            reg.counter("sched/drops_deadline")
        ),
    ]);
    t.row(vec!["stale landings".into(), reg.counter("sched/stale_landings").to_string()]);
    t.row(vec![
        "checkpoints / resumes".into(),
        format!("{} / {}", reg.counter("run/checkpoints"), reg.counter("run/resumes")),
    ]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_trace() -> String {
        let header = Json::obj(vec![
            ("schema", Json::str(TRACE_SCHEMA)),
            ("version", Json::num(TRACE_VERSION as f64)),
            ("config", Json::obj(vec![("method", Json::str("fedskel"))])),
        ]);
        let events = [
            RunEvent::RoundOpen { round: 0, phase: "setskel".into(), clock: 0.0 },
            RunEvent::Exchange {
                round: 0,
                seq: 0,
                client: 0,
                up_params: 17,
                down_params: 38,
                up_wire: 100,
                down_wire: 300,
                up_raw: 200,
                down_raw: 600,
            },
            RunEvent::DeadlineDrop { round: 0, seq: 1, client: 1, wasted_bytes: 250 },
            RunEvent::RoundClose {
                round: 0,
                phase: "setskel".into(),
                mean_loss: 1.25,
                new_acc: Some(0.5),
                local_acc: Some(0.625),
                comm_params: 55,
                comm_wire_bytes: 400,
                sim_secs: 1.0,
                client_secs: vec![(0, 0.5), (1, 1.0)],
                dropped: 1,
                stale: 0,
                wall_secs: 0.02,
                digest: None,
            },
            RunEvent::Eval { round: 0, new_acc: 0.5, local_acc: 0.625 },
        ];
        let mut text = header.to_string();
        text.push('\n');
        for ev in &events {
            text.push_str(&ev.to_json().to_string());
            text.push('\n');
        }
        text
    }

    #[test]
    fn parses_and_folds_a_recorded_trace() {
        let r = parse_trace(&mini_trace()).unwrap();
        assert_eq!(r.version, TRACE_VERSION);
        assert_eq!(r.events, 5);
        assert_eq!(r.folder.log.rounds.len(), 1);
        assert_eq!(r.folder.log.last_new_acc(), Some(0.5));
        assert_eq!(r.folder.ledger.wasted_wire_bytes, 250);
        assert_eq!(r.folder.ledger.total_wire_bytes(), 400);
        assert_eq!(r.folder.registry.counter("sched/drops_deadline"), 1);
    }

    #[test]
    fn summary_surfaces_waste_and_utilization() {
        let r = parse_trace(&mini_trace()).unwrap();
        let s = summary_table(&r);
        assert!(s.contains("wasted wire bytes"), "{s}");
        assert!(s.contains("250"), "{s}");
        assert!(s.contains("fleet utilization"), "{s}");
        // (0.5 + 1.0) busy over 2 × 1.0 makespan = 75.0%
        assert!(s.contains("75.0%"), "{s}");
        assert!(s.contains("fedskel"), "{s}");
        assert!(s.contains("compression ratio"), "{s}");
    }

    #[test]
    fn rejects_wrong_schema_version_and_corrupt_lines() {
        assert!(parse_trace("").is_err());
        let wrong = r#"{"schema":"other.trace","version":1,"config":{}}"#;
        assert!(parse_trace(wrong).is_err());
        let newer = format!(
            r#"{{"schema":"{TRACE_SCHEMA}","version":{},"config":{{}}}}"#,
            TRACE_VERSION + 1
        );
        assert!(parse_trace(&newer).is_err());
        let mut corrupt = mini_trace();
        corrupt.push_str("{\"ev\":\"round_open\",\"round\":");
        let err = format!("{:#}", parse_trace(&corrupt).unwrap_err());
        assert!(err.contains("line 7"), "{err}");
    }
}
