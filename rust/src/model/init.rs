//! Host-side parameter initialization.
//!
//! Mirrors `python/compile/model.py::init_params`' *scheme* (He / Glorot
//! normal by fan-in/fan-out, zero biases, unit norm scales) with the
//! coordinator's own RNG. Bitwise equality with the JAX initializer is not
//! required — clients all start from the server's params anyway — but the
//! statistics must match so the artifacts see well-conditioned weights
//! (python/tests/test_model.py::test_init_statistics pins the scheme).

use crate::model::{Params, spec::ModelSpec};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Initialize a full parameter set for `spec` from `seed`.
pub fn init_params(spec: &ModelSpec, seed: u64) -> Params {
    let mut rng = Rng::new(seed ^ 0x5EED_1234_ABCD_0001);
    spec.params
        .iter()
        .map(|p| {
            let numel = p.numel();
            match p.init.as_str() {
                "zeros" => Tensor::zeros(&p.shape),
                "ones" => {
                    let mut t = Tensor::zeros(&p.shape);
                    t.data_mut().fill(1.0);
                    t
                }
                init => {
                    let (fan_in, fan_out) = fans(&p.shape);
                    let std = match init {
                        "he" => (2.0f32 / fan_in as f32).sqrt(),
                        "glorot" => (2.0f32 / (fan_in + fan_out) as f32).sqrt(),
                        other => panic!("unknown init scheme '{other}'"),
                    };
                    let data = (0..numel).map(|_| rng.normal_scaled(std)).collect();
                    Tensor::from_vec(&p.shape, data).expect("init shape")
                }
            }
        })
        .collect()
}

/// (fan_in, fan_out) matching the python convention: fan_in is the product
/// of all leading dims, fan_out the trailing dim.
fn fans(shape: &[usize]) -> (usize, usize) {
    if shape.len() <= 1 {
        let n = shape.first().copied().unwrap_or(1);
        (n, n)
    } else {
        let fan_out = *shape.last().unwrap();
        let fan_in: usize = shape[..shape.len() - 1].iter().product();
        (fan_in, fan_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{ParamSpec, PrunableSpec};
    use std::collections::BTreeMap;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            input_shape: vec![28, 28, 1],
            num_classes: 10,
            train_batch: 8,
            eval_batch: 8,
            num_params: 5 * 5 * 1 * 6 + 6 + 84 * 10 + 10,
            params: vec![
                ParamSpec { name: "c.w".into(), shape: vec![5, 5, 1, 6], init: "he".into() },
                ParamSpec { name: "c.b".into(), shape: vec![6], init: "zeros".into() },
                ParamSpec { name: "f.w".into(), shape: vec![84, 10], init: "glorot".into() },
                ParamSpec { name: "f.b".into(), shape: vec![10], init: "zeros".into() },
            ],
            prunable: vec![PrunableSpec { name: "c".into(), channels: 6, weight_param: 0, bias_param: 1 }],
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn shapes_and_zero_biases() {
        let p = init_params(&spec(), 0);
        assert_eq!(p.len(), 4);
        assert_eq!(p[0].shape(), &[5, 5, 1, 6]);
        assert!(p[1].data().iter().all(|&x| x == 0.0));
        assert!(p[3].data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn he_std_matches_scheme() {
        // big fc layer for tight statistics
        let s = ModelSpec {
            params: vec![ParamSpec { name: "w".into(), shape: vec![400, 120], init: "he".into() }],
            num_params: 48000,
            prunable: vec![],
            ..spec()
        };
        let p = init_params(&s, 3);
        let data = p[0].data();
        let mean: f32 = data.iter().sum::<f32>() / data.len() as f32;
        let var: f32 = data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / data.len() as f32;
        let want = 2.0 / 400.0;
        assert!((var - want).abs() / want < 0.1, "var {var} want {want}");
        assert!(mean.abs() < 0.01);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = init_params(&spec(), 7);
        let b = init_params(&spec(), 7);
        let c = init_params(&spec(), 8);
        assert_eq!(a[0].data(), b[0].data());
        assert_ne!(a[0].data(), c[0].data());
    }
}
