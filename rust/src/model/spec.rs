//! Manifest schema: the python→rust AOT contract.
//!
//! Mirrors the JSON emitted by `python/compile/aot.py`. Field-for-field —
//! if you change the manifest format, change both sides and bump `version`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// One parameter tensor of a model.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "he" | "glorot" | "zeros" | "ones" — mirrored by `init_params`.
    pub init: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One skeleton-prunable layer.
#[derive(Debug, Clone)]
pub struct PrunableSpec {
    pub name: String,
    /// Output-channel count C_l (skeleton candidates).
    pub channels: usize,
    /// Index into the flat param list of this layer's weight tensor.
    pub weight_param: usize,
    /// Index of the bias tensor.
    pub bias_param: usize,
}

/// Dtype of an artifact argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One positional input/output of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// "train" | "eval" | "convbwd".
    pub kind: String,
    pub file: String,
    /// Skeleton ratio in percent (train/convbwd only).
    pub ratio: Option<usize>,
    pub batch: usize,
    /// Per-prunable-layer skeleton sizes k_l (train/convbwd only).
    pub k: Vec<usize>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One model entry of the manifest.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub num_params: usize,
    pub params: Vec<ParamSpec>,
    pub prunable: Vec<PrunableSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ModelSpec {
    /// Ratio buckets for which a train artifact exists, ascending.
    pub fn train_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.kind == "train")
            .filter_map(|a| a.ratio)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Nearest available bucket ≥quantization of a requested ratio in
    /// percent (clients get *at least* a bucket that can express their
    /// skeleton; we round to the nearest, ties upward).
    pub fn quantize_ratio(&self, ratio_pct: f64) -> Result<usize> {
        let buckets = self.train_buckets();
        if buckets.is_empty() {
            bail!("model {} has no train artifacts", self.name);
        }
        let mut best = buckets[0];
        let mut best_d = f64::MAX;
        for &b in &buckets {
            let d = (b as f64 - ratio_pct).abs();
            if d < best_d || (d == best_d && b > best) {
                best = b;
                best_d = d;
            }
        }
        Ok(best)
    }

    pub fn train_artifact(&self, bucket: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(&format!("train_r{bucket}"))
            .with_context(|| format!("model {}: no train_r{bucket} artifact", self.name))
    }

    pub fn eval_artifact(&self) -> Result<&ArtifactSpec> {
        self.artifacts
            .get("eval")
            .with_context(|| format!("model {}: no eval artifact", self.name))
    }

    /// Skeleton sizes k_l for a bucket: max(1, ceil(r/100 · C_l)).
    pub fn skel_sizes(&self, bucket: usize) -> Vec<usize> {
        self.prunable.iter().map(|p| skel_k(p.channels, bucket)).collect()
    }
}

/// Skeleton size for one prunable layer at ratio-bucket `bucket`
/// (percent): `max(1, ceil(bucket/100 · channels))`. The single
/// implementation of the bucket→k rule — manifest-backed specs
/// ([`ModelSpec::skel_sizes`]) and the native backend's synthetic specs
/// both call this, so they can never diverge.
pub fn skel_k(channels: usize, bucket: usize) -> usize {
    (((bucket as f64 / 100.0) * channels as f64).ceil() as usize).max(1)
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
    /// bench probes: group -> variant -> artifact.
    pub bench: BTreeMap<String, BTreeMap<String, ArtifactSpec>>,
}

fn parse_dtype(s: &str) -> Result<Dtype> {
    match s {
        "f32" => Ok(Dtype::F32),
        "i32" => Ok(Dtype::I32),
        _ => bail!("unknown dtype {s}"),
    }
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape: j.get("shape")?.as_usize_vec()?,
        dtype: parse_dtype(j.get("dtype")?.as_str()?)?,
    })
}

fn parse_artifact(j: &Json) -> Result<ArtifactSpec> {
    Ok(ArtifactSpec {
        kind: j.get("kind")?.as_str()?.to_string(),
        file: j.get("file")?.as_str()?.to_string(),
        ratio: j.opt("ratio").map(|r| r.as_usize()).transpose()?,
        batch: j.get("batch")?.as_usize()?,
        k: match j.opt("k") {
            Some(k) => k.as_usize_vec()?,
            None => vec![],
        },
        inputs: j.get("inputs")?.as_arr()?.iter().map(parse_io).collect::<Result<_>>()?,
        outputs: j.get("outputs")?.as_arr()?.iter().map(parse_io).collect::<Result<_>>()?,
    })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = json::parse(&text).context("parsing manifest.json")?;
        if j.get("version")?.as_usize()? != 1 {
            bail!("unsupported manifest version");
        }

        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            let params: Vec<ParamSpec> = mj
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p.get("shape")?.as_usize_vec()?,
                        init: p.get("init")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<_>>()?;
            let prunable: Vec<PrunableSpec> = mj
                .get("prunable")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(PrunableSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        channels: p.get("channels")?.as_usize()?,
                        weight_param: p.get("weight_param")?.as_usize()?,
                        bias_param: p.get("bias_param")?.as_usize()?,
                    })
                })
                .collect::<Result<_>>()?;
            let mut artifacts = BTreeMap::new();
            for (aname, aj) in mj.get("artifacts")?.as_obj()? {
                artifacts.insert(aname.clone(), parse_artifact(aj)?);
            }
            let spec = ModelSpec {
                name: name.clone(),
                input_shape: mj.get("input_shape")?.as_usize_vec()?,
                num_classes: mj.get("num_classes")?.as_usize()?,
                train_batch: mj.get("train_batch")?.as_usize()?,
                eval_batch: mj.get("eval_batch")?.as_usize()?,
                num_params: mj.get("num_params")?.as_usize()?,
                params,
                prunable,
                artifacts,
            };
            spec.validate()?;
            models.insert(name.clone(), spec);
        }

        let mut bench = BTreeMap::new();
        if let Some(bj) = j.opt("bench") {
            for (group, gj) in bj.as_obj()? {
                let mut variants = BTreeMap::new();
                for (vname, vj) in gj.as_obj()? {
                    variants.insert(vname.clone(), parse_artifact(vj)?);
                }
                bench.insert(group.clone(), variants);
            }
        }

        Ok(Manifest { dir, models, bench })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest (have: {:?})", self.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact_path(&self, a: &ArtifactSpec) -> PathBuf {
        self.dir.join(&a.file)
    }
}

impl ModelSpec {
    /// Internal consistency checks (the manifest is trusted by the runtime,
    /// so validate once at load).
    fn validate(&self) -> Result<()> {
        let total: usize = self.params.iter().map(|p| p.numel()).sum();
        if total != self.num_params {
            bail!("model {}: num_params {} != sum {}", self.name, self.num_params, total);
        }
        for pr in &self.prunable {
            if pr.weight_param >= self.params.len() || pr.bias_param >= self.params.len() {
                bail!("model {}: prunable {} param index OOB", self.name, pr.name);
            }
            let w = &self.params[pr.weight_param];
            if *w.shape.last().unwrap() != pr.channels {
                bail!(
                    "model {}: prunable {} channels {} != weight last dim {:?}",
                    self.name,
                    pr.name,
                    pr.channels,
                    w.shape
                );
            }
        }
        for (aname, a) in &self.artifacts {
            if a.kind == "train" {
                let expect = 2 * self.params.len() + 2 + self.prunable.len() + 2;
                if a.inputs.len() != expect {
                    bail!("model {}: artifact {} has {} inputs, want {expect}", self.name, aname, a.inputs.len());
                }
                let expect_out = self.params.len() + 1 + self.prunable.len();
                if a.outputs.len() != expect_out {
                    bail!("model {}: artifact {} outputs {} != {expect_out}", self.name, aname, a.outputs.len());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            input_shape: vec![4, 4, 1],
            num_classes: 2,
            train_batch: 8,
            eval_batch: 8,
            num_params: 14,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![3, 4], init: "he".into() },
                ParamSpec { name: "b".into(), shape: vec![2], init: "zeros".into() },
            ],
            prunable: vec![PrunableSpec {
                name: "w".into(),
                channels: 4,
                weight_param: 0,
                bias_param: 1,
            }],
            artifacts: [
                ("train_r10".to_string(), art("train", Some(10))),
                ("train_r50".to_string(), art("train", Some(50))),
                ("train_r100".to_string(), art("train", Some(100))),
                ("eval".to_string(), art("eval", None)),
            ]
            .into_iter()
            .collect(),
        }
    }

    fn art(kind: &str, ratio: Option<usize>) -> ArtifactSpec {
        ArtifactSpec {
            kind: kind.into(),
            file: "x.hlo.txt".into(),
            ratio,
            batch: 8,
            k: vec![],
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn buckets_sorted() {
        assert_eq!(toy_spec().train_buckets(), vec![10, 50, 100]);
    }

    #[test]
    fn quantize_nearest_ties_up() {
        let s = toy_spec();
        assert_eq!(s.quantize_ratio(10.0).unwrap(), 10);
        assert_eq!(s.quantize_ratio(29.0).unwrap(), 10);
        assert_eq!(s.quantize_ratio(31.0).unwrap(), 50);
        assert_eq!(s.quantize_ratio(30.0).unwrap(), 50); // tie → up
        assert_eq!(s.quantize_ratio(99.0).unwrap(), 100);
    }

    #[test]
    fn skel_sizes_ceil_min1() {
        let s = toy_spec();
        assert_eq!(s.skel_sizes(100), vec![4]);
        assert_eq!(s.skel_sizes(10), vec![1]);
        assert_eq!(s.skel_sizes(30), vec![2]);
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        let lenet = m.model("lenet_smnist").unwrap();
        assert_eq!(lenet.num_classes, 10);
        assert_eq!(lenet.prunable.len(), 4);
        assert_eq!(lenet.skel_sizes(10), vec![1, 2, 12, 9]);
        assert!(lenet.train_buckets().contains(&100));
        // every referenced file exists
        for a in lenet.artifacts.values() {
            assert!(m.artifact_path(a).exists(), "{}", a.file);
        }
    }
}
