//! Model metadata mirrored from `artifacts/manifest.json`, plus host-side
//! parameter initialization and flat-parameter utilities.
//!
//! The L3 coordinator never re-derives model structure: the AOT pipeline
//! (python/compile/aot.py) is the single source of truth and records every
//! model's parameter table, prunable layers, and per-artifact I/O contract
//! in the manifest. This module loads that contract.
//!
//! Paper: the prunable-layer table is the §3.1 skeleton substrate;
//! per-bucket `k` sizes drive Table 1's ratios and Table 2's volumes.
//! Invariant: parameter order is manifest order everywhere (artifacts,
//! wire frames, aggregation, [`params_digest`]).

pub mod init;
pub mod spec;

pub use init::init_params;
pub use spec::{ArtifactSpec, IoSpec, Manifest, ModelSpec, ParamSpec, PrunableSpec};

use crate::tensor::Tensor;

/// A model's full parameter set, ordered exactly as the manifest's param
/// table (and therefore exactly as the train artifact's leading inputs).
pub type Params = Vec<Tensor>;

/// Total number of scalar parameters.
pub fn num_scalars(params: &Params) -> usize {
    params.iter().map(|t| t.len()).sum()
}

/// Elementwise `a - b` across a whole parameter set (update deltas).
pub fn params_sub(a: &Params, b: &Params) -> crate::Result<Params> {
    a.iter().zip(b).map(|(x, y)| x.sub(y)).collect()
}

/// Deep-copy helper (Params is a Vec<Tensor> so clone is deep already, but
/// the name documents intent at call sites).
pub fn params_clone(p: &Params) -> Params {
    p.clone()
}

/// Order-sensitive FNV-1a digest over every parameter byte (LE f32).
///
/// A cheap bitwise fingerprint of a whole model: CI trains at 1 and 2
/// threads and fails if the digests differ, pinning the parallel kernels'
/// determinism contract end-to-end (`fedskel train` prints it after the
/// final eval).
pub fn params_digest(params: &Params) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for t in params {
        for v in t.data() {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_scalars_sums() {
        let p = vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[5])];
        assert_eq!(num_scalars(&p), 11);
    }

    #[test]
    fn params_digest_is_order_and_value_sensitive() {
        let a = vec![Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap()];
        let b = vec![Tensor::from_vec(&[2], vec![2.0, 1.0]).unwrap()];
        assert_eq!(params_digest(&a), params_digest(&a));
        assert_ne!(params_digest(&a), params_digest(&b));
        let mut c = a.clone();
        c[0].data_mut()[0] = f32::from_bits(a[0].data()[0].to_bits() ^ 1);
        assert_ne!(params_digest(&a), params_digest(&c), "single-bit flip must change digest");
    }

    #[test]
    fn params_sub_works() {
        let a = vec![Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap()];
        let b = vec![Tensor::from_vec(&[2], vec![1.0, 1.0]).unwrap()];
        let d = params_sub(&a, &b).unwrap();
        assert_eq!(d[0].data(), &[2.0, 3.0]);
    }
}
