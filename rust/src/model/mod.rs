//! Model metadata mirrored from `artifacts/manifest.json`, plus host-side
//! parameter initialization and flat-parameter utilities.
//!
//! The L3 coordinator never re-derives model structure: the AOT pipeline
//! (python/compile/aot.py) is the single source of truth and records every
//! model's parameter table, prunable layers, and per-artifact I/O contract
//! in the manifest. This module loads that contract.

pub mod init;
pub mod spec;

pub use init::init_params;
pub use spec::{ArtifactSpec, IoSpec, Manifest, ModelSpec, ParamSpec, PrunableSpec};

use crate::tensor::Tensor;

/// A model's full parameter set, ordered exactly as the manifest's param
/// table (and therefore exactly as the train artifact's leading inputs).
pub type Params = Vec<Tensor>;

/// Total number of scalar parameters.
pub fn num_scalars(params: &Params) -> usize {
    params.iter().map(|t| t.len()).sum()
}

/// Elementwise `a - b` across a whole parameter set (update deltas).
pub fn params_sub(a: &Params, b: &Params) -> crate::Result<Params> {
    a.iter().zip(b).map(|(x, y)| x.sub(y)).collect()
}

/// Deep-copy helper (Params is a Vec<Tensor> so clone is deep already, but
/// the name documents intent at call sites).
pub fn params_clone(p: &Params) -> Params {
    p.clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_scalars_sums() {
        let p = vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[5])];
        assert_eq!(num_scalars(&p), 11);
    }

    #[test]
    fn params_sub_works() {
        let a = vec![Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap()];
        let b = vec![Tensor::from_vec(&[2], vec![1.0, 1.0]).unwrap()];
        let d = params_sub(&a, &b).unwrap();
        assert_eq!(d[0].data(), &[2.0, 3.0]);
    }
}
