//! Per-client state: data shard, capability, ratio/bucket, skeleton,
//! local (personalized) parameters, importance statistics.
//!
//! Paper: one instance = one edge device of §4's testbed (its ratio
//! bucket realizes `r_i ∝ c_i`, §3.2; its accumulated importance drives
//! §3.1 skeleton re-selection). Invariant: the batcher is per-client
//! deterministic, so a round's minibatches depend only on (seed, client,
//! step) — never on scheduling.

use crate::compress::Residual;
use crate::data::shard::{Batcher, Split};
use crate::model::Params;
use crate::skeleton::ImportanceAccumulator;

/// One simulated federated client.
pub struct ClientState {
    pub id: usize,
    pub split: Split,
    /// Compute capability c_i ∈ (0,1], reported to the server (§3.2).
    pub capability: f64,
    /// Assigned skeleton ratio r_i ∈ (0,1].
    pub ratio: f64,
    /// Quantized ratio bucket (an available train artifact).
    pub bucket: usize,
    /// Per-prunable-layer skeleton channel indices (sized for `bucket`).
    pub skeleton: Vec<Vec<i32>>,
    /// Personalized parameters (what Local Test evaluates).
    pub local_params: Params,
    /// Importance integrator for SetSkel processes.
    pub importance: ImportanceAccumulator,
    /// Minibatch source over the train shard.
    pub batcher: Batcher,
    /// Most recent local training loss.
    pub last_loss: f32,
    /// Error-feedback residual for compressed uploads
    /// ([`crate::compress`]): per-parameter accumulated difference
    /// between this client's true updates and their decoded compressed
    /// forms. Empty until the first compressed upload with
    /// `--error-feedback`; lives with the client because the residual is
    /// client-local state the server never sees.
    pub ef_residual: Residual,
}

impl ClientState {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        split: Split,
        capability: f64,
        params: Params,
        prunable_channels: &[usize],
        batch: usize,
        seed: u64,
    ) -> ClientState {
        let batcher = Batcher::new(split.train.clone(), batch, seed ^ (id as u64) << 17);
        ClientState {
            id,
            split,
            capability,
            ratio: 1.0,
            bucket: 100,
            skeleton: crate::skeleton::identity_skeleton(prunable_channels),
            local_params: params,
            importance: ImportanceAccumulator::new(prunable_channels),
            batcher,
            last_loss: f32::NAN,
            ef_residual: Vec::new(),
        }
    }

    /// Local sample count (FedAvg aggregation weight).
    pub fn weight(&self) -> f64 {
        self.split.train.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Dataset, DatasetKind};
    use crate::data::shard::non_iid_shards;
    use crate::tensor::Tensor;

    #[test]
    fn construct_client() {
        let d = Dataset::generate(DatasetKind::Smnist, 100, 0);
        let splits = non_iid_shards(&d, 2, 2, 0.2, 0).unwrap();
        let params = vec![Tensor::zeros(&[2, 4])];
        let c = ClientState::new(0, splits[0].clone(), 0.5, params, &[4], 8, 0);
        assert_eq!(c.skeleton[0], vec![0, 1, 2, 3]);
        assert_eq!(c.weight(), 40.0);
        assert_eq!(c.bucket, 100);
    }
}
