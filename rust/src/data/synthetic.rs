//! Synthetic class-conditional image datasets ("smnist", "sfemnist",
//! "scifar10", "scifar100").
//!
//! Each class c gets a deterministic template built from a few smooth
//! Gaussian blobs plus a class-keyed frequency pattern; a sample is
//! `amplitude · template + pixel noise`. This yields datasets that
//!   * a CNN can genuinely learn (distinct spatial structure per class),
//!   * produce *category-related filters* — the phenomenon (Yu 2018) that
//!     skeleton selection exploits — because different classes activate
//!     different blob/frequency detectors,
//!   * are hard enough that the global-vs-local accuracy gap the paper
//!     reports (Tables 3–4) is visible under non-IID shards.

use anyhow::{bail, Result};

use crate::util::Rng;

/// Which synthetic dataset to generate. Shapes/class counts mirror the
/// paper's datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 28×28×1, 10 classes (MNIST stand-in).
    Smnist,
    /// 28×28×1, 62 classes (FEMNIST stand-in).
    Sfemnist,
    /// 32×32×3, 10 classes (CIFAR-10 stand-in).
    Scifar10,
    /// 32×32×3, 100 classes (CIFAR-100 stand-in).
    Scifar100,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Result<DatasetKind> {
        Ok(match s {
            "smnist" => DatasetKind::Smnist,
            "sfemnist" => DatasetKind::Sfemnist,
            "scifar10" => DatasetKind::Scifar10,
            "scifar100" => DatasetKind::Scifar100,
            _ => bail!("unknown dataset '{s}' (smnist|sfemnist|scifar10|scifar100)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Smnist => "smnist",
            DatasetKind::Sfemnist => "sfemnist",
            DatasetKind::Scifar10 => "scifar10",
            DatasetKind::Scifar100 => "scifar100",
        }
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        match self {
            DatasetKind::Smnist | DatasetKind::Sfemnist => (28, 28, 1),
            DatasetKind::Scifar10 | DatasetKind::Scifar100 => (32, 32, 3),
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::Smnist | DatasetKind::Scifar10 => 10,
            DatasetKind::Sfemnist => 62,
            DatasetKind::Scifar100 => 100,
        }
    }

    /// The model name in the AOT manifest that consumes this dataset with
    /// LeNet (Table 3's rows).
    pub fn lenet_model(&self) -> &'static str {
        match self {
            DatasetKind::Smnist => "lenet_smnist",
            DatasetKind::Sfemnist => "lenet_sfemnist",
            DatasetKind::Scifar10 => "lenet_scifar10",
            DatasetKind::Scifar100 => "lenet_scifar100",
        }
    }
}

/// An in-memory labelled image set (row-major NHWC f32).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub images: Vec<f32>,
    pub labels: Vec<u32>,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image_numel(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Copy sample `i`'s pixels into `out`.
    pub fn copy_image(&self, i: usize, out: &mut [f32]) {
        let n = self.image_numel();
        out.copy_from_slice(&self.images[i * n..(i + 1) * n]);
    }

    /// Contiguous sub-dataset `[start, end)` — used to carve an IID
    /// New-Test pool off the tail of one generation run (same class
    /// templates, disjoint samples).
    pub fn subset(&self, start: usize, end: usize) -> Dataset {
        assert!(start <= end && end <= self.len());
        let numel = self.image_numel();
        Dataset {
            kind: self.kind,
            images: self.images[start * numel..end * numel].to_vec(),
            labels: self.labels[start..end].to_vec(),
            h: self.h,
            w: self.w,
            c: self.c,
        }
    }

    /// Generate `n` samples of `kind` with the given seed. Class balance is
    /// uniform; samples are shuffled (the non-IID structure comes from the
    /// shard splitter, not from generation order).
    pub fn generate(kind: DatasetKind, n: usize, seed: u64) -> Dataset {
        let (h, w, c) = kind.shape();
        let classes = kind.num_classes();
        let templates = ClassTemplates::build(kind, seed);
        let mut rng = Rng::new(seed ^ 0xDA7A_5E7_0001);

        let mut order: Vec<u32> = (0..n).map(|i| (i % classes) as u32).collect();
        rng.shuffle(&mut order);

        let numel = h * w * c;
        let mut images = vec![0.0f32; n * numel];
        let mut labels = vec![0u32; n];
        for i in 0..n {
            let y = order[i];
            labels[i] = y;
            templates.sample(y as usize, &mut rng, &mut images[i * numel..(i + 1) * numel]);
        }
        Dataset { kind, images, labels, h, w, c }
    }
}

/// Deterministic per-class templates.
struct ClassTemplates {
    templates: Vec<Vec<f32>>, // [classes][H*W*C]
}

impl ClassTemplates {
    fn build(kind: DatasetKind, seed: u64) -> ClassTemplates {
        let (h, w, c) = kind.shape();
        let classes = kind.num_classes();
        let mut templates = Vec::with_capacity(classes);
        for class in 0..classes {
            // class-keyed RNG: template depends on (kind, seed, class) only
            let mut trng = Rng::new(
                seed ^ (class as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (kind.num_classes() as u64) << 32,
            );
            let mut t = vec![0.0f32; h * w * c];
            // 3 Gaussian blobs at class-dependent positions
            let nblobs = 3;
            for _ in 0..nblobs {
                let cy = 4.0 + trng.uniform() * (h as f32 - 8.0);
                let cx = 4.0 + trng.uniform() * (w as f32 - 8.0);
                let sig = 1.5 + trng.uniform() * 2.5;
                let amp = 0.8 + trng.uniform() * 1.2;
                let ch = trng.below(c);
                for y in 0..h {
                    for x in 0..w {
                        let d2 = (y as f32 - cy).powi(2) + (x as f32 - cx).powi(2);
                        t[(y * w + x) * c + ch] += amp * (-d2 / (2.0 * sig * sig)).exp();
                    }
                }
            }
            // class-keyed plane-wave pattern (gives conv filters frequency
            // structure to specialize on)
            let fy = 0.2 + trng.uniform() * 0.8;
            let fx = 0.2 + trng.uniform() * 0.8;
            let phase = trng.uniform() * std::f32::consts::TAU;
            let wamp = 0.35;
            for y in 0..h {
                for x in 0..w {
                    let v = wamp * (fy * y as f32 + fx * x as f32 + phase).sin();
                    for ch in 0..c {
                        t[(y * w + x) * c + ch] += v;
                    }
                }
            }
            templates.push(t);
        }
        ClassTemplates { templates }
    }

    /// One sample: amplitude-jittered template + iid pixel noise.
    fn sample(&self, class: usize, rng: &mut Rng, out: &mut [f32]) {
        let t = &self.templates[class];
        let amp = 0.8 + 0.4 * rng.uniform();
        let noise = 0.35;
        for (o, &tv) in out.iter_mut().zip(t.iter()) {
            *o = amp * tv + noise * rng.normal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_classes() {
        for kind in [DatasetKind::Smnist, DatasetKind::Sfemnist, DatasetKind::Scifar10, DatasetKind::Scifar100] {
            let d = Dataset::generate(kind, 64, 0);
            assert_eq!(d.len(), 64);
            assert_eq!(d.images.len(), 64 * d.image_numel());
            let maxl = *d.labels.iter().max().unwrap() as usize;
            assert!(maxl < kind.num_classes());
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = Dataset::generate(DatasetKind::Smnist, 32, 5);
        let b = Dataset::generate(DatasetKind::Smnist, 32, 5);
        let c = Dataset::generate(DatasetKind::Smnist, 32, 6);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn class_balance_roughly_uniform() {
        let d = Dataset::generate(DatasetKind::Smnist, 1000, 1);
        let mut counts = [0usize; 10];
        for &y in &d.labels {
            counts[y as usize] += 1;
        }
        for &c in &counts {
            assert!(c == 100, "balanced by construction: {counts:?}");
        }
    }

    #[test]
    fn classes_are_separable_by_template_distance() {
        // same-class samples must be closer to their class template mean
        // than to other classes' — the minimal learnability property.
        let d = Dataset::generate(DatasetKind::Smnist, 400, 2);
        let numel = d.image_numel();
        let mut means = vec![vec![0.0f64; numel]; 10];
        let mut counts = [0usize; 10];
        for i in 0..d.len() {
            let y = d.labels[i] as usize;
            counts[y] += 1;
            for j in 0..numel {
                means[y][j] += d.images[i * numel + j] as f64;
            }
        }
        for y in 0..10 {
            for j in 0..numel {
                means[y][j] /= counts[y] as f64;
            }
        }
        let mut correct = 0;
        for i in 0..d.len() {
            let img = &d.images[i * numel..(i + 1) * numel];
            let mut best = 0;
            let mut best_d = f64::MAX;
            for y in 0..10 {
                let dist: f64 = img
                    .iter()
                    .zip(&means[y])
                    .map(|(&a, &b)| (a as f64 - b).powi(2))
                    .sum();
                if dist < best_d {
                    best_d = dist;
                    best = y;
                }
            }
            if best == d.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        assert!(acc > 0.9, "nearest-template accuracy {acc}");
    }

    #[test]
    fn pixel_stats_normalized() {
        let d = Dataset::generate(DatasetKind::Scifar10, 200, 3);
        let mean: f64 = d.images.iter().map(|&x| x as f64).sum::<f64>() / d.images.len() as f64;
        let maxabs = d.images.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        assert!(mean.abs() < 0.5, "mean {mean}");
        assert!(maxabs < 10.0, "maxabs {maxabs}");
    }

    #[test]
    fn parse_roundtrip() {
        for name in ["smnist", "sfemnist", "scifar10", "scifar100"] {
            assert_eq!(DatasetKind::parse(name).unwrap().name(), name);
        }
        assert!(DatasetKind::parse("mnist").is_err());
    }
}
