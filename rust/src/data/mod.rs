//! Data substrate: synthetic datasets, non-IID sharding, batching.
//!
//! The paper evaluates on MNIST / FEMNIST / CIFAR-10/100. This environment
//! has no dataset downloads, so we synthesize class-conditional image
//! distributions with the same shapes and class counts (DESIGN.md §3
//! records the substitution argument: FedSkel's mechanics depend on
//! *class-conditional structure + non-IID client skew*, both of which the
//! generator provides, not on natural-image statistics).
//!
//! Paper: the Tables 3/4 evaluation substrate (non-IID shards per
//! client, New/Local test splits). Invariant: generation and sharding are
//! seed-deterministic, so every method comparison sees identical data.

pub mod shard;
pub mod synthetic;

pub use shard::{non_iid_shards, Batcher, Split};
pub use synthetic::{Dataset, DatasetKind};
