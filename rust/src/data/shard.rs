//! Non-IID sharding (McMahan et al.'s pathological split) and batching.
//!
//! The paper follows LG-FedAvg's setting: sort samples by label, cut into
//! `shards_per_client × num_clients` contiguous shards, deal each client
//! `shards_per_client` shards (2 for the 10-class sets, 20 for
//! FEMNIST/CIFAR-100). Each client therefore sees only a few classes —
//! the statistical heterogeneity that makes per-client skeletons differ.

use anyhow::{bail, Result};

use crate::data::synthetic::Dataset;
use crate::util::Rng;

/// A client's local data: indices into the shared [`Dataset`].
#[derive(Debug, Clone)]
pub struct Split {
    pub train: Vec<usize>,
    pub test: Vec<usize>,
}

/// Pathological non-IID split. Returns per-client [`Split`]s whose train
/// and test parts are drawn from the *same* shards (the paper's "Local
/// Test" protocol needs client-distribution test data).
///
/// `test_frac` of each client's samples are held out for local testing.
pub fn non_iid_shards(
    data: &Dataset,
    num_clients: usize,
    shards_per_client: usize,
    test_frac: f64,
    seed: u64,
) -> Result<Vec<Split>> {
    let n = data.len();
    let total_shards = num_clients * shards_per_client;
    if total_shards == 0 || n < total_shards {
        bail!("{n} samples cannot fill {total_shards} shards");
    }

    // sort indices by label (stable: ties keep generation order)
    let mut by_label: Vec<usize> = (0..n).collect();
    by_label.sort_by_key(|&i| data.labels[i]);

    // deal shards
    let shard_sz = n / total_shards;
    let mut shard_ids: Vec<usize> = (0..total_shards).collect();
    let mut rng = Rng::new(seed ^ 0x5AAD_0001);
    rng.shuffle(&mut shard_ids);

    let mut splits = Vec::with_capacity(num_clients);
    for c in 0..num_clients {
        let mut mine = Vec::with_capacity(shards_per_client * shard_sz);
        for s in 0..shards_per_client {
            let shard = shard_ids[c * shards_per_client + s];
            mine.extend_from_slice(&by_label[shard * shard_sz..(shard + 1) * shard_sz]);
        }
        rng.shuffle(&mut mine);
        let n_test = ((mine.len() as f64) * test_frac).round() as usize;
        let test = mine.split_off(mine.len() - n_test);
        splits.push(Split { train: mine, test });
    }
    Ok(splits)
}

/// Number of distinct labels a client sees (diagnostic for non-IID-ness).
pub fn distinct_labels(data: &Dataset, split: &Split) -> usize {
    let mut seen = std::collections::BTreeSet::new();
    for &i in split.train.iter().chain(split.test.iter()) {
        seen.insert(data.labels[i]);
    }
    seen.len()
}

/// Minibatch iterator over a list of sample indices. Pads the final batch
/// by wrapping (artifacts have static batch shape), reshuffles each epoch.
pub struct Batcher {
    indices: Vec<usize>,
    batch: usize,
    cursor: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(indices: Vec<usize>, batch: usize, seed: u64) -> Batcher {
        assert!(batch > 0);
        let mut b = Batcher { indices, batch, cursor: 0, rng: Rng::new(seed) };
        if !b.indices.is_empty() {
            let mut idx = std::mem::take(&mut b.indices);
            b.rng.shuffle(&mut idx);
            b.indices = idx;
        }
        b
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Current (possibly shuffled) index order — checkpoint view.
    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    /// Position within the current epoch's shuffle — checkpoint view.
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// Raw state of the epoch-shuffle RNG — checkpoint view.
    pub fn rng_parts(&self) -> (u64, Option<f32>) {
        self.rng.state_parts()
    }

    /// Rebuild a mid-epoch batcher from checkpoint state. Unlike
    /// [`Batcher::new`] this performs **no** initial shuffle: `indices`
    /// is installed verbatim (it already carries the shuffle applied
    /// before the checkpoint) and the RNG resumes mid-stream.
    pub fn restore(
        indices: Vec<usize>,
        batch: usize,
        cursor: usize,
        rng_state: u64,
        rng_spare: Option<f32>,
    ) -> Batcher {
        assert!(batch > 0);
        Batcher { indices, batch, cursor, rng: Rng::from_parts(rng_state, rng_spare) }
    }

    /// Next batch of exactly `batch` sample indices (wraps + reshuffles at
    /// epoch boundary).
    pub fn next_batch(&mut self) -> Vec<usize> {
        assert!(!self.indices.is_empty(), "empty batcher");
        let mut out = Vec::with_capacity(self.batch);
        while out.len() < self.batch {
            if self.cursor == self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.cursor = 0;
            }
            out.push(self.indices[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// Fill `x` (f32 NHWC) and `y` (i32) buffers for a batch.
    pub fn fill_batch(&mut self, data: &Dataset, x: &mut [f32], y: &mut [i32]) {
        let ids = self.next_batch();
        let numel = data.image_numel();
        for (bi, &i) in ids.iter().enumerate() {
            data.copy_image(i, &mut x[bi * numel..(bi + 1) * numel]);
            y[bi] = data.labels[i] as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Dataset, DatasetKind};

    fn data() -> Dataset {
        Dataset::generate(DatasetKind::Smnist, 1000, 0)
    }

    #[test]
    fn shards_partition_dataset() {
        let d = data();
        let splits = non_iid_shards(&d, 10, 2, 0.2, 0).unwrap();
        let mut all: Vec<usize> = splits
            .iter()
            .flat_map(|s| s.train.iter().chain(s.test.iter()).copied())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 1000, "every sample appears exactly once");
    }

    #[test]
    fn two_shards_give_few_labels() {
        let d = data();
        let splits = non_iid_shards(&d, 10, 2, 0.2, 0).unwrap();
        for s in &splits {
            let k = distinct_labels(&d, s);
            assert!(k <= 3, "2-shard client saw {k} labels (want ≤3)");
        }
    }

    #[test]
    fn test_frac_respected() {
        let d = data();
        let splits = non_iid_shards(&d, 10, 2, 0.25, 1).unwrap();
        for s in &splits {
            let tot = s.train.len() + s.test.len();
            assert_eq!(tot, 100);
            assert_eq!(s.test.len(), 25);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let d = data();
        let a = non_iid_shards(&d, 5, 2, 0.2, 3).unwrap();
        let b = non_iid_shards(&d, 5, 2, 0.2, 3).unwrap();
        let c = non_iid_shards(&d, 5, 2, 0.2, 4).unwrap();
        assert_eq!(a[0].train, b[0].train);
        assert_ne!(a[0].train, c[0].train);
    }

    #[test]
    fn too_many_shards_errors() {
        let d = Dataset::generate(DatasetKind::Smnist, 10, 0);
        assert!(non_iid_shards(&d, 100, 2, 0.2, 0).is_err());
    }

    #[test]
    fn batcher_wraps_and_covers() {
        let mut b = Batcher::new((0..10).collect(), 4, 0);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..10 {
            let batch = b.next_batch();
            assert_eq!(batch.len(), 4);
            seen.extend(batch);
        }
        assert_eq!(seen.len(), 10, "all samples eventually visited");
    }

    #[test]
    fn batcher_restore_resumes_identical_stream() {
        let mut live = Batcher::new((0..10).collect(), 4, 99);
        live.next_batch(); // advance past the first epoch boundary region
        live.next_batch();
        let (state, spare) = live.rng_parts();
        let mut resumed =
            Batcher::restore(live.indices().to_vec(), 4, live.cursor(), state, spare);
        for _ in 0..12 {
            assert_eq!(live.next_batch(), resumed.next_batch());
        }
    }

    #[test]
    fn batcher_fills_buffers() {
        let d = data();
        let mut b = Batcher::new(vec![0, 1, 2, 3], 2, 1);
        let numel = d.image_numel();
        let mut x = vec![0.0f32; 2 * numel];
        let mut y = vec![0i32; 2];
        b.fill_batch(&d, &mut x, &mut y);
        assert!(x.iter().any(|&v| v != 0.0));
        assert!(y.iter().all(|&l| (l as usize) < 10));
    }
}
