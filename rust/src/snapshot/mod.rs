//! Versioned checkpoint/resume snapshots — schema `fedskel.snapshot` v1.
//!
//! A snapshot serializes **all primary run state** the coordinator cannot
//! re-derive from its [`crate::config::RunConfig`]: global parameters,
//! every client's mid-run state (skeleton, personalized params, importance
//! sums, minibatch cursor + RNG, error-feedback residuals), the
//! coordinator RNG, the fleet's device profiles, the sched virtual clock
//! with its in-flight arrivals, pending `(round, seq)` updates (async
//! stragglers, with their recorded skeletons and decoded delta payloads),
//! download anchors, the [`crate::comm::CommLedger`], and the per-round
//! log. Everything else — datasets, shards, transports, compressors,
//! trace sinks — is rebuilt deterministically from the config by
//! `Coordinator::restore`.
//!
//! The resume contract is **bitwise** (ROADMAP item 4):
//!
//! ```text
//! digest(run 2N rounds) == digest(run N → snapshot → fresh-process restore → run N)
//! ```
//!
//! for every scheduler policy, compressor, and kernel tier —
//! `tests/snapshot_resume.rs` sweeps the cross-product and CI reruns it
//! across two real `fedskel` processes. Tensors are stored as the
//! transport wire codec's F32 block encoding ([`wire::encode`] `Full`
//! frames), so f32 payloads round-trip bit-for-bit by construction;
//! every float that is not a tensor travels as its IEEE-754 bit pattern,
//! never through a decimal printer.
//!
//! ## File layout
//!
//! ```text
//! magic  "FSKLSNAP"                      8 bytes
//! version u16 LE                         = 1
//! sections: { tag u16 LE, len u32 LE, body }*
//! checksum u32 LE                        FNV-1a over everything above
//! ```
//!
//! Section tags (all mandatory, any order, unknown ⇒ typed error):
//!
//! | tag | section | contents |
//! |---|---|---|
//! | 0x01 | META    | round counter, determinism key string |
//! | 0x02 | RNG     | coordinator SplitMix64 state + Box–Muller spare |
//! | 0x03 | GLOBAL  | global params as one F32 `Full` wire frame |
//! | 0x04 | CLIENTS | per-client [`ClientSnap`] records |
//! | 0x05 | FLEET   | per-device [`DeviceSnap`] records |
//! | 0x06 | CLOCK   | virtual `now` + in-flight [`Completion`] events |
//! | 0x07 | PENDING | buffered `(round, seq)` updates ([`PendingSnap`]) |
//! | 0x08 | ANCHORS | per-client optional download anchor frames |
//! | 0x09 | LEDGER  | the 8 [`crate::comm::CommLedger`] counters |
//! | 0x0A | RUNLOG  | completed [`RoundLog`] rows (so a resumed CSV matches) |
//!
//! ## Revision policy
//!
//! Mirrors `docs/WIRE_FORMAT.md`: the version is bumped only for
//! incompatible layout changes; readers reject other versions with
//! [`SnapshotError::UnsupportedVersion`] rather than guessing. New
//! optional state gets a new section tag — but because a v1 reader
//! cannot know whether an unknown section is safe to ignore (dropping EF
//! residuals would silently corrupt the "deferred, never lost"
//! guarantee), unknown tags are a typed [`SnapshotError::UnknownSection`]
//! error, and additive changes therefore also bump the version. A
//! corrupt, truncated, or foreign file must never panic and never
//! produce a silently-degraded resume: every failure is a
//! [`SnapshotError`].

use std::fmt;

use crate::comm::CommLedger;
use crate::config::{RatioAssignment, RunConfig};
use crate::kernels::Precision;
use crate::metrics::RoundLog;
use crate::model::{ModelSpec, Params};
use crate::sched::Completion;
use crate::transport::wire::{self, FrameOpts, Quant, RoundMsg, WirePayload};

/// File magic: 8 bytes so a snapshot can never be confused with a wire
/// frame (`FSKL`).
pub const MAGIC: [u8; 8] = *b"FSKLSNAP";

/// Current snapshot schema version (`fedskel.snapshot` v1).
pub const VERSION: u16 = 1;

const TAG_META: u16 = 0x01;
const TAG_RNG: u16 = 0x02;
const TAG_GLOBAL: u16 = 0x03;
const TAG_CLIENTS: u16 = 0x04;
const TAG_FLEET: u16 = 0x05;
const TAG_CLOCK: u16 = 0x06;
const TAG_PENDING: u16 = 0x07;
const TAG_ANCHORS: u16 = 0x08;
const TAG_LEDGER: u16 = 0x09;
const TAG_RUNLOG: u16 = 0x0A;

/// Every way reading a snapshot can fail. Typed (not a bare `anyhow`
/// string) so callers — and the corruption tests — can distinguish a
/// truncated download from a version skew from a config mismatch.
#[derive(Debug, Clone, PartialEq)]
pub enum SnapshotError {
    /// The file ends before the declared structure does.
    Truncated,
    /// The first 8 bytes are not `FSKLSNAP`.
    BadMagic,
    /// The file's schema version is not the one this build reads.
    UnsupportedVersion { found: u16, supported: u16 },
    /// The trailing FNV-1a checksum does not cover the bytes present.
    ChecksumMismatch { stored: u32, computed: u32 },
    /// A section tag this reader does not know (see the revision policy:
    /// unknown state is never silently dropped).
    UnknownSection(u16),
    /// A mandatory section is absent.
    MissingSection(&'static str),
    /// Structurally invalid contents inside a known section.
    Malformed(String),
    /// The snapshot was taken under a different run configuration than
    /// the one trying to resume it (determinism keys differ).
    ConfigMismatch { snapshot: String, run: String },
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Truncated => write!(f, "snapshot truncated"),
            SnapshotError::BadMagic => write!(f, "not a fedskel snapshot (bad magic)"),
            SnapshotError::UnsupportedVersion { found, supported } => {
                write!(f, "unsupported snapshot version {found} (this build reads v{supported})")
            }
            SnapshotError::ChecksumMismatch { stored, computed } => write!(
                f,
                "snapshot checksum mismatch: stored {stored:#010x}, computed {computed:#010x}"
            ),
            SnapshotError::UnknownSection(tag) => {
                write!(f, "unknown snapshot section tag {tag:#06x} (refusing a degraded resume)")
            }
            SnapshotError::MissingSection(name) => {
                write!(f, "snapshot is missing its {name} section")
            }
            SnapshotError::Malformed(what) => write!(f, "malformed snapshot: {what}"),
            SnapshotError::ConfigMismatch { snapshot, run } => write!(
                f,
                "snapshot was taken under a different configuration:\n  snapshot: {snapshot}\n  this run: {run}"
            ),
        }
    }
}

impl std::error::Error for SnapshotError {}

type SnapResult<T> = std::result::Result<T, SnapshotError>;

/// The canonical "same run?" fingerprint: every config knob that steers
/// the deterministic training trajectory, in a fixed order. `rounds` is
/// deliberately excluded (resuming with a larger `--rounds` is the point
/// of checkpointing), as are `workers` (pool and inline training are
/// bitwise identical by contract), trace and checkpoint knobs (observers,
/// not participants), and `eval_every`/`artifacts_dir` (eval never feeds
/// back into training state — but note a resumed run only re-creates the
/// eval rows from its own cadence). `fault` is likewise excluded: the
/// coordinator's reliable-exchange loop recovers every injected loss, so
/// fault injection is trajectory-neutral by construction (it only adds
/// wasted bytes) and a faulted run may resume a clean snapshot and vice
/// versa.
pub fn determinism_key(cfg: &RunConfig) -> String {
    let ratio = match cfg.ratio_assignment {
        RatioAssignment::Linear => "linear".to_string(),
        RatioAssignment::Equidistant { lo, hi } => {
            format!("equidistant:{:016x}:{:016x}", lo.to_bits(), hi.to_bits())
        }
        RatioAssignment::Fixed(r) => format!("fixed:{:016x}", r.to_bits()),
    };
    format!(
        "fedskel.snapshot v{VERSION}; method={}; dataset={}; model={}; clients={}; \
         shards={}; dataset_size={}; new_test_size={}; local_steps={}; \
         updateskel_per_setskel={}; lr={:08x}; mu={:08x}; ratio={ratio}; \
         participation={:016x}; dropout={:016x}; metric={}; seed={}; transport={}; \
         quant={}; compress={}; topk_ratio={:016x}; ef={}; delta_down={}; sched={}; \
         deadline={:016x}; buffer_k={}; staleness_alpha={:016x}; fleet_skew={:016x}; \
         threads={}; kernel_tier={}; client_precision={}",
        cfg.method.name(),
        cfg.dataset.name(),
        cfg.model,
        cfg.num_clients,
        cfg.shards_per_client,
        cfg.dataset_size,
        cfg.new_test_size,
        cfg.local_steps,
        cfg.updateskel_per_setskel,
        cfg.lr.to_bits(),
        cfg.mu.to_bits(),
        cfg.participation.to_bits(),
        cfg.dropout.to_bits(),
        cfg.selection_metric.name(),
        cfg.seed,
        cfg.transport.name(),
        cfg.quant.name(),
        cfg.compress.name(),
        cfg.topk_ratio.to_bits(),
        cfg.error_feedback,
        cfg.delta_down,
        cfg.sched.name(),
        cfg.deadline_secs.to_bits(),
        cfg.buffer_k,
        cfg.staleness_alpha.to_bits(),
        cfg.fleet_skew.to_bits(),
        cfg.threads,
        cfg.kernel_tier.name(),
        cfg.client_precision.name(),
    )
}

/// One client's checkpointed state — the serializable mirror of
/// [`crate::clients::ClientState`] minus what the config re-derives (the
/// data split). Floats that may be NaN (`last_loss` starts as NaN) are
/// stored as bit patterns so equality and round-trips stay bitwise.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientSnap {
    pub id: u32,
    pub capability: f64,
    pub ratio: f64,
    pub bucket: u32,
    pub last_loss_bits: u32,
    pub skeleton: Vec<Vec<i32>>,
    pub local_params: Params,
    pub importance_sums: Vec<Vec<f64>>,
    pub importance_batches: u64,
    /// The batcher's current (shuffled) index order — installed verbatim
    /// on restore, no reshuffle.
    pub batcher_indices: Vec<u32>,
    pub batcher_batch: u32,
    pub batcher_cursor: u64,
    pub batcher_rng_state: u64,
    pub batcher_rng_spare: Option<f32>,
    /// Error-feedback residual, including empty (no compressed upload
    /// yet) and ragged (per-block) layouts.
    pub ef_residual: Vec<Vec<f32>>,
}

/// One fleet device profile (mirror of [`crate::hetero::DeviceProfile`],
/// which itself carries no `PartialEq`).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSnap {
    pub name: String,
    pub capability: f64,
    pub bandwidth_mbps: f64,
    pub latency_s: f64,
    pub cores: u32,
    pub precision: Precision,
}

/// One buffered `(round, seq)` update awaiting aggregation — an async
/// straggler's landed-but-unaggregated upload, or a deadline round's
/// pending arrival. Carries the update's recorded skeleton and, for
/// compressed uploads, the decoded delta payload that refolds into the
/// client's EF residual if the deadline drops it.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingSnap {
    pub round: u64,
    pub seq: u64,
    pub client: u32,
    pub weight: f64,
    pub params: Params,
    pub skeleton: Vec<Vec<i32>>,
    /// Always a dense kind (uploads decode anchor-free), re-encoded as an
    /// F32 `DELTA` frame — f32 values round-trip bitwise.
    pub delta: Option<WirePayload>,
}

/// All primary run state at one round boundary (or mid-round, for async
/// policies with arrivals in flight).
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// [`determinism_key`] of the run that wrote this snapshot.
    pub determinism_key: String,
    /// Rounds completed — the resumed run continues at this round.
    pub round_idx: u64,
    pub rng_state: u64,
    pub rng_spare: Option<f32>,
    pub global: Params,
    pub clients: Vec<ClientSnap>,
    pub fleet: Vec<DeviceSnap>,
    /// Virtual-clock `now` — restored **before** the in-flight events so
    /// a straggler spanning the checkpoint keeps its absolute arrival
    /// time and therefore its staleness weight.
    pub clock_now: f64,
    pub in_flight: Vec<Completion>,
    pub pending: Vec<PendingSnap>,
    pub anchors: Vec<Option<Params>>,
    pub ledger: CommLedger,
    pub rounds_log: Vec<RoundLog>,
}

// ---------------------------------------------------------------- writer

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    put_u64(b, v.to_bits());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn put_opt_f32(b: &mut Vec<u8>, v: Option<f32>) {
    match v {
        None => b.push(0),
        Some(x) => {
            b.push(1);
            put_u32(b, x.to_bits());
        }
    }
}

/// Params as one length-prefixed F32 `Full` wire frame — the codec whose
/// f32 block encoding is bitwise by construction.
fn put_params(b: &mut Vec<u8>, params: &Params) {
    let msg =
        RoundMsg { round: 0, client: 0, weight: 0.0, payload: WirePayload::Full(params.clone()) };
    let frame = wire::encode(&msg, Quant::F32);
    put_u32(b, frame.len() as u32);
    b.extend_from_slice(&frame);
}

/// A decoded dense payload (pending delta), re-encoded as a
/// length-prefixed F32 `DELTA` frame.
fn put_payload(b: &mut Vec<u8>, payload: &WirePayload) -> SnapResult<()> {
    let msg = RoundMsg { round: 0, client: 0, weight: 0.0, payload: payload.clone() };
    let frame = wire::encode_opts(&msg, &FrameOpts { quant: Quant::F32, delta: true, plans: None })
        .map_err(|e| SnapshotError::Malformed(format!("pending delta payload: {e}")))?;
    put_u32(b, frame.len() as u32);
    b.extend_from_slice(&frame);
    Ok(())
}

fn put_skeleton(b: &mut Vec<u8>, skeleton: &[Vec<i32>]) {
    put_u32(b, skeleton.len() as u32);
    for layer in skeleton {
        put_u32(b, layer.len() as u32);
        for &ch in layer {
            put_u32(b, ch as u32);
        }
    }
}

fn put_client(b: &mut Vec<u8>, c: &ClientSnap) -> SnapResult<()> {
    put_u32(b, c.id);
    put_f64(b, c.capability);
    put_f64(b, c.ratio);
    put_u32(b, c.bucket);
    put_u32(b, c.last_loss_bits);
    put_skeleton(b, &c.skeleton);
    put_params(b, &c.local_params);
    put_u32(b, c.importance_sums.len() as u32);
    for layer in &c.importance_sums {
        put_u32(b, layer.len() as u32);
        for &s in layer {
            put_f64(b, s);
        }
    }
    put_u64(b, c.importance_batches);
    put_u32(b, c.batcher_indices.len() as u32);
    for &i in &c.batcher_indices {
        put_u32(b, i);
    }
    put_u32(b, c.batcher_batch);
    put_u64(b, c.batcher_cursor);
    put_u64(b, c.batcher_rng_state);
    put_opt_f32(b, c.batcher_rng_spare);
    put_u32(b, c.ef_residual.len() as u32);
    for layer in &c.ef_residual {
        put_u32(b, layer.len() as u32);
        for &v in layer {
            put_u32(b, v.to_bits());
        }
    }
    Ok(())
}

fn put_round_log(b: &mut Vec<u8>, r: &RoundLog) {
    put_u64(b, r.round as u64);
    put_str(b, &r.phase);
    put_f64(b, r.mean_loss);
    match r.new_acc {
        None => b.push(0),
        Some(a) => {
            b.push(1);
            put_f64(b, a);
        }
    }
    match r.local_acc {
        None => b.push(0),
        Some(a) => {
            b.push(1);
            put_f64(b, a);
        }
    }
    put_u64(b, r.comm_params);
    put_u64(b, r.comm_wire_bytes);
    put_f64(b, r.sim_round_secs);
    put_u32(b, r.client_secs.len() as u32);
    for &(id, secs) in &r.client_secs {
        put_u32(b, id as u32);
        put_f64(b, secs);
    }
    put_u64(b, r.dropped as u64);
    put_u64(b, r.stale as u64);
    put_f64(b, r.wall_secs);
}

fn section(out: &mut Vec<u8>, tag: u16, body: Vec<u8>) {
    put_u16(out, tag);
    put_u32(out, body.len() as u32);
    out.extend_from_slice(&body);
}

// ---------------------------------------------------------------- reader

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> SnapResult<&'a [u8]> {
        if self.remaining() < n {
            return Err(SnapshotError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> SnapResult<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> SnapResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> SnapResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> SnapResult<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn byte(&mut self) -> SnapResult<u8> {
        Ok(self.take(1)?[0])
    }

    fn str(&mut self) -> SnapResult<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| SnapshotError::Malformed("non-UTF-8 string".into()))
    }

    fn opt_f32(&mut self) -> SnapResult<Option<f32>> {
        match self.byte()? {
            0 => Ok(None),
            1 => Ok(Some(f32::from_bits(self.u32()?))),
            other => Err(SnapshotError::Malformed(format!("bad option byte {other}"))),
        }
    }

    /// A count that is about to size an allocation: reject counts the
    /// remaining bytes cannot possibly hold (each item needs ≥ `min_item`
    /// bytes), so a corrupt length cannot OOM the reader.
    fn count(&mut self, min_item: usize) -> SnapResult<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_item.max(1)) > self.remaining() {
            return Err(SnapshotError::Truncated);
        }
        Ok(n)
    }
}

fn get_params(spec: &ModelSpec, r: &mut Reader) -> SnapResult<Params> {
    let n = r.u32()? as usize;
    let frame = r.take(n)?;
    let msg = wire::decode(spec, frame)
        .map_err(|e| SnapshotError::Malformed(format!("param frame: {e}")))?;
    match msg.payload {
        WirePayload::Full(ps) => Ok(ps),
        _ => Err(SnapshotError::Malformed("param frame is not a Full payload".into())),
    }
}

fn get_payload(spec: &ModelSpec, r: &mut Reader) -> SnapResult<WirePayload> {
    let n = r.u32()? as usize;
    let frame = r.take(n)?;
    let (msg, delta) = wire::decode_frame(spec, frame, None)
        .map_err(|e| SnapshotError::Malformed(format!("pending delta frame: {e}")))?;
    if !delta {
        return Err(SnapshotError::Malformed("pending frame lost its DELTA flag".into()));
    }
    Ok(msg.payload)
}

fn get_skeleton(r: &mut Reader) -> SnapResult<Vec<Vec<i32>>> {
    let layers = r.count(4)?;
    let mut skeleton = Vec::with_capacity(layers);
    for _ in 0..layers {
        let k = r.count(4)?;
        let mut layer = Vec::with_capacity(k);
        for _ in 0..k {
            layer.push(r.u32()? as i32);
        }
        skeleton.push(layer);
    }
    Ok(skeleton)
}

fn get_client(spec: &ModelSpec, r: &mut Reader) -> SnapResult<ClientSnap> {
    let id = r.u32()?;
    let capability = r.f64()?;
    let ratio = r.f64()?;
    let bucket = r.u32()?;
    let last_loss_bits = r.u32()?;
    let skeleton = get_skeleton(r)?;
    let local_params = get_params(spec, r)?;
    let layers = r.count(4)?;
    let mut importance_sums = Vec::with_capacity(layers);
    for _ in 0..layers {
        let k = r.count(8)?;
        let mut layer = Vec::with_capacity(k);
        for _ in 0..k {
            layer.push(r.f64()?);
        }
        importance_sums.push(layer);
    }
    let importance_batches = r.u64()?;
    let n_idx = r.count(4)?;
    let mut batcher_indices = Vec::with_capacity(n_idx);
    for _ in 0..n_idx {
        batcher_indices.push(r.u32()?);
    }
    let batcher_batch = r.u32()?;
    let batcher_cursor = r.u64()?;
    let batcher_rng_state = r.u64()?;
    let batcher_rng_spare = r.opt_f32()?;
    let n_res = r.count(4)?;
    let mut ef_residual = Vec::with_capacity(n_res);
    for _ in 0..n_res {
        let k = r.count(4)?;
        let mut layer = Vec::with_capacity(k);
        for _ in 0..k {
            layer.push(f32::from_bits(r.u32()?));
        }
        ef_residual.push(layer);
    }
    Ok(ClientSnap {
        id,
        capability,
        ratio,
        bucket,
        last_loss_bits,
        skeleton,
        local_params,
        importance_sums,
        importance_batches,
        batcher_indices,
        batcher_batch,
        batcher_cursor,
        batcher_rng_state,
        batcher_rng_spare,
        ef_residual,
    })
}

fn get_round_log(r: &mut Reader) -> SnapResult<RoundLog> {
    let round = r.u64()? as usize;
    let phase = r.str()?;
    let mean_loss = r.f64()?;
    let new_acc = match r.byte()? {
        0 => None,
        1 => Some(r.f64()?),
        other => return Err(SnapshotError::Malformed(format!("bad option byte {other}"))),
    };
    let local_acc = match r.byte()? {
        0 => None,
        1 => Some(r.f64()?),
        other => return Err(SnapshotError::Malformed(format!("bad option byte {other}"))),
    };
    let comm_params = r.u64()?;
    let comm_wire_bytes = r.u64()?;
    let sim_round_secs = r.f64()?;
    let n = r.count(12)?;
    let mut client_secs = Vec::with_capacity(n);
    for _ in 0..n {
        let id = r.u32()? as usize;
        client_secs.push((id, r.f64()?));
    }
    let dropped = r.u64()? as usize;
    let stale = r.u64()? as usize;
    let wall_secs = r.f64()?;
    Ok(RoundLog {
        round,
        phase,
        mean_loss,
        new_acc,
        local_acc,
        comm_params,
        comm_wire_bytes,
        sim_round_secs,
        client_secs,
        dropped,
        stale,
        wall_secs,
    })
}

impl Snapshot {
    /// Serialize to the `fedskel.snapshot` v1 byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        put_u16(&mut out, VERSION);

        let mut meta = Vec::new();
        put_u64(&mut meta, self.round_idx);
        put_str(&mut meta, &self.determinism_key);
        section(&mut out, TAG_META, meta);

        let mut rng = Vec::new();
        put_u64(&mut rng, self.rng_state);
        put_opt_f32(&mut rng, self.rng_spare);
        section(&mut out, TAG_RNG, rng);

        let mut global = Vec::new();
        put_params(&mut global, &self.global);
        section(&mut out, TAG_GLOBAL, global);

        let mut clients = Vec::new();
        put_u32(&mut clients, self.clients.len() as u32);
        for c in &self.clients {
            // writer-side payloads are structurally valid by construction
            put_client(&mut clients, c).expect("client snapshot encode");
        }
        section(&mut out, TAG_CLIENTS, clients);

        let mut fleet = Vec::new();
        put_u32(&mut fleet, self.fleet.len() as u32);
        for d in &self.fleet {
            put_str(&mut fleet, &d.name);
            put_f64(&mut fleet, d.capability);
            put_f64(&mut fleet, d.bandwidth_mbps);
            put_f64(&mut fleet, d.latency_s);
            put_u32(&mut fleet, d.cores);
            fleet.push(match d.precision {
                Precision::F32 => 0,
                Precision::Int8 => 1,
            });
        }
        section(&mut out, TAG_FLEET, fleet);

        let mut clock = Vec::new();
        put_f64(&mut clock, self.clock_now);
        put_u32(&mut clock, self.in_flight.len() as u32);
        for c in &self.in_flight {
            put_f64(&mut clock, c.at);
            put_u64(&mut clock, c.round as u64);
            put_u64(&mut clock, c.seq as u64);
            put_u32(&mut clock, c.client as u32);
        }
        section(&mut out, TAG_CLOCK, clock);

        let mut pending = Vec::new();
        put_u32(&mut pending, self.pending.len() as u32);
        for p in &self.pending {
            put_u64(&mut pending, p.round);
            put_u64(&mut pending, p.seq);
            put_u32(&mut pending, p.client);
            put_f64(&mut pending, p.weight);
            put_params(&mut pending, &p.params);
            put_skeleton(&mut pending, &p.skeleton);
            match &p.delta {
                None => pending.push(0),
                Some(payload) => {
                    pending.push(1);
                    put_payload(&mut pending, payload).expect("pending delta encode");
                }
            }
        }
        section(&mut out, TAG_PENDING, pending);

        let mut anchors = Vec::new();
        put_u32(&mut anchors, self.anchors.len() as u32);
        for a in &self.anchors {
            match a {
                None => anchors.push(0),
                Some(ps) => {
                    anchors.push(1);
                    put_params(&mut anchors, ps);
                }
            }
        }
        section(&mut out, TAG_ANCHORS, anchors);

        let mut ledger = Vec::new();
        for v in [
            self.ledger.upload_params,
            self.ledger.download_params,
            self.ledger.upload_wire_bytes,
            self.ledger.download_wire_bytes,
            self.ledger.wasted_wire_bytes,
            self.ledger.upload_raw_bytes,
            self.ledger.download_raw_bytes,
            self.ledger.rounds,
        ] {
            put_u64(&mut ledger, v);
        }
        section(&mut out, TAG_LEDGER, ledger);

        let mut runlog = Vec::new();
        put_u32(&mut runlog, self.rounds_log.len() as u32);
        for row in &self.rounds_log {
            put_round_log(&mut runlog, row);
        }
        section(&mut out, TAG_RUNLOG, runlog);

        let sum = wire::fnv1a32(&out);
        put_u32(&mut out, sum);
        out
    }

    /// Parse + validate a snapshot. `spec` supplies tensor shapes for the
    /// embedded wire frames. Never panics on foreign bytes — every
    /// failure is a typed [`SnapshotError`].
    pub fn decode(spec: &ModelSpec, bytes: &[u8]) -> SnapResult<Snapshot> {
        if bytes.len() < MAGIC.len() + 2 + 4 {
            return Err(SnapshotError::Truncated);
        }
        if bytes[..MAGIC.len()] != MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        let version = u16::from_le_bytes([bytes[8], bytes[9]]);
        if version != VERSION {
            return Err(SnapshotError::UnsupportedVersion { found: version, supported: VERSION });
        }
        let body_end = bytes.len() - 4;
        let stored = u32::from_le_bytes(bytes[body_end..].try_into().unwrap());
        let computed = wire::fnv1a32(&bytes[..body_end]);
        if stored != computed {
            return Err(SnapshotError::ChecksumMismatch { stored, computed });
        }

        let mut meta: Option<(u64, String)> = None;
        let mut rng: Option<(u64, Option<f32>)> = None;
        let mut global: Option<Params> = None;
        let mut clients: Option<Vec<ClientSnap>> = None;
        let mut fleet: Option<Vec<DeviceSnap>> = None;
        let mut clock: Option<(f64, Vec<Completion>)> = None;
        let mut pending: Option<Vec<PendingSnap>> = None;
        let mut anchors: Option<Vec<Option<Params>>> = None;
        let mut ledger: Option<CommLedger> = None;
        let mut rounds_log: Option<Vec<RoundLog>> = None;

        let mut top = Reader::new(&bytes[MAGIC.len() + 2..body_end]);
        while top.remaining() > 0 {
            let tag = top.u16()?;
            let len = top.u32()? as usize;
            let body = top.take(len)?;
            let mut r = Reader::new(body);
            match tag {
                TAG_META => {
                    let round_idx = r.u64()?;
                    let key = r.str()?;
                    meta = Some((round_idx, key));
                }
                TAG_RNG => {
                    let state = r.u64()?;
                    let spare = r.opt_f32()?;
                    rng = Some((state, spare));
                }
                TAG_GLOBAL => global = Some(get_params(spec, &mut r)?),
                TAG_CLIENTS => {
                    let n = r.count(1)?;
                    let mut cs = Vec::with_capacity(n);
                    for _ in 0..n {
                        cs.push(get_client(spec, &mut r)?);
                    }
                    clients = Some(cs);
                }
                TAG_FLEET => {
                    let n = r.count(1)?;
                    let mut ds = Vec::with_capacity(n);
                    for _ in 0..n {
                        let name = r.str()?;
                        let capability = r.f64()?;
                        let bandwidth_mbps = r.f64()?;
                        let latency_s = r.f64()?;
                        let cores = r.u32()?;
                        let precision = match r.byte()? {
                            0 => Precision::F32,
                            1 => Precision::Int8,
                            other => {
                                return Err(SnapshotError::Malformed(format!(
                                    "bad precision byte {other}"
                                )))
                            }
                        };
                        ds.push(DeviceSnap {
                            name,
                            capability,
                            bandwidth_mbps,
                            latency_s,
                            cores,
                            precision,
                        });
                    }
                    fleet = Some(ds);
                }
                TAG_CLOCK => {
                    let now = r.f64()?;
                    let n = r.count(28)?;
                    let mut evs = Vec::with_capacity(n);
                    for _ in 0..n {
                        let at = r.f64()?;
                        let round = r.u64()? as usize;
                        let seq = r.u64()? as usize;
                        let client = r.u32()? as usize;
                        evs.push(Completion { at, round, seq, client });
                    }
                    clock = Some((now, evs));
                }
                TAG_PENDING => {
                    let n = r.count(1)?;
                    let mut ps = Vec::with_capacity(n);
                    for _ in 0..n {
                        let round = r.u64()?;
                        let seq = r.u64()?;
                        let client = r.u32()?;
                        let weight = r.f64()?;
                        let params = get_params(spec, &mut r)?;
                        let skeleton = get_skeleton(&mut r)?;
                        let delta = match r.byte()? {
                            0 => None,
                            1 => Some(get_payload(spec, &mut r)?),
                            other => {
                                return Err(SnapshotError::Malformed(format!(
                                    "bad option byte {other}"
                                )))
                            }
                        };
                        ps.push(PendingSnap { round, seq, client, weight, params, skeleton, delta });
                    }
                    pending = Some(ps);
                }
                TAG_ANCHORS => {
                    let n = r.count(1)?;
                    let mut az = Vec::with_capacity(n);
                    for _ in 0..n {
                        az.push(match r.byte()? {
                            0 => None,
                            1 => Some(get_params(spec, &mut r)?),
                            other => {
                                return Err(SnapshotError::Malformed(format!(
                                    "bad option byte {other}"
                                )))
                            }
                        });
                    }
                    anchors = Some(az);
                }
                TAG_LEDGER => {
                    ledger = Some(CommLedger {
                        upload_params: r.u64()?,
                        download_params: r.u64()?,
                        upload_wire_bytes: r.u64()?,
                        download_wire_bytes: r.u64()?,
                        wasted_wire_bytes: r.u64()?,
                        upload_raw_bytes: r.u64()?,
                        download_raw_bytes: r.u64()?,
                        rounds: r.u64()?,
                    });
                }
                TAG_RUNLOG => {
                    let n = r.count(1)?;
                    let mut rows = Vec::with_capacity(n);
                    for _ in 0..n {
                        rows.push(get_round_log(&mut r)?);
                    }
                    rounds_log = Some(rows);
                }
                other => return Err(SnapshotError::UnknownSection(other)),
            }
            if r.remaining() > 0 {
                return Err(SnapshotError::Malformed(format!(
                    "section {tag:#06x} has {} trailing bytes",
                    r.remaining()
                )));
            }
        }

        let (round_idx, determinism_key) =
            meta.ok_or(SnapshotError::MissingSection("META"))?;
        let (rng_state, rng_spare) = rng.ok_or(SnapshotError::MissingSection("RNG"))?;
        let (clock_now, in_flight) = clock.ok_or(SnapshotError::MissingSection("CLOCK"))?;
        Ok(Snapshot {
            determinism_key,
            round_idx,
            rng_state,
            rng_spare,
            global: global.ok_or(SnapshotError::MissingSection("GLOBAL"))?,
            clients: clients.ok_or(SnapshotError::MissingSection("CLIENTS"))?,
            fleet: fleet.ok_or(SnapshotError::MissingSection("FLEET"))?,
            clock_now,
            in_flight,
            pending: pending.ok_or(SnapshotError::MissingSection("PENDING"))?,
            anchors: anchors.ok_or(SnapshotError::MissingSection("ANCHORS"))?,
            ledger: ledger.ok_or(SnapshotError::MissingSection("LEDGER"))?,
            rounds_log: rounds_log.ok_or(SnapshotError::MissingSection("RUNLOG"))?,
        })
    }

    /// Write the encoded snapshot to `path`; returns bytes written.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<u64> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let bytes = self.encode();
        std::fs::write(path, &bytes)?;
        Ok(bytes.len() as u64)
    }

    /// Read + decode a snapshot file. Decode failures carry the typed
    /// [`SnapshotError`] (downcastable from the `anyhow` chain).
    pub fn load(spec: &ModelSpec, path: &std::path::Path) -> anyhow::Result<Snapshot> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading snapshot {}: {e}", path.display()))?;
        Ok(Snapshot::decode(spec, &bytes)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn toy_spec() -> ModelSpec {
        crate::runtime::mock::toy_spec()
    }

    fn toy_params(spec: &ModelSpec, seed: u64) -> Params {
        crate::model::init_params(spec, seed)
    }

    fn sample_client(spec: &ModelSpec, id: u32) -> ClientSnap {
        ClientSnap {
            id,
            capability: 0.5 + id as f64 * 0.1,
            ratio: 0.4,
            bucket: 40,
            last_loss_bits: f32::NAN.to_bits(),
            skeleton: vec![vec![0, 2, 3], vec![]],
            local_params: toy_params(spec, 7 + id as u64),
            importance_sums: vec![vec![0.25, -1.5, 3.0], vec![]],
            importance_batches: 5,
            batcher_indices: vec![4, 0, 9, 2],
            batcher_batch: 2,
            batcher_cursor: 3,
            batcher_rng_state: 0xDEAD_BEEF_CAFE_F00D,
            batcher_rng_spare: Some(-0.75),
            ef_residual: vec![vec![0.5, -0.25], vec![], vec![1e-30]],
        }
    }

    fn sample(spec: &ModelSpec) -> Snapshot {
        Snapshot {
            determinism_key: determinism_key(&crate::config::RunConfig::default()),
            round_idx: 3,
            rng_state: 0x1234_5678_9ABC_DEF0,
            rng_spare: None,
            global: toy_params(spec, 1),
            clients: vec![sample_client(spec, 0), sample_client(spec, 1)],
            fleet: vec![DeviceSnap {
                name: "dev0".into(),
                capability: 0.125,
                bandwidth_mbps: 12.5,
                latency_s: 0.05,
                cores: 2,
                precision: Precision::Int8,
            }],
            clock_now: 42.5,
            in_flight: vec![Completion { at: 43.75, round: 2, seq: 1, client: 1 }],
            pending: vec![PendingSnap {
                round: 2,
                seq: 0,
                client: 0,
                weight: 64.0,
                params: toy_params(spec, 9),
                skeleton: vec![vec![1, 3]],
                delta: Some(WirePayload::Full(toy_params(spec, 11))),
            }],
            anchors: vec![Some(toy_params(spec, 13)), None],
            ledger: CommLedger {
                upload_params: 1,
                download_params: 2,
                upload_wire_bytes: 3,
                download_wire_bytes: 4,
                wasted_wire_bytes: 5,
                upload_raw_bytes: 6,
                download_raw_bytes: 7,
                rounds: 8,
            },
            rounds_log: vec![RoundLog {
                round: 0,
                phase: "setskel".into(),
                mean_loss: 2.3,
                new_acc: Some(0.5),
                local_acc: None,
                comm_params: 100,
                comm_wire_bytes: 400,
                sim_round_secs: 1.25,
                client_secs: vec![(0, 1.0), (1, 1.25)],
                dropped: 0,
                stale: 1,
                wall_secs: 0.01,
            }],
        }
    }

    #[test]
    fn encode_decode_round_trips_bitwise() {
        let spec = toy_spec();
        let snap = sample(&spec);
        let bytes = snap.encode();
        let back = Snapshot::decode(&spec, &bytes).unwrap();
        assert_eq!(back, snap);
        // and the re-encoding is byte-identical (canonical form)
        assert_eq!(back.encode(), bytes);
    }

    #[test]
    fn nan_loss_and_exact_f32_bits_survive() {
        let spec = toy_spec();
        let snap = sample(&spec);
        let back = Snapshot::decode(&spec, &snap.encode()).unwrap();
        assert!(f32::from_bits(back.clients[0].last_loss_bits).is_nan());
        assert_eq!(back.clients[0].ef_residual[2][0].to_bits(), 1e-30f32.to_bits());
        for (a, b) in back.global.iter().zip(&snap.global) {
            let eq = a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "global params must round-trip bitwise");
        }
    }

    #[test]
    fn truncation_is_typed() {
        let spec = toy_spec();
        let bytes = sample(&spec).encode();
        assert_eq!(Snapshot::decode(&spec, &bytes[..4]).unwrap_err(), SnapshotError::Truncated);
        // mid-body cuts surface as checksum or truncation errors — typed
        // either way, never a panic
        for cut in [15, bytes.len() / 2, bytes.len() - 1] {
            let err = Snapshot::decode(&spec, &bytes[..cut]).unwrap_err();
            assert!(
                matches!(
                    err,
                    SnapshotError::Truncated | SnapshotError::ChecksumMismatch { .. }
                ),
                "cut at {cut}: {err}"
            );
        }
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let spec = toy_spec();
        let mut bytes = sample(&spec).encode();
        let mut wrong = bytes.clone();
        wrong[0] = b'X';
        assert_eq!(Snapshot::decode(&spec, &wrong).unwrap_err(), SnapshotError::BadMagic);
        bytes[8] = 9; // version byte
        assert_eq!(
            Snapshot::decode(&spec, &bytes).unwrap_err(),
            SnapshotError::UnsupportedVersion { found: 9, supported: VERSION }
        );
    }

    #[test]
    fn flipped_byte_is_a_checksum_mismatch() {
        let spec = toy_spec();
        let mut bytes = sample(&spec).encode();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(matches!(
            Snapshot::decode(&spec, &bytes).unwrap_err(),
            SnapshotError::ChecksumMismatch { .. }
        ));
    }

    #[test]
    fn unknown_trailing_section_is_typed() {
        let spec = toy_spec();
        let snap = sample(&spec);
        let bytes = snap.encode();
        // splice in an unknown section before the checksum, re-sign
        let mut patched = bytes[..bytes.len() - 4].to_vec();
        put_u16(&mut patched, 0x7F7F);
        put_u32(&mut patched, 3);
        patched.extend_from_slice(&[1, 2, 3]);
        let sum = wire::fnv1a32(&patched);
        put_u32(&mut patched, sum);
        assert_eq!(
            Snapshot::decode(&spec, &patched).unwrap_err(),
            SnapshotError::UnknownSection(0x7F7F)
        );
    }

    #[test]
    fn missing_section_is_typed() {
        let spec = toy_spec();
        // hand-build a file with only META: magic + version + one section
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        put_u16(&mut bytes, VERSION);
        let mut meta = Vec::new();
        put_u64(&mut meta, 0);
        put_str(&mut meta, "k");
        section(&mut bytes, TAG_META, meta);
        let sum = wire::fnv1a32(&bytes);
        put_u32(&mut bytes, sum);
        assert!(matches!(
            Snapshot::decode(&spec, &bytes).unwrap_err(),
            SnapshotError::MissingSection(_)
        ));
    }

    #[test]
    fn save_load_round_trips() {
        let spec = toy_spec();
        let snap = sample(&spec);
        let path = std::env::temp_dir()
            .join(format!("fedskel_snap_test_{}", std::process::id()))
            .join("round_3.fsnap");
        let bytes = snap.save(&path).unwrap();
        assert!(bytes > 0);
        let back = Snapshot::load(&spec, &path).unwrap();
        assert_eq!(back, snap);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn determinism_key_tracks_training_knobs_not_rounds() {
        let base = crate::config::RunConfig::default();
        let k0 = determinism_key(&base);
        let mut more_rounds = base.clone();
        more_rounds.rounds += 10;
        assert_eq!(k0, determinism_key(&more_rounds), "rounds must not pin the key");
        let mut pool = base.clone();
        pool.workers = 4;
        assert_eq!(k0, determinism_key(&pool), "pool vs inline is bitwise identical");
        let mut other_seed = base.clone();
        other_seed.seed += 1;
        assert_ne!(k0, determinism_key(&other_seed));
        let mut other_sched = base;
        other_sched.sched = crate::sched::SchedKind::AsyncBuffer;
        assert_ne!(k0, determinism_key(&other_sched));
    }

    #[test]
    fn error_display_and_source() {
        let e: Box<dyn std::error::Error> =
            Box::new(SnapshotError::UnsupportedVersion { found: 2, supported: 1 });
        assert!(e.to_string().contains("version 2"));
        let anyhow_err: anyhow::Error = SnapshotError::BadMagic.into();
        assert!(anyhow_err.downcast_ref::<SnapshotError>().is_some());
    }

    #[test]
    fn empty_and_ragged_residuals_round_trip() {
        let spec = toy_spec();
        let mut snap = sample(&spec);
        snap.clients[0].ef_residual = Vec::new(); // never compressed
        snap.clients[1].ef_residual = vec![Vec::new(), vec![f32::MIN_POSITIVE, -0.0]];
        let back = Snapshot::decode(&spec, &snap.encode()).unwrap();
        assert_eq!(back.clients[0].ef_residual, Vec::<Vec<f32>>::new());
        assert_eq!(back.clients[1].ef_residual[1][1].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn reader_rejects_absurd_counts() {
        // a corrupt CLIENTS count must not OOM: craft a section claiming
        // u32::MAX clients with a 1-byte body
        let mut r = Reader::new(&[0xFF, 0xFF, 0xFF, 0xFF, 0x00]);
        assert_eq!(r.count(1).unwrap_err(), SnapshotError::Truncated);
    }

    #[test]
    fn tensor_payload_helper_round_trips() {
        let spec = toy_spec();
        let params: Params = spec
            .params
            .iter()
            .map(|p| {
                Tensor::from_vec(&p.shape, (0..p.numel()).map(|i| (i as f32).sin()).collect())
                    .unwrap()
            })
            .collect();
        let mut buf = Vec::new();
        put_params(&mut buf, &params);
        let back = get_params(&spec, &mut Reader::new(&buf)).unwrap();
        for (a, b) in back.iter().zip(&params) {
            assert!(a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }
}
