//! Heterogeneous-system simulation (Fig. 5 / Table 1 substrate).
//!
//! The paper's testbed is an Intel Xeon server and Raspberry Pi 3B+ edge
//! devices. We have one host CPU, so device classes are *capability
//! profiles*: a client's simulated per-batch compute time is the measured
//! artifact execution time divided by its capability (capability 1.0 = the
//! fastest device; a 0.25-capability device is 4× slower). This preserves
//! exactly the relation the paper's Fig. 5 tests — FedSkel assigns
//! `r_i ∝ c_i` so every device finishes a batch in roughly equal time.
//!
//! Since the parallel execution layer landed, profiles also carry a
//! [`DeviceProfile::cores`] budget: the native backend genuinely runs a
//! client's kernels on that many threads, so the core-count axis of
//! heterogeneity is *emergent* (measured), while `capability` covers the
//! axis we cannot execute (in-order ARM cores on an x86 host).
//!
//! **Semantics when both axes are active** (`cores > 1` anywhere in the
//! fleet): `capability` is the device's *per-core* speed class, and total
//! device speed emerges as `capability × measured thread scaling` — batch
//! time is measured under the client's core budget and then divided by
//! its (per-core) capability, so the two compose rather than double-count
//! (a Pi is slow because its cores are slow *and* few, exactly the
//! paper's testbed gap). With the default `cores = 1` everywhere,
//! `capability` reduces to the original total-throughput divisor.

use crate::comm::comm_seconds;
use crate::kernels::Precision;

/// A device profile.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    /// Relative compute capability c_i ∈ (0, 1]; 1.0 = fastest. With a
    /// multi-core fleet this is the *per-core* speed class (see the
    /// module docs); with the default 1-core budgets it is total
    /// single-batch throughput, as before.
    pub capability: f64,
    /// Link bandwidth in Mbit/s (for round-time simulation).
    pub bandwidth_mbps: f64,
    /// One-way link latency in seconds (charged per transfer by the
    /// simulated-network transport).
    pub latency_s: f64,
    /// CPU cores the simulated device may use for local training — the
    /// per-client thread budget handed to the compute backend
    /// ([`crate::kernels::Parallelism`]). Unlike `capability` (a
    /// post-hoc time divisor), the core budget changes how the kernels
    /// *actually execute*, so straggler behaviour is emergent.
    pub cores: usize,
    /// Forward-pass arithmetic this device trains with
    /// ([`crate::kernels::Precision`]). Defaults to f32; a
    /// capability-starved device can be assigned
    /// [`Precision::Int8`] (see [`assign_precision`]) so its local
    /// compute is genuinely cheaper, mirroring the paper's edge-device
    /// story. Like `cores`, this changes how kernels actually execute.
    pub precision: Precision,
}

impl DeviceProfile {
    pub fn new(name: impl Into<String>, capability: f64, bandwidth_mbps: f64) -> Self {
        DeviceProfile {
            name: name.into(),
            capability,
            bandwidth_mbps,
            latency_s: 0.0,
            cores: 1,
            precision: Precision::F32,
        }
    }

    /// Set a one-way link latency.
    pub fn with_latency(mut self, latency_s: f64) -> Self {
        self.latency_s = latency_s;
        self
    }

    /// Set the device's training-thread core budget (clamped to ≥ 1).
    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores.max(1);
        self
    }

    /// Set the device's forward-pass training precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

/// Apply a fleet-wide client-precision policy. Under
/// [`Precision::F32`] (the default) every device stays f32. Under
/// [`Precision::Int8`], devices whose capability is at or below the
/// fleet's capability midpoint `(min + max) / 2` switch to int8 — the
/// capability-starved half computes cheaply while strong devices keep
/// full precision. A homogeneous fleet goes int8 wholesale (everyone
/// sits at the midpoint).
pub fn assign_precision(fleet: &mut [DeviceProfile], precision: Precision) {
    if precision == Precision::F32 || fleet.is_empty() {
        return;
    }
    let min = fleet.iter().map(|d| d.capability).fold(f64::MAX, f64::min);
    let max = fleet.iter().map(|d| d.capability).fold(f64::MIN, f64::max);
    let mid = (min + max) / 2.0;
    for dev in fleet.iter_mut() {
        if dev.capability <= mid {
            dev.precision = Precision::Int8;
        }
    }
}

/// The paper's 8-device heterogeneous fleet (Fig. 5): equidistant
/// capabilities. Bandwidth defaults to a uniform edge-class link; every
/// device gets a 1-core budget (see [`equidistant_fleet_with_cores`]).
pub fn equidistant_fleet(n: usize, lo: f64, hi: f64, bandwidth_mbps: f64) -> Vec<DeviceProfile> {
    equidistant_fleet_with_cores(n, lo, hi, bandwidth_mbps, 1)
}

/// [`equidistant_fleet`] with per-device core budgets scaled by
/// capability: the fastest device gets `max_cores` threads, a device at
/// capability `c` gets `round(c · max_cores)` (min 1). With the default
/// 0.125..1.0 capability spread and `max_cores = 8`, this reproduces the
/// paper's setting where a Pi-class straggler trains on 1 core while the
/// desktop-class device fans out over 8.
pub fn equidistant_fleet_with_cores(
    n: usize,
    lo: f64,
    hi: f64,
    bandwidth_mbps: f64,
    max_cores: usize,
) -> Vec<DeviceProfile> {
    let max_cores = max_cores.max(1);
    (0..n)
        .map(|i| {
            let c = if n == 1 { hi } else { lo + (hi - lo) * i as f64 / (n - 1) as f64 };
            let cores = ((c * max_cores as f64).round() as usize).clamp(1, max_cores);
            DeviceProfile::new(format!("dev{i}"), c, bandwidth_mbps).with_cores(cores)
        })
        .collect()
}

/// Named profiles for the paper's two measured devices (Table 1).
/// Capabilities are relative single-batch LeNet throughput; the ARM class
/// is ~an order of magnitude slower than the Xeon class and trains on a
/// single core, the Xeon class on 8.
pub fn intel_profile() -> DeviceProfile {
    DeviceProfile::new("intel-xeon", 1.0, 1000.0).with_cores(8)
}

pub fn arm_profile() -> DeviceProfile {
    DeviceProfile::new("arm-rpi3b", 0.1, 100.0).with_cores(1)
}

/// Simulated wall-clock for one client round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundTime {
    pub compute_s: f64,
    pub comm_s: f64,
}

impl RoundTime {
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s
    }
}

/// Compute a client's simulated round time.
///
/// * `measured_batch_s` — measured host execution time of the client's
///   train artifact for one batch (at its ratio bucket).
/// * `batches` — local batches this round.
/// * `exchanged_params` — up+down parameter count for the round.
pub fn simulate_round(
    profile: &DeviceProfile,
    measured_batch_s: f64,
    batches: usize,
    exchanged_params: usize,
) -> RoundTime {
    RoundTime {
        compute_s: measured_batch_s * batches as f64 / profile.capability,
        comm_s: comm_seconds(exchanged_params, profile.bandwidth_mbps),
    }
}

/// Round time from *measured wire bytes* (the transport layer's frame
/// lengths) instead of logical parameter counts — Fig. 5's round time is
/// compute + this.
pub fn simulate_round_wire(
    profile: &DeviceProfile,
    measured_batch_s: f64,
    batches: usize,
    comm_s: f64,
) -> RoundTime {
    RoundTime {
        compute_s: measured_batch_s * batches as f64 / profile.capability,
        comm_s,
    }
}

/// System round time = slowest client (synchronous FL).
pub fn system_round_time(times: &[RoundTime]) -> f64 {
    times.iter().map(|t| t.total()).fold(0.0, f64::max)
}

/// Straggler utilization of one scheduled round: the fraction of the
/// round's `slots × makespan` device-seconds actually spent busy
/// (computing or communicating). Per-client busy time is clamped to the
/// makespan, so work a deadline policy cut off at the round boundary
/// counts only up to the boundary. 1.0 = perfectly packed; a barrier
/// round over a skewed fleet scores low because fast devices idle while
/// the straggler finishes — exactly the waste the [`crate::sched`]
/// policies exist to recover.
pub fn utilization(busy_secs: &[f64], makespan: f64, slots: usize) -> f64 {
    if makespan <= 0.0 || slots == 0 {
        return 0.0;
    }
    let used: f64 = busy_secs.iter().map(|&s| s.clamp(0.0, makespan)).sum();
    used / (slots as f64 * makespan)
}

/// Straggler imbalance: max/min client round time — the quantity FedSkel's
/// ratio assignment is meant to drive toward 1.0.
pub fn imbalance(times: &[RoundTime]) -> f64 {
    let max = times.iter().map(|t| t.total()).fold(f64::MIN, f64::max);
    let min = times.iter().map(|t| t.total()).fold(f64::MAX, f64::min);
    if min <= 0.0 {
        return f64::INFINITY;
    }
    max / min
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_equidistant() {
        let f = equidistant_fleet(8, 0.125, 1.0, 100.0);
        assert_eq!(f.len(), 8);
        assert!((f[0].capability - 0.125).abs() < 1e-9);
        assert!((f[7].capability - 1.0).abs() < 1e-9);
        assert!(f.windows(2).all(|w| w[1].capability > w[0].capability));
    }

    #[test]
    fn slower_device_takes_longer() {
        let fast = DeviceProfile::new("f", 1.0, 100.0);
        let slow = DeviceProfile::new("s", 0.25, 100.0);
        let tf = simulate_round(&fast, 0.1, 10, 1000);
        let ts = simulate_round(&slow, 0.1, 10, 1000);
        assert!((ts.compute_s / tf.compute_s - 4.0).abs() < 1e-9);
        assert_eq!(ts.comm_s, tf.comm_s);
    }

    #[test]
    fn ratio_compensation_balances() {
        // if a device at capability c runs an artifact whose measured time
        // scales ~linearly with r, choosing r = c equalizes round times.
        let fleet = equidistant_fleet(4, 0.25, 1.0, 1e9);
        let full_batch_s = 0.08;
        let times: Vec<RoundTime> = fleet
            .iter()
            .map(|d| {
                let r = d.capability; // r_i ∝ c_i
                let batch_s = full_batch_s * r; // idealized linear scaling
                simulate_round(d, batch_s, 5, 0)
            })
            .collect();
        assert!(imbalance(&times) < 1.01, "imbalance {}", imbalance(&times));
    }

    #[test]
    fn system_time_is_max() {
        let times = vec![
            RoundTime { compute_s: 1.0, comm_s: 0.5 },
            RoundTime { compute_s: 2.0, comm_s: 0.1 },
        ];
        assert!((system_round_time(&times) - 2.1).abs() < 1e-9);
        assert!((imbalance(&times) - 2.1 / 1.5).abs() < 1e-9);
    }

    #[test]
    fn profiles_sane() {
        assert!(intel_profile().capability > arm_profile().capability);
        assert_eq!(intel_profile().latency_s, 0.0);
        assert_eq!(intel_profile().with_latency(0.02).latency_s, 0.02);
        assert_eq!(intel_profile().cores, 8);
        assert_eq!(arm_profile().cores, 1);
        assert_eq!(arm_profile().with_cores(0).cores, 1); // clamped
    }

    #[test]
    fn core_budgets_scale_with_capability() {
        let f = equidistant_fleet_with_cores(8, 0.125, 1.0, 100.0, 8);
        assert_eq!(f[0].cores, 1, "slowest device is a 1-core straggler");
        assert_eq!(f[7].cores, 8, "fastest device gets the full budget");
        assert!(f.windows(2).all(|w| w[1].cores >= w[0].cores));
        // plain fleet stays single-core (back-compat for fig5/transport)
        assert!(equidistant_fleet(4, 0.25, 1.0, 100.0).iter().all(|d| d.cores == 1));
    }

    #[test]
    fn precision_assignment_splits_the_fleet_at_the_midpoint() {
        // f32 policy: everyone stays f32
        let mut fleet = equidistant_fleet(4, 0.25, 1.0, 100.0);
        assign_precision(&mut fleet, Precision::F32);
        assert!(fleet.iter().all(|d| d.precision == Precision::F32));
        // int8 policy: capability ≤ (0.25+1.0)/2 = 0.625 goes int8
        assign_precision(&mut fleet, Precision::Int8);
        assert_eq!(fleet[0].precision, Precision::Int8); // 0.25
        assert_eq!(fleet[1].precision, Precision::Int8); // 0.50
        assert_eq!(fleet[2].precision, Precision::F32); // 0.75
        assert_eq!(fleet[3].precision, Precision::F32); // 1.00
        // homogeneous fleet goes int8 wholesale
        let mut homo = equidistant_fleet(3, 1.0, 1.0, 100.0);
        assign_precision(&mut homo, Precision::Int8);
        assert!(homo.iter().all(|d| d.precision == Precision::Int8));
        // defaults and the builder
        assert_eq!(arm_profile().precision, Precision::F32);
        assert_eq!(arm_profile().with_precision(Precision::Int8).precision, Precision::Int8);
        assign_precision(&mut [], Precision::Int8); // empty fleet is a no-op
    }

    #[test]
    fn utilization_clamps_and_normalizes() {
        // barrier over a 2× skewed pair: (1 + 2) / (2 × 2) = 0.75
        assert!((utilization(&[1.0, 2.0], 2.0, 2) - 0.75).abs() < 1e-12);
        // a straggler cut off at the deadline counts only up to it
        assert!((utilization(&[1.0, 5.0], 2.0, 2) - 0.75).abs() < 1e-12);
        // degenerate inputs are 0, not NaN
        assert_eq!(utilization(&[1.0], 0.0, 1), 0.0);
        assert_eq!(utilization(&[], 1.0, 0), 0.0);
        // perfectly balanced fleet is fully packed
        assert!((utilization(&[2.0, 2.0], 2.0, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wire_round_time_uses_given_comm_seconds() {
        let dev = DeviceProfile::new("d", 0.5, 100.0);
        let t = simulate_round_wire(&dev, 0.1, 4, 0.3);
        assert!((t.compute_s - 0.8).abs() < 1e-9);
        assert!((t.comm_s - 0.3).abs() < 1e-9);
        assert!((t.total() - 1.1).abs() < 1e-9);
    }
}
