//! Trace-sink overhead bench — `JsonlSink` vs `NullSink` on a real run,
//! written to `BENCH_trace_overhead.json`.
//!
//! Runs the same federated training job (native backend, tiny spec,
//! pinned per-bucket batch seconds) twice per trial: once with only a
//! [`crate::trace::NullSink`] attached and once writing a full
//! frame-level `trace.jsonl`. Both arms have an *active* trace, so both
//! pay the per-round digest — the measured difference is purely the
//! JSONL serialization + buffered file writes. The bench takes the
//! minimum wall time over its trials (the standard noise filter for
//! wall-clock gates) and **fails** if the JSONL arm exceeds the budget
//! of [`budget`]: 5% over the null arm plus a 20 ms absolute slack for
//! sub-second smoke runs. It also asserts the two arms trained
//! bit-identical models — tracing must observe a run, never steer it.
//!
//! Knobs (env):
//! * `FEDSKEL_BENCH_SMOKE=1` — 4 rounds on a small dataset (CI).
//! * `FEDSKEL_BENCH_ROUNDS=n` — override the round count.
//! * `FEDSKEL_BENCH_OUT=path` — where the JSON report goes.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::config::RunConfig;
use crate::coordinator::Coordinator;
use crate::metrics::Table;
use crate::model::params_digest;
use crate::runtime::native::NativeBackend;
use crate::trace::NullSink;
use crate::util::json::Json;
use crate::util::timer::Timer;

/// Wall-time budget for the JSONL arm given the null arm's time: 5%
/// relative overhead plus 20 ms absolute slack (so sub-second smoke runs
/// don't gate on scheduler jitter).
pub fn budget(null_s: f64) -> f64 {
    null_s * 1.05 + 0.02
}

/// Pinned per-bucket batch seconds for the tiny spec (see
/// [`crate::bench::sched`]) — keeps the simulated clock deterministic so
/// both arms schedule identically.
fn fixed_secs() -> BTreeMap<usize, f64> {
    [25usize, 50, 100].into_iter().map(|b| (b, b as f64 / 100.0 * 0.08)).collect()
}

fn base_cfg(rounds: usize, dataset: usize) -> RunConfig {
    RunConfig {
        method: crate::config::Method::FedSkel,
        model: "tiny_native".into(),
        num_clients: 6,
        shards_per_client: 2,
        dataset_size: dataset,
        new_test_size: 64,
        rounds,
        local_steps: 2,
        eval_every: 2,
        lr: 0.08,
        seed: 42,
        ..RunConfig::default()
    }
}

/// One full run; `trace_path` picks the arm. Returns (wall secs, digest).
fn run_case(mut cfg: RunConfig, trace_path: Option<&str>) -> Result<(f64, u64)> {
    cfg.trace = trace_path.map(|s| s.to_string());
    let backend = NativeBackend::tiny().with_fixed_batch_secs(fixed_secs());
    let t = Timer::start();
    let mut coord = Coordinator::new(cfg, backend)?;
    if trace_path.is_none() {
        // keep the trace *active* so this arm pays the digest too
        coord.add_trace_sink(Box::new(NullSink));
    }
    coord.run()?;
    Ok((t.elapsed_secs(), params_digest(&coord.global)))
}

/// Run both arms `trials` times, gate the overhead, write `out`.
pub fn run_with(rounds: usize, dataset: usize, trials: usize, out: &str) -> Result<String> {
    let trace_path = std::env::temp_dir()
        .join(format!("fedskel_bench_trace_{}.jsonl", std::process::id()));
    let trace_str = trace_path.to_string_lossy().into_owned();

    let (mut null_s, mut jsonl_s) = (f64::INFINITY, f64::INFINITY);
    let (mut null_digest, mut jsonl_digest) = (0u64, 0u64);
    for _ in 0..trials.max(1) {
        let (w, d) = run_case(base_cfg(rounds, dataset), None)?;
        null_s = null_s.min(w);
        null_digest = d;
        let (w, d) = run_case(base_cfg(rounds, dataset), Some(&trace_str))?;
        jsonl_s = jsonl_s.min(w);
        jsonl_digest = d;
    }
    ensure!(
        null_digest == jsonl_digest,
        "tracing changed the trained model: null {null_digest:#018x} vs jsonl {jsonl_digest:#018x}"
    );
    let events = std::fs::read_to_string(&trace_path)
        .map(|t| t.lines().count().saturating_sub(1))
        .unwrap_or(0);
    std::fs::remove_file(&trace_path).ok();
    let allowed = budget(null_s);
    ensure!(
        jsonl_s <= allowed,
        "JsonlSink overhead above budget: {jsonl_s:.3}s vs null {null_s:.3}s \
         (allowed {allowed:.3}s)"
    );

    let report = Json::obj(vec![
        ("bench", Json::str("trace_overhead")),
        ("model", Json::str("tiny_native")),
        ("rounds", Json::num(rounds as f64)),
        ("trials", Json::num(trials as f64)),
        ("events", Json::num(events as f64)),
        ("null_s", Json::num(null_s)),
        ("jsonl_s", Json::num(jsonl_s)),
        ("budget_s", Json::num(allowed)),
        ("overhead_ratio", Json::num(if null_s > 0.0 { jsonl_s / null_s } else { 1.0 })),
        ("digest", Json::str(format!("{null_digest:#018x}"))),
    ]);
    std::fs::write(out, report.to_string_pretty())?;

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["events recorded".into(), events.to_string()]);
    t.row(vec!["null sink (s, min)".into(), format!("{null_s:.3}")]);
    t.row(vec!["jsonl sink (s, min)".into(), format!("{jsonl_s:.3}")]);
    t.row(vec!["budget (s)".into(), format!("{allowed:.3}")]);
    t.row(vec![
        "overhead".into(),
        format!("{:+.1}%", if null_s > 0.0 { (jsonl_s / null_s - 1.0) * 100.0 } else { 0.0 }),
    ]);
    Ok(format!(
        "Trace-sink overhead (native tiny, {rounds} rounds, min of {trials} trials)\n{}\nwrote {out}",
        t.render()
    ))
}

/// Env-configured entry used by `benches/trace_overhead.rs`:
/// `FEDSKEL_BENCH_SMOKE=1` runs the small CI profile.
pub fn run_env(default_out: &str) -> Result<String> {
    let smoke = std::env::var("FEDSKEL_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let rounds: usize = std::env::var("FEDSKEL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 4 } else { 10 });
    let dataset = if smoke { 320 } else { 640 };
    let trials = if smoke { 2 } else { 3 };
    let out = std::env::var("FEDSKEL_BENCH_OUT").unwrap_or_else(|_| default_out.to_string());
    run_with(rounds, dataset, trials, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_is_five_percent_plus_slack() {
        assert!((budget(1.0) - 1.07).abs() < 1e-12);
        assert!((budget(0.0) - 0.02).abs() < 1e-12);
        // the absolute slack dominates for very fast runs
        assert!(budget(0.1) > 0.1 * 1.05);
    }
}
