//! Table 2 — "Volume of parameters communication" for FedAvg / FedMTL /
//! LG-FedAvg / FedSkel over a full training schedule.
//!
//! Pure accounting over the comm substrate (no artifact execution): for
//! each method we replay its per-round exchange kinds over `rounds` rounds
//! × `clients` clients, both directions, exactly as the coordinator's
//! ledger records them during real runs (the coordinator unit tests pin
//! that the two paths agree).
//!
//! Alongside the paper's parameter counts, each row reports *measured
//! wire bytes*: the exact frame size the transport codec produces for
//! that exchange ([`wire::encoded_len`], which the codec tests pin to
//! `encode(..).len()`), so the 64.8%-class reduction is stated in the
//! unit that actually hits the network.

use anyhow::Result;

use crate::comm::{params_moved, CommLedger, ExchangeKind};
use crate::coordinator::lg_global_ids_of;
use crate::metrics::Table;
use crate::model::spec::{Manifest, ModelSpec};
use crate::transport::wire::{self, Quant};

#[derive(Debug, Clone)]
pub struct CommRow {
    pub method: String,
    pub total_params: u64,
    pub reduction_pct: f64,
    /// Measured bytes-on-the-wire (f32 frames) for the whole schedule.
    pub wire_bytes: u64,
    pub wire_reduction_pct: f64,
}

/// Replay one method's schedule.
pub fn method_ledger(
    spec: &ModelSpec,
    method: &str,
    clients: usize,
    rounds: usize,
    fedskel_ratio: usize,
    updateskel_per_setskel: usize,
) -> Result<CommLedger> {
    let mut ledger = CommLedger::new();
    let lg_ids = lg_global_ids_of(&spec.params, &["fc1.", "fc2.", "fc3.", "fc.", "head."]);
    for r in 0..rounds {
        let (up, down) = match method {
            "fedavg" => (ExchangeKind::Full, ExchangeKind::Full),
            // FedMTL: personalized models never adopt server weights, but
            // the prox anchor is still downloaded each round and the server
            // receives full uploads — full-volume traffic, like the paper's
            // near-zero reduction for FedMTL.
            "fedmtl" => (ExchangeKind::Full, ExchangeKind::Full),
            "lgfedavg" => (
                ExchangeKind::ParamSubset(lg_ids.clone()),
                ExchangeKind::ParamSubset(lg_ids.clone()),
            ),
            "fedskel" => {
                if r % (1 + updateskel_per_setskel) == 0 {
                    (ExchangeKind::Full, ExchangeKind::Full)
                } else {
                    let ks = spec.skel_sizes(fedskel_ratio);
                    (ExchangeKind::Skeleton(ks.clone()), ExchangeKind::Skeleton(ks))
                }
            }
            other => anyhow::bail!("unknown method {other}"),
        };
        let up_bytes = wire::encoded_len(spec, &up, Quant::F32) as u64;
        let down_bytes = wire::encoded_len(spec, &down, Quant::F32) as u64;
        for _ in 0..clients {
            ledger.record(spec, &up, &down);
            ledger.record_wire(up_bytes, down_bytes);
        }
        ledger.end_round();
    }
    Ok(ledger)
}

pub fn run_rows(
    manifest: &Manifest,
    model: &str,
    clients: usize,
    rounds: usize,
    fedskel_ratio: usize,
) -> Result<Vec<CommRow>> {
    let spec = manifest.model(model)?;
    let base = method_ledger(spec, "fedavg", clients, rounds, fedskel_ratio, 3)?;
    let mut rows = Vec::new();
    for m in ["fedavg", "fedmtl", "lgfedavg", "fedskel"] {
        let ledger = method_ledger(spec, m, clients, rounds, fedskel_ratio, 3)?;
        rows.push(CommRow {
            method: m.to_string(),
            total_params: ledger.total_params(),
            reduction_pct: ledger.reduction_vs(&base),
            wire_bytes: ledger.total_wire_bytes(),
            wire_reduction_pct: ledger.wire_reduction_vs(&base),
        });
    }
    Ok(rows)
}

pub fn render(rows: &[CommRow], model: &str, clients: usize, rounds: usize, ratio: usize) -> String {
    let mut t = Table::new(&["Method", "Params Comm.", "Reduction", "Wire bytes", "Wire reduction"]);
    for r in rows {
        let dash = |pct: f64| {
            if pct.abs() < 1e-9 {
                "-".to_string()
            } else {
                format!("{pct:.1}%")
            }
        };
        t.row(vec![
            pretty_name(&r.method, ratio),
            format!("{:.2e}", r.total_params as f64),
            dash(r.reduction_pct),
            format!("{:.2e}", r.wire_bytes as f64),
            dash(r.wire_reduction_pct),
        ]);
    }
    format!(
        "Table 2 — communication, {model}, {clients} clients x {rounds} rounds (up+down)\n\
         (wire bytes = exact f32 frame sizes from the transport codec)\n{}",
        t.render()
    )
}

fn pretty_name(m: &str, ratio: usize) -> String {
    match m {
        "fedavg" => "FedAvg".into(),
        "fedmtl" => "FedMTL".into(),
        "lgfedavg" => "LG-FedAvg".into(),
        "fedskel" => format!("FedSkel (r = {ratio}%)"),
        other => other.into(),
    }
}

pub fn run(
    manifest: &Manifest,
    model: &str,
    clients: usize,
    rounds: usize,
    ratio: usize,
) -> Result<String> {
    let rows = run_rows(manifest, model, clients, rounds, ratio)?;
    Ok(render(&rows, model, clients, rounds, ratio))
}

/// One-round sanity helper used by tests.
pub fn one_round_params(spec: &ModelSpec, kind: &ExchangeKind) -> usize {
    params_moved(spec, kind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::toy_spec;

    #[test]
    fn fedskel_reduces_most_at_low_ratio() {
        let spec = toy_spec();
        let base = method_ledger(&spec, "fedavg", 10, 40, 25, 3).unwrap();
        let skel = method_ledger(&spec, "fedskel", 10, 40, 25, 3).unwrap();
        let mtl = method_ledger(&spec, "fedmtl", 10, 40, 25, 3).unwrap();
        assert!(skel.total_params() < base.total_params());
        assert!(skel.total_wire_bytes() < base.total_wire_bytes());
        // FedMTL moves full volume (anchor down + personalized up)
        assert_eq!(mtl.total_params(), base.total_params());
        assert_eq!(mtl.total_wire_bytes(), base.total_wire_bytes());
    }

    #[test]
    fn wire_rows_populated_and_consistent() {
        let spec = toy_spec();
        let l = method_ledger(&spec, "fedavg", 3, 5, 25, 3).unwrap();
        // 3 clients × 5 rounds × 2 directions × one full frame each
        let frame = wire::encoded_len(&spec, &ExchangeKind::Full, Quant::F32) as u64;
        assert_eq!(l.total_wire_bytes(), 3 * 5 * 2 * frame);
    }

    #[test]
    fn fedskel_setskel_cadence_counts_full_rounds() {
        let spec = toy_spec();
        // 4 rounds with 1:3 cadence = 1 full + 3 skeleton
        let l = method_ledger(&spec, "fedskel", 1, 4, 25, 3).unwrap();
        let full = spec.num_params as u64;
        let ks = spec.skel_sizes(25);
        let skel = one_round_params(&spec, &ExchangeKind::Skeleton(ks)) as u64;
        assert_eq!(l.total_params(), 2 * full + 6 * skel);
    }

    #[test]
    fn unknown_method_errors() {
        assert!(method_ledger(&toy_spec(), "sgd", 1, 1, 10, 3).is_err());
    }
}
