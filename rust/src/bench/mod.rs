//! Shared implementations of the paper's benchmark experiments.
//!
//! Each submodule regenerates one table/figure of the paper and is reused
//! by both the `fedskel` CLI subcommands and the `cargo bench` targets
//! (rust/benches/*.rs), so the numbers in EXPERIMENTS.md come from exactly
//! one code path.

pub mod checkpoint_overhead;
pub mod comm_pareto;
#[cfg(feature = "pjrt")]
pub mod fig5;
pub mod prof_overhead;
pub mod sched;
#[cfg(feature = "pjrt")]
pub mod table1;
pub mod table1_native;
pub mod table2;
pub mod trace_overhead;
