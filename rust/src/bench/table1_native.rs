//! Table 1 on the native CPU backend — no artifacts, no `pjrt` feature.
//!
//! Times the skeleton-sliced backward pass and the whole train step at
//! each ratio bucket against the full update (r = 100%), plus the
//! compute-bound prediction from the sliced-GEMM FLOP ratio — and sweeps
//! the measurement over a list of kernel-thread budgets, so the report
//! records *scaling* (how the parallel execution layer speeds a fixed
//! ratio up) next to *slicing* (how a smaller ratio speeds a fixed budget
//! up). This is the default-build path that records the repo's central
//! performance claim: results are written to `BENCH_table1_native.json`
//! (now with a per-thread-count dimension) so the perf trajectory is
//! tracked per commit (CI runs it in smoke mode at 1 and 2 threads).
//!
//! Speedups are computed *within* a thread count (baseline = r100 at the
//! same budget); `thread_scaling` compares a row's step time against the
//! 1-thread run at the same ratio when the sweep includes one.
//!
//! Since the kernel-tier PR the sweep carries a third dimension: every
//! (ratio, threads) cell is measured once per [`KernelTier`], and each
//! row records the backward pass's achieved GFLOP/s
//! (`backward_gemm_flops / bwd_time`) so the SIMD-vs-scalar floor gate
//! ([`gate_simd_floor`]) has an absolute throughput axis to compare on.
//! The tiers share the bitwise determinism contract, so rows differ only
//! in time columns — never in what the training run would compute.
//!
//! Knobs (env):
//! * `FEDSKEL_BENCH_SMOKE=1` — tiny model, 1 sample, no warmup (CI).
//! * `FEDSKEL_BENCH_SAMPLES=n` — timing samples per measurement.
//! * `FEDSKEL_BENCH_THREADS=a,b,c` — thread counts to sweep.
//! * `FEDSKEL_BENCH_TIERS=scalar,simd` — kernel tiers to sweep.
//! * `FEDSKEL_BENCH_OUT=path` — where the JSON report goes.

use anyhow::{bail, Result};

use crate::benchkit::Bench;
use crate::kernels::{KernelTier, Parallelism};
use crate::metrics::Table;
use crate::model::init_params;
use crate::runtime::native::{prefix_skeleton, NativeBackend, NativeModel};
use crate::util::json::Json;
use crate::util::Rng;

/// One measured (ratio, thread-count, kernel-tier) row.
#[derive(Debug, Clone)]
pub struct NativeRow {
    pub ratio: usize,
    /// Kernel-thread budget this row was measured under.
    pub threads: usize,
    /// Kernel tier this row was measured under.
    pub tier: KernelTier,
    /// Median skeleton-sliced backward time.
    pub bwd_ms: f64,
    pub bwd_speedup: f64,
    /// Achieved backward GEMM throughput: `backward_gemm_flops / bwd_s`.
    pub bwd_gflops: f64,
    /// Median full train-step time (forward + loss + backward + update).
    pub step_ms: f64,
    pub overall_speedup: f64,
    /// FLOP-ratio prediction for the backward speedup.
    pub bwd_speedup_computebound: f64,
    /// Step-time scaling vs the 1-thread run at the same ratio (1.0 when
    /// the sweep has no 1-thread run to compare against).
    pub thread_scaling: f64,
}

/// Measure backward-pass and train-step time per ratio bucket, under the
/// model's configured [`Parallelism`]. Every ratio must be a train bucket
/// of the model; r=100 is always measured as the baseline.
pub fn run_rows(model: &NativeModel, ratios: &[usize], bench: &Bench) -> Result<Vec<NativeRow>> {
    let spec = model.spec.clone();
    let threads = model.parallelism().threads();
    let tier = model.parallelism().tier();
    let batch = spec.train_batch;
    let numel: usize = spec.input_shape.iter().product();
    let mut rng = Rng::new(0xB41C);
    let x: Vec<f32> = (0..batch * numel).map(|_| rng.normal() * 0.5).collect();
    let y: Vec<i32> = (0..batch).map(|i| (i % spec.num_classes) as i32).collect();
    let params = init_params(&spec, 7);
    let mut backend = NativeBackend::new(model.clone());

    let mut measure = |r: usize| -> Result<(f64, f64, f64)> {
        let ks = spec.train_artifact(r)?.k.clone();
        let skel = prefix_skeleton(&ks);
        let trace = model.forward(&params, &x, batch)?;
        let (_loss, dlog) = model.loss_grad(&trace, &y)?;
        let bwd = bench
            .run(&format!("native bwd {} r{r} t{threads} {}", spec.name, tier.name()), || {
                model.backward(&x, &params, &trace, &dlog, &skel).expect("backward");
            })
            .median_s;
        let step = bench
            .run(&format!("native train_step {} r{r} t{threads} {}", spec.name, tier.name()), || {
                backend
                    .train_step(r, &params, &params, &x, &y, &skel, 0.05, 0.0)
                    .expect("train step");
            })
            .median_s;
        Ok((bwd, step, model.backward_gemm_flops(batch, &skel)))
    };

    let (base_bwd, base_step, base_flops) = measure(100)?;
    let mut rows = Vec::new();
    for &r in ratios {
        let (bwd, step, flops) =
            if r == 100 { (base_bwd, base_step, base_flops) } else { measure(r)? };
        rows.push(NativeRow {
            ratio: r,
            threads,
            tier,
            bwd_ms: bwd * 1e3,
            bwd_speedup: base_bwd / bwd,
            bwd_gflops: flops / (bwd * 1e9),
            step_ms: step * 1e3,
            overall_speedup: base_step / step,
            bwd_speedup_computebound: base_flops / flops,
            thread_scaling: 1.0,
        });
    }
    Ok(rows)
}

/// Run the per-ratio measurement at every (kernel tier, thread budget)
/// combination and fill each row's `thread_scaling` against the sweep's
/// 1-thread run *of the same tier* (if present). Rows are ordered
/// sweep-major: all ratios at `(tiers[0], threads[0])`, then all at
/// `(tiers[0], threads[1])`, …, then `tiers[1]` …
pub fn run_sweep(
    model: &NativeModel,
    ratios: &[usize],
    threads: &[usize],
    tiers: &[KernelTier],
    bench: &Bench,
) -> Result<Vec<NativeRow>> {
    let mut all = Vec::new();
    for &tier in tiers {
        for &t in threads {
            let m = model.clone().with_parallelism(Parallelism::new(t).with_tier(tier));
            all.extend(run_rows(&m, ratios, bench)?);
        }
    }
    let serial: Vec<(KernelTier, usize, f64)> = all
        .iter()
        .filter(|r| r.threads == 1)
        .map(|r| (r.tier, r.ratio, r.step_ms))
        .collect();
    for row in &mut all {
        let base = serial.iter().find(|(tier, ratio, _)| *tier == row.tier && *ratio == row.ratio);
        if let Some(&(_, _, base_ms)) = base {
            row.thread_scaling = base_ms / row.step_ms;
        }
    }
    Ok(all)
}

/// Render the paper-shaped table (one block per tier × thread count).
pub fn render(model: &str, rows: &[NativeRow]) -> String {
    let mut t = Table::new(&[
        "tier",
        "threads",
        "r",
        "Back-prop (ms)",
        "Back-prop speedup",
        "Back-prop GFLOP/s",
        "Train step (ms)",
        "Overall speedup",
        "Back-prop (compute-bound est.)",
        "Thread scaling",
    ]);
    for row in rows {
        t.row(vec![
            row.tier.name().to_string(),
            format!("{}", row.threads),
            format!("{}%", row.ratio),
            format!("{:.3}", row.bwd_ms),
            format!("{:.2}x", row.bwd_speedup),
            format!("{:.2}", row.bwd_gflops),
            format!("{:.3}", row.step_ms),
            format!("{:.2}x", row.overall_speedup),
            format!("{:.2}x", row.bwd_speedup_computebound),
            format!("{:.2}x", row.thread_scaling),
        ]);
    }
    format!(
        "Table 1 (native CPU backend, {model}) — speedups vs full update (r=100%) \
         per kernel tier × thread budget\n{}",
        t.render()
    )
}

/// JSON report (the `BENCH_table1_native.json` schema). `threads` and
/// `tiers` are the swept dimension lists; every row carries its own
/// `threads`/`tier` values.
pub fn rows_to_json(
    model: &str,
    batch: usize,
    threads: &[usize],
    tiers: &[KernelTier],
    rows: &[NativeRow],
) -> Json {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("ratio", Json::num(r.ratio as f64)),
                ("threads", Json::num(r.threads as f64)),
                ("tier", Json::str(r.tier.name())),
                ("bwd_ms", Json::num(r.bwd_ms)),
                ("bwd_speedup", Json::num(r.bwd_speedup)),
                ("bwd_gflops", Json::num(r.bwd_gflops)),
                ("step_ms", Json::num(r.step_ms)),
                ("overall_speedup", Json::num(r.overall_speedup)),
                ("bwd_speedup_computebound", Json::num(r.bwd_speedup_computebound)),
                ("thread_scaling", Json::num(r.thread_scaling)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str("table1_native")),
        ("model", Json::str(model)),
        ("batch", Json::num(batch as f64)),
        ("threads", Json::Arr(threads.iter().map(|&t| Json::num(t as f64)).collect())),
        ("tiers", Json::Arr(tiers.iter().map(|t| Json::str(t.name())).collect())),
        ("unit", Json::str("ms")),
        ("rows", Json::Arr(rows_json)),
    ])
}

pub fn write_json(
    path: &str,
    model: &str,
    batch: usize,
    threads: &[usize],
    tiers: &[KernelTier],
    rows: &[NativeRow],
) -> Result<()> {
    std::fs::write(path, rows_to_json(model, batch, threads, tiers, rows).to_string_pretty())?;
    Ok(())
}

/// Measure, render, and write the JSON report with explicit settings —
/// the CLI (`fedskel speedup`) resolves its own flags and calls this, so
/// flags are never silently overridden by environment variables.
pub fn run_with(
    model: &NativeModel,
    ratios: &[usize],
    threads: &[usize],
    tiers: &[KernelTier],
    samples: usize,
    out: &str,
) -> Result<(String, Vec<NativeRow>)> {
    let samples = samples.max(1);
    // sanitize the sweep so the JSON's top-level `threads`/`tiers` always
    // match what the rows actually measured: drop zeros (Parallelism
    // would clamp them to 1) and duplicates, default to a serial
    // scalar-only sweep
    let mut sane: Vec<usize> = Vec::with_capacity(threads.len());
    for &t in threads {
        if t > 0 && !sane.contains(&t) {
            sane.push(t);
        }
    }
    if sane.is_empty() {
        sane.push(1);
    }
    let threads = sane;
    let mut sane_tiers: Vec<KernelTier> = Vec::with_capacity(tiers.len());
    for &t in tiers {
        if !sane_tiers.contains(&t) {
            sane_tiers.push(t);
        }
    }
    if sane_tiers.is_empty() {
        sane_tiers.push(KernelTier::Scalar);
    }
    let tiers = sane_tiers;
    let bench = Bench::new(if samples <= 1 { 0 } else { 2 }, samples);
    let rows = run_sweep(model, ratios, &threads, &tiers, &bench)?;
    write_json(out, &model.spec.name, model.spec.train_batch, &threads, &tiers, &rows)?;
    let report = format!("{}\nwrote {out}", render(&model.spec.name, &rows));
    Ok((report, rows))
}

/// Gate: the SIMD tier's backward GFLOP/s must be at least `min_speedup`
/// times the scalar tier's, averaged over every (ratio, threads) cell
/// measured at both tiers. Returns the summary line on success, bails
/// (with the same numbers) on failure or when no cell has both tiers.
pub fn gate_simd_floor(rows: &[NativeRow], min_speedup: f64) -> Result<String> {
    let mut speedups = Vec::new();
    for s in rows.iter().filter(|r| r.tier == KernelTier::Simd) {
        let scalar = rows.iter().find(|r| {
            r.tier == KernelTier::Scalar && r.ratio == s.ratio && r.threads == s.threads
        });
        if let Some(sc) = scalar {
            if sc.bwd_gflops > 0.0 {
                speedups.push(s.bwd_gflops / sc.bwd_gflops);
            }
        }
    }
    if speedups.is_empty() {
        bail!("simd floor gate: no (ratio, threads) cell was measured at both tiers");
    }
    let mean = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let line = format!(
        "simd floor gate: simd/scalar bwd GFLOP/s = {mean:.2}x mean over {} cell(s) \
         (floor {min_speedup:.2}x)",
        speedups.len()
    );
    if mean < min_speedup {
        bail!("{line} — FAILED");
    }
    Ok(line)
}

/// Per-layer forward-GEMM throughput under the model's configured
/// [`Parallelism`] (tier + threads): times `pgemm` on each layer's
/// forward shape (`m = rows(batch)`, `k = patch_len`, `n = cout` for
/// convs; `m = batch`, `k/n = in/out` for dense) and reports
/// `(layer name, GFLOP/s)` rows.
pub fn per_layer_gflops(model: &NativeModel, bench: &Bench) -> Vec<(String, f64)> {
    use crate::runtime::native::Layer;
    let batch = model.spec.train_batch;
    let tier = model.parallelism().tier().name();
    let mut rng = Rng::new(0x61F1);
    let mut out = Vec::new();
    for (li, layer) in model.layers.iter().enumerate() {
        let (name, m, k, n) = match layer {
            Layer::Conv { conv, .. } => {
                (format!("conv{li}"), conv.rows(batch), conv.patch_len(), conv.cout)
            }
            Layer::Dense { in_dim, out_dim, .. } => (format!("fc{li}"), batch, *in_dim, *out_dim),
        };
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() * 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
        let mut c = vec![0.0f32; m * n];
        let par = model.parallelism();
        let t = bench
            .run(&format!("pgemm {name} {m}x{k}x{n} {tier}"), || {
                crate::kernels::pgemm(par, m, k, n, &a, &b, &mut c);
            })
            .median_s;
        out.push((format!("{name} ({m}x{k}x{n})"), 2.0 * (m * k * n) as f64 / (t * 1e9)));
    }
    out
}

/// Env-configured run used by `benches/hotpath.rs` and
/// `benches/table1_speedup.rs`: times the LeNet spec (or the tiny one in
/// smoke mode), sweeps `FEDSKEL_BENCH_THREADS` (default `1,2` in smoke,
/// `1,2,4` otherwise) × `FEDSKEL_BENCH_TIERS` (default both), writes the
/// JSON report, returns the rendered table.
pub fn run_env(default_out: &str) -> Result<String> {
    let smoke = std::env::var("FEDSKEL_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let samples: usize = std::env::var("FEDSKEL_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 1 } else { 10 });
    let threads: Vec<usize> = std::env::var("FEDSKEL_BENCH_THREADS")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|v| v.trim().parse().ok())
                .filter(|&t| t > 0)
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| if smoke { vec![1, 2] } else { vec![1, 2, 4] });
    let tiers: Vec<KernelTier> = std::env::var("FEDSKEL_BENCH_TIERS")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|v| KernelTier::parse(v.trim()).ok())
                .collect::<Vec<KernelTier>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![KernelTier::Scalar, KernelTier::Simd]);
    let (model, ratios): (NativeModel, Vec<usize>) = if smoke {
        (NativeModel::tiny(), vec![100, 50, 25])
    } else {
        (NativeModel::lenet(), vec![100, 50, 40, 25, 10])
    };
    let out = std::env::var("FEDSKEL_BENCH_OUT").unwrap_or_else(|_| default_out.to_string());
    let (report, _rows) = run_with(&model, &ratios, &threads, &tiers, samples, &out)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_rows_and_report() {
        let model = NativeModel::micro();
        let bench = Bench::new(0, 1);
        let rows = run_rows(&model, &[100, 50], &bench).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].ratio, 100);
        assert_eq!(rows[0].threads, 1);
        assert_eq!(rows[0].tier, KernelTier::Scalar);
        assert!((rows[0].bwd_speedup - 1.0).abs() < 1e-9);
        assert!((rows[0].overall_speedup - 1.0).abs() < 1e-9);
        assert!(rows.iter().all(|r| r.bwd_ms > 0.0 && r.step_ms > 0.0));
        assert!(rows.iter().all(|r| r.bwd_gflops > 0.0 && r.bwd_gflops.is_finite()));
        // r50 strictly cheaper in the compute-bound model
        assert!(rows[1].bwd_speedup_computebound > 1.0);
        let s = render("micro_native", &rows);
        assert!(s.contains("100%") && s.contains("50%") && s.contains("scalar"));
        let j = rows_to_json("micro_native", 2, &[1], &[KernelTier::Scalar], &rows);
        assert!(j.to_string().contains("\"bench\":\"table1_native\""));
        // unknown bucket is an error
        assert!(run_rows(&model, &[100, 33], &bench).is_err());
    }

    #[test]
    fn thread_sweep_adds_dimension_and_scaling() {
        let model = NativeModel::micro();
        let bench = Bench::new(0, 1);
        let rows = run_sweep(&model, &[100, 50], &[1, 2], &[KernelTier::Scalar], &bench).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows.iter().filter(|r| r.threads == 1).count(), 2);
        assert_eq!(rows.iter().filter(|r| r.threads == 2).count(), 2);
        // 1-thread rows scale 1.0 against themselves; every row got a
        // finite positive scaling (a 1-thread baseline exists)
        assert!(rows
            .iter()
            .filter(|r| r.threads == 1)
            .all(|r| (r.thread_scaling - 1.0).abs() < 1e-12));
        assert!(rows.iter().all(|r| r.thread_scaling > 0.0));
        let j = rows_to_json("micro_native", 2, &[1, 2], &[KernelTier::Scalar], &rows);
        let s = j.to_string();
        assert!(s.contains("\"threads\":[1,2]") || s.contains("\"threads\": [1,2]"), "{s}");
        assert!(s.contains("\"thread_scaling\""));
        assert!(s.contains("\"tiers\":[\"scalar\"]") || s.contains("\"tiers\": [\"scalar\"]"), "{s}");
    }

    #[test]
    fn tier_sweep_and_floor_gate() {
        let model = NativeModel::micro();
        let bench = Bench::new(0, 1);
        let tiers = [KernelTier::Scalar, KernelTier::Simd];
        let rows = run_sweep(&model, &[100], &[1], &tiers, &bench).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].tier, KernelTier::Scalar);
        assert_eq!(rows[1].tier, KernelTier::Simd);
        // a floor of 0 always passes (both tiers measured); the gate line
        // reports the measured ratio
        let line = gate_simd_floor(&rows, 0.0).unwrap();
        assert!(line.contains("1 cell(s)"), "{line}");
        // an unmeetable floor fails with the same numbers
        assert!(gate_simd_floor(&rows, 1e9).is_err());
        // scalar-only rows can't be gated
        let scalar_rows = run_sweep(&model, &[100], &[1], &[KernelTier::Scalar], &bench).unwrap();
        assert!(gate_simd_floor(&scalar_rows, 1.0).is_err());
    }

    #[test]
    fn per_layer_gflops_covers_every_layer() {
        let model = NativeModel::micro();
        let bench = Bench::new(0, 1);
        let rows = per_layer_gflops(&model, &bench);
        assert_eq!(rows.len(), model.layers.len());
        assert!(rows.iter().all(|(_, g)| *g > 0.0 && g.is_finite()));
        // conv layers are labeled conv<i>, dense fc<i>, with shapes
        assert!(rows[0].0.starts_with("conv0 ("), "{}", rows[0].0);
        assert!(rows[1].0.starts_with("fc1 ("), "{}", rows[1].0);
    }
}
