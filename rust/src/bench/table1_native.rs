//! Table 1 on the native CPU backend — no artifacts, no `pjrt` feature.
//!
//! Times the skeleton-sliced backward pass and the whole train step at
//! each ratio bucket against the full update (r = 100%), plus the
//! compute-bound prediction from the sliced-GEMM FLOP ratio. This is the
//! default-build path that records the repo's central performance claim:
//! results are written to `BENCH_table1_native.json` so the perf
//! trajectory is tracked per commit (CI runs it in smoke mode).
//!
//! Knobs (env):
//! * `FEDSKEL_BENCH_SMOKE=1` — tiny model, 1 sample, no warmup (CI).
//! * `FEDSKEL_BENCH_SAMPLES=n` — timing samples per measurement.
//! * `FEDSKEL_BENCH_OUT=path` — where the JSON report goes.

use anyhow::Result;

use crate::benchkit::Bench;
use crate::metrics::Table;
use crate::model::init_params;
use crate::runtime::native::{prefix_skeleton, NativeBackend, NativeModel};
use crate::util::json::Json;
use crate::util::Rng;

/// One measured ratio row.
#[derive(Debug, Clone)]
pub struct NativeRow {
    pub ratio: usize,
    /// Median skeleton-sliced backward time.
    pub bwd_ms: f64,
    pub bwd_speedup: f64,
    /// Median full train-step time (forward + loss + backward + update).
    pub step_ms: f64,
    pub overall_speedup: f64,
    /// FLOP-ratio prediction for the backward speedup.
    pub bwd_speedup_computebound: f64,
}

/// Measure backward-pass and train-step time per ratio bucket. Every
/// ratio must be a train bucket of the model; r=100 is always measured as
/// the baseline.
pub fn run_rows(model: &NativeModel, ratios: &[usize], bench: &Bench) -> Result<Vec<NativeRow>> {
    let spec = model.spec.clone();
    let batch = spec.train_batch;
    let numel: usize = spec.input_shape.iter().product();
    let mut rng = Rng::new(0xB41C);
    let x: Vec<f32> = (0..batch * numel).map(|_| rng.normal() * 0.5).collect();
    let y: Vec<i32> = (0..batch).map(|i| (i % spec.num_classes) as i32).collect();
    let params = init_params(&spec, 7);
    let mut backend = NativeBackend::new(model.clone());

    let mut measure = |r: usize| -> Result<(f64, f64, f64)> {
        let ks = spec.train_artifact(r)?.k.clone();
        let skel = prefix_skeleton(&ks);
        let trace = model.forward(&params, &x, batch)?;
        let (_loss, dlog) = model.loss_grad(&trace, &y)?;
        let bwd = bench
            .run(&format!("native bwd {} r{r}", spec.name), || {
                model.backward(&x, &params, &trace, &dlog, &skel).expect("backward");
            })
            .median_s;
        let step = bench
            .run(&format!("native train_step {} r{r}", spec.name), || {
                backend
                    .train_step(r, &params, &params, &x, &y, &skel, 0.05, 0.0)
                    .expect("train step");
            })
            .median_s;
        Ok((bwd, step, model.backward_gemm_flops(batch, &skel)))
    };

    let (base_bwd, base_step, base_flops) = measure(100)?;
    let mut rows = Vec::new();
    for &r in ratios {
        let (bwd, step, flops) =
            if r == 100 { (base_bwd, base_step, base_flops) } else { measure(r)? };
        rows.push(NativeRow {
            ratio: r,
            bwd_ms: bwd * 1e3,
            bwd_speedup: base_bwd / bwd,
            step_ms: step * 1e3,
            overall_speedup: base_step / step,
            bwd_speedup_computebound: base_flops / flops,
        });
    }
    Ok(rows)
}

/// Render the paper-shaped table.
pub fn render(model: &str, rows: &[NativeRow]) -> String {
    let mut t = Table::new(&[
        "r",
        "Back-prop (ms)",
        "Back-prop speedup",
        "Train step (ms)",
        "Overall speedup",
        "Back-prop (compute-bound est.)",
    ]);
    for row in rows {
        t.row(vec![
            format!("{}%", row.ratio),
            format!("{:.3}", row.bwd_ms),
            format!("{:.2}x", row.bwd_speedup),
            format!("{:.3}", row.step_ms),
            format!("{:.2}x", row.overall_speedup),
            format!("{:.2}x", row.bwd_speedup_computebound),
        ]);
    }
    format!(
        "Table 1 (native CPU backend, {model}) — speedups vs full update (r=100%)\n{}",
        t.render()
    )
}

/// JSON report (the `BENCH_table1_native.json` schema).
pub fn rows_to_json(model: &str, batch: usize, rows: &[NativeRow]) -> Json {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("ratio", Json::num(r.ratio as f64)),
                ("bwd_ms", Json::num(r.bwd_ms)),
                ("bwd_speedup", Json::num(r.bwd_speedup)),
                ("step_ms", Json::num(r.step_ms)),
                ("overall_speedup", Json::num(r.overall_speedup)),
                ("bwd_speedup_computebound", Json::num(r.bwd_speedup_computebound)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str("table1_native")),
        ("model", Json::str(model)),
        ("batch", Json::num(batch as f64)),
        ("unit", Json::str("ms")),
        ("rows", Json::Arr(rows_json)),
    ])
}

pub fn write_json(path: &str, model: &str, batch: usize, rows: &[NativeRow]) -> Result<()> {
    std::fs::write(path, rows_to_json(model, batch, rows).to_string_pretty())?;
    Ok(())
}

/// Measure, render, and write the JSON report with explicit settings —
/// the CLI (`fedskel speedup`) resolves its own flags and calls this, so
/// flags are never silently overridden by environment variables.
pub fn run_with(model: &NativeModel, ratios: &[usize], samples: usize, out: &str) -> Result<String> {
    let samples = samples.max(1);
    let bench = Bench::new(if samples <= 1 { 0 } else { 2 }, samples);
    let rows = run_rows(model, ratios, &bench)?;
    write_json(out, &model.spec.name, model.spec.train_batch, &rows)?;
    Ok(format!("{}\nwrote {out}", render(&model.spec.name, &rows)))
}

/// Env-configured run used by `benches/hotpath.rs` and
/// `benches/table1_speedup.rs`: times the LeNet spec (or the tiny one in
/// smoke mode), writes the JSON report, returns the rendered table.
pub fn run_env(default_out: &str) -> Result<String> {
    let smoke = std::env::var("FEDSKEL_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let samples: usize = std::env::var("FEDSKEL_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 1 } else { 10 });
    let (model, ratios): (NativeModel, Vec<usize>) = if smoke {
        (NativeModel::tiny(), vec![100, 50, 25])
    } else {
        (NativeModel::lenet(), vec![100, 50, 40, 25, 10])
    };
    let out = std::env::var("FEDSKEL_BENCH_OUT").unwrap_or_else(|_| default_out.to_string());
    run_with(&model, &ratios, samples, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_rows_and_report() {
        let model = NativeModel::micro();
        let bench = Bench::new(0, 1);
        let rows = run_rows(&model, &[100, 50], &bench).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].ratio, 100);
        assert!((rows[0].bwd_speedup - 1.0).abs() < 1e-9);
        assert!((rows[0].overall_speedup - 1.0).abs() < 1e-9);
        assert!(rows.iter().all(|r| r.bwd_ms > 0.0 && r.step_ms > 0.0));
        // r50 strictly cheaper in the compute-bound model
        assert!(rows[1].bwd_speedup_computebound > 1.0);
        let s = render("micro_native", &rows);
        assert!(s.contains("100%") && s.contains("50%"));
        let j = rows_to_json("micro_native", 2, &rows);
        assert!(j.to_string().contains("\"bench\":\"table1_native\""));
        // unknown bucket is an error
        assert!(run_rows(&model, &[100, 33], &bench).is_err());
    }
}
