//! Table 1 on the native CPU backend — no artifacts, no `pjrt` feature.
//!
//! Times the skeleton-sliced backward pass and the whole train step at
//! each ratio bucket against the full update (r = 100%), plus the
//! compute-bound prediction from the sliced-GEMM FLOP ratio — and sweeps
//! the measurement over a list of kernel-thread budgets, so the report
//! records *scaling* (how the parallel execution layer speeds a fixed
//! ratio up) next to *slicing* (how a smaller ratio speeds a fixed budget
//! up). This is the default-build path that records the repo's central
//! performance claim: results are written to `BENCH_table1_native.json`
//! (now with a per-thread-count dimension) so the perf trajectory is
//! tracked per commit (CI runs it in smoke mode at 1 and 2 threads).
//!
//! Speedups are computed *within* a thread count (baseline = r100 at the
//! same budget); `thread_scaling` compares a row's step time against the
//! 1-thread run at the same ratio when the sweep includes one.
//!
//! Knobs (env):
//! * `FEDSKEL_BENCH_SMOKE=1` — tiny model, 1 sample, no warmup (CI).
//! * `FEDSKEL_BENCH_SAMPLES=n` — timing samples per measurement.
//! * `FEDSKEL_BENCH_THREADS=a,b,c` — thread counts to sweep.
//! * `FEDSKEL_BENCH_OUT=path` — where the JSON report goes.

use anyhow::Result;

use crate::benchkit::Bench;
use crate::kernels::Parallelism;
use crate::metrics::Table;
use crate::model::init_params;
use crate::runtime::native::{prefix_skeleton, NativeBackend, NativeModel};
use crate::util::json::Json;
use crate::util::Rng;

/// One measured (ratio, thread-count) row.
#[derive(Debug, Clone)]
pub struct NativeRow {
    pub ratio: usize,
    /// Kernel-thread budget this row was measured under.
    pub threads: usize,
    /// Median skeleton-sliced backward time.
    pub bwd_ms: f64,
    pub bwd_speedup: f64,
    /// Median full train-step time (forward + loss + backward + update).
    pub step_ms: f64,
    pub overall_speedup: f64,
    /// FLOP-ratio prediction for the backward speedup.
    pub bwd_speedup_computebound: f64,
    /// Step-time scaling vs the 1-thread run at the same ratio (1.0 when
    /// the sweep has no 1-thread run to compare against).
    pub thread_scaling: f64,
}

/// Measure backward-pass and train-step time per ratio bucket, under the
/// model's configured [`Parallelism`]. Every ratio must be a train bucket
/// of the model; r=100 is always measured as the baseline.
pub fn run_rows(model: &NativeModel, ratios: &[usize], bench: &Bench) -> Result<Vec<NativeRow>> {
    let spec = model.spec.clone();
    let threads = model.parallelism().threads();
    let batch = spec.train_batch;
    let numel: usize = spec.input_shape.iter().product();
    let mut rng = Rng::new(0xB41C);
    let x: Vec<f32> = (0..batch * numel).map(|_| rng.normal() * 0.5).collect();
    let y: Vec<i32> = (0..batch).map(|i| (i % spec.num_classes) as i32).collect();
    let params = init_params(&spec, 7);
    let mut backend = NativeBackend::new(model.clone());

    let mut measure = |r: usize| -> Result<(f64, f64, f64)> {
        let ks = spec.train_artifact(r)?.k.clone();
        let skel = prefix_skeleton(&ks);
        let trace = model.forward(&params, &x, batch)?;
        let (_loss, dlog) = model.loss_grad(&trace, &y)?;
        let bwd = bench
            .run(&format!("native bwd {} r{r} t{threads}", spec.name), || {
                model.backward(&x, &params, &trace, &dlog, &skel).expect("backward");
            })
            .median_s;
        let step = bench
            .run(&format!("native train_step {} r{r} t{threads}", spec.name), || {
                backend
                    .train_step(r, &params, &params, &x, &y, &skel, 0.05, 0.0)
                    .expect("train step");
            })
            .median_s;
        Ok((bwd, step, model.backward_gemm_flops(batch, &skel)))
    };

    let (base_bwd, base_step, base_flops) = measure(100)?;
    let mut rows = Vec::new();
    for &r in ratios {
        let (bwd, step, flops) =
            if r == 100 { (base_bwd, base_step, base_flops) } else { measure(r)? };
        rows.push(NativeRow {
            ratio: r,
            threads,
            bwd_ms: bwd * 1e3,
            bwd_speedup: base_bwd / bwd,
            step_ms: step * 1e3,
            overall_speedup: base_step / step,
            bwd_speedup_computebound: base_flops / flops,
            thread_scaling: 1.0,
        });
    }
    Ok(rows)
}

/// Run the per-ratio measurement at every thread budget in `threads` and
/// fill each row's `thread_scaling` against the sweep's 1-thread run (if
/// present). Rows are ordered sweep-major: all ratios at `threads[0]`,
/// then all at `threads[1]`, …
pub fn run_sweep(
    model: &NativeModel,
    ratios: &[usize],
    threads: &[usize],
    bench: &Bench,
) -> Result<Vec<NativeRow>> {
    let mut all = Vec::new();
    for &t in threads {
        let m = model.clone().with_parallelism(Parallelism::new(t));
        all.extend(run_rows(&m, ratios, bench)?);
    }
    let serial: Vec<(usize, f64)> =
        all.iter().filter(|r| r.threads == 1).map(|r| (r.ratio, r.step_ms)).collect();
    for row in &mut all {
        if let Some(&(_, base_ms)) = serial.iter().find(|(ratio, _)| *ratio == row.ratio) {
            row.thread_scaling = base_ms / row.step_ms;
        }
    }
    Ok(all)
}

/// Render the paper-shaped table (one block per thread count).
pub fn render(model: &str, rows: &[NativeRow]) -> String {
    let mut t = Table::new(&[
        "threads",
        "r",
        "Back-prop (ms)",
        "Back-prop speedup",
        "Train step (ms)",
        "Overall speedup",
        "Back-prop (compute-bound est.)",
        "Thread scaling",
    ]);
    for row in rows {
        t.row(vec![
            format!("{}", row.threads),
            format!("{}%", row.ratio),
            format!("{:.3}", row.bwd_ms),
            format!("{:.2}x", row.bwd_speedup),
            format!("{:.3}", row.step_ms),
            format!("{:.2}x", row.overall_speedup),
            format!("{:.2}x", row.bwd_speedup_computebound),
            format!("{:.2}x", row.thread_scaling),
        ]);
    }
    format!(
        "Table 1 (native CPU backend, {model}) — speedups vs full update (r=100%) \
         per kernel-thread budget\n{}",
        t.render()
    )
}

/// JSON report (the `BENCH_table1_native.json` schema). `threads` is the
/// swept budget list; every row carries its own `threads` value.
pub fn rows_to_json(model: &str, batch: usize, threads: &[usize], rows: &[NativeRow]) -> Json {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("ratio", Json::num(r.ratio as f64)),
                ("threads", Json::num(r.threads as f64)),
                ("bwd_ms", Json::num(r.bwd_ms)),
                ("bwd_speedup", Json::num(r.bwd_speedup)),
                ("step_ms", Json::num(r.step_ms)),
                ("overall_speedup", Json::num(r.overall_speedup)),
                ("bwd_speedup_computebound", Json::num(r.bwd_speedup_computebound)),
                ("thread_scaling", Json::num(r.thread_scaling)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str("table1_native")),
        ("model", Json::str(model)),
        ("batch", Json::num(batch as f64)),
        ("threads", Json::Arr(threads.iter().map(|&t| Json::num(t as f64)).collect())),
        ("unit", Json::str("ms")),
        ("rows", Json::Arr(rows_json)),
    ])
}

pub fn write_json(
    path: &str,
    model: &str,
    batch: usize,
    threads: &[usize],
    rows: &[NativeRow],
) -> Result<()> {
    std::fs::write(path, rows_to_json(model, batch, threads, rows).to_string_pretty())?;
    Ok(())
}

/// Measure, render, and write the JSON report with explicit settings —
/// the CLI (`fedskel speedup`) resolves its own flags and calls this, so
/// flags are never silently overridden by environment variables.
pub fn run_with(
    model: &NativeModel,
    ratios: &[usize],
    threads: &[usize],
    samples: usize,
    out: &str,
) -> Result<String> {
    let samples = samples.max(1);
    // sanitize the sweep so the JSON's top-level `threads` always matches
    // what the rows actually measured: drop zeros (Parallelism would
    // clamp them to 1) and duplicates, default to a serial-only sweep
    let mut sane: Vec<usize> = Vec::with_capacity(threads.len());
    for &t in threads {
        if t > 0 && !sane.contains(&t) {
            sane.push(t);
        }
    }
    if sane.is_empty() {
        sane.push(1);
    }
    let threads = sane;
    let bench = Bench::new(if samples <= 1 { 0 } else { 2 }, samples);
    let rows = run_sweep(model, ratios, &threads, &bench)?;
    write_json(out, &model.spec.name, model.spec.train_batch, &threads, &rows)?;
    Ok(format!("{}\nwrote {out}", render(&model.spec.name, &rows)))
}

/// Env-configured run used by `benches/hotpath.rs` and
/// `benches/table1_speedup.rs`: times the LeNet spec (or the tiny one in
/// smoke mode), sweeps `FEDSKEL_BENCH_THREADS` (default `1,2` in smoke,
/// `1,2,4` otherwise), writes the JSON report, returns the rendered table.
pub fn run_env(default_out: &str) -> Result<String> {
    let smoke = std::env::var("FEDSKEL_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let samples: usize = std::env::var("FEDSKEL_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 1 } else { 10 });
    let threads: Vec<usize> = std::env::var("FEDSKEL_BENCH_THREADS")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|v| v.trim().parse().ok())
                .filter(|&t| t > 0)
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| if smoke { vec![1, 2] } else { vec![1, 2, 4] });
    let (model, ratios): (NativeModel, Vec<usize>) = if smoke {
        (NativeModel::tiny(), vec![100, 50, 25])
    } else {
        (NativeModel::lenet(), vec![100, 50, 40, 25, 10])
    };
    let out = std::env::var("FEDSKEL_BENCH_OUT").unwrap_or_else(|_| default_out.to_string());
    run_with(&model, &ratios, &threads, samples, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_rows_and_report() {
        let model = NativeModel::micro();
        let bench = Bench::new(0, 1);
        let rows = run_rows(&model, &[100, 50], &bench).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].ratio, 100);
        assert_eq!(rows[0].threads, 1);
        assert!((rows[0].bwd_speedup - 1.0).abs() < 1e-9);
        assert!((rows[0].overall_speedup - 1.0).abs() < 1e-9);
        assert!(rows.iter().all(|r| r.bwd_ms > 0.0 && r.step_ms > 0.0));
        // r50 strictly cheaper in the compute-bound model
        assert!(rows[1].bwd_speedup_computebound > 1.0);
        let s = render("micro_native", &rows);
        assert!(s.contains("100%") && s.contains("50%"));
        let j = rows_to_json("micro_native", 2, &[1], &rows);
        assert!(j.to_string().contains("\"bench\":\"table1_native\""));
        // unknown bucket is an error
        assert!(run_rows(&model, &[100, 33], &bench).is_err());
    }

    #[test]
    fn thread_sweep_adds_dimension_and_scaling() {
        let model = NativeModel::micro();
        let bench = Bench::new(0, 1);
        let rows = run_sweep(&model, &[100, 50], &[1, 2], &bench).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows.iter().filter(|r| r.threads == 1).count(), 2);
        assert_eq!(rows.iter().filter(|r| r.threads == 2).count(), 2);
        // 1-thread rows scale 1.0 against themselves; every row got a
        // finite positive scaling (a 1-thread baseline exists)
        assert!(rows
            .iter()
            .filter(|r| r.threads == 1)
            .all(|r| (r.thread_scaling - 1.0).abs() < 1e-12));
        assert!(rows.iter().all(|r| r.thread_scaling > 0.0));
        let j = rows_to_json("micro_native", 2, &[1, 2], &rows);
        let s = j.to_string();
        assert!(s.contains("\"threads\":[1,2]") || s.contains("\"threads\": [1,2]"), "{s}");
        assert!(s.contains("\"thread_scaling\""));
    }
}
