//! Span-profiler overhead bench — [`crate::prof`] enabled vs disabled on
//! a real run, written to `BENCH_prof_overhead.json`.
//!
//! Runs the same federated training job (native CIFAR-scale model,
//! pinned per-bucket batch seconds) twice per trial: once with the
//! profiler disabled (every `prof::scope` is one relaxed atomic load)
//! and once recording every span. The bench takes the minimum wall time
//! over its trials (the standard noise filter for wall-clock gates) and
//! **fails** if the profiled arm exceeds the budget of [`budget`]: 5%
//! over the disabled arm plus a 20 ms absolute slack for sub-second
//! smoke runs. It also asserts:
//!
//! * the two arms trained bit-identical models — the profiler only
//!   reads clocks, it must observe a run, never steer it;
//! * kernel + phase spans account for ≥ 90% of `train_step` wall time
//!   ([`crate::prof::coverage_of`]) — the attribution the profiler
//!   exists to provide actually covers the hot path.
//!
//! Knobs (env):
//! * `FEDSKEL_BENCH_SMOKE=1` — 4 rounds on a small dataset (CI).
//! * `FEDSKEL_BENCH_ROUNDS=n` — override the round count.
//! * `FEDSKEL_BENCH_OUT=path` — where the JSON report goes.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::config::RunConfig;
use crate::coordinator::Coordinator;
use crate::metrics::Table;
use crate::model::params_digest;
use crate::prof;
use crate::runtime::native::NativeBackend;
use crate::runtime::step::Backend;
use crate::util::json::Json;
use crate::util::timer::Timer;

/// Minimum fraction of `train_step` wall time its child spans must
/// explain in the profiled arm.
pub const COVERAGE_FLOOR: f64 = 0.90;

/// Wall-time budget for the profiled arm given the disabled arm's time:
/// 5% relative overhead plus 20 ms absolute slack (so sub-second smoke
/// runs don't gate on scheduler jitter).
pub fn budget(off_s: f64) -> f64 {
    off_s * 1.05 + 0.02
}

/// CIFAR-scale backend with pinned per-bucket batch seconds (see
/// [`crate::bench::sched`]) — keeps the simulated clock deterministic so
/// both arms schedule identically.
fn backend() -> NativeBackend {
    let b = NativeBackend::cifar();
    let secs: BTreeMap<usize, f64> = b
        .spec()
        .train_buckets()
        .into_iter()
        .map(|bk| (bk, bk as f64 / 100.0 * 0.08))
        .collect();
    b.with_fixed_batch_secs(secs)
}

fn base_cfg(rounds: usize, dataset: usize) -> RunConfig {
    RunConfig {
        method: crate::config::Method::FedSkel,
        model: "cifar_native".into(),
        num_clients: 6,
        shards_per_client: 2,
        dataset_size: dataset,
        new_test_size: 64,
        rounds,
        local_steps: 2,
        eval_every: 2,
        lr: 0.08,
        seed: 42,
        ..RunConfig::default()
    }
}

/// One full run; `profiled` picks the arm. Returns (wall secs, digest,
/// train_step coverage if profiled).
fn run_case(cfg: RunConfig, profiled: bool) -> Result<(f64, u64, Option<f64>)> {
    prof::reset();
    if profiled {
        prof::enable();
    }
    let t = Timer::start();
    let mut coord = Coordinator::new(cfg, backend())?;
    coord.run()?;
    let wall = t.elapsed_secs();
    let coverage = if profiled { prof::coverage_of("train_step") } else { None };
    prof::disable();
    Ok((wall, params_digest(&coord.global), coverage))
}

/// Run both arms `trials` times, gate overhead + coverage, write `out`.
pub fn run_with(rounds: usize, dataset: usize, trials: usize, out: &str) -> Result<String> {
    let (mut off_s, mut on_s) = (f64::INFINITY, f64::INFINITY);
    let (mut off_digest, mut on_digest) = (0u64, 0u64);
    let mut coverage = 0.0f64;
    let mut span_paths = 0usize;
    for _ in 0..trials.max(1) {
        let (w, d, _) = run_case(base_cfg(rounds, dataset), false)?;
        off_s = off_s.min(w);
        off_digest = d;
        let (w, d, c) = run_case(base_cfg(rounds, dataset), true)?;
        // span_stats was reset by the next run_case call, so capture now
        span_paths = prof::span_stats().len();
        on_s = on_s.min(w);
        on_digest = d;
        coverage = c.unwrap_or(0.0);
    }
    ensure!(
        off_digest == on_digest,
        "profiling changed the trained model: off {off_digest:#018x} vs on {on_digest:#018x}"
    );
    ensure!(
        coverage >= COVERAGE_FLOOR,
        "span coverage of train_step below floor: {:.1}% < {:.0}%",
        coverage * 100.0,
        COVERAGE_FLOOR * 100.0
    );
    let allowed = budget(off_s);
    ensure!(
        on_s <= allowed,
        "profiler overhead above budget: {on_s:.3}s vs disabled {off_s:.3}s \
         (allowed {allowed:.3}s)"
    );

    let report = Json::obj(vec![
        ("bench", Json::str("prof_overhead")),
        ("model", Json::str("cifar_native")),
        ("rounds", Json::num(rounds as f64)),
        ("trials", Json::num(trials as f64)),
        ("span_paths", Json::num(span_paths as f64)),
        ("train_step_coverage", Json::num(coverage)),
        ("coverage_floor", Json::num(COVERAGE_FLOOR)),
        ("off_s", Json::num(off_s)),
        ("on_s", Json::num(on_s)),
        ("budget_s", Json::num(allowed)),
        ("overhead_ratio", Json::num(if off_s > 0.0 { on_s / off_s } else { 1.0 })),
        ("digest", Json::str(format!("{off_digest:#018x}"))),
    ]);
    std::fs::write(out, report.to_string_pretty())?;

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["span paths recorded".into(), span_paths.to_string()]);
    t.row(vec!["train_step coverage".into(), format!("{:.1}%", coverage * 100.0)]);
    t.row(vec!["profiler off (s, min)".into(), format!("{off_s:.3}")]);
    t.row(vec!["profiler on (s, min)".into(), format!("{on_s:.3}")]);
    t.row(vec!["budget (s)".into(), format!("{allowed:.3}")]);
    t.row(vec![
        "overhead".into(),
        format!("{:+.1}%", if off_s > 0.0 { (on_s / off_s - 1.0) * 100.0 } else { 0.0 }),
    ]);
    Ok(format!(
        "Span-profiler overhead (native cifar, {rounds} rounds, min of {trials} trials)\n{}\nwrote {out}",
        t.render()
    ))
}

/// Env-configured entry used by `benches/prof_overhead.rs`:
/// `FEDSKEL_BENCH_SMOKE=1` runs the small CI profile.
pub fn run_env(default_out: &str) -> Result<String> {
    let smoke = std::env::var("FEDSKEL_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let rounds: usize = std::env::var("FEDSKEL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 4 } else { 10 });
    let dataset = if smoke { 320 } else { 640 };
    let trials = if smoke { 2 } else { 3 };
    let out = std::env::var("FEDSKEL_BENCH_OUT").unwrap_or_else(|_| default_out.to_string());
    run_with(rounds, dataset, trials, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_is_five_percent_plus_slack() {
        assert!((budget(1.0) - 1.07).abs() < 1e-12);
        assert!((budget(0.0) - 0.02).abs() < 1e-12);
        // the absolute slack dominates for very fast runs
        assert!(budget(0.1) > 0.1 * 1.05);
    }
}
