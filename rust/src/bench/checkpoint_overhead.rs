//! Checkpoint-write overhead bench — `--checkpoint-every 1` vs no
//! checkpointing on a real run, written to `BENCH_checkpoint.json`.
//!
//! Runs the same federated training job (native CIFAR-scale model,
//! pinned per-bucket batch seconds) twice per trial: once plain and once
//! writing a `snap_round_N.fsnap` snapshot after **every** round — the
//! worst-case cadence. The bench takes the minimum wall time over its
//! trials (the standard noise filter for wall-clock gates) and **fails**
//! if the checkpointing arm exceeds the budget of [`budget`]: 5% over
//! the plain arm plus a 20 ms absolute slack for sub-second smoke runs.
//! It also asserts the two arms trained bit-identical models (snapshot
//! writes are a pure read of the coordinator) and that the final
//! snapshot restores to the same digest — the overhead being gated is
//! the cost of checkpoints that actually work.
//!
//! Knobs (env):
//! * `FEDSKEL_BENCH_SMOKE=1` — 4 rounds on a small dataset (CI).
//! * `FEDSKEL_BENCH_ROUNDS=n` — override the round count.
//! * `FEDSKEL_BENCH_OUT=path` — where the JSON report goes.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::config::RunConfig;
use crate::coordinator::Coordinator;
use crate::metrics::Table;
use crate::model::params_digest;
use crate::runtime::native::NativeBackend;
use crate::runtime::step::Backend;
use crate::util::json::Json;
use crate::util::timer::Timer;

/// Wall-time budget for the checkpointing arm given the plain arm's
/// time: 5% relative overhead plus 20 ms absolute slack (so sub-second
/// smoke runs don't gate on scheduler jitter).
pub fn budget(plain_s: f64) -> f64 {
    plain_s * 1.05 + 0.02
}

/// CIFAR-scale backend with pinned per-bucket batch seconds (see
/// [`crate::bench::sched`]) — keeps the simulated clock deterministic so
/// both arms schedule identically.
fn backend() -> NativeBackend {
    let b = NativeBackend::cifar();
    let secs: BTreeMap<usize, f64> = b
        .spec()
        .train_buckets()
        .into_iter()
        .map(|bk| (bk, bk as f64 / 100.0 * 0.08))
        .collect();
    b.with_fixed_batch_secs(secs)
}

fn base_cfg(rounds: usize, dataset: usize) -> RunConfig {
    RunConfig {
        method: crate::config::Method::FedSkel,
        model: "cifar_native".into(),
        num_clients: 6,
        shards_per_client: 2,
        dataset_size: dataset,
        new_test_size: 64,
        rounds,
        local_steps: 2,
        eval_every: 2,
        lr: 0.08,
        seed: 42,
        ..RunConfig::default()
    }
}

/// One full run; `ckpt_dir` picks the arm. Returns (wall secs, digest).
fn run_case(mut cfg: RunConfig, ckpt_dir: Option<&str>) -> Result<(f64, u64)> {
    if let Some(dir) = ckpt_dir {
        cfg.checkpoint_dir = Some(dir.to_string());
        cfg.checkpoint_every = 1;
    }
    let t = Timer::start();
    let mut coord = Coordinator::new(cfg, backend())?;
    coord.run()?;
    Ok((t.elapsed_secs(), params_digest(&coord.global)))
}

/// Run both arms `trials` times, gate the overhead, write `out`.
pub fn run_with(rounds: usize, dataset: usize, trials: usize, out: &str) -> Result<String> {
    let ckpt_dir =
        std::env::temp_dir().join(format!("fedskel_bench_ckpt_{}", std::process::id()));
    let dir_str = ckpt_dir.to_string_lossy().into_owned();

    let (mut plain_s, mut ckpt_s) = (f64::INFINITY, f64::INFINITY);
    let (mut plain_digest, mut ckpt_digest) = (0u64, 0u64);
    for _ in 0..trials.max(1) {
        let (w, d) = run_case(base_cfg(rounds, dataset), None)?;
        plain_s = plain_s.min(w);
        plain_digest = d;
        let (w, d) = run_case(base_cfg(rounds, dataset), Some(&dir_str))?;
        ckpt_s = ckpt_s.min(w);
        ckpt_digest = d;
    }
    ensure!(
        plain_digest == ckpt_digest,
        "checkpointing changed the trained model: plain {plain_digest:#018x} \
         vs ckpt {ckpt_digest:#018x}"
    );

    // the snapshots must be *working* checkpoints, not just fast ones:
    // the final one restores to the arm's own digest
    let last = ckpt_dir.join(format!("snap_round_{rounds}.fsnap"));
    let snapshot_bytes = std::fs::metadata(&last).map(|m| m.len()).unwrap_or(0);
    let resumed = Coordinator::restore(base_cfg(rounds, dataset), backend(), &last)?;
    let resumed_digest = params_digest(&resumed.global);
    ensure!(
        resumed_digest == ckpt_digest,
        "final snapshot restored to a different model: {resumed_digest:#018x} \
         vs {ckpt_digest:#018x}"
    );
    std::fs::remove_dir_all(&ckpt_dir).ok();

    let allowed = budget(plain_s);
    ensure!(
        ckpt_s <= allowed,
        "checkpoint-write overhead above budget: {ckpt_s:.3}s vs plain {plain_s:.3}s \
         (allowed {allowed:.3}s)"
    );

    let report = Json::obj(vec![
        ("bench", Json::str("checkpoint_overhead")),
        ("model", Json::str("cifar_native")),
        ("rounds", Json::num(rounds as f64)),
        ("trials", Json::num(trials as f64)),
        ("snapshots_per_run", Json::num(rounds as f64)),
        ("snapshot_bytes", Json::num(snapshot_bytes as f64)),
        ("plain_s", Json::num(plain_s)),
        ("ckpt_s", Json::num(ckpt_s)),
        ("budget_s", Json::num(allowed)),
        ("overhead_ratio", Json::num(if plain_s > 0.0 { ckpt_s / plain_s } else { 1.0 })),
        ("digest", Json::str(format!("{plain_digest:#018x}"))),
    ]);
    std::fs::write(out, report.to_string_pretty())?;

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["snapshots per run".into(), rounds.to_string()]);
    t.row(vec!["snapshot size (bytes)".into(), snapshot_bytes.to_string()]);
    t.row(vec!["plain (s, min)".into(), format!("{plain_s:.3}")]);
    t.row(vec!["checkpoint-every-1 (s, min)".into(), format!("{ckpt_s:.3}")]);
    t.row(vec!["budget (s)".into(), format!("{allowed:.3}")]);
    t.row(vec![
        "overhead".into(),
        format!("{:+.1}%", if plain_s > 0.0 { (ckpt_s / plain_s - 1.0) * 100.0 } else { 0.0 }),
    ]);
    Ok(format!(
        "Checkpoint-write overhead (native cifar, {rounds} rounds, min of {trials} trials)\n{}\nwrote {out}",
        t.render()
    ))
}

/// Env-configured entry used by `benches/checkpoint_overhead.rs`:
/// `FEDSKEL_BENCH_SMOKE=1` runs the small CI profile.
pub fn run_env(default_out: &str) -> Result<String> {
    let smoke = std::env::var("FEDSKEL_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let rounds: usize = std::env::var("FEDSKEL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 4 } else { 10 });
    let dataset = if smoke { 320 } else { 640 };
    let trials = if smoke { 2 } else { 3 };
    let out = std::env::var("FEDSKEL_BENCH_OUT").unwrap_or_else(|_| default_out.to_string());
    run_with(rounds, dataset, trials, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_is_five_percent_plus_slack() {
        assert!((budget(1.0) - 1.07).abs() < 1e-12);
        assert!((budget(0.0) - 0.02).abs() < 1e-12);
        // the absolute slack dominates for very fast runs
        assert!(budget(0.1) > 0.1 * 1.05);
    }
}
