//! Communication-vs-accuracy Pareto bench — method × compressor × ratio
//! sweep, written to `BENCH_comm_pareto.json`.
//!
//! Runs full federated training on the native backend (the `lenet` spec
//! — the paper's Table-2 workload, where skeleton savings are real
//! because the prunable layers dominate the parameter count) over the
//! simulated network, once per (method ∈ {fedavg, fedskel}) ×
//! (compressor ∈ {identity, f16, int8, topk@r}) × (error-feedback
//! on/off), and reports the full frontier the compression pipeline is
//! for:
//!
//! * **wire bytes** — measured frame bytes, both directions, vs the
//!   **raw** dense-f32 cost of the same exchanges (the achieved ratio);
//! * **final accuracy** — New-Test accuracy on the 512-sample IID split
//!   (one sample = ~0.2 pp of resolution);
//! * **time-to-accuracy** — virtual simnet seconds until 95% of the
//!   same method's uncompressed final accuracy.
//!
//! FedSkel cells run at a fixed skeleton ratio of 25% (every client in
//! the r=25 bucket), the regime the paper's 64.8% reduction claim lives
//! in; compressed cells also enable `--delta-down` so SetSkel downloads
//! delta-encode against each client's anchor. Per-bucket batch seconds
//! are pinned ([`NativeBackend::with_fixed_batch_secs`]) so every
//! number is a pure function of the config.
//!
//! Two assertions gate CI (a failed assertion fails the bench):
//!
//! 1. int8 + error-feedback FedSkel moves **≤ 40% of the wire bytes**
//!    of f32 FedAvg (≥ 60% reduction — the paper's Table-2 territory,
//!    now in measured bytes);
//! 2. its final accuracy lands **within 0.5 pp** of uncompressed f32
//!    FedSkel — the error-feedback claim.
//!
//! Knobs (env):
//! * `FEDSKEL_BENCH_SMOKE=1` — 8 rounds on a small dataset (CI).
//! * `FEDSKEL_BENCH_ROUNDS=n` — override the round count.
//! * `FEDSKEL_BENCH_OUT=path` — where the JSON report goes.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::compress::CompressKind;
use crate::config::{Method, RatioAssignment, RunConfig};
use crate::coordinator::Coordinator;
use crate::metrics::Table;
use crate::model::params_digest;
use crate::runtime::native::NativeBackend;
use crate::util::json::Json;

const CLIENTS: usize = 6;
/// Every FedSkel client trains in the r=25 bucket.
const SKEL_RATIO: f64 = 0.25;

/// One point of the sweep.
#[derive(Debug, Clone, Copy)]
struct Cell {
    method: Method,
    compress: CompressKind,
    topk_ratio: f64,
    error_feedback: bool,
    delta_down: bool,
}

fn cells() -> Vec<Cell> {
    let c = |method, compress, topk_ratio, error_feedback, delta_down| Cell {
        method,
        compress,
        topk_ratio,
        error_feedback,
        delta_down,
    };
    vec![
        // the two uncompressed references
        c(Method::FedAvg, CompressKind::Identity, 0.1, false, false),
        c(Method::FedSkel, CompressKind::Identity, 0.1, false, false),
        // quantized updates with error feedback
        c(Method::FedAvg, CompressKind::Int8, 0.1, true, true),
        c(Method::FedSkel, CompressKind::F16, 0.1, true, true),
        c(Method::FedSkel, CompressKind::Int8, 0.1, true, true),
        // the error-feedback ablation: same codec, residuals discarded
        c(Method::FedSkel, CompressKind::Int8, 0.1, false, true),
        // top-k sparsified updates at two keep ratios
        c(Method::FedSkel, CompressKind::TopK, 0.25, true, true),
        c(Method::FedSkel, CompressKind::TopK, 0.05, true, true),
    ]
}

/// One measured row of `BENCH_comm_pareto.json`.
#[derive(Debug, Clone)]
pub struct ParetoRow {
    pub method: Method,
    pub compress: CompressKind,
    pub topk_ratio: Option<f64>,
    pub error_feedback: bool,
    pub delta_down: bool,
    pub wire_bytes: u64,
    /// Dense-f32 frame cost of the same exchanges.
    pub raw_bytes: u64,
    /// raw ÷ wire (1.0 = uncompressed).
    pub achieved_ratio: f64,
    /// Percent fewer wire bytes than the f32 FedAvg baseline row.
    pub wire_reduction_pct: f64,
    pub final_new_acc: f64,
    /// Accuracy − the same method's uncompressed (identity) accuracy,
    /// in percentage points.
    pub acc_delta_vs_f32_pp: f64,
    /// Virtual seconds to reach `target_acc` (None = never).
    pub time_to_acc_s: Option<f64>,
    /// 95% of the same method's uncompressed final accuracy.
    pub target_acc: f64,
    pub makespan_s: f64,
    /// FNV fingerprint of the trained global model.
    pub digest: u64,
}

struct CaseOut {
    wire_bytes: u64,
    raw_bytes: u64,
    achieved_ratio: f64,
    final_new_acc: f64,
    /// (cumulative virtual secs, new-test accuracy) per eval round.
    acc_curve: Vec<(f64, f64)>,
    makespan_s: f64,
    digest: u64,
}

/// Pinned per-bucket batch seconds for the lenet spec: linear in the
/// ratio, 80 ms at r=100 — the compute-bound shape Table 1 measures.
fn fixed_secs() -> BTreeMap<usize, f64> {
    [10usize, 25, 40, 50, 100].into_iter().map(|b| (b, b as f64 / 100.0 * 0.08)).collect()
}

fn cell_cfg(cell: &Cell, rounds: usize, dataset: usize) -> RunConfig {
    RunConfig {
        method: cell.method,
        model: "lenet_native".into(),
        num_clients: CLIENTS,
        shards_per_client: 2,
        dataset_size: dataset,
        new_test_size: 512,
        rounds,
        local_steps: 2,
        updateskel_per_setskel: 3,
        eval_every: 2,
        lr: 0.08,
        seed: 42,
        ratio_assignment: RatioAssignment::Fixed(SKEL_RATIO),
        compress: cell.compress,
        topk_ratio: cell.topk_ratio,
        error_feedback: cell.error_feedback,
        delta_down: cell.delta_down,
        ..RunConfig::default()
    }
}

fn run_case(cfg: RunConfig) -> Result<CaseOut> {
    let backend = NativeBackend::lenet().with_fixed_batch_secs(fixed_secs());
    let mut coord = Coordinator::new(cfg, backend)?;
    coord.run()?;
    let mut cum = 0.0f64;
    let mut acc_curve = Vec::new();
    for rl in &coord.log.rounds {
        cum += rl.sim_round_secs;
        if let Some(a) = rl.new_acc {
            acc_curve.push((cum, a));
        }
    }
    Ok(CaseOut {
        wire_bytes: coord.ledger.total_wire_bytes(),
        raw_bytes: coord.ledger.total_raw_bytes(),
        achieved_ratio: coord.ledger.compression_ratio(),
        final_new_acc: coord.log.last_new_acc().unwrap_or(0.0),
        acc_curve,
        makespan_s: cum,
        digest: params_digest(&coord.global),
    })
}

fn time_to_acc(curve: &[(f64, f64)], target: f64) -> Option<f64> {
    curve.iter().find(|&&(_, a)| a >= target).map(|&(t, _)| t)
}

/// Run the full sweep, write `out`, and enforce the two CI gates.
/// Returns the rendered table.
pub fn run_with(rounds: usize, dataset: usize, out: &str) -> Result<String> {
    let cs = cells();
    let outs: Vec<CaseOut> =
        cs.iter().map(|c| run_case(cell_cfg(c, rounds, dataset))).collect::<Result<_>>()?;

    // per-method uncompressed references
    let ref_idx = |m: Method| -> usize {
        cs.iter()
            .position(|c| c.method == m && c.compress == CompressKind::Identity)
            .expect("every method has an identity cell")
    };
    let baseline_wire = outs[ref_idx(Method::FedAvg)].wire_bytes;

    let mut rows = Vec::with_capacity(cs.len());
    for (c, o) in cs.iter().zip(&outs) {
        let refc = &outs[ref_idx(c.method)];
        let target = 0.95 * refc.final_new_acc;
        rows.push(ParetoRow {
            method: c.method,
            compress: c.compress,
            topk_ratio: (c.compress == CompressKind::TopK).then_some(c.topk_ratio),
            error_feedback: c.error_feedback,
            delta_down: c.delta_down,
            wire_bytes: o.wire_bytes,
            raw_bytes: o.raw_bytes,
            achieved_ratio: o.achieved_ratio,
            wire_reduction_pct: 100.0 * (1.0 - o.wire_bytes as f64 / baseline_wire as f64),
            final_new_acc: o.final_new_acc,
            acc_delta_vs_f32_pp: 100.0 * (o.final_new_acc - refc.final_new_acc),
            time_to_acc_s: time_to_acc(&o.acc_curve, target),
            target_acc: target,
            makespan_s: o.makespan_s,
            digest: o.digest,
        });
    }

    // the report is written (and the table rendered) *before* the gates
    // run, so a failed gate in CI still leaves the JSON artifact and
    // attaches the full table to the error for diagnosis
    std::fs::write(out, rows_to_json(rounds, &rows).to_string_pretty())?;
    let report = format!("{}\nwrote {out}", render(&rows));
    if let Err(e) = check_gates(&rows, baseline_wire) {
        return Err(e.context(report));
    }
    Ok(report)
}

/// The two CI acceptance gates plus the identity-accounting invariant.
fn check_gates(rows: &[ParetoRow], baseline_wire: u64) -> Result<()> {
    let int8_ef = rows
        .iter()
        .find(|r| {
            r.method == Method::FedSkel && r.compress == CompressKind::Int8 && r.error_feedback
        })
        .expect("int8+ef fedskel cell");
    ensure!(
        (int8_ef.wire_bytes as f64) <= 0.40 * baseline_wire as f64,
        "int8+ef fedskel must cut ≥60% of f32 fedavg wire bytes: {} vs baseline {}",
        int8_ef.wire_bytes,
        baseline_wire
    );
    ensure!(
        int8_ef.acc_delta_vs_f32_pp.abs() <= 0.5,
        "int8+ef fedskel accuracy drifted {:.3} pp from f32 fedskel (> 0.5 pp)",
        int8_ef.acc_delta_vs_f32_pp
    );
    // uncompressed f32 rows must report exactly no compression — the
    // raw counter charges the same frames the encoder emitted
    for r in rows.iter().filter(|r| r.compress == CompressKind::Identity) {
        ensure!(
            r.wire_bytes == r.raw_bytes,
            "identity row wire {} != raw {}",
            r.wire_bytes,
            r.raw_bytes
        );
    }
    Ok(())
}

fn row_label(r: &ParetoRow) -> String {
    let mut s = r.compress.name().to_string();
    if let Some(k) = r.topk_ratio {
        s.push_str(&format!("@{k}"));
    }
    if r.error_feedback {
        s.push_str("+ef");
    }
    s
}

/// Render the Pareto table.
pub fn render(rows: &[ParetoRow]) -> String {
    let mut t = Table::new(&[
        "method",
        "compress",
        "wire (B)",
        "raw (B)",
        "ratio",
        "red. %",
        "final acc",
        "Δacc (pp)",
        "t-to-acc (s)",
    ]);
    for r in rows {
        t.row(vec![
            r.method.name().into(),
            row_label(r),
            format!("{}", r.wire_bytes),
            format!("{}", r.raw_bytes),
            format!("{:.2}", r.achieved_ratio),
            format!("{:.1}", r.wire_reduction_pct),
            format!("{:.3}", r.final_new_acc),
            format!("{:+.2}", r.acc_delta_vs_f32_pp),
            r.time_to_acc_s.map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    format!(
        "Comm-vs-accuracy Pareto (native lenet, {CLIENTS} clients, skeleton r={SKEL_RATIO}, \
         pinned batch secs) — wire bytes / achieved compression / accuracy per compressor\n{}",
        t.render()
    )
}

/// The `BENCH_comm_pareto.json` schema.
pub fn rows_to_json(rounds: usize, rows: &[ParetoRow]) -> Json {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("method", Json::str(r.method.name())),
                ("compress", Json::str(r.compress.name())),
                ("topk_ratio", r.topk_ratio.map(Json::num).unwrap_or(Json::Null)),
                ("error_feedback", Json::Bool(r.error_feedback)),
                ("delta_down", Json::Bool(r.delta_down)),
                ("wire_bytes", Json::num(r.wire_bytes as f64)),
                ("raw_bytes", Json::num(r.raw_bytes as f64)),
                ("achieved_ratio", Json::num(r.achieved_ratio)),
                ("wire_reduction_pct", Json::num(r.wire_reduction_pct)),
                ("final_new_acc", Json::num(r.final_new_acc)),
                ("acc_delta_vs_f32_pp", Json::num(r.acc_delta_vs_f32_pp)),
                ("time_to_acc_s", r.time_to_acc_s.map(Json::num).unwrap_or(Json::Null)),
                ("target_acc", Json::num(r.target_acc)),
                ("makespan_s", Json::num(r.makespan_s)),
                ("digest", Json::str(format!("{:#018x}", r.digest))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str("comm_pareto")),
        ("model", Json::str("lenet_native")),
        ("clients", Json::num(CLIENTS as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("skeleton_ratio", Json::num(SKEL_RATIO)),
        ("rows", Json::Arr(rows_json)),
    ])
}

/// Env-configured entry used by `benches/comm_pareto.rs`:
/// `FEDSKEL_BENCH_SMOKE=1` runs the small CI profile.
pub fn run_env(default_out: &str) -> Result<String> {
    let smoke = std::env::var("FEDSKEL_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let rounds: usize = std::env::var("FEDSKEL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 8 } else { 16 });
    let dataset = if smoke { 360 } else { 960 };
    let out = std::env::var("FEDSKEL_BENCH_OUT").unwrap_or_else(|_| default_out.to_string());
    run_with(rounds, dataset, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_both_references_and_the_gated_cell() {
        let cs = cells();
        assert!(cs
            .iter()
            .any(|c| c.method == Method::FedAvg && c.compress == CompressKind::Identity));
        assert!(cs
            .iter()
            .any(|c| c.method == Method::FedSkel && c.compress == CompressKind::Identity));
        assert!(cs
            .iter()
            .any(|c| c.method == Method::FedSkel
                && c.compress == CompressKind::Int8
                && c.error_feedback));
        // the EF ablation shares the codec with the gated cell
        assert!(cs
            .iter()
            .any(|c| c.method == Method::FedSkel
                && c.compress == CompressKind::Int8
                && !c.error_feedback));
    }

    #[test]
    fn time_to_acc_finds_first_crossing() {
        let curve = [(1.0, 0.2), (2.0, 0.5), (3.0, 0.9)];
        assert_eq!(time_to_acc(&curve, 0.5), Some(2.0));
        assert_eq!(time_to_acc(&curve, 0.95), None);
        assert_eq!(time_to_acc(&[], 0.1), None);
    }

    #[test]
    fn row_json_schema() {
        let row = ParetoRow {
            method: Method::FedSkel,
            compress: CompressKind::Int8,
            topk_ratio: None,
            error_feedback: true,
            delta_down: true,
            wire_bytes: 1000,
            raw_bytes: 4000,
            achieved_ratio: 4.0,
            wire_reduction_pct: 75.0,
            final_new_acc: 0.61,
            acc_delta_vs_f32_pp: -0.2,
            time_to_acc_s: Some(1.5),
            target_acc: 0.58,
            makespan_s: 9.0,
            digest: 0xBEEF,
        };
        let s = rows_to_json(8, &[row]).to_string();
        assert!(s.contains("\"bench\":\"comm_pareto\""), "{s}");
        assert!(s.contains("\"compress\":\"int8\""), "{s}");
        assert!(s.contains("\"error_feedback\":true"), "{s}");
        assert!(s.contains("\"topk_ratio\":null"), "{s}");
        assert!(s.contains("\"wire_reduction_pct\":75"), "{s}");
    }
}
