//! Table 1 — "Speedups on Intel CPU and ARM CPU with different skeleton
//! ratio r": conv-layer backprop speedup and overall train-step speedup
//! per ratio.
//!
//! Substitution (DESIGN.md §3): the paper measured Caffe on a Xeon and a
//! Raspberry Pi. We measure the real AOT artifacts on the host CPU
//! ("measured" columns) and additionally report the compute-bound
//! prediction from the pruned-GEMM FLOP ratio — the regime a slow
//! in-order edge core approaches (the paper's ARM numbers sit between the
//! two, closer to compute-bound for backprop).

use anyhow::{Context, Result};

use crate::benchkit::Bench;
use crate::metrics::Table;
use crate::model::spec::{ArtifactSpec, Dtype, Manifest};
use crate::runtime::{ArgBuf, PjrtRuntime};
use crate::util::Rng;

/// Result rows, exposed for tests/EXPERIMENTS tooling.
#[derive(Debug, Clone)]
pub struct SpeedupRow {
    pub ratio: usize,
    pub bwd_speedup: f64,
    pub overall_speedup: f64,
    pub bwd_speedup_computebound: f64,
}

/// Deterministic argument buffers for an artifact.
pub fn dummy_args(spec: &ArtifactSpec, seed: u64) -> Vec<ArgBuf> {
    let mut rng = Rng::new(seed);
    spec.inputs
        .iter()
        .map(|io| match io.dtype {
            Dtype::F32 => ArgBuf::F32 {
                shape: io.shape.clone(),
                data: (0..io.numel()).map(|_| rng.normal() * 0.1).collect(),
            },
            Dtype::I32 => {
                // index vectors: ascending identity prefix is always valid
                ArgBuf::I32 {
                    shape: io.shape.clone(),
                    data: (0..io.numel() as i32).collect(),
                }
            }
        })
        .collect()
}

fn time_artifact(
    rt: &mut PjrtRuntime,
    manifest: &Manifest,
    art: &ArtifactSpec,
    samples: usize,
) -> Result<f64> {
    let loaded = rt.load(manifest.artifact_path(art), art)?;
    let args = dummy_args(art, 7);
    let bench = Bench::new(2, samples);
    let stats = bench.run(&format!("exec {}", art.file), || {
        loaded.run(&args).expect("artifact execution");
    });
    Ok(stats.median_s)
}

/// FLOPs of the skeleton backward GEMMs of a convbwd probe.
fn probe_flops(art: &ArtifactSpec) -> f64 {
    // per conv GEMM (m,k,n) at skeleton size ksz:
    //   dW: 2·m·k·ksz, dA: 2·m·ksz·k  →  4·m·k·ksz
    let mut total = 0.0;
    let mut gi = 0;
    for io in &art.inputs {
        if io.name.ends_with(".a") {
            let (m, k) = (io.shape[0] as f64, io.shape[1] as f64);
            let ksz = art.k[gi] as f64;
            total += 4.0 * m * k * ksz;
            gi += 1;
        }
    }
    total
}

/// Run the Table 1 experiment; returns (rows, rendered report).
pub fn run_rows(
    manifest: &Manifest,
    ratios: &[usize],
    samples: usize,
) -> Result<Vec<SpeedupRow>> {
    let mut rt = PjrtRuntime::new()?;
    let probes = manifest
        .bench
        .get("convbwd_lenet")
        .context("manifest lacks convbwd_lenet bench probes — rebuild artifacts")?;
    let lenet = manifest.model("lenet_smnist")?;

    let base_probe = probes.get("r100").context("no r100 probe")?;
    let base_bwd = time_artifact(&mut rt, manifest, base_probe, samples)?;
    let base_flops = probe_flops(base_probe);

    let base_train = lenet.train_artifact(100)?;
    let base_overall = time_artifact(&mut rt, manifest, base_train, samples)?;

    let mut rows = Vec::new();
    for &r in ratios {
        let probe = probes
            .get(&format!("r{r}"))
            .with_context(|| format!("no convbwd probe r{r}"))?;
        let bwd = time_artifact(&mut rt, manifest, probe, samples)?;
        let train = lenet.train_artifact(r)?;
        let overall = time_artifact(&mut rt, manifest, train, samples)?;
        rows.push(SpeedupRow {
            ratio: r,
            bwd_speedup: base_bwd / bwd,
            overall_speedup: base_overall / overall,
            bwd_speedup_computebound: base_flops / probe_flops(probe),
        });
    }
    Ok(rows)
}

/// Render the paper-shaped table.
pub fn render(rows: &[SpeedupRow]) -> String {
    let mut t = Table::new(&[
        "r",
        "Back-prop (measured)",
        "Overall (measured)",
        "Back-prop (compute-bound est.)",
    ]);
    for row in rows {
        t.row(vec![
            format!("{}%", row.ratio),
            format!("{:.2}x", row.bwd_speedup),
            format!("{:.2}x", row.overall_speedup),
            format!("{:.2}x", row.bwd_speedup_computebound),
        ]);
    }
    format!(
        "Table 1 — speedups vs full update (r=100%), LeNet conv back-prop / whole train step\n{}",
        t.render()
    )
}

pub fn run(manifest: &Manifest, ratios: &[usize], samples: usize) -> Result<String> {
    let rows = run_rows(manifest, ratios, samples)?;
    Ok(render(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::IoSpec;

    #[test]
    fn probe_flops_counts_gemms() {
        let art = ArtifactSpec {
            kind: "convbwd".into(),
            file: "x".into(),
            ratio: Some(50),
            batch: 4,
            k: vec![3, 8],
            inputs: vec![
                IoSpec { name: "conv0.dz".into(), shape: vec![16, 6], dtype: Dtype::F32 },
                IoSpec { name: "conv0.a".into(), shape: vec![16, 25], dtype: Dtype::F32 },
                IoSpec { name: "conv0.w".into(), shape: vec![25, 6], dtype: Dtype::F32 },
                IoSpec { name: "conv0.idx".into(), shape: vec![3], dtype: Dtype::I32 },
                IoSpec { name: "conv1.dz".into(), shape: vec![4, 16], dtype: Dtype::F32 },
                IoSpec { name: "conv1.a".into(), shape: vec![4, 150], dtype: Dtype::F32 },
                IoSpec { name: "conv1.w".into(), shape: vec![150, 16], dtype: Dtype::F32 },
                IoSpec { name: "conv1.idx".into(), shape: vec![8], dtype: Dtype::I32 },
            ],
            outputs: vec![],
        };
        let f = probe_flops(&art);
        assert_eq!(f, 4.0 * 16.0 * 25.0 * 3.0 + 4.0 * 4.0 * 150.0 * 8.0);
    }

    #[test]
    fn dummy_args_match_spec() {
        let art = ArtifactSpec {
            kind: "t".into(),
            file: "x".into(),
            ratio: None,
            batch: 1,
            k: vec![],
            inputs: vec![
                IoSpec { name: "a".into(), shape: vec![2, 3], dtype: Dtype::F32 },
                IoSpec { name: "idx".into(), shape: vec![4], dtype: Dtype::I32 },
            ],
            outputs: vec![],
        };
        let args = dummy_args(&art, 0);
        assert_eq!(args.len(), 2);
        assert_eq!(args[0].shape(), &[2, 3]);
        match &args[1] {
            ArgBuf::I32 { data, .. } => assert_eq!(data, &vec![0, 1, 2, 3]),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn render_shapes_table() {
        let rows = vec![SpeedupRow {
            ratio: 10,
            bwd_speedup: 5.5,
            overall_speedup: 1.8,
            bwd_speedup_computebound: 8.0,
        }];
        let s = render(&rows);
        assert!(s.contains("10%"));
        assert!(s.contains("5.50x"));
    }
}
