//! Round-scheduler bench — policy × method × fleet-skew sweep, written
//! to `BENCH_sched.json`.
//!
//! Runs full federated training on the native backend (the `tiny` spec:
//! real conv/GEMM compute, real accuracy) over the simulated network,
//! once per (method ∈ {fedavg, fedskel}) × (fleet skew) × (policy ∈
//! {sync, deadline, async}), and reports the quantities the paper's
//! straggler story is about:
//!
//! * **makespan** — total virtual seconds for the whole run (the sum of
//!   per-round virtual-clock durations);
//! * **time-to-accuracy** — virtual seconds until the New-Test accuracy
//!   first reaches 95% of the best final accuracy any policy achieved
//!   for that method/skew;
//! * **straggler utilization** — mean over rounds of busy device-seconds
//!   ÷ (participants × round duration), [`crate::hetero::utilization`].
//!
//! Per-bucket batch seconds are **pinned** (not measured) via
//! [`NativeBackend::with_fixed_batch_secs`], so every makespan is a pure
//! function of the config — bitwise reproducible on noisy CI hosts. The
//! deadline for the DeadlineDrop case is derived from the sync run of
//! the same cell: the midpoint of its two slowest per-client mean round
//! times, which provably drops the slowest device's longest rounds while
//! keeping the rest — so the bench asserts (and CI therefore enforces)
//! that DeadlineDrop and AsyncBuffer makespans land strictly below
//! Sync's on every fleet.
//!
//! Knobs (env):
//! * `FEDSKEL_BENCH_SMOKE=1` — 6 rounds on a small dataset (CI).
//! * `FEDSKEL_BENCH_ROUNDS=n` — override the round count.
//! * `FEDSKEL_BENCH_OUT=path` — where the JSON report goes.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::config::{Method, RunConfig};
use crate::coordinator::Coordinator;
use crate::hetero::utilization;
use crate::metrics::Table;
use crate::model::params_digest;
use crate::runtime::native::NativeBackend;
use crate::sched::SchedKind;
use crate::util::json::Json;

const CLIENTS: usize = 8;
/// AsyncBuffer closes each round on the (fleet − 1)-th arrival.
const BUFFER_K: usize = CLIENTS - 1;
const STALENESS_ALPHA: f64 = 0.5;

/// One measured (method, policy, skew) cell of `BENCH_sched.json`.
#[derive(Debug, Clone)]
pub struct SchedRow {
    pub method: Method,
    pub policy: SchedKind,
    pub skew: f64,
    /// The derived per-round deadline (DeadlineDrop rows only).
    pub deadline_s: Option<f64>,
    /// The buffer size (AsyncBuffer rows only).
    pub buffer_k: Option<usize>,
    pub makespan_s: f64,
    /// Virtual seconds to reach `target_acc` (None = never reached).
    pub time_to_acc_s: Option<f64>,
    /// 95% of the best final accuracy across this cell's three policies.
    pub target_acc: f64,
    pub final_new_acc: f64,
    pub utilization: f64,
    pub dropped: usize,
    pub stale: usize,
    pub wasted_bytes: u64,
    /// FNV fingerprint of the trained global model.
    pub digest: u64,
}

/// Everything one coordinator run yields before cross-policy metrics
/// (time-to-accuracy target) are known.
struct CaseOut {
    makespan_s: f64,
    /// (cumulative virtual secs, new-test accuracy) per eval round.
    acc_curve: Vec<(f64, f64)>,
    final_new_acc: f64,
    utilization: f64,
    dropped: usize,
    stale: usize,
    wasted_bytes: u64,
    digest: u64,
    /// Per-client mean virtual round seconds (sync runs feed these to
    /// the deadline derivation).
    mean_client_secs: Vec<f64>,
}

/// Pinned per-bucket batch seconds for the tiny spec: linear in the
/// ratio, 80 ms at r=100 — the compute-bound shape Table 1 measures.
fn fixed_secs() -> BTreeMap<usize, f64> {
    [25usize, 50, 100].into_iter().map(|b| (b, b as f64 / 100.0 * 0.08)).collect()
}

fn base_cfg(method: Method, skew: f64, rounds: usize, dataset: usize) -> RunConfig {
    RunConfig {
        method,
        model: "tiny_native".into(),
        num_clients: CLIENTS,
        shards_per_client: 2,
        dataset_size: dataset,
        new_test_size: 64,
        rounds,
        local_steps: 2,
        eval_every: 2,
        lr: 0.08,
        fleet_skew: skew,
        seed: 42,
        ..RunConfig::default()
    }
}

fn run_case(cfg: RunConfig) -> Result<CaseOut> {
    let n = cfg.num_clients;
    let backend = NativeBackend::tiny().with_fixed_batch_secs(fixed_secs());
    let mut coord = Coordinator::new(cfg, backend)?;
    coord.run()?;

    let mut sums = vec![0.0f64; n];
    let mut counts = vec![0usize; n];
    let mut cum = 0.0f64;
    let mut acc_curve = Vec::new();
    let mut util_sum = 0.0f64;
    let mut util_rounds = 0usize;
    let mut dropped = 0usize;
    let mut stale = 0usize;
    for rl in &coord.log.rounds {
        cum += rl.sim_round_secs;
        for &(id, s) in &rl.client_secs {
            sums[id] += s;
            counts[id] += 1;
        }
        if !rl.client_secs.is_empty() && rl.sim_round_secs > 0.0 {
            let busy: Vec<f64> = rl.client_secs.iter().map(|&(_, s)| s).collect();
            util_sum += utilization(&busy, rl.sim_round_secs, busy.len());
            util_rounds += 1;
        }
        dropped += rl.dropped;
        stale += rl.stale;
        if let Some(a) = rl.new_acc {
            acc_curve.push((cum, a));
        }
    }
    let mean_client_secs: Vec<f64> =
        sums.iter().zip(&counts).map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 }).collect();
    Ok(CaseOut {
        makespan_s: cum,
        acc_curve,
        final_new_acc: coord.log.last_new_acc().unwrap_or(0.0),
        utilization: if util_rounds > 0 { util_sum / util_rounds as f64 } else { 0.0 },
        dropped,
        stale,
        wasted_bytes: coord.ledger.wasted_wire_bytes,
        digest: params_digest(&coord.global),
        mean_client_secs,
    })
}

/// Midpoint of the two slowest per-client mean round times. The slowest
/// client's longest round necessarily exceeds its own mean, which
/// exceeds this midpoint — so at least one round drops it and the
/// deadline makespan lands strictly below the sync makespan.
fn derive_deadline(mean_secs: &[f64]) -> f64 {
    let mut v = mean_secs.to_vec();
    v.sort_by(f64::total_cmp);
    let max = v[v.len() - 1];
    let second = if v.len() >= 2 { v[v.len() - 2] } else { max };
    if max > second {
        (max + second) / 2.0
    } else {
        max * 0.999
    }
}

fn time_to_acc(curve: &[(f64, f64)], target: f64) -> Option<f64> {
    curve.iter().find(|&&(_, a)| a >= target).map(|&(t, _)| t)
}

/// Run the full sweep and write `out`. Returns the rendered table.
pub fn run_with(rounds: usize, dataset: usize, skews: &[f64], out: &str) -> Result<String> {
    let mut rows: Vec<SchedRow> = Vec::new();
    for &method in &[Method::FedAvg, Method::FedSkel] {
        for &skew in skews {
            let sync = run_case(base_cfg(method, skew, rounds, dataset))?;
            let deadline_s = derive_deadline(&sync.mean_client_secs);

            let mut dcfg = base_cfg(method, skew, rounds, dataset);
            dcfg.sched = SchedKind::DeadlineDrop;
            dcfg.deadline_secs = deadline_s;
            let deadline = run_case(dcfg)?;

            let mut acfg = base_cfg(method, skew, rounds, dataset);
            acfg.sched = SchedKind::AsyncBuffer;
            acfg.buffer_k = BUFFER_K;
            acfg.staleness_alpha = STALENESS_ALPHA;
            let async_buf = run_case(acfg)?;

            ensure!(
                deadline.makespan_s < sync.makespan_s,
                "{} skew {skew}: deadline makespan {} !< sync {}",
                method.name(),
                deadline.makespan_s,
                sync.makespan_s
            );
            ensure!(
                async_buf.makespan_s < sync.makespan_s,
                "{} skew {skew}: async makespan {} !< sync {}",
                method.name(),
                async_buf.makespan_s,
                sync.makespan_s
            );

            let best = sync.final_new_acc.max(deadline.final_new_acc).max(async_buf.final_new_acc);
            let target = 0.95 * best;
            let cells = [
                (SchedKind::Sync, None, None, sync),
                (SchedKind::DeadlineDrop, Some(deadline_s), None, deadline),
                (SchedKind::AsyncBuffer, None, Some(BUFFER_K), async_buf),
            ];
            for (policy, dl, bk, case) in cells {
                rows.push(SchedRow {
                    method,
                    policy,
                    skew,
                    deadline_s: dl,
                    buffer_k: bk,
                    makespan_s: case.makespan_s,
                    time_to_acc_s: time_to_acc(&case.acc_curve, target),
                    target_acc: target,
                    final_new_acc: case.final_new_acc,
                    utilization: case.utilization,
                    dropped: case.dropped,
                    stale: case.stale,
                    wasted_bytes: case.wasted_bytes,
                    digest: case.digest,
                });
            }
        }
    }
    std::fs::write(out, rows_to_json(rounds, skews, &rows).to_string_pretty())?;
    Ok(format!("{}\nwrote {out}", render(&rows)))
}

/// Render the paper-shaped comparison table.
pub fn render(rows: &[SchedRow]) -> String {
    let mut t = Table::new(&[
        "method",
        "skew",
        "policy",
        "makespan (s)",
        "t-to-acc (s)",
        "final acc",
        "util",
        "drop",
        "stale",
    ]);
    for r in rows {
        t.row(vec![
            r.method.name().into(),
            format!("{}", r.skew),
            r.policy.name().into(),
            format!("{:.3}", r.makespan_s),
            r.time_to_acc_s.map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into()),
            format!("{:.3}", r.final_new_acc),
            format!("{:.2}", r.utilization),
            format!("{}", r.dropped),
            format!("{}", r.stale),
        ]);
    }
    format!(
        "Round scheduling (native tiny, {CLIENTS} clients, pinned batch secs) — \
         makespan / time-to-accuracy / straggler utilization per policy\n{}",
        t.render()
    )
}

/// The `BENCH_sched.json` schema.
pub fn rows_to_json(rounds: usize, skews: &[f64], rows: &[SchedRow]) -> Json {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("method", Json::str(r.method.name())),
                ("policy", Json::str(r.policy.name())),
                ("skew", Json::num(r.skew)),
                ("deadline_s", r.deadline_s.map(Json::num).unwrap_or(Json::Null)),
                ("buffer_k", r.buffer_k.map(|k| Json::num(k as f64)).unwrap_or(Json::Null)),
                ("makespan_s", Json::num(r.makespan_s)),
                ("time_to_acc_s", r.time_to_acc_s.map(Json::num).unwrap_or(Json::Null)),
                ("target_acc", Json::num(r.target_acc)),
                ("final_new_acc", Json::num(r.final_new_acc)),
                ("utilization", Json::num(r.utilization)),
                ("dropped", Json::num(r.dropped as f64)),
                ("stale", Json::num(r.stale as f64)),
                ("wasted_bytes", Json::num(r.wasted_bytes as f64)),
                ("digest", Json::str(format!("{:#018x}", r.digest))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str("sched")),
        ("model", Json::str("tiny_native")),
        ("clients", Json::num(CLIENTS as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("staleness_alpha", Json::num(STALENESS_ALPHA)),
        ("skews", Json::arr_f64(skews)),
        ("rows", Json::Arr(rows_json)),
    ])
}

/// Env-configured entry used by `benches/sched_policies.rs`:
/// `FEDSKEL_BENCH_SMOKE=1` runs the small CI profile.
pub fn run_env(default_out: &str) -> Result<String> {
    let smoke = std::env::var("FEDSKEL_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let rounds: usize = std::env::var("FEDSKEL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 6 } else { 16 });
    let dataset = if smoke { 320 } else { 960 };
    let skews = [2.0, 8.0];
    let out = std::env::var("FEDSKEL_BENCH_OUT").unwrap_or_else(|_| default_out.to_string());
    run_with(rounds, dataset, &skews, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_derivation_splits_the_two_slowest() {
        let d = derive_deadline(&[0.16, 0.2, 0.64, 1.28]);
        assert!((d - 0.96).abs() < 1e-12);
        // a tie falls back to just under the max (still drops it)
        let d = derive_deadline(&[1.0, 1.0]);
        assert!(d < 1.0);
        assert_eq!(derive_deadline(&[2.0]), 2.0 * 0.999);
    }

    #[test]
    fn time_to_acc_finds_first_crossing() {
        let curve = [(1.0, 0.2), (2.0, 0.5), (3.0, 0.9)];
        assert_eq!(time_to_acc(&curve, 0.5), Some(2.0));
        assert_eq!(time_to_acc(&curve, 0.95), None);
        assert_eq!(time_to_acc(&[], 0.1), None);
    }

    #[test]
    fn row_json_schema() {
        let row = SchedRow {
            method: Method::FedAvg,
            policy: SchedKind::DeadlineDrop,
            skew: 8.0,
            deadline_s: Some(0.96),
            buffer_k: None,
            makespan_s: 5.5,
            time_to_acc_s: None,
            target_acc: 0.5,
            final_new_acc: 0.52,
            utilization: 0.61,
            dropped: 6,
            stale: 0,
            wasted_bytes: 1234,
            digest: 0xABCD,
        };
        let s = rows_to_json(6, &[8.0], &[row]).to_string();
        assert!(s.contains("\"bench\":\"sched\""), "{s}");
        assert!(s.contains("\"policy\":\"deadline\""), "{s}");
        assert!(s.contains("\"time_to_acc_s\":null"), "{s}");
        assert!(s.contains("\"wasted_bytes\":1234"), "{s}");
    }
}
