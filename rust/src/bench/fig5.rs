//! Figure 5 — per-client one-batch runtime on an 8-device heterogeneous
//! system, FedSkel (r_i ∝ c_i) vs FedAvg (r = 100% everywhere).
//!
//! Device classes are capability profiles (DESIGN.md §3): client i's
//! simulated batch time = measured host time of its ratio-bucket train
//! artifact ÷ capability c_i. FedSkel assigns each device the bucket
//! nearest its capability, so slow devices run genuinely smaller backprop
//! GEMMs and the per-device times flatten — the paper's workload
//! balancing claim.

use anyhow::Result;

use crate::hetero::{equidistant_fleet, imbalance, simulate_round, RoundTime};
use crate::metrics::Table;
use crate::model::Manifest;
use crate::runtime::step::Backend;
use crate::runtime::PjrtBackend;

#[derive(Debug, Clone)]
pub struct DeviceRow {
    pub device: usize,
    pub capability: f64,
    pub bucket: usize,
    pub fedavg_batch_s: f64,
    pub fedskel_batch_s: f64,
}

#[derive(Debug, Clone)]
pub struct Fig5Result {
    pub rows: Vec<DeviceRow>,
    pub fedavg_system_s: f64,
    pub fedskel_system_s: f64,
    pub fedavg_imbalance: f64,
    pub fedskel_imbalance: f64,
}

impl Fig5Result {
    /// Whole-system speedup (synchronous round = slowest device).
    pub fn system_speedup(&self) -> f64 {
        self.fedavg_system_s / self.fedskel_system_s
    }
}

/// Measure per-bucket batch times once, then simulate the fleet.
pub fn run_result(manifest: &Manifest, devices: usize, samples: usize) -> Result<Fig5Result> {
    let mut backend = PjrtBackend::new(manifest, "lenet_smnist")?;
    backend.timing_reps = samples.max(1);
    let spec = backend.spec().clone();

    let fleet = equidistant_fleet(devices, 0.125, 1.0, 100.0);
    let mut rows = Vec::with_capacity(devices);
    let mut fedavg_times: Vec<RoundTime> = Vec::new();
    let mut fedskel_times: Vec<RoundTime> = Vec::new();

    let full_batch_s = backend.batch_time_secs(100)?;
    for (i, dev) in fleet.iter().enumerate() {
        // paper's rule: r_i ∝ c_i (capabilities already normalized to max 1)
        let bucket = spec.quantize_ratio(dev.capability * 100.0)?;
        let skel_batch_s = backend.batch_time_secs(bucket)?;

        let t_avg = simulate_round(dev, full_batch_s, 1, 0);
        let t_skel = simulate_round(dev, skel_batch_s, 1, 0);
        rows.push(DeviceRow {
            device: i,
            capability: dev.capability,
            bucket,
            fedavg_batch_s: t_avg.total(),
            fedskel_batch_s: t_skel.total(),
        });
        fedavg_times.push(t_avg);
        fedskel_times.push(t_skel);
    }

    Ok(Fig5Result {
        rows,
        fedavg_system_s: crate::hetero::system_round_time(&fedavg_times),
        fedskel_system_s: crate::hetero::system_round_time(&fedskel_times),
        fedavg_imbalance: imbalance(&fedavg_times),
        fedskel_imbalance: imbalance(&fedskel_times),
    })
}

pub fn render(res: &Fig5Result) -> String {
    let mut t = Table::new(&["device", "capability", "FedSkel bucket", "FedAvg batch", "FedSkel batch"]);
    let max_t = res
        .rows
        .iter()
        .map(|r| r.fedavg_batch_s)
        .fold(0.0f64, f64::max);
    for r in &res.rows {
        t.row(vec![
            format!("dev{}", r.device),
            format!("{:.3}", r.capability),
            format!("r{}%", r.bucket),
            format!("{:.1}ms {}", r.fedavg_batch_s * 1e3, bar(r.fedavg_batch_s, max_t)),
            format!("{:.1}ms {}", r.fedskel_batch_s * 1e3, bar(r.fedskel_batch_s, max_t)),
        ]);
    }
    format!(
        "Figure 5 — per-device one-batch runtime (simulated heterogeneous fleet)\n{}\n\
         system round (max device): FedAvg {:.1}ms  FedSkel {:.1}ms  → {:.2}x system speedup\n\
         straggler imbalance (max/min): FedAvg {:.2}  FedSkel {:.2}\n",
        t.render(),
        res.fedavg_system_s * 1e3,
        res.fedskel_system_s * 1e3,
        res.system_speedup(),
        res.fedavg_imbalance,
        res.fedskel_imbalance,
    )
}

fn bar(v: f64, max: f64) -> String {
    let n = ((v / max) * 30.0).round() as usize;
    "#".repeat(n.max(1))
}

pub fn run(manifest: &Manifest, devices: usize, samples: usize) -> Result<String> {
    Ok(render(&run_result(manifest, devices, samples)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_speedup_math() {
        let res = Fig5Result {
            rows: vec![DeviceRow {
                device: 0,
                capability: 0.5,
                bucket: 50,
                fedavg_batch_s: 0.2,
                fedskel_batch_s: 0.1,
            }],
            fedavg_system_s: 0.2,
            fedskel_system_s: 0.1,
            fedavg_imbalance: 4.0,
            fedskel_imbalance: 1.2,
        };
        assert!((res.system_speedup() - 2.0).abs() < 1e-12);
        let s = render(&res);
        assert!(s.contains("dev0"));
        assert!(s.contains("2.00x system speedup"));
    }
}
