//! Host-side dense f32 tensors.
//!
//! The coordinator never does heavy math — model compute lives in the AOT
//! artifacts — but aggregation, importance bookkeeping, accuracy
//! calculation, and data synthesis all need a small shaped-array type.
//! This is deliberately minimal: contiguous row-major f32 storage plus the
//! handful of ops L3 actually uses.
//!
//! Paper: substrate for Table 2's parameter accounting and the Table 3/4
//! accuracy bookkeeping. Invariant: storage is contiguous row-major, so
//! `data()` can be handed straight to the wire codec and the native
//! kernels without copies.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// From existing data; checks element count.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Scalar tensor.
    pub fn scalar(x: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1);
        self.data[0]
    }

    /// `self += alpha * other` (elementwise, same shape).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("axpy shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Elementwise difference `self - other`.
    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("sub shape mismatch");
        }
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Argmax over the last axis for a 2-D tensor [rows, cols].
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        if self.shape.len() != 2 {
            bail!("argmax_rows wants 2-D, got {:?}", self.shape);
        }
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Vec::with_capacity(r);
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            let mut best = 0;
            for j in 1..c {
                if row[j] > row[best] {
                    best = j;
                }
            }
            out.push(best);
        }
        Ok(out)
    }

    /// Gather columns (last-dim) of a 2-D tensor — host-side mirror of the
    /// skeleton gather, used in aggregation tests.
    pub fn gather_cols(&self, idx: &[usize]) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("gather_cols wants 2-D");
        }
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut data = Vec::with_capacity(r * idx.len());
        for i in 0..r {
            for &j in idx {
                data.push(self.data[i * c + j]);
            }
        }
        Tensor::from_vec(&[r, idx.len()], data)
    }

    /// View the trailing axis size (output channels for weight tensors).
    pub fn last_dim(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    /// Number of elements whose last-dim index is in `idx` (= rows × k).
    pub fn count_sub_lastdim(&self, k: usize) -> usize {
        if self.shape.is_empty() {
            return 1;
        }
        let rows: usize = self.shape[..self.shape.len() - 1].iter().product();
        rows * k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![10.0, 10.0, 10.0]).unwrap();
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.data(), &[6.0, 7.0, 8.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12.0, 14.0, 16.0]);
        let c = Tensor::zeros(&[4]);
        assert!(a.axpy(1.0, &c).is_err());
    }

    #[test]
    fn norms_and_stats() {
        let t = Tensor::from_vec(&[2, 2], vec![3.0, -4.0, 0.0, 0.0]).unwrap();
        assert!((t.norm() - 5.0).abs() < 1e-6);
        assert!((t.mean() + 0.25).abs() < 1e-6);
        assert_eq!(t.max_abs(), 4.0);
    }

    #[test]
    fn argmax_rows_works() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.3, 2.0, -1.0, 1.0]).unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
        assert!(Tensor::zeros(&[3]).argmax_rows().is_err());
    }

    #[test]
    fn gather_cols_works() {
        let t = Tensor::from_vec(&[2, 4], vec![0., 1., 2., 3., 4., 5., 6., 7.]).unwrap();
        let g = t.gather_cols(&[0, 3]).unwrap();
        assert_eq!(g.shape(), &[2, 2]);
        assert_eq!(g.data(), &[0., 3., 4., 7.]);
    }

    #[test]
    fn sub_and_item() {
        let a = Tensor::from_vec(&[2], vec![5.0, 7.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        assert_eq!(a.sub(&b).unwrap().data(), &[4.0, 5.0]);
        assert_eq!(Tensor::scalar(3.5).item(), 3.5);
    }

    #[test]
    fn count_sub_lastdim_counts() {
        let t = Tensor::zeros(&[5, 5, 1, 6]);
        assert_eq!(t.count_sub_lastdim(2), 50);
        assert_eq!(Tensor::scalar(1.0).count_sub_lastdim(1), 1);
    }
}
