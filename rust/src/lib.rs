//! # FedSkel — Rust + JAX + Pallas reproduction
//!
//! Reproduction of *"FedSkel: Efficient Federated Learning on Heterogeneous
//! Systems with Skeleton Gradients Update"* (Luo et al., CIKM 2021).
//!
//! This crate is **Layer 3** of the three-layer stack (see `DESIGN.md`):
//! the federated-learning coordinator. It owns the server loop, the
//! simulated client fleet, skeleton selection and ratio assignment, masked
//! aggregation, communication accounting, the heterogeneity simulator,
//! metrics, and the CLI. All numeric compute (model forward/backward with
//! skeleton-pruned gradients) executes AOT-compiled HLO artifacts produced
//! by the Python layers (`python/compile/`) through the PJRT CPU client —
//! Python never runs on the training path.
//!
//! ## Module map
//!
//! (The narrative version — one federated round's data flow, where Eq. 2
//! and the skeleton slice happen, and the parallel-kernel determinism
//! contract — lives in `docs/ARCHITECTURE.md`.)
//!
//! | module | role |
//! |---|---|
//! | [`util`] | RNG (SplitMix64), JSON, CLI parsing, timing |
//! | [`tensor`] | host-side dense f32 tensors |
//! | [`config`] | run configuration (file + CLI overrides) |
//! | [`data`] | synthetic datasets + non-IID sharding |
//! | [`model`] | model specs mirrored from `manifest.json`, param init |
//! | [`kernels`] | native conv/GEMM/pool kernels (skeleton-sliced backward) + parallel layer |
//! | [`runtime`] | backends: native CPU, PJRT artifacts, deterministic mock |
//! | [`skeleton`] | importance accumulation, top-k selection, ratio policy |
//! | [`clients`] | per-client state |
//! | [`aggregate`] | FedAvg / FedSkel / LG-FedAvg / FedMTL aggregation |
//! | [`comm`] | communication accounting + bandwidth model |
//! | [`compress`] | error-feedback update compression (quantize / top-k) + delta-vs-anchor downloads |
//! | [`transport`] | wire codec, pluggable transports, client worker pool |
//! | [`hetero`] | device profiles (capability, link, core budget) + straggler simulation |
//! | [`sched`] | virtual-clock round scheduler: sync / deadline-drop / async-buffer policies |
//! | [`coordinator`] | the SetSkel/UpdateSkel federated training loop |
//! | [`snapshot`] | versioned checkpoint/resume snapshots with bitwise resume parity |
//! | [`trace`] | event-sourced run tracing: sinks, metrics registry, replay, watch |
//! | [`prof`] | hierarchical span profiler: RAII scopes, Chrome-trace export, attribution |
//! | [`metrics`] | accuracy/loss tracking, round logs, table printers |
//! | [`benchkit`] | criterion-substitute micro/macro bench harness |
//!
//! ## Quickstart (library)
//!
//! The same loop the `fedskel train` CLI drives, as a library call —
//! and a runnable doctest (`cargo test --doc`), so this snippet cannot
//! rot. The deterministic mock backend needs no artifacts; swap in
//! [`runtime::NativeBackend`] for real compute:
//!
//! ```
//! use fedskel::config::{Method, RunConfig};
//! use fedskel::coordinator::Coordinator;
//! use fedskel::runtime::mock::MockBackend;
//!
//! # fn main() -> anyhow::Result<()> {
//! let cfg = RunConfig {
//!     method: Method::FedSkel,
//!     model: "toy".into(),
//!     num_clients: 4,
//!     shards_per_client: 2,
//!     dataset_size: 400,
//!     new_test_size: 64,
//!     rounds: 4,
//!     local_steps: 2,
//!     eval_every: 0,
//!     ..RunConfig::default()
//! };
//! let mut coord = Coordinator::new(cfg, MockBackend::toy())?;
//! coord.run()?;
//! assert_eq!(coord.log.rounds.len(), 4);
//! // every payload really moved as encoded wire frames
//! assert!(coord.ledger.total_wire_bytes() > 0);
//! assert!(coord.log.last_new_acc().is_some());
//! # Ok(())
//! # }
//! ```

pub mod aggregate;
pub mod benchkit;
pub mod clients;
pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod hetero;
pub mod kernels;
pub mod metrics;
pub mod model;
pub mod prof;
pub mod runtime;
pub mod sched;
pub mod skeleton;
pub mod snapshot;
pub mod tensor;
pub mod trace;
pub mod transport;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

pub mod bench;
