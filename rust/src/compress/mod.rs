//! Error-feedback gradient compression for round uploads.
//!
//! FedSkel's Table-2 result ships fewer *parameters*; this module
//! shrinks the *bytes per parameter* on top, without silently trading
//! away the paper's "negligible accuracy loss" claim. Each client's
//! upload is first turned into an **update delta** vs the round's shared
//! anchor (the global the client trained from), then a [`Compressor`]
//! decides, per value block, how the wire should carry it: exact f32
//! ([`CompressKind::Identity`]), dense quantization
//! ([`CompressKind::F16`] / [`CompressKind::Int8`], with tensors below
//! [`QUANT_MIN_NUMEL`] values kept f32 — the per-param quant override),
//! or magnitude top-k sparsification ([`CompressKind::TopK`]).
//!
//! **Error feedback** (Karimireddy et al.-style, the mechanism FedSKETCH
//! and Konečný et al.'s structured/quantized updates rely on): the
//! residual between the true update and its decoded form is accumulated
//! per client per coordinate and *added back into the next round's
//! update before compression*, so quantization error is deferred, never
//! lost. The residual is computed with [`block_roundtrip`], which is
//! bitwise the value the server's decoder reconstructs (it shares the
//! wire codec's conversion routines).
//!
//! Compression respects the exchange kind's structure: an UpdateSkel
//! upload still carries only skeleton channels — the compressor runs
//! over the gathered blocks, and residuals map back to full-tensor
//! coordinates, persisting until a coordinate is next carried. Stale
//! async arrivals ([`crate::sched`]) compress against their own origin
//! round's anchor, because encode/decode happens at submission time.
//!
//! ```
//! use fedskel::compress::{block_roundtrip, CompressKind, Compressor};
//!
//! // keep the 50% largest-magnitude update values
//! let comp = CompressKind::TopK.build(0.5);
//! let vals = [0.9f32, -0.1, 0.0, 2.0];
//! let plan = comp.plan(&vals);
//! assert_eq!(plan.idx.as_deref(), Some(&[0u32, 3][..]));
//!
//! // error feedback: what the wire dropped becomes next round's residual
//! let decoded = block_roundtrip(&vals, &plan);
//! let residual: Vec<f32> = vals.iter().zip(&decoded).map(|(v, d)| v - d).collect();
//! assert_eq!(residual, vec![0.0, -0.1, 0.0, 0.0]);
//! ```

use anyhow::{bail, Result};

use crate::comm::ExchangeKind;
use crate::model::{params_sub, ModelSpec, Params};
use crate::prof;
use crate::transport::wire::{self, BlockPlan, Quant, WirePayload};

/// Value blocks smaller than this stay f32 under the quantizing
/// compressors — the *per-param quant override*. Biases and small heads
/// cost almost nothing on the wire, and quantization error there hurts
/// accuracy the most.
pub const QUANT_MIN_NUMEL: usize = 64;

/// Which upload compressor a run uses (config/CLI-selectable).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CompressKind {
    /// No compression: the pre-compression wire path, byte for byte.
    #[default]
    Identity,
    /// Dense IEEE half-precision update deltas.
    F16,
    /// Dense symmetric per-block int8 update deltas.
    Int8,
    /// Magnitude top-k sparsified update deltas (f32 survivors).
    TopK,
}

impl CompressKind {
    pub fn parse(s: &str) -> Result<CompressKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "identity" | "none" => CompressKind::Identity,
            "f16" => CompressKind::F16,
            "int8" | "i8" => CompressKind::Int8,
            "topk" | "top-k" => CompressKind::TopK,
            _ => bail!("unknown compressor '{s}' — valid modes: identity|f16|int8|topk"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompressKind::Identity => "identity",
            CompressKind::F16 => "f16",
            CompressKind::Int8 => "int8",
            CompressKind::TopK => "topk",
        }
    }

    /// Identity compression must never enter the delta pipeline — the
    /// coordinator short-circuits to the plain wire path instead.
    pub fn is_identity(&self) -> bool {
        *self == CompressKind::Identity
    }

    /// Build the compressor (`topk_ratio` only matters for
    /// [`CompressKind::TopK`]).
    pub fn build(&self, topk_ratio: f64) -> Box<dyn Compressor> {
        match self {
            CompressKind::Identity => Box::new(IdentityCompressor),
            CompressKind::F16 => Box::new(QuantizeCompressor(Quant::F16)),
            CompressKind::Int8 => Box::new(QuantizeCompressor(Quant::Int8)),
            CompressKind::TopK => Box::new(TopKCompressor { ratio: topk_ratio }),
        }
    }
}

/// Plans the wire encoding of one value block of a delta payload.
/// Implementations must be deterministic pure functions of the values —
/// the thread-count and scheduling determinism contracts extend through
/// compression.
pub trait Compressor: Send {
    fn name(&self) -> &'static str;

    /// Decide how one block's values go on the wire.
    fn plan(&self, vals: &[f32]) -> BlockPlan;
}

/// Exact f32, dense — the do-nothing compressor.
pub struct IdentityCompressor;

impl Compressor for IdentityCompressor {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn plan(&self, _vals: &[f32]) -> BlockPlan {
        BlockPlan::dense(Quant::F32)
    }
}

/// Dense quantization at a fixed [`Quant`], with small blocks kept f32.
pub struct QuantizeCompressor(pub Quant);

impl Compressor for QuantizeCompressor {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn plan(&self, vals: &[f32]) -> BlockPlan {
        if vals.len() < QUANT_MIN_NUMEL {
            BlockPlan::dense(Quant::F32)
        } else {
            BlockPlan::dense(self.0)
        }
    }
}

/// Keep the `ceil(ratio · n)` largest-|v| values of each block (ties
/// break toward the lower index, indices ship ascending — fully
/// deterministic).
pub struct TopKCompressor {
    pub ratio: f64,
}

impl Compressor for TopKCompressor {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn plan(&self, vals: &[f32]) -> BlockPlan {
        let n = vals.len();
        if n == 0 {
            return BlockPlan::dense(Quant::F32);
        }
        let k = ((self.ratio * n as f64).ceil() as usize).clamp(1, n);
        if k >= n {
            return BlockPlan::dense(Quant::F32);
        }
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            vals[b as usize]
                .abs()
                .total_cmp(&vals[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut idx = order[..k].to_vec();
        idx.sort_unstable();
        BlockPlan { quant: Quant::F32, idx: Some(idx) }
    }
}

/// Per-client error-feedback state: one flat residual buffer per
/// parameter tensor (full-tensor coordinates). Empty until the client's
/// first compressed upload.
pub type Residual = Vec<Vec<f32>>;

/// The values the server's decoder reconstructs for `vals` under `plan`
/// — dense quantize/dequantize or sparse gather → quantize → scatter
/// into zeros. Shares the wire codec's conversion routines
/// ([`wire::quant_roundtrip`]), so the equality is bitwise.
pub fn block_roundtrip(vals: &[f32], plan: &BlockPlan) -> Vec<f32> {
    match &plan.idx {
        None => wire::quant_roundtrip(vals, plan.quant),
        Some(idx) => {
            let gathered: Vec<f32> = idx.iter().map(|&i| vals[i as usize]).collect();
            let decoded = wire::quant_roundtrip(&gathered, plan.quant);
            let mut out = vec![0.0f32; vals.len()];
            for (v, &i) in decoded.iter().zip(idx) {
                out[i as usize] = *v;
            }
            out
        }
    }
}

/// Build one client's compressed upload: the delta payload
/// (`trained − anchor`, shaped by the round's [`ExchangeKind`]) with the
/// error-feedback residual folded in, plus one [`BlockPlan`] per value
/// block for the wire encoder. When `residual` is `Some`, it is updated
/// in place to the new per-coordinate compression error (and lazily
/// initialized to zeros on first use); `None` disables error feedback.
///
/// The caller ships the payload with
/// [`wire::encode_opts`]`(…, delta = true, plans)`; the server
/// reconstructs full tensors by [`WirePayload::add_into`] onto the same
/// anchor.
pub fn compress_update(
    comp: &dyn Compressor,
    spec: &ModelSpec,
    kind: &ExchangeKind,
    skeleton: &[Vec<i32>],
    anchor: &Params,
    trained: &Params,
    mut residual: Option<&mut Residual>,
) -> Result<(WirePayload, Vec<BlockPlan>)> {
    // Outer span qualifies the compressor-specific child span
    // (`compress/identity`, `compress/topk`, …) — [`Compressor::name`]
    // is already `'static`, so nesting gives the per-kind path for free.
    let _span = prof::scope("compress");
    let _kind_span = prof::scope(comp.name());
    let delta = params_sub(trained, anchor)?;
    let mut payload = match kind {
        ExchangeKind::Full => WirePayload::full(&delta),
        ExchangeKind::Skeleton(_) => WirePayload::skeleton(spec, &delta, skeleton)?,
        ExchangeKind::ParamSubset(ids) => WirePayload::subset(spec, &delta, ids)?,
        ExchangeKind::None => bail!("cannot compress an empty exchange"),
    };
    if let Some(res) = residual.as_mut() {
        if res.len() != spec.params.len() {
            **res = spec.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        }
    }
    // reborrow the per-parameter residual buffer for one block's pass
    // (None when error feedback is off)
    macro_rules! res_of {
        ($pid:expr) => {
            residual.as_mut().map(|r| &mut r[$pid])
        };
    }

    let mut plans = Vec::new();
    match &mut payload {
        WirePayload::Full(ps) => {
            for (pid, t) in ps.iter_mut().enumerate() {
                plans.push(process_block(comp, res_of!(pid), None, t.data_mut()));
            }
        }
        WirePayload::Skeleton { layers, others } => {
            for (li, l) in layers.iter_mut().enumerate() {
                let p = &spec.prunable[li];
                let c = p.channels;
                let k = l.idx.len();
                let rows = if k == 0 { 0 } else { l.weight.len() / k };
                // gathered block position j = r·k + jj maps to the full
                // weight coordinate r·C + idx[jj]; biases map channelwise
                let wcoords: Vec<usize> = (0..rows)
                    .flat_map(|r| l.idx.iter().map(move |&ch| r * c + ch as usize))
                    .collect();
                plans.push(process_block(
                    comp,
                    res_of!(p.weight_param),
                    Some(&wcoords),
                    &mut l.weight,
                ));
                let bcoords: Vec<usize> = l.idx.iter().map(|&ch| ch as usize).collect();
                plans.push(process_block(
                    comp,
                    res_of!(p.bias_param),
                    Some(&bcoords),
                    &mut l.bias,
                ));
            }
            for (pid, t) in others.iter_mut() {
                plans.push(process_block(comp, res_of!(*pid), None, t.data_mut()));
            }
        }
        WirePayload::ParamSubset(es) => {
            for (pid, t) in es.iter_mut() {
                plans.push(process_block(comp, res_of!(*pid), None, t.data_mut()));
            }
        }
        WirePayload::AnchorDelta(_) => {
            bail!("anchor-delta is a download form, not a compressible upload")
        }
    }
    Ok((payload, plans))
}

/// One block through the error-feedback pipeline: fold the stored
/// residual into the values, plan the encoding, and store the new
/// residual (value − decoded) back at full-tensor coordinates
/// (`coords[j]`; identity when `coords` is `None`).
fn process_block(
    comp: &dyn Compressor,
    residual: Option<&mut Vec<f32>>,
    coords: Option<&[usize]>,
    vals: &mut [f32],
) -> BlockPlan {
    let Some(r) = residual else {
        return comp.plan(vals);
    };
    let _span = prof::scope("ef_fold");
    for (j, v) in vals.iter_mut().enumerate() {
        let c = coords.map_or(j, |cs| cs[j]);
        *v += r[c];
    }
    let plan = comp.plan(vals);
    let decoded = block_roundtrip(vals, &plan);
    for (j, (&v, &d)) in vals.iter().zip(&decoded).enumerate() {
        let c = coords.map_or(j, |cs| cs[j]);
        r[c] = v - d;
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_params;
    use crate::runtime::mock::toy_spec;

    #[test]
    fn parse_and_names() {
        assert_eq!(CompressKind::parse("Identity").unwrap(), CompressKind::Identity);
        assert_eq!(CompressKind::parse("none").unwrap(), CompressKind::Identity);
        assert_eq!(CompressKind::parse("f16").unwrap(), CompressKind::F16);
        assert_eq!(CompressKind::parse("i8").unwrap(), CompressKind::Int8);
        assert_eq!(CompressKind::parse("top-k").unwrap(), CompressKind::TopK);
        let err = format!("{:#}", CompressKind::parse("zstd").unwrap_err());
        assert!(err.contains("identity|f16|int8|topk"), "{err}");
        assert!(CompressKind::Identity.is_identity());
        assert!(!CompressKind::Int8.is_identity());
        assert_eq!(CompressKind::default(), CompressKind::Identity);
    }

    #[test]
    fn topk_plans_pick_magnitude_with_deterministic_ties() {
        let comp = CompressKind::TopK.build(0.5);
        // |−3| first, then the |2| tie breaks toward the lower index
        let plan = comp.plan(&[1.0, -3.0, 2.0, 2.0]);
        assert_eq!(plan.idx.as_deref(), Some(&[1u32, 2][..]));
        assert_eq!(plan.quant, Quant::F32);
        // ratio 1.0 (or tiny blocks where ceil(r·n) = n) go dense
        assert!(CompressKind::TopK.build(1.0).plan(&[1.0, 2.0]).idx.is_none());
        assert!(comp.plan(&[]).idx.is_none());
        // k is at least 1
        let plan = CompressKind::TopK.build(1e-9).plan(&[0.5, 4.0, 1.0]);
        assert_eq!(plan.idx.as_deref(), Some(&[1u32][..]));
    }

    #[test]
    fn quantizers_keep_small_blocks_f32() {
        let comp = CompressKind::Int8.build(0.0);
        let small = vec![0.5f32; QUANT_MIN_NUMEL - 1];
        assert_eq!(comp.plan(&small), BlockPlan::dense(Quant::F32));
        let big = vec![0.5f32; QUANT_MIN_NUMEL];
        assert_eq!(comp.plan(&big), BlockPlan::dense(Quant::Int8));
        let comp = CompressKind::F16.build(0.0);
        assert_eq!(comp.plan(&big), BlockPlan::dense(Quant::F16));
    }

    #[test]
    fn block_roundtrip_matches_sparse_semantics() {
        let plan = BlockPlan { quant: Quant::F32, idx: Some(vec![1, 3]) };
        let out = block_roundtrip(&[9.0, 1.0, 9.0, 2.0], &plan);
        assert_eq!(out, vec![0.0, 1.0, 0.0, 2.0]);
        let dense = block_roundtrip(&[1.0, 2.0], &BlockPlan::dense(Quant::F32));
        assert_eq!(dense, vec![1.0, 2.0]);
    }

    #[test]
    fn compress_update_full_topk_tracks_residuals() {
        let spec = toy_spec();
        let anchor = init_params(&spec, 1);
        let trained = init_params(&spec, 2);
        let comp = CompressKind::TopK.build(0.25);
        let mut res: Residual = Vec::new();
        let (payload, plans) = compress_update(
            comp.as_ref(),
            &spec,
            &ExchangeKind::Full,
            &[],
            &anchor,
            &trained,
            Some(&mut res),
        )
        .unwrap();
        assert_eq!(plans.len(), spec.params.len());
        assert_eq!(res.len(), spec.params.len());
        let WirePayload::Full(ps) = &payload else { panic!("wrong kind") };
        for (pid, plan) in plans.iter().enumerate() {
            let decoded = block_roundtrip(ps[pid].data(), plan);
            for (j, (&v, &d)) in ps[pid].data().iter().zip(&decoded).enumerate() {
                // carried coordinates have zero residual; dropped ones
                // carry the full adjusted value forward
                assert_eq!(res[pid][j], v - d);
            }
        }
        // a second round folds the residual back in: the adjusted values
        // are (new delta) + (old residual)
        let (payload2, _plans2) = compress_update(
            comp.as_ref(),
            &spec,
            &ExchangeKind::Full,
            &[],
            &anchor,
            &trained,
            Some(&mut res),
        )
        .unwrap();
        let WirePayload::Full(ps2) = &payload2 else { panic!("wrong kind") };
        let delta0 = trained[0].sub(&anchor[0]).unwrap();
        let WirePayload::Full(ps1) = &payload else { panic!() };
        // position 0 of tensor 0: adjusted₂ = delta + residual₁ where
        // residual₁ = adjusted₁ − decoded₁ and adjusted₁ = delta
        let r1 = ps1.clone();
        let dec1 = block_roundtrip(r1[0].data(), &plans[0]);
        let want = delta0.data()[0] + (r1[0].data()[0] - dec1[0]);
        assert_eq!(ps2[0].data()[0], want);
    }

    #[test]
    fn compress_update_skeleton_maps_residuals_to_selected_channels() {
        let spec = toy_spec();
        let anchor = init_params(&spec, 3);
        let trained = init_params(&spec, 4);
        let comp = CompressKind::TopK.build(0.5);
        let mut res: Residual = Vec::new();
        let skel = vec![vec![1i32, 3]];
        let (_payload, plans) = compress_update(
            comp.as_ref(),
            &spec,
            &ExchangeKind::Skeleton(vec![2]),
            &skel,
            &anchor,
            &trained,
            Some(&mut res),
        )
        .unwrap();
        // blocks: layer-0 weight, layer-0 bias, head.w, head.b
        assert_eq!(plans.len(), 4);
        // residuals never touch unselected channels (columns 0 and 2)
        let c = spec.prunable[0].channels;
        let rows = spec.params[0].numel() / c;
        for r in 0..rows {
            assert_eq!(res[0][r * c], 0.0);
            assert_eq!(res[0][r * c + 2], 0.0);
        }
        assert_eq!(res[1][0], 0.0);
        assert_eq!(res[1][2], 0.0);
        // at least one selected coordinate carries a nonzero residual
        // (top-k drops half the block)
        let selected_nonzero = (0..rows)
            .flat_map(|r| [r * c + 1, r * c + 3])
            .any(|j| res[0][j] != 0.0);
        assert!(selected_nonzero, "top-k on the gathered block must leave residuals");
    }

    #[test]
    fn compress_update_without_error_feedback_plans_only() {
        let spec = toy_spec();
        let anchor = init_params(&spec, 5);
        let trained = init_params(&spec, 6);
        let comp = CompressKind::F16.build(0.0);
        let (payload, plans) =
            compress_update(comp.as_ref(), &spec, &ExchangeKind::Full, &[], &anchor, &trained, None)
                .unwrap();
        assert_eq!(plans.len(), spec.params.len());
        // the payload is the raw delta (unquantized; the encoder applies
        // the plans on the wire)
        let WirePayload::Full(ps) = &payload else { panic!("wrong kind") };
        let want = crate::model::params_sub(&trained, &anchor).unwrap();
        assert_eq!(ps, &want);
        // identity compression never sets a non-f32 or sparse plan
        let id = CompressKind::Identity.build(0.0);
        let (_p, plans) =
            compress_update(id.as_ref(), &spec, &ExchangeKind::Full, &[], &anchor, &trained, None)
                .unwrap();
        assert!(plans.iter().all(|p| *p == BlockPlan::dense(Quant::F32)));
    }
}
