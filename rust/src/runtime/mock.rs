//! Deterministic in-process [`Backend`] for coordinator tests — no
//! artifacts or PJRT needed.
//!
//! Semantics chosen so coordinator invariants are observable:
//! * `train_step` adds `lr` to every *skeleton* entry of prunable tensors
//!   and to every entry of non-prunable tensors (so tests can check which
//!   channels a round touched), plus the FedProx pull `lr·mu·(g − p)`.
//! * loss decays deterministically with the number of calls.
//! * importance of channel `c` in layer `l` is `mean(|x|) · (c+1) · (l+1)`
//!   — stable, distinct, and data-dependent so SetSkel logic is testable.
//! * `eval_logits` votes for class `round(sum(sample)) mod classes`,
//!   making accuracy deterministic in the data.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::spec::{ArtifactSpec, Dtype, IoSpec, ModelSpec, ParamSpec, PrunableSpec};
use crate::model::Params;
use crate::runtime::step::{Backend, StepOut};
use crate::tensor::Tensor;

/// A small 2-layer spec (one prunable layer of 4 channels + head) with
/// train artifacts at buckets 25/50/100 — no files behind it, for tests.
pub fn toy_spec() -> ModelSpec {
    let params = vec![
        ParamSpec { name: "l0.w".into(), shape: vec![8, 4], init: "he".into() },
        ParamSpec { name: "l0.b".into(), shape: vec![4], init: "zeros".into() },
        ParamSpec { name: "head.w".into(), shape: vec![4, 3], init: "glorot".into() },
        ParamSpec { name: "head.b".into(), shape: vec![3], init: "zeros".into() },
    ];
    let prunable = vec![PrunableSpec { name: "l0".into(), channels: 4, weight_param: 0, bias_param: 1 }];
    let mut artifacts = BTreeMap::new();
    for bucket in [25usize, 50, 100] {
        let k = ((bucket as f64 / 100.0 * 4.0).ceil() as usize).max(1);
        let mut inputs: Vec<IoSpec> = params
            .iter()
            .map(|p| IoSpec { name: format!("param.{}", p.name), shape: p.shape.clone(), dtype: Dtype::F32 })
            .collect();
        inputs.extend(params.iter().map(|p| IoSpec {
            name: format!("global.{}", p.name),
            shape: p.shape.clone(),
            dtype: Dtype::F32,
        }));
        // input geometry matches the smnist dataset the coordinator tests
        // run on (the mock itself only looks at x's mean)
        inputs.push(IoSpec { name: "x".into(), shape: vec![2, 28, 28, 1], dtype: Dtype::F32 });
        inputs.push(IoSpec { name: "y".into(), shape: vec![2], dtype: Dtype::I32 });
        inputs.push(IoSpec { name: "idx.l0".into(), shape: vec![k], dtype: Dtype::I32 });
        inputs.push(IoSpec { name: "lr".into(), shape: vec![], dtype: Dtype::F32 });
        inputs.push(IoSpec { name: "mu".into(), shape: vec![], dtype: Dtype::F32 });
        let mut outputs: Vec<IoSpec> = params
            .iter()
            .map(|p| IoSpec { name: format!("new.{}", p.name), shape: p.shape.clone(), dtype: Dtype::F32 })
            .collect();
        outputs.push(IoSpec { name: "loss".into(), shape: vec![], dtype: Dtype::F32 });
        outputs.push(IoSpec { name: "imp.l0".into(), shape: vec![4], dtype: Dtype::F32 });
        artifacts.insert(
            format!("train_r{bucket}"),
            ArtifactSpec {
                kind: "train".into(),
                file: format!("toy_train_r{bucket}.hlo.txt"),
                ratio: Some(bucket),
                batch: 2,
                k: vec![k],
                inputs,
                outputs,
            },
        );
    }
    artifacts.insert(
        "eval".into(),
        ArtifactSpec {
            kind: "eval".into(),
            file: "toy_eval.hlo.txt".into(),
            ratio: None,
            batch: 4,
            k: vec![],
            inputs: vec![],
            outputs: vec![IoSpec { name: "logits".into(), shape: vec![4, 3], dtype: Dtype::F32 }],
        },
    );
    ModelSpec {
        name: "toy".into(),
        input_shape: vec![28, 28, 1],
        num_classes: 3,
        train_batch: 2,
        eval_batch: 4,
        num_params: 8 * 4 + 4 + 4 * 3 + 3,
        params,
        prunable,
        artifacts,
    }
}

/// The mock backend (see module docs for semantics).
pub struct MockBackend {
    spec: ModelSpec,
    pub calls: usize,
    pub eval_calls: usize,
    /// every (bucket, skeleton) pair seen, for assertions
    pub trained_skeletons: Vec<(usize, Vec<Vec<i32>>)>,
    /// simulated seconds per batch per bucket (defaults r/100 * 0.08)
    pub batch_secs: BTreeMap<usize, f64>,
}

impl MockBackend {
    pub fn new(spec: ModelSpec) -> MockBackend {
        MockBackend {
            spec,
            calls: 0,
            eval_calls: 0,
            trained_skeletons: Vec::new(),
            batch_secs: BTreeMap::new(),
        }
    }

    pub fn toy() -> MockBackend {
        MockBackend::new(toy_spec())
    }
}

impl Backend for MockBackend {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn train_step(
        &mut self,
        bucket: usize,
        params: &Params,
        global: &Params,
        x: &[f32],
        _y: &[i32],
        skeleton: &[Vec<i32>],
        lr: f32,
        mu: f32,
    ) -> Result<StepOut> {
        self.calls += 1;
        self.trained_skeletons.push((bucket, skeleton.to_vec()));

        let mut channelwise = vec![None; self.spec.params.len()];
        for (li, p) in self.spec.prunable.iter().enumerate() {
            channelwise[p.weight_param] = Some(li);
            channelwise[p.bias_param] = Some(li);
        }

        let mut new_params = params.clone();
        for (pi, t) in new_params.iter_mut().enumerate() {
            match channelwise[pi] {
                None => {
                    for (v, g) in t.data_mut().iter_mut().zip(global[pi].data()) {
                        *v += lr + lr * mu * (g - *v);
                    }
                }
                Some(li) => {
                    let channels = self.spec.prunable[li].channels;
                    let rows = t.len() / channels;
                    let g = global[pi].data();
                    let d = t.data_mut();
                    for &c in &skeleton[li] {
                        let c = c as usize;
                        for r in 0..rows {
                            let i = r * channels + c;
                            d[i] += lr + lr * mu * (g[i] - d[i]);
                        }
                    }
                }
            }
        }

        let mean_abs_x = x.iter().map(|v| v.abs()).sum::<f32>() / x.len().max(1) as f32;
        let importance: Vec<Vec<f32>> = self
            .spec
            .prunable
            .iter()
            .enumerate()
            .map(|(li, p)| {
                (0..p.channels)
                    .map(|c| mean_abs_x * (c + 1) as f32 * (li + 1) as f32)
                    .collect()
            })
            .collect();

        Ok(StepOut {
            params: new_params,
            loss: 1.0 / (1.0 + self.calls as f32),
            importance,
        })
    }

    fn eval_logits(&mut self, _params: &Params, x: &[f32]) -> Result<Tensor> {
        self.eval_calls += 1;
        let b = self.spec.eval_batch;
        let classes = self.spec.num_classes;
        let per = x.len() / b;
        let mut logits = vec![0.0f32; b * classes];
        for i in 0..b {
            let s: f32 = x[i * per..(i + 1) * per].iter().sum();
            let vote = (s.round().abs() as usize) % classes;
            logits[i * classes + vote] = 1.0;
        }
        Tensor::from_vec(&[b, classes], logits)
    }

    fn batch_time_secs(&mut self, bucket: usize) -> Result<f64> {
        Ok(*self
            .batch_secs
            .get(&bucket)
            .unwrap_or(&(bucket as f64 / 100.0 * 0.08)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_params;

    #[test]
    fn toy_spec_valid() {
        let s = toy_spec();
        assert_eq!(s.train_buckets(), vec![25, 50, 100]);
        assert_eq!(s.skel_sizes(25), vec![1]);
        assert_eq!(s.train_artifact(50).unwrap().k, vec![2]);
    }

    #[test]
    fn mock_train_touches_only_skeleton() {
        let mut b = MockBackend::toy();
        let spec = b.spec().clone();
        let p = init_params(&spec, 0);
        let x = vec![1.0f32; 2 * 4];
        let y = vec![0i32; 2];
        let out = b
            .train_step(50, &p, &p, &x, &y, &[vec![1, 3]], 0.1, 0.0)
            .unwrap();
        let dw = out.params[0].sub(&p[0]).unwrap();
        for r in 0..8 {
            for c in 0..4 {
                let v = dw.data()[r * 4 + c];
                if c == 1 || c == 3 {
                    assert!((v - 0.1).abs() < 1e-6);
                } else {
                    assert_eq!(v, 0.0);
                }
            }
        }
        // head moved
        let dh = out.params[2].sub(&p[2]).unwrap();
        assert!(dh.data().iter().all(|&v| (v - 0.1).abs() < 1e-6));
    }

    #[test]
    fn mock_loss_decreases_and_importance_ordered() {
        let mut b = MockBackend::toy();
        let spec = b.spec().clone();
        let p = init_params(&spec, 0);
        let x = vec![1.0f32; 8];
        let y = vec![0i32; 2];
        let o1 = b.train_step(100, &p, &p, &x, &y, &[vec![0, 1, 2, 3]], 0.1, 0.0).unwrap();
        let o2 = b.train_step(100, &p, &p, &x, &y, &[vec![0, 1, 2, 3]], 0.1, 0.0).unwrap();
        assert!(o2.loss < o1.loss);
        let imp = &o1.importance[0];
        assert!(imp.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn mock_eval_shape() {
        let mut b = MockBackend::toy();
        let spec = b.spec().clone();
        let p = init_params(&spec, 0);
        let x = vec![0.6f32; 4 * 4];
        let l = b.eval_logits(&p, &x).unwrap();
        assert_eq!(l.shape(), &[4, 3]);
    }
}
