//! Dependency-free CPU training backend with skeleton-sliced kernels.
//!
//! [`NativeBackend`] is a full [`Backend`] implementation — real forward,
//! real softmax cross-entropy loss, real backward — built on
//! [`crate::kernels`] (im2col conv + cache-blocked GEMM). Its backward
//! pass is *sliced by the skeleton channel indices*: per prunable layer,
//! weight/bias gradients are computed only for the k selected output
//! channels and gradient back-propagation flows only through those
//! channels (`dW_s = Aᵀ·dZ_s`, `dA = dZ_s·W_sᵀ` — the same lowering as
//! `python/compile/kernels/skeleton_bwd.py`), so backward FLOPs scale
//! with k/C exactly as FedSkel §3.2 claims. Non-skeleton channels get no
//! gradient compute at all and their parameters stay bit-identical.
//!
//! Unlike [`MockBackend`](crate::runtime::mock::MockBackend) (fake
//! arithmetic, for coordinator-logic tests) and the `pjrt` runtime (real
//! but needs the vendored `xla` toolchain), this backend runs the paper's
//! Table-1 experiment in a default `cargo build` — see
//! `benches/hotpath.rs` and [`crate::bench::table1_native`].
//!
//! Channel importance (paper Eq. 2) is gradient-based: for channel `c`,
//! `M_c = mean |a_c ⊙ ∂L/∂z_c|` over the batch and spatial positions —
//! the first-order Taylor saliency of zeroing the channel, which reduces
//! to the activation-magnitude metric when gradients are uniform.
//!
//! Every kernel call runs under the model's [`Parallelism`] budget (a
//! simulated client's core count, see [`crate::kernels::parallel`]):
//! results are bitwise identical at any thread count, so the budget only
//! moves wall-clock — the axis Fig. 5's heterogeneous fleet varies.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::kernels::{
    maxpool2_bwd, pgemm, pgemm_int8, pim2col, pmaxpool2_fwd, relu, relu_bwd, scatter_cols_add,
    sliced_backward, Conv2d, KernelTier, Parallelism, Precision,
};
use crate::model::spec::{skel_k, ArtifactSpec, ModelSpec, ParamSpec, PrunableSpec};
use crate::model::Params;
use crate::prof;
use crate::runtime::step::{Backend, StepOut};
use crate::tensor::Tensor;
use crate::util::timer::Timer;

/// One layer of a native model. `w`/`b` index the flat param list;
/// `prunable` indexes `spec.prunable` for skeleton layers.
#[derive(Debug, Clone)]
pub enum Layer {
    /// im2col conv (stride 1, valid), always ReLU, optional 2×2 max pool.
    Conv { conv: Conv2d, w: usize, b: usize, prunable: Option<usize>, pool: bool },
    /// Dense `z = a·W + b`, optional ReLU.
    Dense { in_dim: usize, out_dim: usize, w: usize, b: usize, prunable: Option<usize>, relu: bool },
}

/// A CNN architecture the native kernels can execute, plus the
/// [`ModelSpec`] the coordinator programs against (same spec shape the
/// AOT manifest would carry; artifact entries are synthetic `native://`
/// markers holding the per-bucket skeleton sizes).
#[derive(Debug, Clone)]
pub struct NativeModel {
    pub spec: ModelSpec,
    pub layers: Vec<Layer>,
    /// Compute-thread budget every kernel call runs under. Results are
    /// bitwise independent of it (see `kernels::parallel`); it only
    /// changes wall-clock — which is exactly what the heterogeneity
    /// simulation varies per client.
    par: Parallelism,
    /// Forward-pass arithmetic. [`Precision::Int8`] routes the conv/dense
    /// forward GEMMs through [`pgemm_int8`] (quantized `i8×i8→i32`, then
    /// dequantized); backward always runs f32 on the traced activations.
    /// Unlike `par`, this *does* change results — int8 is an
    /// approximation, so eval stays f32 (see [`NativeBackend`]).
    precision: Precision,
}

/// Cached forward intermediates for one batch — everything backward needs.
pub struct Trace {
    batch: usize,
    /// Per-layer final output (post-ReLU, post-pool).
    outs: Vec<Vec<f32>>,
    /// Conv layers: the im2col patch matrix (reused by both backward GEMMs).
    patches: Vec<Vec<f32>>,
    /// Conv layers with pool: post-ReLU pre-pool activation.
    prepool: Vec<Vec<f32>>,
    /// Conv layers with pool: winning input index per pooled element.
    argmax: Vec<Vec<u32>>,
}

impl Trace {
    pub fn logits(&self) -> &[f32] {
        self.outs.last().expect("model has layers")
    }

    /// Final output of layer `li` (post-ReLU, post-pool).
    pub fn layer_output(&self, li: usize) -> &[f32] {
        &self.outs[li]
    }

    pub fn batch(&self) -> usize {
        self.batch
    }
}

/// Identity-prefix skeleton (`[0, k)` per layer) — what the benches and
/// timing probes use when channel choice doesn't matter. Same
/// construction as [`crate::skeleton::identity_skeleton`], applied to
/// skeleton *sizes* rather than full channel counts.
pub fn prefix_skeleton(ks: &[usize]) -> Vec<Vec<i32>> {
    crate::skeleton::identity_skeleton(ks)
}

#[allow(clippy::too_many_arguments)]
fn make_spec(
    name: &str,
    input_shape: Vec<usize>,
    num_classes: usize,
    train_batch: usize,
    eval_batch: usize,
    params: Vec<ParamSpec>,
    prunable: Vec<PrunableSpec>,
    buckets: &[usize],
) -> ModelSpec {
    let mut artifacts = BTreeMap::new();
    for &bkt in buckets {
        let k: Vec<usize> = prunable.iter().map(|p| skel_k(p.channels, bkt)).collect();
        artifacts.insert(
            format!("train_r{bkt}"),
            ArtifactSpec {
                kind: "train".into(),
                file: format!("native://{name}/train_r{bkt}"),
                ratio: Some(bkt),
                batch: train_batch,
                k,
                inputs: vec![],
                outputs: vec![],
            },
        );
    }
    artifacts.insert(
        "eval".into(),
        ArtifactSpec {
            kind: "eval".into(),
            file: format!("native://{name}/eval"),
            ratio: None,
            batch: eval_batch,
            k: vec![],
            inputs: vec![],
            outputs: vec![],
        },
    );
    let num_params = params.iter().map(|p| p.numel()).sum();
    ModelSpec {
        name: name.into(),
        input_shape,
        num_classes,
        train_batch,
        eval_batch,
        num_params,
        params,
        prunable,
        artifacts,
    }
}

fn conv_params(name: &str, c: &Conv2d) -> [ParamSpec; 2] {
    [
        ParamSpec {
            name: format!("{name}.w"),
            shape: vec![c.kh, c.kw, c.cin, c.cout],
            init: "he".into(),
        },
        ParamSpec { name: format!("{name}.b"), shape: vec![c.cout], init: "zeros".into() },
    ]
}

fn dense_params(name: &str, in_dim: usize, out_dim: usize, init: &str) -> [ParamSpec; 2] {
    [
        ParamSpec { name: format!("{name}.w"), shape: vec![in_dim, out_dim], init: init.into() },
        ParamSpec { name: format!("{name}.b"), shape: vec![out_dim], init: "zeros".into() },
    ]
}

impl NativeModel {
    /// Build a custom model from explicit layers — test/bench harnesses
    /// that need specific geometry (e.g. a pool-free smooth net for
    /// finite-difference checks). `params`/`prunable` must be consistent
    /// with `layers`' param indices.
    #[allow(clippy::too_many_arguments)]
    pub fn custom(
        name: &str,
        input_shape: Vec<usize>,
        num_classes: usize,
        train_batch: usize,
        eval_batch: usize,
        params: Vec<ParamSpec>,
        prunable: Vec<PrunableSpec>,
        buckets: &[usize],
        layers: Vec<Layer>,
    ) -> NativeModel {
        let spec = make_spec(
            name,
            input_shape,
            num_classes,
            train_batch,
            eval_batch,
            params,
            prunable,
            buckets,
        );
        NativeModel { spec, layers, par: Parallelism::serial(), precision: Precision::F32 }
    }

    /// LeNet-5 on 28×28×1 / 10 classes — the paper's Table-1 workload.
    /// Prunable: conv1(6), conv2(16), fc1(120), fc2(84); fc3 is the head.
    pub fn lenet() -> NativeModel {
        let c1 = Conv2d { in_h: 28, in_w: 28, cin: 1, cout: 6, kh: 5, kw: 5 }; // →24², pool→12²
        let c2 = Conv2d { in_h: 12, in_w: 12, cin: 6, cout: 16, kh: 5, kw: 5 }; // →8², pool→4²
        let mut params = Vec::new();
        params.extend(conv_params("conv1", &c1));
        params.extend(conv_params("conv2", &c2));
        params.extend(dense_params("fc1", 256, 120, "he"));
        params.extend(dense_params("fc2", 120, 84, "he"));
        params.extend(dense_params("fc3", 84, 10, "glorot"));
        let prunable = vec![
            PrunableSpec { name: "conv1".into(), channels: 6, weight_param: 0, bias_param: 1 },
            PrunableSpec { name: "conv2".into(), channels: 16, weight_param: 2, bias_param: 3 },
            PrunableSpec { name: "fc1".into(), channels: 120, weight_param: 4, bias_param: 5 },
            PrunableSpec { name: "fc2".into(), channels: 84, weight_param: 6, bias_param: 7 },
        ];
        let spec = make_spec(
            "lenet_native",
            vec![28, 28, 1],
            10,
            32,
            64,
            params,
            prunable,
            &[10, 25, 40, 50, 100],
        );
        let layers = vec![
            Layer::Conv { conv: c1, w: 0, b: 1, prunable: Some(0), pool: true },
            Layer::Conv { conv: c2, w: 2, b: 3, prunable: Some(1), pool: true },
            Layer::Dense { in_dim: 256, out_dim: 120, w: 4, b: 5, prunable: Some(2), relu: true },
            Layer::Dense { in_dim: 120, out_dim: 84, w: 6, b: 7, prunable: Some(3), relu: true },
            Layer::Dense { in_dim: 84, out_dim: 10, w: 8, b: 9, prunable: None, relu: false },
        ];
        NativeModel { spec, layers, par: Parallelism::serial(), precision: Precision::F32 }
    }

    /// Small single-prunable-layer CNN on 28×28×1 / 10 classes — fast
    /// enough for coordinator integration tests on real compute.
    pub fn tiny() -> NativeModel {
        let c1 = Conv2d { in_h: 28, in_w: 28, cin: 1, cout: 4, kh: 5, kw: 5 }; // →24², pool→12²
        let mut params = Vec::new();
        params.extend(conv_params("conv1", &c1));
        params.extend(dense_params("head", 576, 10, "glorot"));
        let prunable = vec![PrunableSpec {
            name: "conv1".into(),
            channels: 4,
            weight_param: 0,
            bias_param: 1,
        }];
        let spec = make_spec(
            "tiny_native",
            vec![28, 28, 1],
            10,
            4,
            8,
            params,
            prunable,
            &[25, 50, 100],
        );
        let layers = vec![
            Layer::Conv { conv: c1, w: 0, b: 1, prunable: Some(0), pool: true },
            Layer::Dense { in_dim: 576, out_dim: 10, w: 2, b: 3, prunable: None, relu: false },
        ];
        NativeModel { spec, layers, par: Parallelism::serial(), precision: Precision::F32 }
    }

    /// Micro conv+dense net on 8×8×1 / 3 classes (~250 params) — sized so
    /// a per-parameter finite-difference gradient check is instant.
    pub fn micro() -> NativeModel {
        let c1 = Conv2d { in_h: 8, in_w: 8, cin: 1, cout: 3, kh: 3, kw: 3 }; // →6², pool→3²
        let mut params = Vec::new();
        params.extend(conv_params("conv1", &c1));
        params.extend(dense_params("fc1", 27, 6, "he"));
        params.extend(dense_params("head", 6, 3, "glorot"));
        let prunable = vec![
            PrunableSpec { name: "conv1".into(), channels: 3, weight_param: 0, bias_param: 1 },
            PrunableSpec { name: "fc1".into(), channels: 6, weight_param: 2, bias_param: 3 },
        ];
        let spec = make_spec(
            "micro_native",
            vec![8, 8, 1],
            3,
            2,
            2,
            params,
            prunable,
            &[50, 100],
        );
        let layers = vec![
            Layer::Conv { conv: c1, w: 0, b: 1, prunable: Some(0), pool: true },
            Layer::Dense { in_dim: 27, out_dim: 6, w: 2, b: 3, prunable: Some(1), relu: true },
            Layer::Dense { in_dim: 6, out_dim: 3, w: 4, b: 5, prunable: None, relu: false },
        ];
        NativeModel { spec, layers, par: Parallelism::serial(), precision: Precision::F32 }
    }

    /// CIFAR-scale conv net on 32×32×3 / 10 classes — realistic channel
    /// widths (32/64 conv channels, a 1600→256 dense layer) so the
    /// kernel tiers and the skeleton-slicing FLOPs claim are measured
    /// where panel packing and register blocking actually pay off.
    /// Prunable: conv1(32), conv2(64), fc1(256); the head is full-width.
    pub fn cifar() -> NativeModel {
        let c1 = Conv2d { in_h: 32, in_w: 32, cin: 3, cout: 32, kh: 5, kw: 5 }; // →28², pool→14²
        let c2 = Conv2d { in_h: 14, in_w: 14, cin: 32, cout: 64, kh: 5, kw: 5 }; // →10², pool→5²
        let mut params = Vec::new();
        params.extend(conv_params("conv1", &c1));
        params.extend(conv_params("conv2", &c2));
        params.extend(dense_params("fc1", 1600, 256, "he"));
        params.extend(dense_params("head", 256, 10, "glorot"));
        let prunable = vec![
            PrunableSpec { name: "conv1".into(), channels: 32, weight_param: 0, bias_param: 1 },
            PrunableSpec { name: "conv2".into(), channels: 64, weight_param: 2, bias_param: 3 },
            PrunableSpec { name: "fc1".into(), channels: 256, weight_param: 4, bias_param: 5 },
        ];
        let spec = make_spec(
            "cifar_native",
            vec![32, 32, 3],
            10,
            32,
            64,
            params,
            prunable,
            &[10, 25, 50, 100],
        );
        let layers = vec![
            Layer::Conv { conv: c1, w: 0, b: 1, prunable: Some(0), pool: true },
            Layer::Conv { conv: c2, w: 2, b: 3, prunable: Some(1), pool: true },
            Layer::Dense { in_dim: 1600, out_dim: 256, w: 4, b: 5, prunable: Some(2), relu: true },
            Layer::Dense { in_dim: 256, out_dim: 10, w: 6, b: 7, prunable: None, relu: false },
        ];
        NativeModel { spec, layers, par: Parallelism::serial(), precision: Precision::F32 }
    }

    /// Builder form of [`NativeModel::set_parallelism`].
    pub fn with_parallelism(mut self, par: Parallelism) -> NativeModel {
        self.par = par;
        self
    }

    /// Set the compute-thread budget for every subsequent kernel call.
    /// Never changes results (bitwise), only wall-clock.
    pub fn set_parallelism(&mut self, par: Parallelism) {
        self.par = par;
    }

    pub fn parallelism(&self) -> Parallelism {
        self.par
    }

    /// Builder form of [`NativeModel::set_precision`].
    pub fn with_precision(mut self, precision: Precision) -> NativeModel {
        self.precision = precision;
        self
    }

    /// Set the forward-pass arithmetic. Unlike the thread budget this
    /// changes results: int8 approximates the f32 forward.
    pub fn set_precision(&mut self, precision: Precision) {
        self.precision = precision;
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    fn validate_params(&self, params: &Params) -> Result<()> {
        if params.len() != self.spec.params.len() {
            bail!("got {} params, spec wants {}", params.len(), self.spec.params.len());
        }
        for (t, p) in params.iter().zip(&self.spec.params) {
            if t.len() != p.numel() {
                bail!("param {} has {} elems, spec wants {}", p.name, t.len(), p.numel());
            }
        }
        Ok(())
    }

    fn validate_skeleton(&self, skeleton: &[Vec<i32>]) -> Result<()> {
        if skeleton.len() != self.spec.prunable.len() {
            bail!("skeleton has {} layers, model has {}", skeleton.len(), self.spec.prunable.len());
        }
        for (s, p) in skeleton.iter().zip(&self.spec.prunable) {
            if s.iter().any(|&c| c < 0 || c as usize >= p.channels) {
                bail!("skeleton index out of range for layer {} ({} channels)", p.name, p.channels);
            }
        }
        Ok(())
    }

    /// Full forward pass, caching every intermediate backward needs.
    pub fn forward(&self, params: &Params, x: &[f32], batch: usize) -> Result<Trace> {
        let _span = prof::scope("forward");
        self.validate_params(params)?;
        let numel: usize = self.spec.input_shape.iter().product();
        if x.len() != batch * numel {
            bail!("x has {} elems, want {} (batch {batch})", x.len(), batch * numel);
        }
        let n = self.layers.len();
        let mut trace = Trace {
            batch,
            outs: Vec::with_capacity(n),
            patches: vec![Vec::new(); n],
            prepool: vec![Vec::new(); n],
            argmax: vec![Vec::new(); n],
        };
        for (li, layer) in self.layers.iter().enumerate() {
            let input: &[f32] = if li == 0 { x } else { &trace.outs[li - 1] };
            match layer {
                Layer::Conv { conv, w, b, pool, .. } => {
                    let m = conv.rows(batch);
                    let mut patches = vec![0.0f32; m * conv.patch_len()];
                    pim2col(self.par, conv, batch, input, &mut patches);
                    let mut z = vec![0.0f32; m * conv.cout];
                    match self.precision {
                        Precision::F32 => conv.forward_par(
                            self.par,
                            batch,
                            &patches,
                            params[*w].data(),
                            params[*b].data(),
                            &mut z,
                        ),
                        Precision::Int8 => pgemm_int8(
                            self.par,
                            m,
                            conv.patch_len(),
                            conv.cout,
                            &patches,
                            params[*w].data(),
                            params[*b].data(),
                            &mut z,
                        ),
                    }
                    relu(&mut z);
                    trace.patches[li] = patches;
                    if *pool {
                        let (oh, ow) = (conv.out_h(), conv.out_w());
                        let mut pooled = vec![0.0f32; batch * (oh / 2) * (ow / 2) * conv.cout];
                        let mut am = vec![0u32; pooled.len()];
                        pmaxpool2_fwd(self.par, batch, oh, ow, conv.cout, &z, &mut pooled, &mut am);
                        trace.prepool[li] = z;
                        trace.argmax[li] = am;
                        trace.outs.push(pooled);
                    } else {
                        trace.outs.push(z);
                    }
                }
                Layer::Dense { in_dim, out_dim, w, b, relu: act, .. } => {
                    if input.len() != batch * in_dim {
                        bail!("layer {li}: input {} != batch·{in_dim}", input.len());
                    }
                    let mut z = vec![0.0f32; batch * out_dim];
                    let bias = params[*b].data();
                    match self.precision {
                        Precision::F32 => {
                            for chunk in z.chunks_exact_mut(*out_dim) {
                                chunk.copy_from_slice(bias);
                            }
                            pgemm(
                                self.par,
                                batch,
                                *in_dim,
                                *out_dim,
                                input,
                                params[*w].data(),
                                &mut z,
                            );
                        }
                        Precision::Int8 => pgemm_int8(
                            self.par,
                            batch,
                            *in_dim,
                            *out_dim,
                            input,
                            params[*w].data(),
                            bias,
                            &mut z,
                        ),
                    }
                    if *act {
                        relu(&mut z);
                    }
                    trace.outs.push(z);
                }
            }
        }
        Ok(trace)
    }

    /// Mean softmax cross-entropy over the batch and its gradient w.r.t.
    /// the logits. Loss accumulates in f64 so finite-difference gradient
    /// checks aren't noise-limited by the reduction.
    pub fn loss_grad(&self, trace: &Trace, y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let _span = prof::scope("loss");
        let (b, c) = (trace.batch, self.spec.num_classes);
        if y.len() != b {
            bail!("y has {} labels, batch is {b}", y.len());
        }
        let logits = trace.logits();
        let mut dlogits = vec![0.0f32; b * c];
        let mut loss = 0.0f64;
        for i in 0..b {
            let row = &logits[i * c..(i + 1) * c];
            let label = y[i] as usize;
            if label >= c {
                bail!("label {label} out of range ({c} classes)");
            }
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f64;
            for &v in row {
                denom += ((v - max) as f64).exp();
            }
            loss += denom.ln() - (row[label] - max) as f64;
            let drow = &mut dlogits[i * c..(i + 1) * c];
            for (j, (d, &v)) in drow.iter_mut().zip(row).enumerate() {
                let p = (((v - max) as f64).exp() / denom) as f32;
                *d = (p - if j == label { 1.0 } else { 0.0 }) / b as f32;
            }
        }
        Ok(((loss / b as f64) as f32, dlogits))
    }

    /// Skeleton-sliced backward from `dlogits`. Returns per-parameter
    /// gradients (zeros outside the skeleton channels) and per-prunable-
    /// layer channel importance (Eq. 2; zeros outside the skeleton).
    ///
    /// The input gradient of the first layer is never computed, and per
    /// prunable layer only the `skeleton[l]` channels get gradient work.
    pub fn backward(
        &self,
        x: &[f32],
        params: &Params,
        trace: &Trace,
        dlogits: &[f32],
        skeleton: &[Vec<i32>],
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        self.validate_params(params)?;
        self.validate_skeleton(skeleton)?;
        // Span name distinguishes the paper's skeleton-sliced backward
        // (gradient work ∝ k/C) from a full-skeleton round.
        let sliced = skeleton
            .iter()
            .zip(&self.spec.prunable)
            .any(|(s, p)| s.len() < p.channels);
        let _span = prof::scope(if sliced { "backward:sliced" } else { "backward:full" });
        let batch = trace.batch;
        let mut grads: Vec<Vec<f32>> =
            self.spec.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        let mut imps: Vec<Vec<f32>> =
            self.spec.prunable.iter().map(|p| vec![0.0f32; p.channels]).collect();
        let mut g = dlogits.to_vec();
        let (mut dz_s, mut w_t) = (Vec::new(), Vec::new());
        for (li, layer) in self.layers.iter().enumerate().rev() {
            match layer {
                Layer::Conv { conv, w, b, prunable, pool } => {
                    let m = conv.rows(batch);
                    let k = conv.patch_len();
                    // gradient w.r.t. the pre-pool, post-ReLU activation
                    let (mut dz, act): (Vec<f32>, &[f32]) = if *pool {
                        let mut dact = vec![0.0f32; m * conv.cout];
                        maxpool2_bwd(&g, &trace.argmax[li], &mut dact);
                        (dact, &trace.prepool[li])
                    } else {
                        (std::mem::take(&mut g), &trace.outs[li])
                    };
                    relu_bwd(act, &mut dz);
                    let full; // identity indices for non-prunable conv
                    let idx: &[i32] = match prunable {
                        Some(pi) => &skeleton[*pi],
                        None => {
                            full = (0..conv.cout as i32).collect::<Vec<i32>>();
                            &full
                        }
                    };
                    let ks = idx.len();
                    let mut dw_t = vec![0.0f32; ks * k];
                    let mut db_s = vec![0.0f32; ks];
                    let mut da_patches =
                        if li > 0 { Some(vec![0.0f32; m * k]) } else { None };
                    sliced_backward(
                        self.par,
                        m,
                        k,
                        conv.cout,
                        &dz,
                        &trace.patches[li],
                        params[*w].data(),
                        idx,
                        &mut dz_s,
                        &mut w_t,
                        &mut dw_t,
                        &mut db_s,
                        da_patches.as_deref_mut(),
                    );
                    if let Some(pi) = prunable {
                        channel_importance(act, &dz_s, conv.cout, idx, &mut imps[*pi]);
                    }
                    scatter_cols_add(k, conv.cout, &dw_t, idx, &mut grads[*w]);
                    for (j, &c) in idx.iter().enumerate() {
                        grads[*b][c as usize] += db_s[j];
                    }
                    if let Some(dap) = da_patches {
                        let prev_len = if li == 0 { 0 } else { trace.outs[li - 1].len() };
                        let mut dprev = vec![0.0f32; prev_len];
                        conv.col2im_add(batch, &dap, &mut dprev);
                        g = dprev;
                    }
                }
                Layer::Dense { in_dim, out_dim, w, b, prunable, relu: act } => {
                    let input: &[f32] = if li == 0 { x } else { &trace.outs[li - 1] };
                    let mut dz = std::mem::take(&mut g);
                    if *act {
                        relu_bwd(&trace.outs[li], &mut dz);
                    }
                    let full;
                    let idx: &[i32] = match prunable {
                        Some(pi) => &skeleton[*pi],
                        None => {
                            full = (0..*out_dim as i32).collect::<Vec<i32>>();
                            &full
                        }
                    };
                    let ks = idx.len();
                    let mut dw_t = vec![0.0f32; ks * in_dim];
                    let mut db_s = vec![0.0f32; ks];
                    let mut da = if li > 0 { Some(vec![0.0f32; batch * in_dim]) } else { None };
                    sliced_backward(
                        self.par,
                        batch,
                        *in_dim,
                        *out_dim,
                        &dz,
                        input,
                        params[*w].data(),
                        idx,
                        &mut dz_s,
                        &mut w_t,
                        &mut dw_t,
                        &mut db_s,
                        da.as_deref_mut(),
                    );
                    if let Some(pi) = prunable {
                        channel_importance(&trace.outs[li], &dz_s, *out_dim, idx, &mut imps[*pi]);
                    }
                    scatter_cols_add(*in_dim, *out_dim, &dw_t, idx, &mut grads[*w]);
                    for (j, &c) in idx.iter().enumerate() {
                        grads[*b][c as usize] += db_s[j];
                    }
                    if let Some(da) = da {
                        g = da;
                    }
                }
            }
        }
        Ok((grads, imps))
    }

    /// GEMM FLOPs of one skeleton-sliced backward pass at `batch` (the
    /// compute-bound Table-1 prediction; gathers/pool/ReLU excluded).
    pub fn backward_gemm_flops(&self, batch: usize, skeleton: &[Vec<i32>]) -> f64 {
        let mut total = 0.0;
        for (li, layer) in self.layers.iter().enumerate() {
            let (m, k, cout, prunable) = match layer {
                Layer::Conv { conv, prunable, .. } => {
                    (conv.rows(batch), conv.patch_len(), conv.cout, prunable)
                }
                Layer::Dense { in_dim, out_dim, prunable, .. } => {
                    (batch, *in_dim, *out_dim, prunable)
                }
            };
            let ks = match prunable {
                Some(pi) => skeleton[*pi].len(),
                None => cout,
            };
            // dW GEMM, plus the dA GEMM for every layer but the first
            let gemms = if li == 0 { 1.0 } else { 2.0 };
            total += gemms * 2.0 * (m * k * ks) as f64;
        }
        total
    }

    /// SGD with optional FedProx pull: for every updated entry,
    /// `p ← p − lr·(grad + mu·(p − anchor))`. Prunable tensors update only
    /// their skeleton channels; everything else updates fully.
    pub fn apply_sgd(
        &self,
        params: &mut Params,
        anchor: &Params,
        grads: &[Vec<f32>],
        skeleton: &[Vec<i32>],
        lr: f32,
        mu: f32,
    ) -> Result<()> {
        if anchor.len() != params.len() || grads.len() != params.len() {
            bail!("param/grad count mismatch");
        }
        let _span = prof::scope("sgd_step");
        let mut channelwise: Vec<Option<usize>> = vec![None; params.len()];
        for (li, p) in self.spec.prunable.iter().enumerate() {
            channelwise[p.weight_param] = Some(li);
            channelwise[p.bias_param] = Some(li);
        }
        for (pi, t) in params.iter_mut().enumerate() {
            let d = t.data_mut();
            let a = anchor[pi].data();
            let gr = &grads[pi];
            match channelwise[pi] {
                None => {
                    for ((v, &g), &av) in d.iter_mut().zip(gr).zip(a) {
                        *v -= lr * (g + mu * (*v - av));
                    }
                }
                Some(li) => {
                    let channels = self.spec.prunable[li].channels;
                    let rows = d.len() / channels;
                    for &c in &skeleton[li] {
                        let c = c as usize;
                        for r in 0..rows {
                            let i = r * channels + c;
                            d[i] -= lr * (gr[i] + mu * (d[i] - a[i]));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Eq. 2 channel importance from gathered gradients: for skeleton slot
/// `j` (channel `idx[j]`), the mean over rows of `|act[·,c] · dz_s[·,j]|`.
fn channel_importance(act: &[f32], dz_s: &[f32], cout: usize, idx: &[i32], imp: &mut [f32]) {
    let ks = idx.len();
    if ks == 0 {
        return;
    }
    let m = dz_s.len() / ks;
    for (j, &c) in idx.iter().enumerate() {
        let c = c as usize;
        let mut s = 0.0f64;
        for row in 0..m {
            s += (act[row * cout + c] * dz_s[row * ks + j]).abs() as f64;
        }
        imp[c] = (s / m.max(1) as f64) as f32;
    }
}

/// The native CPU [`Backend`].
pub struct NativeBackend {
    model: NativeModel,
    /// Measured batch seconds, keyed by `(bucket, threads, tier,
    /// precision)` — the same bucket times differently under different
    /// core budgets, kernel tiers, and precisions, and that difference is
    /// what makes straggler behaviour emergent. Keying on all four axes
    /// means switching tier or precision mid-run can never serve a stale
    /// timing.
    timing_cache: BTreeMap<(usize, usize, KernelTier, Precision), f64>,
    /// Optional deterministic `bucket → seconds` override for
    /// [`Backend::batch_time_secs`]. When a bucket is present here the
    /// virtual-clock scheduler sees this exact figure instead of a host
    /// measurement — the sched bench pins policy makespans with it so
    /// they reproduce on noisy CI hosts. Training still executes for
    /// real; only the *simulated* clock is fixed.
    fixed_batch_secs: BTreeMap<usize, f64>,
    /// repetitions when measuring batch time
    pub timing_reps: usize,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> NativeBackend {
        NativeBackend {
            model,
            timing_cache: BTreeMap::new(),
            fixed_batch_secs: BTreeMap::new(),
            timing_reps: 3,
        }
    }

    /// Builder form of [`Backend::set_parallelism`].
    pub fn with_parallelism(mut self, par: Parallelism) -> NativeBackend {
        self.model.set_parallelism(par);
        self
    }

    /// Pin `bucket → simulated seconds` instead of measuring those
    /// buckets on the host (un-pinned buckets still measure).
    pub fn with_fixed_batch_secs(mut self, secs: BTreeMap<usize, f64>) -> NativeBackend {
        self.fixed_batch_secs = secs;
        self
    }

    /// LeNet-5 (the Table-1 workload).
    pub fn lenet() -> NativeBackend {
        NativeBackend::new(NativeModel::lenet())
    }

    /// Small single-prunable-layer net for integration tests.
    pub fn tiny() -> NativeBackend {
        NativeBackend::new(NativeModel::tiny())
    }

    /// Micro net for gradient checks.
    pub fn micro() -> NativeBackend {
        NativeBackend::new(NativeModel::micro())
    }

    /// CIFAR-scale conv net (the kernel-tier bench workload).
    pub fn cifar() -> NativeBackend {
        NativeBackend::new(NativeModel::cifar())
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }
}

impl Backend for NativeBackend {
    fn spec(&self) -> &ModelSpec {
        &self.model.spec
    }

    fn train_step(
        &mut self,
        bucket: usize,
        params: &Params,
        global: &Params,
        x: &[f32],
        y: &[i32],
        skeleton: &[Vec<i32>],
        lr: f32,
        mu: f32,
    ) -> Result<StepOut> {
        let _span = prof::scope("train_step");
        let ks = &self.model.spec.train_artifact(bucket)?.k;
        if skeleton.len() != ks.len() {
            bail!("skeleton layer count {} != {}", skeleton.len(), ks.len());
        }
        for (li, (s, &k)) in skeleton.iter().zip(ks).enumerate() {
            if s.len() != k {
                bail!("skeleton layer {li} has {} indices, bucket r{bucket} wants {k}", s.len());
            }
        }
        let batch = self.model.spec.train_batch;
        let trace = self.model.forward(params, x, batch)?;
        let (loss, dlogits) = self.model.loss_grad(&trace, y)?;
        let (grads, importance) = self.model.backward(x, params, &trace, &dlogits, skeleton)?;
        let mut new_params = {
            let _span = prof::scope("clone_params");
            params.clone()
        };
        self.model.apply_sgd(&mut new_params, global, &grads, skeleton, lr, mu)?;
        Ok(StepOut { params: new_params, loss, importance })
    }

    fn eval_logits(&mut self, params: &Params, x: &[f32]) -> Result<Tensor> {
        // Server-side eval is always f32 regardless of the client
        // training precision: accuracy comparisons across a mixed-
        // precision fleet must measure the *model*, not the cheap
        // forward approximation a weak device trains with.
        let prev = self.model.precision();
        self.model.set_precision(Precision::F32);
        let b = self.model.spec.eval_batch;
        let trace = self.model.forward(params, x, b);
        self.model.set_precision(prev);
        let trace = trace?;
        Tensor::from_vec(&[b, self.model.spec.num_classes], trace.logits().to_vec())
    }

    fn set_parallelism(&mut self, par: Parallelism) {
        self.model.set_parallelism(par);
    }

    fn parallelism(&self) -> Parallelism {
        self.model.parallelism()
    }

    fn set_precision(&mut self, precision: Precision) {
        self.model.set_precision(precision);
    }

    fn precision(&self) -> Precision {
        self.model.precision()
    }

    fn batch_time_secs(&mut self, bucket: usize) -> Result<f64> {
        if let Some(&t) = self.fixed_batch_secs.get(&bucket) {
            return Ok(t);
        }
        let par = self.model.parallelism();
        let key = (bucket, par.threads(), par.tier(), self.model.precision());
        if let Some(&t) = self.timing_cache.get(&key) {
            return Ok(t);
        }
        let spec = self.model.spec.clone();
        let params = crate::model::init_params(&spec, 1234);
        let numel: usize = spec.input_shape.iter().product();
        let x = vec![0.1f32; spec.train_batch * numel];
        let y: Vec<i32> =
            (0..spec.train_batch).map(|i| (i % spec.num_classes) as i32).collect();
        let skel = prefix_skeleton(&spec.train_artifact(bucket)?.k);
        self.train_step(bucket, &params, &params, &x, &y, &skel, 0.01, 0.0)?; // warmup
        let reps = self.timing_reps;
        let timer = Timer::start();
        for _ in 0..reps {
            self.train_step(bucket, &params, &params, &x, &y, &skel, 0.01, 0.0)?;
        }
        let t = timer.elapsed_secs() / reps as f64;
        self.timing_cache.insert(key, t);
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_params;

    fn batch_data(spec: &ModelSpec, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = crate::util::Rng::new(seed);
        let numel: usize = spec.input_shape.iter().product();
        let x = (0..spec.train_batch * numel).map(|_| rng.normal() * 0.5).collect();
        let y = (0..spec.train_batch).map(|i| (i % spec.num_classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn specs_are_consistent() {
        for model in [
            NativeModel::lenet(),
            NativeModel::tiny(),
            NativeModel::micro(),
            NativeModel::cifar(),
        ] {
            let s = &model.spec;
            assert_eq!(s.num_params, s.params.iter().map(|p| p.numel()).sum::<usize>());
            for p in &s.prunable {
                assert_eq!(*s.params[p.weight_param].shape.last().unwrap(), p.channels);
                assert_eq!(s.params[p.bias_param].shape, vec![p.channels]);
            }
            assert!(s.train_buckets().contains(&100));
            for &bkt in &s.train_buckets() {
                assert_eq!(s.train_artifact(bkt).unwrap().k, s.skel_sizes(bkt));
            }
        }
        assert_eq!(NativeModel::lenet().spec.skel_sizes(25), vec![2, 4, 30, 21]);
        assert_eq!(NativeModel::cifar().spec.skel_sizes(25), vec![8, 16, 64]);
    }

    #[test]
    fn cifar_layer_geometry_chains() {
        // 32²×3 →conv5→ 28²×32 →pool→ 14²×32 →conv5→ 10²×64 →pool→ 5²×64
        // = 1600 → fc1(256) → head(10)
        let mut b = NativeBackend::cifar();
        let spec = b.spec().clone();
        assert_eq!(spec.input_shape, vec![32, 32, 3]);
        let p = init_params(&spec, 11);
        let numel: usize = spec.input_shape.iter().product();
        let x = vec![0.2f32; spec.eval_batch * numel];
        let logits = b.eval_logits(&p, &x).unwrap();
        assert_eq!(logits.shape(), &[64, 10]);
    }

    #[test]
    fn train_step_runs_and_masks_updates() {
        let mut b = NativeBackend::tiny();
        let spec = b.spec().clone();
        let p = init_params(&spec, 3);
        let (x, y) = batch_data(&spec, 4);
        let skel = vec![vec![0i32, 2]]; // bucket 50 → k=2 of 4 channels
        let out = b.train_step(50, &p, &p, &x, &y, &skel, 0.05, 0.0).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        assert_eq!(out.importance.len(), 1);
        assert_eq!(out.importance[0].len(), 4);
        // non-skeleton channels of conv1 are bit-identical
        let (w_new, w_old) = (out.params[0].data(), p[0].data());
        for (i, (a, o)) in w_new.iter().zip(w_old).enumerate() {
            let c = i % 4;
            if c == 1 || c == 3 {
                assert_eq!(a, o, "non-skeleton channel {c} moved");
            }
        }
        // head moved (full update)
        assert!(out.params[2].sub(&p[2]).unwrap().max_abs() > 0.0);
        // wrong skeleton size is rejected
        assert!(b.train_step(50, &p, &p, &x, &y, &[vec![0]], 0.05, 0.0).is_err());
    }

    #[test]
    fn repeated_steps_overfit_one_batch() {
        let mut b = NativeBackend::micro();
        let spec = b.spec().clone();
        let mut p = init_params(&spec, 1);
        let (x, y) = batch_data(&spec, 2);
        let skel = prefix_skeleton(&spec.train_artifact(100).unwrap().k);
        let first = b.train_step(100, &p, &p, &x, &y, &skel, 0.1, 0.0).unwrap().loss;
        let mut last = first;
        for _ in 0..60 {
            let out = b.train_step(100, &p, &p, &x, &y, &skel, 0.1, 0.0).unwrap();
            p = out.params;
            last = out.loss;
        }
        assert!(last < first * 0.8, "loss {first} -> {last} did not drop");
    }

    #[test]
    fn eval_logits_shape_and_determinism() {
        let mut b = NativeBackend::tiny();
        let spec = b.spec().clone();
        let p = init_params(&spec, 9);
        let numel: usize = spec.input_shape.iter().product();
        let x = vec![0.3f32; spec.eval_batch * numel];
        let l1 = b.eval_logits(&p, &x).unwrap();
        let l2 = b.eval_logits(&p, &x).unwrap();
        assert_eq!(l1.shape(), &[8, 10]);
        assert_eq!(l1, l2);
    }

    #[test]
    fn fedprox_pull_moves_toward_anchor() {
        let mut b = NativeBackend::micro();
        let spec = b.spec().clone();
        let p = init_params(&spec, 5);
        let anchor = init_params(&spec, 6);
        let (x, y) = batch_data(&spec, 7);
        let skel = prefix_skeleton(&spec.train_artifact(100).unwrap().k);
        let plain = b.train_step(100, &p, &anchor, &x, &y, &skel, 0.05, 0.0).unwrap();
        let prox = b.train_step(100, &p, &anchor, &x, &y, &skel, 0.05, 2.0).unwrap();
        // the prox step lands strictly closer to the anchor
        let d_plain: f32 = plain.params[0].sub(&anchor[0]).unwrap().norm();
        let d_prox: f32 = prox.params[0].sub(&anchor[0]).unwrap().norm();
        assert!(d_prox < d_plain, "{d_prox} !< {d_plain}");
    }

    #[test]
    fn backward_flops_scale_with_skeleton() {
        let model = NativeModel::lenet();
        let full = prefix_skeleton(&model.spec.skel_sizes(100));
        let quarter = prefix_skeleton(&model.spec.skel_sizes(25));
        let f100 = model.backward_gemm_flops(32, &full);
        let f25 = model.backward_gemm_flops(32, &quarter);
        assert!(f100 > 2.5 * f25, "r100 {f100} vs r25 {f25}");
    }

    #[test]
    fn batch_time_positive_and_cached() {
        let mut b = NativeBackend::micro();
        b.timing_reps = 1;
        let t1 = b.batch_time_secs(100).unwrap();
        let t2 = b.batch_time_secs(100).unwrap();
        assert!(t1 > 0.0);
        assert_eq!(t1, t2);
    }

    #[test]
    fn parallel_train_step_bitwise_matches_serial() {
        let spec = NativeModel::tiny().spec.clone();
        let p = init_params(&spec, 21);
        let (x, y) = batch_data(&spec, 22);
        let skel = vec![vec![0i32, 2]];
        let mut serial = NativeBackend::tiny();
        let a = serial.train_step(50, &p, &p, &x, &y, &skel, 0.05, 0.0).unwrap();
        let mut threaded = NativeBackend::tiny().with_parallelism(Parallelism::new(3));
        let b = threaded.train_step(50, &p, &p, &x, &y, &skel, 0.05, 0.0).unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.importance, b.importance);
    }

    #[test]
    fn fixed_batch_secs_pins_the_simulated_clock() {
        let mut b = NativeBackend::micro()
            .with_fixed_batch_secs([(100usize, 0.5f64)].into_iter().collect());
        b.timing_reps = 1;
        assert_eq!(b.batch_time_secs(100).unwrap(), 0.5);
        // a pinned bucket ignores the thread budget too — it is a
        // simulated figure, not a measurement
        b.set_parallelism(Parallelism::new(2));
        assert_eq!(b.batch_time_secs(100).unwrap(), 0.5);
        // un-pinned buckets still measure
        assert!(b.batch_time_secs(50).unwrap() > 0.0);
    }

    #[test]
    fn batch_time_cache_keys_on_thread_budget() {
        let mut b = NativeBackend::micro();
        b.timing_reps = 1;
        let t1 = b.batch_time_secs(100).unwrap();
        b.set_parallelism(Parallelism::new(2));
        let t2 = b.batch_time_secs(100).unwrap(); // re-measured under the new budget
        assert!(t1 > 0.0 && t2 > 0.0);
        assert_eq!(b.parallelism().threads(), 2);
        b.set_parallelism(Parallelism::serial());
        assert_eq!(b.batch_time_secs(100).unwrap(), t1); // 1-thread entry still cached
    }

    #[test]
    fn batch_time_cache_keys_on_kernel_tier() {
        let mut b = NativeBackend::micro();
        b.timing_reps = 1;
        let t_scalar = b.batch_time_secs(100).unwrap();
        b.set_parallelism(Parallelism::serial().with_tier(KernelTier::Simd));
        let t_simd = b.batch_time_secs(100).unwrap(); // re-measured, not served stale
        assert!(t_scalar > 0.0 && t_simd > 0.0);
        // switching back serves the original scalar entry verbatim
        b.set_parallelism(Parallelism::serial());
        assert_eq!(b.batch_time_secs(100).unwrap(), t_scalar);
    }

    #[test]
    fn batch_time_cache_keys_on_precision() {
        let mut b = NativeBackend::micro();
        b.timing_reps = 1;
        let t_f32 = b.batch_time_secs(100).unwrap();
        b.set_precision(Precision::Int8);
        let t_int8 = b.batch_time_secs(100).unwrap(); // re-measured under int8
        assert!(t_f32 > 0.0 && t_int8 > 0.0);
        assert_eq!(b.precision(), Precision::Int8);
        b.set_precision(Precision::F32);
        assert_eq!(b.batch_time_secs(100).unwrap(), t_f32);
    }

    #[test]
    fn simd_tier_train_step_bitwise_matches_scalar() {
        // the tier axis of the determinism contract, end to end
        let spec = NativeModel::tiny().spec.clone();
        let p = init_params(&spec, 31);
        let (x, y) = batch_data(&spec, 32);
        let skel = vec![vec![0i32, 2]];
        let mut scalar = NativeBackend::tiny();
        let a = scalar.train_step(50, &p, &p, &x, &y, &skel, 0.05, 0.0).unwrap();
        for threads in [1usize, 2, 7] {
            let mut simd = NativeBackend::tiny()
                .with_parallelism(Parallelism::new(threads).with_tier(KernelTier::Simd));
            let b = simd.train_step(50, &p, &p, &x, &y, &skel, 0.05, 0.0).unwrap();
            assert_eq!(a.params, b.params, "{threads} threads");
            assert_eq!(a.loss, b.loss, "{threads} threads");
            assert_eq!(a.importance, b.importance, "{threads} threads");
        }
    }

    #[test]
    fn int8_training_masks_and_eval_stays_f32() {
        let spec = NativeModel::tiny().spec.clone();
        let p = init_params(&spec, 41);
        let (x, y) = batch_data(&spec, 42);
        let skel = vec![vec![0i32, 2]];
        let mut b = NativeBackend::tiny();
        b.set_precision(Precision::Int8);
        let out = b.train_step(50, &p, &p, &x, &y, &skel, 0.05, 0.0).unwrap();
        assert!(out.loss.is_finite());
        // the skeleton masking contract holds under int8 too
        let (w_new, w_old) = (out.params[0].data(), p[0].data());
        for (i, (a, o)) in w_new.iter().zip(w_old).enumerate() {
            let c = i % 4;
            if c == 1 || c == 3 {
                assert_eq!(a, o, "non-skeleton channel {c} moved under int8");
            }
        }
        // eval forces f32: identical logits whatever the client precision
        let numel: usize = spec.input_shape.iter().product();
        let xe = vec![0.3f32; spec.eval_batch * numel];
        let l_int8 = b.eval_logits(&p, &xe).unwrap();
        assert_eq!(b.precision(), Precision::Int8, "eval must restore the precision");
        let mut bf = NativeBackend::tiny();
        let l_f32 = bf.eval_logits(&p, &xe).unwrap();
        assert_eq!(l_int8, l_f32);
    }

    #[test]
    fn int8_forward_is_close_to_f32() {
        let model = NativeModel::tiny();
        let spec = model.spec.clone();
        let p = init_params(&spec, 51);
        let (x, _) = batch_data(&spec, 52);
        let f32_trace = model.forward(&p, &x, spec.train_batch).unwrap();
        let int8_model = NativeModel::tiny().with_precision(Precision::Int8);
        let int8_trace = int8_model.forward(&p, &x, spec.train_batch).unwrap();
        let (a, b) = (f32_trace.logits(), int8_trace.logits());
        let max_ref = a.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        let max_err = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        // generous: quantization noise, not divergence
        assert!(max_err <= 0.1 * max_ref + 1e-3, "max err {max_err} vs ref magnitude {max_ref}");
    }
}
