//! The real PJRT runtime: HLO-text loading, compilation cache, execution.

use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::model::spec::{ArtifactSpec, Dtype};
use crate::runtime::ArgBuf;
use crate::tensor::Tensor;

/// Owns the PJRT CPU client and a compile cache keyed by artifact file.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    /// Create the CPU client (one per process is plenty).
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(to_anyhow).context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, cache: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by path).
    pub fn load(&mut self, path: impl AsRef<Path>, spec: &ArtifactSpec) -> Result<LoadedArtifact> {
        let key = path.as_ref().display().to_string();
        if let Some(exe) = self.cache.get(&key) {
            return Ok(LoadedArtifact { exe: exe.clone(), spec: spec.clone() });
        }
        let proto = xla::HloModuleProto::from_text_file(&key)
            .map_err(to_anyhow)
            .with_context(|| format!("parsing HLO text {key}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(to_anyhow)
            .with_context(|| format!("compiling {key}"))?;
        let exe = Rc::new(exe);
        self.cache.insert(key, exe.clone());
        Ok(LoadedArtifact { exe, spec: spec.clone() })
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

/// A compiled artifact bound to its manifest I/O contract.
pub struct LoadedArtifact {
    exe: Rc<xla::PjRtLoadedExecutable>,
    spec: ArtifactSpec,
}

impl LoadedArtifact {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with positional args; returns the flattened output tuple as
    /// f32 tensors (scalar outputs come back as shape-[] tensors).
    pub fn run(&self, args: &[ArgBuf]) -> Result<Vec<Tensor>> {
        self.validate(args)?;
        let literals: Vec<xla::Literal> = args.iter().map(to_literal).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(to_anyhow)
            .with_context(|| format!("executing {}", self.spec.file))?;
        let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        // aot.py lowers with return_tuple=True → always a tuple.
        let parts = lit.to_tuple().map_err(to_anyhow)?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.file,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(l, o)| {
                let v: Vec<f32> = l.to_vec::<f32>().map_err(to_anyhow)?;
                Tensor::from_vec(&o.shape, v)
            })
            .collect()
    }

    fn validate(&self, args: &[ArgBuf]) -> Result<()> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} args, manifest wants {}",
                self.spec.file,
                args.len(),
                self.spec.inputs.len()
            );
        }
        for (i, (a, s)) in args.iter().zip(&self.spec.inputs).enumerate() {
            let dt_ok = matches!(
                (a, s.dtype),
                (ArgBuf::F32 { .. }, Dtype::F32) | (ArgBuf::I32 { .. }, Dtype::I32)
            );
            if !dt_ok {
                bail!("{}: arg {i} ({}) dtype mismatch", self.spec.file, s.name);
            }
            if a.shape() != s.shape.as_slice() {
                bail!(
                    "{}: arg {i} ({}) shape {:?} != manifest {:?}",
                    self.spec.file,
                    s.name,
                    a.shape(),
                    s.shape
                );
            }
        }
        Ok(())
    }
}

fn to_literal(a: &ArgBuf) -> Result<xla::Literal> {
    let dims: Vec<i64>;
    let lit = match a {
        ArgBuf::F32 { shape, data } => {
            dims = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data)
        }
        ArgBuf::I32 { shape, data } => {
            dims = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data)
        }
    };
    lit.reshape(&dims).map_err(to_anyhow)
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::IoSpec;

    fn art(inputs: Vec<IoSpec>, outputs: Vec<IoSpec>) -> ArtifactSpec {
        ArtifactSpec {
            kind: "eval".into(),
            file: "t.hlo.txt".into(),
            ratio: None,
            batch: 1,
            k: vec![],
            inputs,
            outputs,
        }
    }

    fn io(name: &str, shape: &[usize], dtype: Dtype) -> IoSpec {
        IoSpec { name: name.into(), shape: shape.to_vec(), dtype }
    }

    // Validation is testable without a client via a LoadedArtifact with a
    // dummy exe? The exe is required; instead test validate() indirectly
    // through the real-runtime integration test (rust/tests/). Here we
    // test literal conversion shape bookkeeping.
    #[test]
    fn literal_roundtrip_f32() {
        let a = ArgBuf::F32 { shape: vec![2, 3], data: vec![1., 2., 3., 4., 5., 6.] };
        let l = to_literal(&a).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn literal_roundtrip_i32_and_scalar() {
        let a = ArgBuf::i32_vec(vec![7, 8]);
        let l = to_literal(&a).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7, 8]);
        let s = ArgBuf::scalar_f32(2.5);
        let l = to_literal(&s).unwrap();
        assert_eq!(l.element_count(), 1);
    }

    #[test]
    fn artifact_spec_helpers() {
        let a = art(vec![io("x", &[2], Dtype::F32)], vec![io("y", &[2], Dtype::F32)]);
        assert_eq!(a.inputs[0].numel(), 2);
    }
}
