//! Artifact execution runtime.
//!
//! Wraps the `xla` crate's PJRT CPU client: loads `artifacts/*.hlo.txt`
//! (HLO **text** — see DESIGN.md §2 for why not serialized protos),
//! compiles once per artifact, and executes with positional arguments
//! validated against the manifest's I/O contract.
//!
//! The [`Backend`] trait is the seam the coordinator programs against:
//! `pjrt::PjrtBackend` is the artifact-true runtime (behind the `pjrt`
//! feature, which needs the vendored `xla` crate);
//! [`native::NativeBackend`] is a real, dependency-free CPU backend with
//! skeleton-sliced kernels ([`crate::kernels`]) available in every build;
//! [`mock::MockBackend`] is a deterministic in-process stand-in so
//! coordinator logic is testable without any compute at all.
//!
//! Paper: Table 1's measured speedups and Fig. 5's per-device batch
//! times come from backends behind this seam. Invariants: `train_step`
//! leaves non-skeleton channels bit-identical, and results are bitwise
//! independent of the configured thread budget
//! ([`Backend::set_parallelism`]).

pub mod mock;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod step;

pub use native::{NativeBackend, NativeModel};
#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedArtifact, PjrtRuntime};
#[cfg(feature = "pjrt")]
pub use step::PjrtBackend;
pub use step::{Backend, StepOut};

use crate::tensor::Tensor;

/// One positional artifact argument.
#[derive(Debug, Clone)]
pub enum ArgBuf {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl ArgBuf {
    pub fn from_tensor(t: &Tensor) -> ArgBuf {
        ArgBuf::F32 { shape: t.shape().to_vec(), data: t.data().to_vec() }
    }

    pub fn scalar_f32(x: f32) -> ArgBuf {
        ArgBuf::F32 { shape: vec![], data: vec![x] }
    }

    pub fn i32_vec(v: Vec<i32>) -> ArgBuf {
        ArgBuf::I32 { shape: vec![v.len()], data: v }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            ArgBuf::F32 { shape, .. } | ArgBuf::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            ArgBuf::F32 { data, .. } => data.len(),
            ArgBuf::I32 { data, .. } => data.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argbuf_constructors() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let a = ArgBuf::from_tensor(&t);
        assert_eq!(a.shape(), &[2, 2]);
        assert_eq!(a.numel(), 4);
        let s = ArgBuf::scalar_f32(0.5);
        assert_eq!(s.shape(), &[] as &[usize]);
        let i = ArgBuf::i32_vec(vec![1, 2, 3]);
        assert_eq!(i.shape(), &[3]);
        assert_eq!(i.numel(), 3);
    }
}
