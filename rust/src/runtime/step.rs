//! The [`Backend`] seam: typed train/eval steps over loaded artifacts.
//!
//! The coordinator never assembles positional argument lists itself — this
//! module turns (params, batch, skeleton, hyperparams) into the artifact's
//! manifest-ordered `ArgBuf`s and slices the output tuple back into typed
//! pieces.
//!
//! Paper: every Table 1/2 measurement and Fig. 5 simulation drives a
//! model through this trait. Invariants: `train_step` must leave
//! non-skeleton channels of prunable tensors bit-identical, and results
//! must be bitwise independent of the [`Parallelism`] budget.

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};

use crate::kernels::{Parallelism, Precision};
#[cfg(feature = "pjrt")]
use crate::model::Manifest;
use crate::model::{ModelSpec, Params};
#[cfg(feature = "pjrt")]
use crate::runtime::pjrt::{LoadedArtifact, PjrtRuntime};
use crate::runtime::ArgBuf;
use crate::tensor::Tensor;
#[cfg(feature = "pjrt")]
use crate::util::timer::Timer;

/// Result of one local train step.
#[derive(Debug, Clone)]
pub struct StepOut {
    pub params: Params,
    pub loss: f32,
    /// Per-prunable-layer channel importance (Eq. 2) for this batch.
    pub importance: Vec<Vec<f32>>,
}

/// What the coordinator needs from a compute backend.
pub trait Backend {
    fn spec(&self) -> &ModelSpec;

    /// One local SGD step at ratio-bucket `bucket`.
    ///
    /// `skeleton[l]` must have exactly the bucket's k_l channel indices.
    /// `mu` enables the FedProx-style term against `global`.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &mut self,
        bucket: usize,
        params: &Params,
        global: &Params,
        x: &[f32],
        y: &[i32],
        skeleton: &[Vec<i32>],
        lr: f32,
        mu: f32,
    ) -> Result<StepOut>;

    /// Batched logits for accuracy evaluation; `x` is one eval batch.
    fn eval_logits(&mut self, params: &Params, x: &[f32]) -> Result<Tensor>;

    /// Measured (and cached) seconds for one train batch at `bucket` —
    /// feeds the heterogeneity simulator. Implementations that honor
    /// [`Backend::set_parallelism`] must key their cache by the budget
    /// too: the same bucket times differently on a 1-core and an 8-core
    /// simulated device.
    fn batch_time_secs(&mut self, bucket: usize) -> Result<f64>;

    /// Compute-thread budget for subsequent steps — a simulated client's
    /// core count ([`crate::hetero::DeviceProfile::cores`]). Backends
    /// that cannot use host threads ignore it; the native backend shards
    /// its kernels under it. Implementations MUST keep step results
    /// bitwise independent of the budget (only wall-clock may change).
    fn set_parallelism(&mut self, _par: Parallelism) {}

    /// The currently configured compute-thread budget.
    fn parallelism(&self) -> Parallelism {
        Parallelism::serial()
    }

    /// Forward-pass arithmetic for subsequent train steps — what a
    /// capability-starved simulated device computes with
    /// ([`crate::hetero::DeviceProfile::precision`]). Unlike
    /// [`Backend::set_parallelism`] this may change results (int8 is an
    /// approximation); implementations must keep *eval* f32 so server-
    /// side accuracy measures the model, not the client approximation.
    /// Backends without a quantized path ignore it.
    fn set_precision(&mut self, _precision: Precision) {}

    /// The currently configured client training precision.
    fn precision(&self) -> Precision {
        Precision::F32
    }
}

/// Real backend: executes the model's AOT artifacts on PJRT.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    runtime: PjrtRuntime,
    manifest: Manifest,
    spec: ModelSpec,
    train_cache: BTreeMap<usize, LoadedArtifact>,
    eval_cache: Option<LoadedArtifact>,
    /// Per-bucket argument buffers, assembled once and refilled in place
    /// each step ([`refill_train_args`]) — the hot path never re-allocates
    /// the 2P+4 ArgBufs or copies tensors into fresh vectors.
    args_cache: BTreeMap<usize, Vec<ArgBuf>>,
    timing_cache: BTreeMap<usize, f64>,
    /// repetitions when measuring batch time
    pub timing_reps: usize,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Create for one model of the manifest. Artifacts compile lazily.
    pub fn new(manifest: &Manifest, model: &str) -> Result<PjrtBackend> {
        let spec = manifest.model(model)?.clone();
        Ok(PjrtBackend {
            runtime: PjrtRuntime::new()?,
            manifest: manifest.clone(),
            spec,
            train_cache: BTreeMap::new(),
            eval_cache: None,
            args_cache: BTreeMap::new(),
            timing_cache: BTreeMap::new(),
            timing_reps: 3,
        })
    }

    fn train_artifact(&mut self, bucket: usize) -> Result<&LoadedArtifact> {
        if !self.train_cache.contains_key(&bucket) {
            let art = self.spec.train_artifact(bucket)?.clone();
            let loaded = self.runtime.load(self.manifest.artifact_path(&art), &art)?;
            self.train_cache.insert(bucket, loaded);
        }
        Ok(&self.train_cache[&bucket])
    }

    fn eval_artifact(&mut self) -> Result<&LoadedArtifact> {
        if self.eval_cache.is_none() {
            let art = self.spec.eval_artifact()?.clone();
            let loaded = self.runtime.load(self.manifest.artifact_path(&art), &art)?;
            self.eval_cache = Some(loaded);
        }
        Ok(self.eval_cache.as_ref().unwrap())
    }

    /// Buckets with a train artifact (delegates to the spec).
    pub fn buckets(&self) -> Vec<usize> {
        self.spec.train_buckets()
    }
}

/// Assemble the manifest-ordered argument list for a train artifact.
/// Allocates fresh buffers — done once per bucket; the per-step path is
/// [`refill_train_args`].
#[allow(clippy::too_many_arguments)]
pub fn train_args(
    spec: &ModelSpec,
    k_sizes: &[usize],
    params: &Params,
    global: &Params,
    x: &[f32],
    y: &[i32],
    skeleton: &[Vec<i32>],
    lr: f32,
    mu: f32,
) -> Result<Vec<ArgBuf>> {
    let p = spec.params.len();
    if params.len() != p || global.len() != p {
        bail!("param count mismatch: got {}/{} want {p}", params.len(), global.len());
    }
    if skeleton.len() != spec.prunable.len() {
        bail!("skeleton layer count {} != {}", skeleton.len(), spec.prunable.len());
    }
    let mut args = Vec::with_capacity(2 * p + 4 + skeleton.len());
    for t in params {
        args.push(ArgBuf::from_tensor(t));
    }
    for t in global {
        args.push(ArgBuf::from_tensor(t));
    }
    let (h, w, c) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
    let b = spec.train_batch;
    if x.len() != b * h * w * c || y.len() != b {
        bail!("batch buffer sizes wrong: x {} y {}", x.len(), y.len());
    }
    args.push(ArgBuf::F32 { shape: vec![b, h, w, c], data: x.to_vec() });
    args.push(ArgBuf::I32 { shape: vec![b], data: y.to_vec() });
    for (li, s) in skeleton.iter().enumerate() {
        if s.len() != k_sizes[li] {
            bail!(
                "skeleton layer {li} has {} indices, bucket wants {}",
                s.len(),
                k_sizes[li]
            );
        }
        args.push(ArgBuf::i32_vec(s.clone()));
    }
    args.push(ArgBuf::scalar_f32(lr));
    args.push(ArgBuf::scalar_f32(mu));
    Ok(args)
}

/// Refill a previously assembled train-argument buffer in place — the
/// per-step hot path. Where [`train_args`] allocates 2P+4 fresh `ArgBuf`s
/// (done once per bucket), this only `copy_from_slice`s into the existing
/// buffers, so steady-state steps make zero heap allocations for
/// arguments. Sizes are checked against the cached buffers (which
/// [`train_args`] validated against the spec when it built them).
#[allow(clippy::too_many_arguments)]
pub fn refill_train_args(
    spec: &ModelSpec,
    args: &mut [ArgBuf],
    params: &Params,
    global: &Params,
    x: &[f32],
    y: &[i32],
    skeleton: &[Vec<i32>],
    lr: f32,
    mu: f32,
) -> Result<()> {
    let p = spec.params.len();
    let expect = 2 * p + 4 + skeleton.len();
    if args.len() != expect {
        bail!("arg buffer has {} slots, step wants {expect}", args.len());
    }
    if params.len() != p || global.len() != p {
        bail!("param count mismatch: got {}/{} want {p}", params.len(), global.len());
    }
    for (slot, t) in args[..p].iter_mut().zip(params) {
        refill_f32(slot, t.data())?;
    }
    for (slot, t) in args[p..2 * p].iter_mut().zip(global) {
        refill_f32(slot, t.data())?;
    }
    refill_f32(&mut args[2 * p], x)?;
    refill_i32(&mut args[2 * p + 1], y)?;
    for (li, s) in skeleton.iter().enumerate() {
        refill_i32(&mut args[2 * p + 2 + li], s)?;
    }
    let n = args.len();
    refill_f32(&mut args[n - 2], &[lr])?;
    refill_f32(&mut args[n - 1], &[mu])?;
    Ok(())
}

fn refill_f32(slot: &mut ArgBuf, src: &[f32]) -> Result<()> {
    match slot {
        ArgBuf::F32 { data, .. } if data.len() == src.len() => {
            data.copy_from_slice(src);
            Ok(())
        }
        other => {
            bail!("arg slot mismatch: want f32[{}], have {:?} buffer", src.len(), other.shape())
        }
    }
}

fn refill_i32(slot: &mut ArgBuf, src: &[i32]) -> Result<()> {
    match slot {
        ArgBuf::I32 { data, .. } if data.len() == src.len() => {
            data.copy_from_slice(src);
            Ok(())
        }
        other => {
            bail!("arg slot mismatch: want i32[{}], have {:?} buffer", src.len(), other.shape())
        }
    }
}

/// Slice a train artifact's output tuple into a [`StepOut`].
pub fn split_train_outputs(spec: &ModelSpec, mut outs: Vec<Tensor>) -> Result<StepOut> {
    let p = spec.params.len();
    let l = spec.prunable.len();
    if outs.len() != p + 1 + l {
        bail!("train outputs {} != {}", outs.len(), p + 1 + l);
    }
    let imps: Vec<Vec<f32>> = outs.split_off(p + 1).into_iter().map(|t| t.into_vec()).collect();
    let loss = outs.pop().unwrap().item();
    Ok(StepOut { params: outs, loss, importance: imps })
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn train_step(
        &mut self,
        bucket: usize,
        params: &Params,
        global: &Params,
        x: &[f32],
        y: &[i32],
        skeleton: &[Vec<i32>],
        lr: f32,
        mu: f32,
    ) -> Result<StepOut> {
        self.train_artifact(bucket)?; // compile/load once (cached)
        // steady state: refill the bucket's cached arg buffers in place —
        // no ModelSpec clone, no fresh allocations per step.
        if let Some(args) = self.args_cache.get_mut(&bucket) {
            refill_train_args(&self.spec, args, params, global, x, y, skeleton, lr, mu)?;
        } else {
            let k = self.spec.train_artifact(bucket)?.k.clone();
            let args = train_args(&self.spec, &k, params, global, x, y, skeleton, lr, mu)?;
            self.args_cache.insert(bucket, args);
        }
        let outs = self.train_cache[&bucket]
            .run(&self.args_cache[&bucket])
            .with_context(|| format!("train step bucket r{bucket}"))?;
        split_train_outputs(&self.spec, outs)
    }

    fn eval_logits(&mut self, params: &Params, x: &[f32]) -> Result<Tensor> {
        let shp = &self.spec.input_shape;
        let (h, w, c) = (shp[0], shp[1], shp[2]);
        let b = self.spec.eval_batch;
        if x.len() != b * h * w * c {
            bail!("eval x has {} elems, want {}", x.len(), b * h * w * c);
        }
        let mut args: Vec<ArgBuf> = params.iter().map(ArgBuf::from_tensor).collect();
        args.push(ArgBuf::F32 { shape: vec![b, h, w, c], data: x.to_vec() });
        let mut outs = self.eval_artifact()?.run(&args).context("eval step")?;
        Ok(outs.pop().unwrap())
    }

    fn batch_time_secs(&mut self, bucket: usize) -> Result<f64> {
        if let Some(&t) = self.timing_cache.get(&bucket) {
            return Ok(t);
        }
        // deterministic dummy batch
        let spec = self.spec.clone();
        let params = crate::model::init_params(&spec, 1234);
        let (h, w, c) = (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
        let x = vec![0.1f32; spec.train_batch * h * w * c];
        let y: Vec<i32> = (0..spec.train_batch).map(|i| (i % spec.num_classes) as i32).collect();
        let ks = self.spec.train_artifact(bucket)?.k.clone();
        let skel: Vec<Vec<i32>> = ks.iter().map(|&k| (0..k as i32).collect()).collect();
        // warmup
        self.train_step(bucket, &params, &params, &x, &y, &skel, 0.01, 0.0)?;
        let reps = self.timing_reps;
        let timer = Timer::start();
        for _ in 0..reps {
            self.train_step(bucket, &params, &params, &x, &y, &skel, 0.01, 0.0)?;
        }
        let t = timer.elapsed_secs() / reps as f64;
        self.timing_cache.insert(bucket, t);
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::toy_spec;

    #[test]
    fn train_args_order_and_validation() {
        let spec = toy_spec();
        let params = crate::model::init_params(&spec, 0);
        let b = spec.train_batch;
        let numel = spec.input_shape.iter().product::<usize>();
        let x = vec![0.0f32; b * numel];
        let y = vec![0i32; b];
        let skel = vec![vec![0i32, 1]];
        let args = train_args(&spec, &[2], &params, &params, &x, &y, &skel, 0.1, 0.0).unwrap();
        // 2P + x + y + idx + lr + mu
        assert_eq!(args.len(), 2 * spec.params.len() + 2 + 1 + 2);
        assert!(matches!(args[args.len() - 1], ArgBuf::F32 { .. }));
        assert!(matches!(args[2 * spec.params.len() + 2], ArgBuf::I32 { .. }));
        // wrong skeleton size
        assert!(train_args(&spec, &[2], &params, &params, &x, &y, &[vec![0]], 0.1, 0.0).is_err());
        // wrong batch buffer
        assert!(train_args(&spec, &[2], &params, &params, &x[1..].to_vec(), &y, &skel, 0.1, 0.0).is_err());
    }

    #[test]
    fn refill_matches_fresh_assembly() {
        let spec = toy_spec();
        let b = spec.train_batch;
        let numel = spec.input_shape.iter().product::<usize>();
        let p1 = crate::model::init_params(&spec, 0);
        let p2 = crate::model::init_params(&spec, 1);
        let g2 = crate::model::init_params(&spec, 2);
        let x1 = vec![0.5f32; b * numel];
        let y1 = vec![1i32; b];
        let x2: Vec<f32> = (0..b * numel).map(|i| i as f32 * 1e-3).collect();
        let y2 = vec![2i32, 0];
        let mut args =
            train_args(&spec, &[2], &p1, &p1, &x1, &y1, &[vec![0, 1]], 0.1, 0.0).unwrap();
        refill_train_args(&spec, &mut args, &p2, &g2, &x2, &y2, &[vec![1, 3]], 0.2, 0.7)
            .unwrap();
        let fresh =
            train_args(&spec, &[2], &p2, &g2, &x2, &y2, &[vec![1, 3]], 0.2, 0.7).unwrap();
        assert_eq!(format!("{args:?}"), format!("{fresh:?}"));
    }

    #[test]
    fn refill_rejects_size_mismatches() {
        let spec = toy_spec();
        let b = spec.train_batch;
        let numel = spec.input_shape.iter().product::<usize>();
        let p = crate::model::init_params(&spec, 0);
        let x = vec![0.0f32; b * numel];
        let y = vec![0i32; b];
        let skel = [vec![0i32, 1]];
        let mut args = train_args(&spec, &[2], &p, &p, &x, &y, &skel, 0.1, 0.0).unwrap();
        // wrong batch buffer
        assert!(refill_train_args(&spec, &mut args, &p, &p, &x[1..], &y, &skel, 0.1, 0.0)
            .is_err());
        // wrong skeleton size
        assert!(
            refill_train_args(&spec, &mut args, &p, &p, &x, &y, &[vec![0]], 0.1, 0.0).is_err()
        );
        // wrong slot count
        let mut short = args.split_off(2);
        assert!(refill_train_args(&spec, &mut short, &p, &p, &x, &y, &skel, 0.1, 0.0).is_err());
    }

    #[test]
    fn split_train_outputs_slices() {
        let spec = toy_spec();
        let mut outs: Vec<Tensor> = spec
            .params
            .iter()
            .map(|p| Tensor::zeros(&p.shape))
            .collect();
        outs.push(Tensor::scalar(1.5));
        for p in &spec.prunable {
            outs.push(Tensor::zeros(&[p.channels]));
        }
        let s = split_train_outputs(&spec, outs).unwrap();
        assert_eq!(s.params.len(), spec.params.len());
        assert_eq!(s.loss, 1.5);
        assert_eq!(s.importance.len(), 1);
        assert!(split_train_outputs(&spec, vec![Tensor::scalar(0.0)]).is_err());
    }
}
