//! The three round policies behind [`crate::sched`]'s virtual clock.
//!
//! A policy answers two questions each round: *how many* participants to
//! select (only [`DeadlineDropPolicy`] over-selects) and *which queued
//! completion events close the round* — everything else (shipping,
//! training, aggregation order, ledgers) is the coordinator's job.
//!
//! Invariants the parity tests pin (`tests/sched_parity.rs`):
//! * `DeadlineDropPolicy` with an infinite deadline ≡ [`SyncPolicy`].
//! * `AsyncBufferPolicy` with `k = participants` (the `k = 0` default)
//!   and `alpha = 0` ≡ [`SyncPolicy`].
//! * [`staleness_weight`] is in `(0, 1]`, equals 1 at staleness 0, and is
//!   monotonically non-increasing in staleness.

use super::{Completion, RoundOutcome, VirtualClock};

/// FedBuff-style staleness discount: an update trained `staleness` rounds
/// before the round it is aggregated in contributes with its FedAvg
/// weight scaled by `(1 + staleness)^(-alpha)`. `alpha = 0` disables the
/// discount (every weight stays 1×); larger `alpha` suppresses stale
/// gradients harder.
pub fn staleness_weight(staleness: usize, alpha: f64) -> f64 {
    (1.0 + staleness as f64).powf(-alpha.max(0.0))
}

/// Decides when a round ends and which arrivals aggregate.
pub trait RoundPolicy: Send {
    fn name(&self) -> &'static str;

    /// Participants to select this round, given the sampling target and
    /// the available (non-busy) fleet size. Default: the target itself.
    fn select_count(&self, target: usize, avail: usize) -> usize {
        target.min(avail)
    }

    /// Exponent the coordinator discounts stale arrivals' weights with
    /// ([`staleness_weight`]). Only [`AsyncBufferPolicy`] can produce
    /// stale arrivals, so only it overrides this.
    fn staleness_alpha(&self) -> f64 {
        0.0
    }

    /// Consume completion events from the clock and decide the round.
    /// `submitted` is how many events this round's participants queued;
    /// the clock may also hold in-flight events from earlier rounds.
    /// Events left on the clock stay in flight for later rounds.
    fn run_round(
        &mut self,
        round: usize,
        submitted: usize,
        clock: &mut VirtualClock,
    ) -> RoundOutcome;
}

/// The barrier: the round ends when the last participant's update lands,
/// and every update is aggregated — bitwise the pre-scheduler loop.
pub struct SyncPolicy;

impl RoundPolicy for SyncPolicy {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn run_round(
        &mut self,
        _round: usize,
        _submitted: usize,
        clock: &mut VirtualClock,
    ) -> RoundOutcome {
        let mut accepted = Vec::new();
        let mut end = clock.now();
        while let Some(c) = clock.pop() {
            end = end.max(c.at);
            accepted.push(c);
        }
        RoundOutcome { accepted, dropped: Vec::new(), round_end: end }
    }
}

/// Fixed per-round deadline: arrivals past `round_start + deadline_secs`
/// are discarded; the server over-selects participants by `over_select`
/// to compensate for the expected losses. The round ends at the deadline
/// whenever anything was dropped (the server waited that long before
/// giving up), else at the last arrival.
pub struct DeadlineDropPolicy {
    /// Relative deadline in virtual seconds (`f64::INFINITY` = never
    /// drop, which makes this policy identical to [`SyncPolicy`]).
    pub deadline_secs: f64,
    /// Selection multiplier (≥ 1.0): with target k and a *finite*
    /// deadline, select `ceil(k · over_select)` of the available clients
    /// (capped at the fleet). At full participation — or with an
    /// infinite deadline, which can drop no one — nothing changes.
    pub over_select: f64,
}

impl RoundPolicy for DeadlineDropPolicy {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn select_count(&self, target: usize, avail: usize) -> usize {
        if avail == 0 {
            return 0;
        }
        // An infinite deadline never drops anyone, so there is nothing
        // to compensate for — selection must match the sync barrier
        // exactly (the ≡-sync invariant holds at any participation).
        if !self.deadline_secs.is_finite() {
            return target.min(avail);
        }
        let scaled = (target as f64 * self.over_select.max(1.0)).ceil() as usize;
        scaled.clamp(1, avail)
    }

    fn run_round(
        &mut self,
        _round: usize,
        _submitted: usize,
        clock: &mut VirtualClock,
    ) -> RoundOutcome {
        let deadline = clock.now() + self.deadline_secs;
        let mut accepted = Vec::new();
        let mut dropped = Vec::new();
        let mut last = clock.now();
        while let Some(c) = clock.pop() {
            if c.at <= deadline {
                last = last.max(c.at);
                accepted.push(c);
            } else {
                dropped.push(c);
            }
        }
        let round_end = if dropped.is_empty() { last } else { deadline };
        RoundOutcome { accepted, dropped, round_end }
    }
}

/// FedBuff-style buffered aggregation: the round closes on the K-th
/// arrival (counting stragglers from earlier rounds at their true
/// virtual arrival time); everything still queued stays in flight. The
/// coordinator discounts stale arrivals' weights by [`staleness_weight`]
/// and excludes in-flight clients from the next round's sampling.
pub struct AsyncBufferPolicy {
    /// Buffer size K. `0` means "this round's participant count" — which
    /// never leaves anything in flight and (with `alpha = 0`) reproduces
    /// [`SyncPolicy`] bit-for-bit.
    pub k: usize,
    /// Staleness-discount exponent handed to [`staleness_weight`].
    pub alpha: f64,
}

impl RoundPolicy for AsyncBufferPolicy {
    fn name(&self) -> &'static str {
        "async"
    }

    fn staleness_alpha(&self) -> f64 {
        self.alpha
    }

    fn run_round(
        &mut self,
        _round: usize,
        submitted: usize,
        clock: &mut VirtualClock,
    ) -> RoundOutcome {
        let target = if self.k == 0 { submitted } else { self.k };
        let mut accepted: Vec<Completion> = Vec::new();
        let mut end = clock.now();
        while accepted.len() < target {
            let Some(c) = clock.pop() else { break };
            end = end.max(c.at);
            accepted.push(c);
        }
        RoundOutcome { accepted, dropped: Vec::new(), round_end: end }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clock_with(events: &[(f64, usize, usize, usize)]) -> VirtualClock {
        let mut c = VirtualClock::new();
        for &(at, round, seq, client) in events {
            c.push(Completion { at, round, seq, client });
        }
        c
    }

    #[test]
    fn sync_waits_for_everyone() {
        let mut clock = clock_with(&[(1.0, 0, 0, 0), (3.0, 0, 1, 1), (2.0, 0, 2, 2)]);
        let out = SyncPolicy.run_round(0, 3, &mut clock);
        assert_eq!(out.accepted.len(), 3);
        assert!(out.dropped.is_empty());
        assert_eq!(out.round_end, 3.0);
        assert_eq!(clock.pending(), 0);
    }

    #[test]
    fn sync_empty_round_ends_at_now() {
        let mut clock = VirtualClock::new();
        clock.advance_to(5.0);
        let out = SyncPolicy.run_round(0, 0, &mut clock);
        assert!(out.accepted.is_empty());
        assert_eq!(out.round_end, 5.0);
    }

    #[test]
    fn deadline_splits_on_the_deadline() {
        let mut p = DeadlineDropPolicy { deadline_secs: 2.5, over_select: 1.0 };
        let mut clock = clock_with(&[(1.0, 0, 0, 0), (2.0, 0, 1, 1), (4.0, 0, 2, 2)]);
        let out = p.run_round(0, 3, &mut clock);
        assert_eq!(out.accepted.len(), 2);
        assert_eq!(out.dropped.len(), 1);
        assert_eq!(out.dropped[0].client, 2);
        // round ends at the deadline, not the last accepted arrival
        assert_eq!(out.round_end, 2.5);
        assert_eq!(clock.pending(), 0, "dropped events do not stay in flight");
    }

    #[test]
    fn deadline_infinite_is_sync() {
        let mut p = DeadlineDropPolicy { deadline_secs: f64::INFINITY, over_select: 1.0 };
        let mut clock = clock_with(&[(1.0, 0, 0, 0), (9.0, 0, 1, 1)]);
        let out = p.run_round(0, 2, &mut clock);
        assert_eq!(out.accepted.len(), 2);
        assert!(out.dropped.is_empty());
        assert_eq!(out.round_end, 9.0);
    }

    #[test]
    fn deadline_over_selects_only_when_it_can_drop() {
        let p = DeadlineDropPolicy { deadline_secs: 1.0, over_select: 1.25 };
        assert_eq!(p.select_count(4, 8), 5); // ceil(4 * 1.25)
        assert_eq!(p.select_count(8, 8), 8); // capped at the fleet
        assert_eq!(p.select_count(1, 8), 2);
        assert_eq!(p.select_count(3, 0), 0);
        // an infinite deadline drops nothing, so selection matches the
        // sync barrier at any participation (the ≡-sync invariant)
        let inf = DeadlineDropPolicy { deadline_secs: f64::INFINITY, over_select: 1.25 };
        assert_eq!(inf.select_count(4, 8), 4);
        assert_eq!(inf.select_count(8, 8), 8);
        // the other policies never over-select
        assert_eq!(SyncPolicy.select_count(4, 8), 4);
        assert_eq!(AsyncBufferPolicy { k: 0, alpha: 0.0 }.select_count(4, 8), 4);
    }

    #[test]
    fn staleness_alpha_is_owned_by_the_async_policy() {
        assert_eq!(AsyncBufferPolicy { k: 3, alpha: 0.7 }.staleness_alpha(), 0.7);
        // policies that never produce stale arrivals report no discount
        assert_eq!(SyncPolicy.staleness_alpha(), 0.0);
        let p = DeadlineDropPolicy { deadline_secs: 1.0, over_select: 1.0 };
        assert_eq!(p.staleness_alpha(), 0.0);
    }

    #[test]
    fn async_buffer_closes_on_kth_arrival_and_defers_the_rest() {
        let mut p = AsyncBufferPolicy { k: 2, alpha: 0.5 };
        let mut clock = clock_with(&[(1.0, 0, 0, 0), (2.0, 0, 1, 1), (7.0, 0, 2, 2)]);
        let out = p.run_round(0, 3, &mut clock);
        assert_eq!(out.accepted.len(), 2);
        assert_eq!(out.round_end, 2.0);
        assert_eq!(clock.pending(), 1, "the straggler stays in flight");
        assert_eq!(clock.busy_clients(), vec![2]);
        // the straggler lands in a later round at its true arrival time
        clock.advance_to(out.round_end);
        clock.push(Completion { at: 2.5, round: 1, seq: 0, client: 0 });
        let out = p.run_round(1, 1, &mut clock);
        assert_eq!(out.accepted.len(), 2);
        assert_eq!(out.round_end, 7.0);
        let stale: Vec<usize> =
            out.accepted.iter().filter(|c| c.round < 1).map(|c| c.client).collect();
        assert_eq!(stale, vec![2]);
    }

    #[test]
    fn async_k_zero_takes_exactly_this_rounds_submissions() {
        let mut p = AsyncBufferPolicy { k: 0, alpha: 0.0 };
        let mut clock = clock_with(&[(1.0, 0, 0, 0), (2.0, 0, 1, 1)]);
        let out = p.run_round(0, 2, &mut clock);
        assert_eq!(out.accepted.len(), 2);
        assert_eq!(clock.pending(), 0);
        assert_eq!(out.round_end, 2.0);
    }

    #[test]
    fn async_never_hangs_on_a_short_queue() {
        let mut p = AsyncBufferPolicy { k: 10, alpha: 0.0 };
        let mut clock = clock_with(&[(1.0, 0, 0, 0)]);
        let out = p.run_round(0, 1, &mut clock);
        assert_eq!(out.accepted.len(), 1);
        assert_eq!(clock.pending(), 0);
    }

    #[test]
    fn staleness_weight_shape() {
        assert_eq!(staleness_weight(0, 0.7), 1.0);
        assert_eq!(staleness_weight(5, 0.0), 1.0);
        assert!((staleness_weight(1, 1.0) - 0.5).abs() < 1e-12);
        assert!(staleness_weight(3, 0.5) < staleness_weight(2, 0.5));
        // negative alpha is clamped (never *amplify* stale updates)
        assert_eq!(staleness_weight(4, -2.0), 1.0);
    }
}
