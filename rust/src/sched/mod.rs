//! Virtual-clock round scheduler: the straggler axis of the paper,
//! executed as an event queue instead of a barrier.
//!
//! FedSkel's system claim (up to 1.82× whole-training speedup on
//! heterogeneous fleets) is about *stragglers* — yet a barrier-synchronous
//! round loop can only ever charge the max over participants. This module
//! orders per-client **completion events** on a virtual clock (simulated
//! seconds: measured batch time under the client's core budget ÷ its
//! per-core capability, plus measured frame bytes over its simulated
//! link) and lets a pluggable [`RoundPolicy`] decide when a round ends
//! and which arrivals the server aggregates:
//!
//! * [`SyncPolicy`] — today's barrier: wait for everyone; bitwise
//!   identical to the pre-scheduler coordinator loop.
//! * [`DeadlineDropPolicy`] — over-select participants, discard any
//!   update that lands after a fixed per-round deadline, aggregate the
//!   rest. The round ends at the deadline whenever something was dropped.
//! * [`AsyncBufferPolicy`] — FedBuff-style buffered aggregation: the
//!   round closes on the K-th arrival; later arrivals stay **in flight**
//!   on the clock and land in a later round at their true virtual
//!   arrival time, weight-discounted by [`staleness_weight`].
//!
//! Determinism contract: events are ordered by
//! `(arrival time, round, submission seq, client)` with `f64::total_cmp`,
//! so ties cannot depend on heap internals; accepted updates are handed
//! back sorted by `(round, seq)`, which is exactly the pre-scheduler
//! aggregation order — under [`SyncPolicy`] the coordinator reproduces
//! the barrier loop bit-for-bit (same FNV param digest).
//!
//! Transport faults (`--fault`) never reach this clock: the
//! coordinator's reliable-exchange loop retransmits until the frame
//! decodes and feeds the scheduler the *successful* attempt's receipt —
//! identical bytes, identical link seconds — so injected chaos cannot
//! perturb arrival times, staleness weights, or drop decisions.

pub mod policy;

pub use policy::{staleness_weight, AsyncBufferPolicy, DeadlineDropPolicy, RoundPolicy, SyncPolicy};

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

/// One client's completion event on the virtual clock: "this client's
/// upload lands at the server at absolute virtual time `at`".
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// Absolute virtual arrival time (seconds since the run started).
    pub at: f64,
    /// The round the client trained in (stale arrivals keep their origin).
    pub round: usize,
    /// Submission index within its round — the deterministic tie-breaker
    /// and the key the coordinator buffers the pending update under.
    pub seq: usize,
    pub client: usize,
}

impl PartialEq for Completion {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Completion {}

impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Completion {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at
            .total_cmp(&other.at)
            .then(self.round.cmp(&other.round))
            .then(self.seq.cmp(&other.seq))
            .then(self.client.cmp(&other.client))
    }
}

/// A monotone virtual clock over a min-heap of [`Completion`] events.
///
/// `now` only moves forward ([`VirtualClock::advance_to`] clamps), and
/// popping an event does *not* advance time — deciding when a round ends
/// is the policy's job, not the queue's.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: f64,
    heap: BinaryHeap<Reverse<Completion>>,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Current virtual time (= the end of the last decided round).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Queue a completion event. Events must not arrive in the past.
    pub fn push(&mut self, c: Completion) {
        debug_assert!(c.at >= self.now, "event at {} before now {}", c.at, self.now);
        self.heap.push(Reverse(c));
    }

    /// Pop the earliest event (ties broken by `(round, seq, client)`).
    pub fn pop(&mut self) -> Option<Completion> {
        self.heap.pop().map(|Reverse(c)| c)
    }

    /// Earliest queued event, if any.
    pub fn peek(&self) -> Option<&Completion> {
        self.heap.peek().map(|Reverse(c)| c)
    }

    /// Move time forward (never backward).
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }

    /// Events still queued (in-flight updates).
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Distinct client ids with an event still queued, ascending.
    pub fn busy_clients(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.heap.iter().map(|Reverse(c)| c.client).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// All queued events in deterministic `(at, round, seq, client)`
    /// order — the checkpoint view of in-flight arrivals. Non-destructive.
    pub fn events_sorted(&self) -> Vec<Completion> {
        let mut evs: Vec<Completion> = self.heap.iter().map(|Reverse(c)| *c).collect();
        evs.sort();
        evs
    }

    /// Rebuild a clock from a checkpoint: absolute time `now` plus the
    /// in-flight events recorded by [`VirtualClock::events_sorted`].
    ///
    /// Time is installed **before** the events are queued, so a straggler
    /// that spans the checkpoint keeps its absolute arrival time — the
    /// restored run computes the same staleness (origin round vs landing
    /// round) and the same arrival order as the uninterrupted run, rather
    /// than re-basing events against a wall-zero clock. Fails (typed, no
    /// panic) if any event claims to arrive before `now`.
    pub fn restore(now: f64, events: Vec<Completion>) -> Result<VirtualClock> {
        let mut clock = VirtualClock::new();
        clock.advance_to(now);
        for c in events {
            if c.at < now {
                bail!(
                    "snapshot clock event at {} predates restored now {} \
                     (round {}, seq {}, client {})",
                    c.at,
                    now,
                    c.round,
                    c.seq,
                    c.client
                );
            }
            clock.push(c);
        }
        Ok(clock)
    }
}

/// What a policy decided for one round.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Arrivals the server aggregates this round, sorted by
    /// `(round, seq)` — the deterministic aggregation order.
    pub accepted: Vec<Completion>,
    /// Arrivals discarded at the round deadline (their updates are gone;
    /// the coordinator ledgers their frames as wasted bytes).
    pub dropped: Vec<Completion>,
    /// Absolute virtual time the round ended.
    pub round_end: f64,
}

/// Which round-scheduling policy a run uses (config/CLI selectable).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedKind {
    /// Barrier round: wait for every participant (the pre-scheduler
    /// behavior, bit-for-bit).
    #[default]
    Sync,
    /// Drop updates that miss a per-round deadline.
    DeadlineDrop,
    /// FedBuff-style: aggregate the first K arrivals, defer the rest.
    AsyncBuffer,
}

impl SchedKind {
    pub fn parse(s: &str) -> Result<SchedKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sync" => SchedKind::Sync,
            "deadline" | "deadline-drop" | "deadlinedrop" => SchedKind::DeadlineDrop,
            "async" | "async-buffer" | "asyncbuffer" | "fedbuff" => SchedKind::AsyncBuffer,
            _ => bail!("unknown scheduler '{s}' (sync|deadline|async)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedKind::Sync => "sync",
            SchedKind::DeadlineDrop => "deadline",
            SchedKind::AsyncBuffer => "async",
        }
    }

    /// Build the policy from the run's scheduler knobs
    /// (`--deadline-secs`, `--buffer-k`, `--staleness-alpha`).
    pub fn build(
        &self,
        deadline_secs: f64,
        buffer_k: usize,
        staleness_alpha: f64,
    ) -> Box<dyn RoundPolicy> {
        match self {
            SchedKind::Sync => Box::new(SyncPolicy),
            SchedKind::DeadlineDrop => {
                Box::new(DeadlineDropPolicy { deadline_secs, over_select: 1.25 })
            }
            SchedKind::AsyncBuffer => {
                Box::new(AsyncBufferPolicy { k: buffer_k, alpha: staleness_alpha })
            }
        }
    }
}

/// The clock + policy pair the coordinator drives a run through.
pub struct RoundScheduler {
    clock: VirtualClock,
    policy: Box<dyn RoundPolicy>,
    /// Events submitted since the last [`RoundScheduler::run_round`] —
    /// the "this round's participants" count policies size buffers by.
    submitted: usize,
}

impl RoundScheduler {
    pub fn new(policy: Box<dyn RoundPolicy>) -> RoundScheduler {
        RoundScheduler { clock: VirtualClock::new(), policy, submitted: 0 }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// How many participants the policy wants selected this round, given
    /// the sampling target and the available (non-busy) fleet size.
    pub fn select_count(&self, target: usize, avail: usize) -> usize {
        self.policy.select_count(target, avail)
    }

    /// The policy's staleness-discount exponent (0 for policies that
    /// never produce stale arrivals).
    pub fn staleness_alpha(&self) -> f64 {
        self.policy.staleness_alpha()
    }

    /// Current virtual time (= the start of the next round).
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Updates still in flight from earlier rounds.
    pub fn in_flight(&self) -> usize {
        self.clock.pending()
    }

    /// Clients whose previous update has not landed yet — excluded from
    /// participant sampling until it does.
    pub fn busy_clients(&self) -> Vec<usize> {
        self.clock.busy_clients()
    }

    /// Queue one client's completion `secs` of virtual time after the
    /// round started.
    pub fn submit(&mut self, client: usize, round: usize, seq: usize, secs: f64) {
        let at = self.clock.now() + secs;
        self.clock.push(Completion { at, round, seq, client });
        self.submitted += 1;
    }

    /// Checkpoint view of the clock: `(now, in-flight events)` in
    /// deterministic order. `submitted` needs no snapshot — checkpoints
    /// happen at round boundaries where `run_round` has already taken it
    /// back to zero.
    pub fn clock_state(&self) -> (f64, Vec<Completion>) {
        (self.clock.now(), self.clock.events_sorted())
    }

    /// Install a checkpointed clock (see [`VirtualClock::restore`]) in
    /// place of the current one. Fails if any event predates `now`.
    pub fn restore_clock(&mut self, now: f64, events: Vec<Completion>) -> Result<()> {
        self.clock = VirtualClock::restore(now, events)?;
        Ok(())
    }

    /// Let the policy decide the round from the queued events, advance
    /// the clock to the round's end, and hand back the accepted arrivals
    /// in `(round, seq)` order.
    pub fn run_round(&mut self, round: usize) -> RoundOutcome {
        // Wall time spent *deciding* the round (policy + queue ops) — the
        // profiler's attribution table sets this against the round's
        // `sim_secs` virtual-clock span so scheduling overhead is visible.
        let _span = crate::prof::scope("sched_round");
        let submitted = std::mem::take(&mut self.submitted);
        let mut out = self.policy.run_round(round, submitted, &mut self.clock);
        out.accepted.sort_by(|a, b| (a.round, a.seq).cmp(&(b.round, b.seq)));
        self.clock.advance_to(out.round_end);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: f64, round: usize, seq: usize, client: usize) -> Completion {
        Completion { at, round, seq, client }
    }

    #[test]
    fn clock_orders_by_time_then_round_seq_client() {
        let mut c = VirtualClock::new();
        c.push(ev(2.0, 0, 1, 7));
        c.push(ev(1.0, 0, 3, 2));
        c.push(ev(2.0, 0, 0, 9));
        c.push(ev(1.0, 0, 2, 5));
        let order: Vec<(f64, usize)> =
            std::iter::from_fn(|| c.pop()).map(|e| (e.at, e.seq)).collect();
        assert_eq!(order, vec![(1.0, 2), (1.0, 3), (2.0, 0), (2.0, 1)]);
        assert_eq!(c.pending(), 0);
    }

    #[test]
    fn clock_is_monotone_and_tracks_busy_clients() {
        let mut c = VirtualClock::new();
        c.advance_to(3.0);
        c.advance_to(1.0); // never backwards
        assert_eq!(c.now(), 3.0);
        c.push(ev(4.0, 1, 0, 2));
        c.push(ev(5.0, 1, 1, 2));
        c.push(ev(6.0, 1, 2, 0));
        assert_eq!(c.busy_clients(), vec![0, 2]);
        assert_eq!(c.peek().unwrap().at, 4.0);
    }

    #[test]
    fn kind_parse_name_build() {
        assert_eq!(SchedKind::parse("Sync").unwrap(), SchedKind::Sync);
        assert_eq!(SchedKind::parse("deadline").unwrap(), SchedKind::DeadlineDrop);
        assert_eq!(SchedKind::parse("fedbuff").unwrap(), SchedKind::AsyncBuffer);
        assert!(SchedKind::parse("barrier").is_err());
        assert_eq!(SchedKind::default(), SchedKind::Sync);
        assert_eq!(SchedKind::Sync.build(f64::INFINITY, 0, 0.0).name(), "sync");
        assert_eq!(SchedKind::DeadlineDrop.build(1.0, 0, 0.0).name(), "deadline");
        assert_eq!(SchedKind::AsyncBuffer.build(1.0, 3, 0.5).name(), "async");
    }

    #[test]
    fn clock_restore_keeps_absolute_times_and_rejects_past_events() {
        let mut c = VirtualClock::new();
        c.advance_to(5.0);
        c.push(ev(7.5, 2, 1, 3));
        c.push(ev(6.0, 1, 0, 1));
        let (now, evs) = (c.now(), c.events_sorted());
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].at, 6.0); // deterministic order
        let mut r = VirtualClock::restore(now, evs).unwrap();
        assert_eq!(r.now(), 5.0);
        assert_eq!(r.pop().unwrap().at, 6.0);
        assert_eq!(r.pop().unwrap().at, 7.5);
        // an event claiming to arrive before the restored now is a
        // corrupt snapshot, not a panic
        assert!(VirtualClock::restore(5.0, vec![ev(4.0, 0, 0, 0)]).is_err());
    }

    #[test]
    fn scheduler_clock_round_trips_through_restore() {
        let mut s = RoundScheduler::new(Box::new(SyncPolicy));
        s.submit(0, 0, 0, 2.0);
        s.run_round(0);
        s.submit(1, 1, 0, 9.0); // leave one event in flight
        let (now, evs) = s.clock_state();
        let mut t = RoundScheduler::new(Box::new(SyncPolicy));
        t.restore_clock(now, evs).unwrap();
        assert_eq!(t.now(), 2.0);
        assert_eq!(t.in_flight(), 1);
        assert_eq!(t.busy_clients(), vec![1]);
    }

    #[test]
    fn scheduler_submits_relative_to_round_start_and_sorts_accepted() {
        let mut s = RoundScheduler::new(Box::new(SyncPolicy));
        s.submit(0, 0, 0, 2.0);
        s.submit(1, 0, 1, 1.0);
        let out = s.run_round(0);
        // accepted in (round, seq) order even though client 1 arrived first
        let seqs: Vec<usize> = out.accepted.iter().map(|c| c.seq).collect();
        assert_eq!(seqs, vec![0, 1]);
        assert_eq!(out.round_end, 2.0);
        assert_eq!(s.now(), 2.0);
        // next round's events start at the new now
        s.submit(0, 1, 0, 0.5);
        let out = s.run_round(1);
        assert_eq!(out.accepted[0].at, 2.5);
        assert_eq!(s.now(), 2.5);
        assert_eq!(s.in_flight(), 0);
    }
}
