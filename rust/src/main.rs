//! `fedskel` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   train        run a federated training job (any method)
//!   serve        run the coordinator with remote worker processes over TCP
//!   client       join a `fedskel serve` coordinator as a stateless worker
//!   profile      short profiled train: span attribution + Chrome trace
//!   watch        terminal dashboard over a trace.jsonl (live or recorded)
//!   report       replay a trace.jsonl into summary + round tables
//!   speedup      Table 1: per-ratio backprop / overall speedups
//!   hetero-sim   Fig. 5: 8-device heterogeneous round times
//!   comm-report  Table 2: per-method communication volumes
//!   info         print manifest inventory
//!
//! Examples:
//!   fedskel train --method fedskel --dataset smnist --rounds 20 --trace trace.jsonl
//!   fedskel train --rounds 5 --profile profile.json
//!   fedskel serve --listen 127.0.0.1:7700 --min-clients 2 --rounds 20
//!   fedskel client --connect 127.0.0.1:7700
//!   fedskel profile --method fedskel --dataset smnist
//!   fedskel watch trace.jsonl --follow
//!   fedskel report trace.jsonl --csv replay.csv
//!   fedskel report --profile profile.json
//!   fedskel speedup --ratios 10,20,30,40
//!   fedskel hetero-sim --devices 8
//!   fedskel comm-report --rounds 1000 --clients 100

use std::path::Path;

use anyhow::{bail, Result};

use fedskel::model::Manifest;
use fedskel::util::cli::Cli;

#[cfg(feature = "pjrt")]
use fedskel::config::{standard_flags, RunConfig};
#[cfg(feature = "pjrt")]
use fedskel::coordinator::Coordinator;
#[cfg(feature = "pjrt")]
use fedskel::runtime::PjrtBackend;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match sub.as_str() {
        "train" => cmd_train(argv),
        "serve" => cmd_serve(argv),
        "client" => cmd_client(argv),
        "profile" => cmd_profile(argv),
        "watch" => cmd_watch(argv),
        "report" => cmd_report(argv),
        "speedup" => cmd_speedup(argv),
        "hetero-sim" => cmd_hetero(argv),
        "comm-report" => cmd_comm(argv),
        "info" => cmd_info(argv),
        "help" | "--help" | "-h" => {
            println!(
                "fedskel — FedSkel (CIKM'21) reproduction\n\n\
                 USAGE: fedskel <train|serve|client|profile|watch|report|speedup|hetero-sim|comm-report|info> [flags]\n\
                 Run `fedskel <cmd> --help` for per-command flags."
            );
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' — try `fedskel help`"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(argv: Vec<String>) -> Result<()> {
    use fedskel::config::{standard_flags, RunConfig};
    use fedskel::coordinator::Coordinator;
    use fedskel::runtime::NativeBackend;

    let cli = standard_flags(Cli::new(
        "fedskel train",
        "run one federated training job on the native CPU backend",
    ))
    .flag("log-csv", None, "write per-round CSV log to this path")
    .flag("resume", None, "resume from a .fsnap snapshot written by --checkpoint-dir")
    .flag(
        "fixed-batch-secs",
        None,
        "pin the simulated full-model batch time to this many seconds \
         (each train bucket scales as secs x bucket/100); makes sim clocks \
         reproduce across hosts and processes",
    );
    let args = cli.parse_from(argv)?;
    let mut cfg = RunConfig { rounds: 10, ..RunConfig::default() };
    if let Some(path) = args.get("config") {
        cfg.apply_json_file(path)?;
    }
    cfg.apply_args(&args)?;
    // the native build ships exactly two models — LeNet on smnist and the
    // CIFAR-scale conv net on scifar10; refuse any other request instead
    // of silently training the wrong network
    use fedskel::data::DatasetKind;
    match (cfg.dataset, cfg.model.as_str()) {
        (DatasetKind::Smnist, "lenet_native" | "lenet_smnist") => cfg.model = "lenet_native".into(),
        (DatasetKind::Scifar10, "cifar_native" | "lenet_scifar10") => {
            cfg.model = "cifar_native".into()
        }
        (dataset, other) => bail!(
            "the native backend ships lenet_native (smnist) and cifar_native (scifar10) \
             only (got --dataset {} --model {other}) — build with --features pjrt for \
             manifest models",
            dataset.name()
        ),
    }

    fedskel::trace::set_quiet(args.bool("quiet"));
    fedskel::trace::human(&format!("config: {}", cfg.to_json().to_string()));
    if cfg.profile.is_some() {
        // enable before the coordinator is built so warm-up/probe spans
        // are captured too; the profiler only reads clocks, so the param
        // digest below is bitwise identical either way
        fedskel::prof::reset();
        fedskel::prof::enable();
    }
    let fixed_batch_secs: Option<f64> = match args.get("fixed-batch-secs") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    let mk_backend = || {
        let b = if cfg.model == "cifar_native" {
            NativeBackend::cifar()
        } else {
            NativeBackend::lenet()
        };
        let b = b.with_parallelism(
            fedskel::kernels::Parallelism::new(cfg.threads).with_tier(cfg.kernel_tier),
        );
        match fixed_batch_secs {
            Some(secs) => {
                use fedskel::runtime::Backend as _;
                let map = b
                    .spec()
                    .train_buckets()
                    .into_iter()
                    .map(|bk| (bk, secs * bk as f64 / 100.0))
                    .collect();
                b.with_fixed_batch_secs(map)
            }
            None => b,
        }
    };
    // --workers N trains N clients concurrently (NativeBackend is Send,
    // so the native CLI can build the pool the plain constructor refuses)
    let mut coord = match (args.get("resume"), cfg.workers > 0) {
        (Some(snap), true) => {
            let workers: Vec<NativeBackend> = (0..cfg.workers).map(|_| mk_backend()).collect();
            Coordinator::restore_with_pool(cfg.clone(), mk_backend(), workers, Path::new(snap))?
        }
        (Some(snap), false) => Coordinator::restore(cfg.clone(), mk_backend(), Path::new(snap))?,
        (None, true) => {
            let workers: Vec<NativeBackend> = (0..cfg.workers).map(|_| mk_backend()).collect();
            Coordinator::with_pool(cfg.clone(), mk_backend(), workers)?
        }
        (None, false) => Coordinator::new(cfg.clone(), mk_backend())?,
    };
    if let Some(snap) = args.get("resume") {
        fedskel::trace::human(&format!("resumed from {snap} at round {}", coord.round_idx()));
    }
    fedskel::trace::human(&format!(
        "{} clients on {} ({}), {} rounds, method {} — native CPU backend, \
         {} worker(s), ≤{} kernel thread(s)/client, {} kernels, {} clients, \
         sched {} (deadline {}s, buffer-k {}, staleness-alpha {}), compress {}{}{}",
        cfg.num_clients,
        cfg.dataset.name(),
        cfg.model,
        cfg.rounds,
        cfg.method.name(),
        cfg.workers,
        cfg.threads,
        cfg.kernel_tier.name(),
        cfg.client_precision.name(),
        cfg.sched.name(),
        cfg.deadline_secs,
        cfg.buffer_k,
        cfg.staleness_alpha,
        cfg.compress.name(),
        if cfg.error_feedback { "+ef" } else { "" },
        if cfg.delta_down { "+delta-down" } else { "" },
    ));
    for r in coord.round_idx()..cfg.rounds {
        coord.step_round()?;
        let log = coord.log.rounds.last().unwrap();
        let sched_note = if log.dropped > 0 || log.stale > 0 {
            format!("  drop {} stale {}", log.dropped, log.stale)
        } else {
            String::new()
        };
        fedskel::trace::human(&format!(
            "round {:>4} [{:<10}] loss {:.4} comm {:>10} sim {:>8.3}s wall {:>7.2}s{}{}{}",
            r,
            log.phase,
            log.mean_loss,
            log.comm_params,
            log.sim_round_secs,
            log.wall_secs,
            log.new_acc.map(|a| format!("  new {:.2}%", a * 100.0)).unwrap_or_default(),
            log.local_acc.map(|a| format!("  local {:.2}%", a * 100.0)).unwrap_or_default(),
            sched_note,
        ));
    }
    let new_acc = coord.evaluate_new()?;
    let local_acc = coord.evaluate_local()?;
    println!(
        "final: new {:.2}%  local {:.2}%  total comm {} params",
        new_acc * 100.0,
        local_acc * 100.0,
        coord.ledger.total_params()
    );
    println!(
        "wire: {} bytes ({} raw f32 frame bytes, {:.2}x achieved compression)",
        coord.ledger.total_wire_bytes(),
        coord.ledger.total_raw_bytes(),
        coord.ledger.compression_ratio()
    );
    // bitwise fingerprint of the trained global model — CI compares this
    // across --threads values to pin kernel determinism end-to-end
    println!("param digest: {:#018x}", fedskel::model::params_digest(&coord.global));
    if let Some(path) = args.get("log-csv") {
        coord.log.save_csv(path)?;
        println!("wrote {path}");
    }
    finish_profile(&cfg)?;
    Ok(())
}

/// When `--profile PATH` is set: export the run's spans as a Chrome
/// trace and print the self-time attribution table. Shared by both
/// backends' `cmd_train`.
fn finish_profile(cfg: &fedskel::config::RunConfig) -> Result<()> {
    let Some(path) = &cfg.profile else {
        return Ok(());
    };
    fedskel::prof::disable();
    let export = fedskel::prof::export_chrome(Path::new(path))?;
    print!("{}", fedskel::prof::attribution_table(24));
    let dropped = if export.dropped > 0 {
        format!(", {} dropped at the buffer cap", export.dropped)
    } else {
        String::new()
    };
    println!(
        "wrote {path} ({} span events across {} thread(s){dropped})",
        export.events, export.threads
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train(argv: Vec<String>) -> Result<()> {
    let cli = standard_flags(Cli::new("fedskel train", "run one federated training job"))
        .flag("log-csv", None, "write per-round CSV log to this path")
        .flag("resume", None, "resume from a .fsnap snapshot written by --checkpoint-dir");
    let args = cli.parse_from(argv)?;
    let mut cfg = RunConfig::default();
    if let Some(path) = args.get("config") {
        cfg.apply_json_file(path)?;
    }
    cfg.apply_args(&args)?;

    fedskel::trace::set_quiet(args.bool("quiet"));
    fedskel::trace::human(&format!("config: {}", cfg.to_json().to_string()));
    if cfg.profile.is_some() {
        fedskel::prof::reset();
        fedskel::prof::enable();
    }
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let backend = PjrtBackend::new(&manifest, &cfg.model)?;
    let mut coord = match args.get("resume") {
        Some(snap) => Coordinator::restore(cfg.clone(), backend, Path::new(snap))?,
        None => Coordinator::new(cfg.clone(), backend)?,
    };

    fedskel::trace::human(&format!(
        "{} clients on {} ({}), {} rounds, method {}, sched {}",
        cfg.num_clients,
        cfg.dataset.name(),
        cfg.model,
        cfg.rounds,
        cfg.method.name(),
        cfg.sched.name()
    ));
    for r in coord.round_idx()..cfg.rounds {
        coord.step_round()?;
        let log = coord.log.rounds.last().unwrap();
        let sched_note = if log.dropped > 0 || log.stale > 0 {
            format!("  drop {} stale {}", log.dropped, log.stale)
        } else {
            String::new()
        };
        fedskel::trace::human(&format!(
            "round {:>4} [{:<10}] loss {:.4} comm {:>10} sim {:>8.3}s wall {:>7.2}s{}{}{}",
            r,
            log.phase,
            log.mean_loss,
            log.comm_params,
            log.sim_round_secs,
            log.wall_secs,
            log.new_acc.map(|a| format!("  new {:.2}%", a * 100.0)).unwrap_or_default(),
            log.local_acc.map(|a| format!("  local {:.2}%", a * 100.0)).unwrap_or_default(),
            sched_note,
        ));
    }
    let new_acc = coord.evaluate_new()?;
    let local_acc = coord.evaluate_local()?;
    println!(
        "final: new {:.2}%  local {:.2}%  total comm {} params",
        new_acc * 100.0,
        local_acc * 100.0,
        coord.ledger.total_params()
    );
    println!("param digest: {:#018x}", fedskel::model::params_digest(&coord.global));
    if let Some(path) = args.get("log-csv") {
        coord.log.save_csv(path)?;
        println!("wrote {path}");
    }
    finish_profile(&cfg)?;
    Ok(())
}

/// `fedskel serve` — the multi-process deployment's coordinator. All
/// federation state (sampling, skeletons, aggregation, the virtual
/// clock, checkpoints) lives here; `fedskel client` processes are
/// stateless workers that execute shipped `TrainJob`s. Because remote
/// execution runs the same `run_local_steps` the in-process pool runs
/// and the proto codec round-trips jobs bitwise, the param digest this
/// prints equals the digest of `fedskel train` with the same flags —
/// `tests/e2e_multiprocess.rs` locks that in.
#[cfg(not(feature = "pjrt"))]
fn cmd_serve(argv: Vec<String>) -> Result<()> {
    use std::io::Write as _;
    use std::time::Duration;

    use fedskel::config::{standard_flags, RunConfig};
    use fedskel::coordinator::remote::RemoteFleet;
    use fedskel::coordinator::Coordinator;
    use fedskel::data::DatasetKind;
    use fedskel::runtime::{Backend as _, NativeBackend};

    let cli = standard_flags(Cli::new(
        "fedskel serve",
        "run the coordinator, dispatching local training to remote `fedskel client` \
         worker processes over TCP",
    ))
    .flag("listen", Some("127.0.0.1:0"), "TCP listen address (port 0 = OS-assigned)")
    .flag("min-clients", Some("1"), "wait for this many workers before round 0")
    .flag("join-timeout-secs", Some("60"), "give up if min-clients have not joined in time")
    .flag("log-csv", None, "write per-round CSV log to this path")
    .flag("resume", None, "resume from a .fsnap snapshot written by --checkpoint-dir")
    .flag(
        "fixed-batch-secs",
        None,
        "pin the simulated full-model batch time to this many seconds \
         (each train bucket scales as secs x bucket/100); makes sim clocks \
         reproduce across hosts and processes",
    );
    let args = cli.parse_from(argv)?;
    let mut cfg = RunConfig { rounds: 10, ..RunConfig::default() };
    if let Some(path) = args.get("config") {
        cfg.apply_json_file(path)?;
    }
    cfg.apply_args(&args)?;
    // the worker fleet is remote and dynamic; an in-process pool size is
    // meaningless here
    cfg.workers = 0;
    match (cfg.dataset, cfg.model.as_str()) {
        (DatasetKind::Smnist, "lenet_native" | "lenet_smnist") => cfg.model = "lenet_native".into(),
        (DatasetKind::Scifar10, "cifar_native" | "lenet_scifar10") => {
            cfg.model = "cifar_native".into()
        }
        (dataset, other) => bail!(
            "the native backend ships lenet_native (smnist) and cifar_native (scifar10) \
             only (got --dataset {} --model {other})",
            dataset.name()
        ),
    }

    fedskel::trace::set_quiet(args.bool("quiet"));
    fedskel::trace::human(&format!("config: {}", cfg.to_json().to_string()));
    if cfg.profile.is_some() {
        fedskel::prof::reset();
        fedskel::prof::enable();
    }
    let fixed_batch_secs: Option<f64> = match args.get("fixed-batch-secs") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    let mk_backend = || {
        let b = if cfg.model == "cifar_native" {
            NativeBackend::cifar()
        } else {
            NativeBackend::lenet()
        };
        let b = b.with_parallelism(
            fedskel::kernels::Parallelism::new(cfg.threads).with_tier(cfg.kernel_tier),
        );
        match fixed_batch_secs {
            Some(secs) => {
                let map = b
                    .spec()
                    .train_buckets()
                    .into_iter()
                    .map(|bk| (bk, secs * bk as f64 / 100.0))
                    .collect();
                b.with_fixed_batch_secs(map)
            }
            None => b,
        }
    };

    // bind + announce before waiting: whoever spawned us (the E2E
    // harness, an operator script) reads the OS-assigned port from this
    // line and starts the workers
    let key = fedskel::snapshot::determinism_key(&cfg);
    let spec = mk_backend().spec().clone();
    let mut fleet = RemoteFleet::new(args.str("listen")?, spec, &cfg.model, &key)?;
    let addr = fleet
        .local_addr()
        .ok_or_else(|| anyhow::anyhow!("listener has no bound address"))?;
    println!("listening on {addr}");
    std::io::stdout().flush()?;
    let min = args.usize("min-clients")?;
    let timeout = Duration::from_secs_f64(args.f64("join-timeout-secs")?);
    let joined = fleet.wait_for_workers(min, timeout)?;
    for (slot, name) in fleet.roster() {
        fedskel::trace::human(&format!("worker slot {slot}: {name}"));
    }
    fedskel::trace::human(&format!("{joined} worker(s) joined; starting"));

    let mut coord = match args.get("resume") {
        Some(snap) => {
            Coordinator::restore_with_remote(cfg.clone(), mk_backend(), fleet, Path::new(snap))?
        }
        None => Coordinator::with_remote(cfg.clone(), mk_backend(), fleet)?,
    };
    if let Some(snap) = args.get("resume") {
        fedskel::trace::human(&format!("resumed from {snap} at round {}", coord.round_idx()));
    }
    for r in coord.round_idx()..cfg.rounds {
        coord.step_round()?;
        let log = coord.log.rounds.last().unwrap();
        let sched_note = if log.dropped > 0 || log.stale > 0 {
            format!("  drop {} stale {}", log.dropped, log.stale)
        } else {
            String::new()
        };
        fedskel::trace::human(&format!(
            "round {:>4} [{:<10}] loss {:.4} comm {:>10} sim {:>8.3}s wall {:>7.2}s{}{}{}",
            r,
            log.phase,
            log.mean_loss,
            log.comm_params,
            log.sim_round_secs,
            log.wall_secs,
            log.new_acc.map(|a| format!("  new {:.2}%", a * 100.0)).unwrap_or_default(),
            log.local_acc.map(|a| format!("  local {:.2}%", a * 100.0)).unwrap_or_default(),
            sched_note,
        ));
    }
    let new_acc = coord.evaluate_new()?;
    let local_acc = coord.evaluate_local()?;
    println!(
        "final: new {:.2}%  local {:.2}%  total comm {} params",
        new_acc * 100.0,
        local_acc * 100.0,
        coord.ledger.total_params()
    );
    println!(
        "wire: {} bytes ({} raw f32 frame bytes, {:.2}x achieved compression)",
        coord.ledger.total_wire_bytes(),
        coord.ledger.total_raw_bytes(),
        coord.ledger.compression_ratio()
    );
    println!("param digest: {:#018x}", fedskel::model::params_digest(&coord.global));
    if let Some(path) = args.get("log-csv") {
        coord.log.save_csv(path)?;
        println!("wrote {path}");
    }
    if let Some(fleet) = coord.remote_mut() {
        fleet.shutdown("run complete");
    }
    finish_profile(&cfg)?;
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(_argv: Vec<String>) -> Result<()> {
    bail!("`fedskel serve` drives the native CPU backend; rebuild without `--features pjrt`");
}

/// `fedskel client` — a stateless remote worker. Connects, handshakes
/// (wire version + determinism key), then executes `Job` frames with the
/// same `run_local_steps` the in-process pool uses and mails back
/// `Outcome`s until the server says `Shutdown`. Holding no federation
/// state, it survives a coordinator SIGKILL by simply reconnecting —
/// the resumed server re-ships whatever the round needs.
#[cfg(not(feature = "pjrt"))]
fn cmd_client(argv: Vec<String>) -> Result<()> {
    use std::time::Duration;

    use fedskel::model::ModelSpec;
    use fedskel::runtime::{Backend as _, NativeBackend};
    use fedskel::transport::pool::run_local_steps;
    use fedskel::transport::proto::{self, CtrlMsg};
    use fedskel::transport::tcp::TcpTransport;
    use fedskel::transport::{wire, Envelope, Peer, Transport as _};

    let cli = Cli::new(
        "fedskel client",
        "join a `fedskel serve` coordinator as a stateless remote worker",
    )
    .flag("connect", None, "server address, e.g. 127.0.0.1:7700 (required)")
    .flag(
        "worker-id",
        None,
        "this worker's raw peer id (default: the process id); must be unique per server",
    )
    .flag(
        "reconnect-secs",
        Some("60"),
        "keep retrying a dead server this long before giving up (rides out restarts)",
    )
    .switch("quiet", "suppress human progress lines");
    let args = cli.parse_from(argv)?;
    fedskel::trace::set_quiet(args.bool("quiet"));
    let Some(addr) = args.get("connect") else {
        bail!("`fedskel client` needs --connect HOST:PORT (see serve's \"listening on\" line)");
    };
    let addr = addr.to_string();
    let raw_id: usize = match args.get("worker-id") {
        Some(v) => v.parse()?,
        None => std::process::id() as usize,
    };
    let reconnect = Duration::from_secs_f64(args.f64("reconnect-secs")?);
    let me = Peer::Client(raw_id);
    // a reconnecting worker echoes the key it was welcomed with, so a
    // *different* run reusing the address rejects it instead of mixing
    let mut key = String::new();

    'session: loop {
        let mut t = TcpTransport::connect_with_backoff(&addr, me, reconnect)?;
        let hello = proto::encode(&CtrlMsg::Hello {
            wire_version: wire::VERSION,
            determinism_key: key.clone(),
            worker: format!("w{raw_id}"),
        });
        if t.send(Envelope { from: me, to: Peer::Server, frame: hello }).is_err() {
            continue 'session;
        }
        let mut backend: Option<NativeBackend> = None;
        let mut spec: Option<ModelSpec> = None;
        loop {
            let env = match t.recv_wait(me, Duration::from_millis(200))? {
                Some(env) => env,
                None => {
                    if t.connected().is_empty() {
                        // the server went away mid-run (crash, SIGKILL):
                        // nothing to preserve — reconnect and re-handshake
                        fedskel::trace::human(&format!(
                            "worker {raw_id}: lost {addr}, reconnecting"
                        ));
                        continue 'session;
                    }
                    continue;
                }
            };
            // Welcome always precedes the first Job on this ordered
            // connection, so `spec` is set before any Job must decode
            let Ok(msg) = proto::decode(&env.frame, spec.as_ref()) else { continue };
            match msg {
                CtrlMsg::Welcome { slot, model, determinism_key } => {
                    key = determinism_key;
                    let b = match model.as_str() {
                        "lenet_native" => NativeBackend::lenet(),
                        "cifar_native" => NativeBackend::cifar(),
                        other => bail!(
                            "server runs model '{other}', which this native worker cannot build"
                        ),
                    };
                    spec = Some(b.spec().clone());
                    backend = Some(b);
                    fedskel::trace::human(&format!(
                        "worker {raw_id}: welcomed by {addr} as slot {slot} ({model})"
                    ));
                }
                CtrlMsg::Job { seq, job } => {
                    let Some(b) = backend.as_mut() else { continue };
                    let outcome = run_local_steps(b, job)?;
                    let frame = proto::encode(&CtrlMsg::Outcome { seq, outcome });
                    if t.send(Envelope { from: me, to: Peer::Server, frame }).is_err() {
                        continue 'session;
                    }
                }
                CtrlMsg::Shutdown { reason } => {
                    println!("server shut down: {reason}");
                    return Ok(());
                }
                CtrlMsg::Reject { reason } => bail!("server rejected this worker: {reason}"),
                // servers never legitimately send these
                CtrlMsg::Hello { .. } | CtrlMsg::Outcome { .. } => {}
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn cmd_client(_argv: Vec<String>) -> Result<()> {
    bail!("`fedskel client` drives the native CPU backend; rebuild without `--features pjrt`");
}

/// `fedskel profile` — a short profiled training run. Sugar for
/// `fedskel train --profile profile.json --rounds 2` that keeps every
/// train flag available; explicit `--profile`/`--rounds` flags win.
fn cmd_profile(mut argv: Vec<String>) -> Result<()> {
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        println!(
            "fedskel profile — short profiled train: span attribution + Chrome trace\n\n\
             Runs `fedskel train` with `--profile profile.json --rounds 2` defaults\n\
             (override either); accepts every `fedskel train` flag."
        );
        return Ok(());
    }
    let has = |flag: &str, argv: &[String]| {
        argv.iter().any(|a| a == flag || a.starts_with(&format!("{flag}=")))
    };
    if !has("--profile", &argv) {
        argv.extend(["--profile".to_string(), "profile.json".to_string()]);
    }
    if !has("--rounds", &argv) {
        argv.extend(["--rounds".to_string(), "2".to_string()]);
    }
    cmd_train(argv)
}

fn cmd_watch(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new(
        "fedskel watch",
        "terminal dashboard over a trace.jsonl — accuracy curve, wire vs raw \
         bytes, fleet utilization, drops/staleness",
    )
    .flag("replay", None, "render a recorded trace once and exit")
    .switch("follow", "keep re-reading the file (tail a live run)")
    .flag("interval-ms", Some("500"), "refresh interval in --follow mode")
    .flag("profile", None, "append the self-time attribution table from this Chrome-trace profile");
    let args = cli.parse_from(argv)?;
    let interval = args.u64("interval-ms")?;
    let profile = args.get("profile").map(Path::new);
    if let Some(path) = args.get("replay") {
        return fedskel::trace::watch::watch(Path::new(path), false, interval, profile);
    }
    let Some(path) = args.positional.first() else {
        bail!(
            "usage: fedskel watch <trace.jsonl> [--follow] or \
             fedskel watch --replay <trace.jsonl>"
        );
    };
    fedskel::trace::watch::watch(Path::new(path), args.bool("follow"), interval, profile)
}

fn cmd_report(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new(
        "fedskel report",
        "strictly replay a trace.jsonl into the run's summary and round tables",
    )
    .flag("csv", None, "write the replayed per-round CSV log to this path")
    .flag("json", None, "write the replayed per-round JSON log to this path")
    .flag("metrics", None, "write the folded metrics registry (JSON) to this path")
    .flag("profile", None, "summarize a Chrome-trace profile exported by train --profile");
    let args = cli.parse_from(argv)?;
    // --profile alone summarizes a profile with no trace required
    if let Some(prof) = args.get("profile") {
        print!("{}", fedskel::prof::report_from_chrome(Path::new(prof))?);
        if args.positional.is_empty() {
            return Ok(());
        }
    }
    let Some(path) = args.positional.first() else {
        bail!(
            "usage: fedskel report <trace.jsonl> [--csv PATH] [--json PATH] [--metrics PATH] \
             [--profile PATH]"
        );
    };
    let replay = fedskel::trace::replay::read_trace(Path::new(path))?;
    println!("validated {} events (trace v{})", replay.events, replay.version);
    print!("{}", fedskel::trace::replay::summary_table(&replay));
    if let Some(out) = args.get("csv") {
        replay.folder.log.save_csv(out)?;
        println!("wrote {out}");
    }
    if let Some(out) = args.get("json") {
        let mut body = replay.folder.log.to_json().to_string();
        body.push('\n');
        std::fs::write(out, body)?;
        println!("wrote {out}");
    }
    if let Some(out) = args.get("metrics") {
        let mut body = replay.folder.registry.to_json().to_string();
        body.push('\n');
        std::fs::write(out, body)?;
        println!("wrote {out}");
        // and the percentile view of every folded histogram on stdout
        let mut t = fedskel::metrics::Table::new(&["histogram", "count", "mean", "p50", "p95", "p99"]);
        let mut any = false;
        for (name, h) in replay.folder.registry.histograms() {
            any = true;
            t.row(vec![
                name.to_string(),
                h.count.to_string(),
                format!("{:.6}", h.mean()),
                format!("{:.6}", h.quantile(0.50)),
                format!("{:.6}", h.quantile(0.95)),
                format!("{:.6}", h.quantile(0.99)),
            ]);
        }
        if any {
            print!("{}", t.render());
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_speedup(argv: Vec<String>) -> Result<()> {
    use fedskel::bench::table1_native;
    use fedskel::kernels::{KernelTier, Parallelism};
    use fedskel::runtime::NativeModel;

    let cli = Cli::new(
        "fedskel speedup",
        "Table 1 on the native CPU backend: backprop & overall speedups per skeleton ratio",
    )
    .flag("out", Some("BENCH_table1_native.json"), "JSON report path")
    .flag("samples", Some("10"), "timing samples")
    .flag("model", Some("lenet"), "native model to measure: lenet|cifar")
    .flag("ratios", Some("100,50,40,25,10"), "skeleton ratio % list (comma list)")
    .flag("threads", Some("1,2,4"), "thread counts to sweep (comma list)")
    .flag("tiers", Some("scalar,simd"), "kernel tiers to sweep (comma list)")
    .flag(
        "gate-simd-min",
        Some("0"),
        "fail unless simd bwd GFLOP/s ≥ this × scalar's (0 = no gate)",
    );
    let args = cli.parse_from(argv)?;
    let model = match args.str("model")? {
        "lenet" | "lenet_native" => NativeModel::lenet(),
        "cifar" | "cifar_native" => NativeModel::cifar(),
        other => bail!("unknown native model '{other}' — valid models: lenet|cifar"),
    };
    let tiers = args
        .str("tiers")?
        .split(',')
        .map(|t| KernelTier::parse(t.trim()))
        .collect::<Result<Vec<KernelTier>>>()?;
    let (report, rows) = table1_native::run_with(
        &model,
        &args.usize_list("ratios")?,
        &args.usize_list("threads")?,
        &tiers,
        args.usize("samples")?,
        args.str("out")?,
    )?;
    println!("{report}");
    // per-layer forward-GEMM throughput at each measured tier, serial —
    // the absolute-throughput view behind the table's speedup columns
    let bench = fedskel::benchkit::Bench::new(if args.usize("samples")? <= 1 { 0 } else { 1 }, 3);
    for &tier in &tiers {
        let m = model.clone().with_parallelism(Parallelism::new(1).with_tier(tier));
        println!("per-layer forward GEMM GFLOP/s (tier {}, 1 thread):", tier.name());
        for (name, gflops) in table1_native::per_layer_gflops(&m, &bench) {
            println!("  {name:<28} {gflops:>8.2}");
        }
    }
    let gate = args.f64("gate-simd-min")?;
    if gate > 0.0 {
        println!("{}", table1_native::gate_simd_floor(&rows, gate)?);
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_speedup(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("fedskel speedup", "Table 1: backprop & overall speedups per skeleton ratio")
        .flag("artifacts", Some("artifacts"), "artifacts dir")
        .flag("ratios", Some("40,30,20,10"), "ratio % list")
        .flag("samples", Some("10"), "timing samples");
    let args = cli.parse_from(argv)?;
    let manifest = Manifest::load(args.str("artifacts")?)?;
    let report =
        fedskel::bench::table1::run(&manifest, &args.usize_list("ratios")?, args.usize("samples")?)?;
    println!("{report}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_hetero(_argv: Vec<String>) -> Result<()> {
    bail!("`fedskel hetero-sim` measures AOT artifacts and needs the `pjrt` feature");
}

#[cfg(feature = "pjrt")]
fn cmd_hetero(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("fedskel hetero-sim", "Fig. 5: per-client batch times, FedSkel vs FedAvg")
        .flag("artifacts", Some("artifacts"), "artifacts dir")
        .flag("devices", Some("8"), "fleet size")
        .flag("samples", Some("5"), "timing samples");
    let args = cli.parse_from(argv)?;
    let manifest = Manifest::load(args.str("artifacts")?)?;
    let report = fedskel::bench::fig5::run(&manifest, args.usize("devices")?, args.usize("samples")?)?;
    println!("{report}");
    Ok(())
}

fn cmd_comm(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("fedskel comm-report", "Table 2: parameter-communication volume per method")
        .flag("artifacts", Some("artifacts"), "artifacts dir")
        .flag("model", Some("lenet_smnist"), "manifest model")
        .flag("clients", Some("100"), "clients")
        .flag("rounds", Some("1000"), "rounds")
        .flag("ratio", Some("10"), "FedSkel ratio %");
    let args = cli.parse_from(argv)?;
    let manifest = Manifest::load(args.str("artifacts")?)?;
    let report = fedskel::bench::table2::run(
        &manifest,
        args.str("model")?,
        args.usize("clients")?,
        args.usize("rounds")?,
        args.usize("ratio")?,
    )?;
    println!("{report}");
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("fedskel info", "print the artifact manifest inventory")
        .flag("artifacts", Some("artifacts"), "artifacts dir");
    let args = cli.parse_from(argv)?;
    let manifest = Manifest::load(args.str("artifacts")?)?;
    for (name, m) in &manifest.models {
        println!(
            "{name}: {} params, {} prunable layers, classes {}, buckets {:?}",
            m.num_params,
            m.prunable.len(),
            m.num_classes,
            m.train_buckets()
        );
    }
    for (group, variants) in &manifest.bench {
        println!("bench {group}: {:?}", variants.keys().collect::<Vec<_>>());
    }
    Ok(())
}
