//! Hierarchical span profiler: where does a training round's wall time go?
//!
//! FedSkel's headline numbers are *time* claims (up to 5.52× CONV
//! back-prop, 1.82× end-to-end), so the repo needs per-kernel,
//! per-phase wall-time attribution to show the k/C FLOP reduction
//! actually lands as seconds across the scalar/SIMD/int8 tiers. The
//! `trace` subsystem records *what happened* per round; this module
//! records *where the time went* inside a step.
//!
//! ## Design
//!
//! [`scope`] returns an RAII guard that times a named span on the
//! calling thread using monotonic [`Instant`]s. Spans nest via a
//! thread-local stack: a `gemm:simd` span opened while
//! `train_step/forward` is live aggregates under the *path*
//! `train_step/forward/gemm:simd`. On guard drop the duration is folded
//! into a per-(path, thread) sheet — count, total, child time (for
//! self-time attribution), a fixed-bucket [`Histogram`] for
//! p50/p95/p99 — and appended to a bounded Chrome-trace event buffer.
//!
//! Profiling is **off by default** and must never perturb results: the
//! disabled [`scope`] path is a single relaxed atomic load returning an
//! inert guard, and the profiler only ever *reads* clocks — parameter
//! digests are bitwise identical with profiling on or off, a contract
//! gated in CI by `BENCH_prof_overhead.json`
//! ([`crate::bench::prof_overhead`]).
//!
//! ## Span vocabulary
//!
//! Instrumented call-sites use stable names (documented in
//! `docs/OBSERVABILITY.md`): kernels per tier (`gemm:scalar`,
//! `gemm:simd`, `gemm:int8`, `gemm_bt_a:*`, `im2col`, `col_sums`,
//! `maxpool_fwd`), runtime phases (`train_step`, `forward`, `loss`,
//! `backward:sliced` / `backward:full`, `sgd_step`), transport
//! (`encode:*` / `decode:*` per frame kind, `checksum`), compression
//! (`compress/<kind>`, `ef_fold`), and coordinator round phases
//! (`round/select|download|dispatch|upload|aggregate|eval|checkpoint`).
//!
//! Parallel kernels are spanned on the *caller* thread around the whole
//! fork/join, so a kernel span includes its spawn/join overhead and the
//! timing tree stays single-rooted per thread.
//!
//! ## Output
//!
//! [`export_chrome`] writes Chrome Trace Event Format JSON (`ph:"X"`
//! complete events, microsecond `ts`/`dur`) loadable in
//! `chrome://tracing` or Perfetto. [`span_stats`] /
//! [`attribution_table`] give the merged timing tree and a
//! self-time-ranked table; [`drain_into_registry`] folds each path into
//! a [`Registry`] histogram named `prof/<path>`.
//!
//! ```
//! use fedskel::prof;
//!
//! prof::reset();
//! prof::enable();
//! {
//!     let _step = prof::scope("train_step");
//!     let _fwd = prof::scope("forward");
//! } // guards drop in reverse order; durations fold into the sheet
//! prof::disable();
//! let stats = prof::span_stats();
//! assert_eq!(stats["train_step/forward"].count, 1);
//! ```

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::metrics::Table;
use crate::trace::registry::{Histogram, Registry};
use crate::util::json::{self, Json};

/// Per-thread cap on buffered Chrome events; completions beyond it are
/// still aggregated (stats stay exact) but drop their timeline event,
/// counted in [`dropped_events`].
pub const MAX_EVENTS_PER_THREAD: usize = 1 << 18;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Turn span collection on (globally, all threads).
pub fn enable() {
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn span collection off. Guards already armed still record on drop.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Is the profiler currently collecting?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Process-lifetime time origin for Chrome `ts` values.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Aggregated timings for one span path (merged across threads by
/// [`span_stats`]).
#[derive(Debug, Clone, Default)]
pub struct SpanStat {
    /// Completed spans at this path.
    pub count: u64,
    /// Total wall seconds across completions.
    pub total_secs: f64,
    /// Wall seconds spent inside child spans of this path.
    pub child_secs: f64,
    /// Distribution of per-completion durations (seconds).
    pub hist: Histogram,
}

impl SpanStat {
    /// Time at this path not attributed to any child span.
    pub fn self_secs(&self) -> f64 {
        (self.total_secs - self.child_secs).max(0.0)
    }

    fn merge(&mut self, other: &SpanStat) {
        self.count += other.count;
        self.total_secs += other.total_secs;
        self.child_secs += other.child_secs;
        self.hist.merge(&other.hist);
    }
}

/// One buffered Chrome `ph:"X"` event.
struct ChromeEvent {
    path: String,
    ts_us: u64,
    dur_us: u64,
}

/// Everything one thread has recorded; shared with the global sheet
/// list so the main thread can drain without thread exit.
#[derive(Default)]
struct Sheet {
    tid: u64,
    stats: BTreeMap<String, SpanStat>,
    events: Vec<ChromeEvent>,
    dropped: u64,
}

/// All threads' sheets, registered on each thread's first span.
static SHEETS: Mutex<Vec<Arc<Mutex<Sheet>>>> = Mutex::new(Vec::new());

fn sheets() -> &'static Mutex<Vec<Arc<Mutex<Sheet>>>> {
    &SHEETS
}

/// A live span on this thread's stack.
struct Frame {
    path: String,
    start: Instant,
    child_secs: f64,
}

struct Local {
    stack: Vec<Frame>,
    sheet: Arc<Mutex<Sheet>>,
}

impl Local {
    fn new() -> Local {
        let sheet = Arc::new(Mutex::new(Sheet {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            ..Sheet::default()
        }));
        sheets().lock().unwrap().push(Arc::clone(&sheet));
        Local { stack: Vec::new(), sheet }
    }
}

thread_local! {
    static LOCAL: RefCell<Local> = RefCell::new(Local::new());
}

/// RAII guard returned by [`scope`]; records the span when dropped.
#[must_use = "a span guard times until it is dropped — bind it to a variable"]
pub struct SpanGuard {
    armed: bool,
}

/// Open a span named `name` on the calling thread. When profiling is
/// disabled this is one relaxed atomic load and an inert guard — safe
/// to leave in the hottest kernels. Names must be `'static` (span paths
/// are built by joining the live stack's names with `/`).
pub fn scope(name: &'static str) -> SpanGuard {
    if !ENABLED.load(Ordering::Relaxed) {
        return SpanGuard { armed: false };
    }
    let armed = LOCAL
        .try_with(|l| {
            let mut l = l.borrow_mut();
            let path = match l.stack.last() {
                Some(parent) => format!("{}/{}", parent.path, name),
                None => name.to_string(),
            };
            l.stack.push(Frame { path, start: Instant::now(), child_secs: 0.0 });
        })
        .is_ok();
    SpanGuard { armed }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        // try_with: never panic out of a destructor during TLS teardown.
        let _ = LOCAL.try_with(|l| {
            let mut l = l.borrow_mut();
            let Some(frame) = l.stack.pop() else { return };
            let dur = frame.start.elapsed().as_secs_f64();
            if let Some(parent) = l.stack.last_mut() {
                parent.child_secs += dur;
            }
            let ts_us = frame.start.duration_since(epoch()).as_micros() as u64;
            let mut sheet = l.sheet.lock().unwrap();
            let stat = sheet.stats.entry(frame.path.clone()).or_default();
            stat.count += 1;
            stat.total_secs += dur;
            stat.child_secs += frame.child_secs;
            stat.hist.observe(dur);
            if sheet.events.len() < MAX_EVENTS_PER_THREAD {
                let dur_us = (dur * 1e6) as u64;
                sheet.events.push(ChromeEvent { path: frame.path, ts_us, dur_us });
            } else {
                sheet.dropped += 1;
            }
        });
    }
}

/// Clear all recorded spans and buffered events on every thread (live
/// span stacks are untouched — call between runs, not mid-span).
pub fn reset() {
    for sheet in sheets().lock().unwrap().iter() {
        let mut s = sheet.lock().unwrap();
        s.stats.clear();
        s.events.clear();
        s.dropped = 0;
    }
}

/// Timeline events dropped to the per-thread buffer cap (their
/// durations still count in [`span_stats`]).
pub fn dropped_events() -> u64 {
    sheets().lock().unwrap().iter().map(|s| s.lock().unwrap().dropped).sum()
}

/// The merged timing tree: every span path observed on any thread, with
/// cross-thread aggregated stats, in deterministic (sorted-path) order.
pub fn span_stats() -> BTreeMap<String, SpanStat> {
    let mut out: BTreeMap<String, SpanStat> = BTreeMap::new();
    for sheet in sheets().lock().unwrap().iter() {
        let s = sheet.lock().unwrap();
        for (path, stat) in &s.stats {
            out.entry(path.clone()).or_default().merge(stat);
        }
    }
    out
}

/// Fraction of wall time at spans whose leaf name is `leaf` that is
/// covered by child spans — the "≥90% of train-step time attributed"
/// acceptance gate uses `coverage_of("train_step")`. `None` if the leaf
/// was never observed (or recorded zero time).
pub fn coverage_of(leaf: &str) -> Option<f64> {
    let suffix = format!("/{leaf}");
    let (mut total, mut child) = (0.0f64, 0.0f64);
    for (path, stat) in span_stats() {
        if path == leaf || path.ends_with(&suffix) {
            total += stat.total_secs;
            child += stat.child_secs;
        }
    }
    if total > 0.0 {
        Some((child / total).clamp(0.0, 1.0))
    } else {
        None
    }
}

/// Fold every span path into `reg` as a `prof/<path>` histogram of
/// per-completion durations (seconds), percentile-queryable via
/// [`Histogram::quantile`].
pub fn drain_into_registry(reg: &mut Registry) {
    for (path, stat) in span_stats() {
        reg.merge_histogram(&format!("prof/{path}"), &stat.hist);
    }
}

fn leaf(path: &str) -> &str {
    path.rsplit('/').next().unwrap_or(path)
}

/// Render the merged timing tree as a self-time-ranked attribution
/// table (top `limit` paths): count, total, self time + share, and
/// per-completion p50/p95/p99. Self-time share is against the sum of
/// all self times, which equals the total profiled wall time.
pub fn attribution_table(limit: usize) -> String {
    let stats = span_stats();
    let wall: f64 = stats.values().map(|s| s.self_secs()).sum();
    let mut rows: Vec<(&String, &SpanStat)> = stats.iter().collect();
    rows.sort_by(|a, b| b.1.self_secs().total_cmp(&a.1.self_secs()).then(a.0.cmp(b.0)));
    let mut t = Table::new(&[
        "span", "count", "total s", "self s", "self %", "p50 ms", "p95 ms", "p99 ms",
    ]);
    for (path, s) in rows.iter().take(limit) {
        let share = if wall > 0.0 { 100.0 * s.self_secs() / wall } else { 0.0 };
        t.row(vec![
            (*path).clone(),
            s.count.to_string(),
            format!("{:.4}", s.total_secs),
            format!("{:.4}", s.self_secs()),
            format!("{share:.1}%"),
            format!("{:.3}", s.hist.quantile(0.50) * 1e3),
            format!("{:.3}", s.hist.quantile(0.95) * 1e3),
            format!("{:.3}", s.hist.quantile(0.99) * 1e3),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "profiled wall time: {:.4} s across {} span paths",
        wall,
        stats.len()
    ));
    if let Some(cov) = coverage_of("train_step") {
        out.push_str(&format!("; train_step child coverage: {:.1}%", cov * 100.0));
    }
    out.push('\n');
    out
}

/// Schema tag stamped into exported profiles (`otherData.schema`).
pub const PROFILE_SCHEMA: &str = "fedskel.profile";
/// Profile schema revision; bump when the event shape changes
/// (revision policy in `docs/OBSERVABILITY.md`).
pub const PROFILE_VERSION: u64 = 1;

/// Counts returned by [`export_chrome`].
pub struct ChromeExport {
    /// `ph:"X"` events written.
    pub events: usize,
    /// Threads that contributed at least one event.
    pub threads: usize,
    /// Events dropped to the buffer cap (not written).
    pub dropped: u64,
}

/// Write every buffered span as Chrome Trace Event Format JSON: an
/// object with `traceEvents` (`ph:"M"` thread-name metadata plus
/// `ph:"X"` complete events, `ts`/`dur` in microseconds), loadable in
/// `chrome://tracing` / Perfetto. Event `name` is the leaf span name;
/// the full path rides in `args.path`.
pub fn export_chrome(path: &Path) -> Result<ChromeExport> {
    let mut events: Vec<Json> = Vec::new();
    let (mut n_events, mut n_threads, mut dropped) = (0usize, 0usize, 0u64);
    for sheet in sheets().lock().unwrap().iter() {
        let s = sheet.lock().unwrap();
        dropped += s.dropped;
        if s.events.is_empty() {
            continue;
        }
        n_threads += 1;
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(s.tid as f64)),
            ("name", Json::str("thread_name")),
            ("args", Json::obj(vec![("name", Json::Str(format!("fedskel-{}", s.tid)))])),
        ]));
        for ev in &s.events {
            n_events += 1;
            events.push(Json::obj(vec![
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(s.tid as f64)),
                ("name", Json::Str(leaf(&ev.path).to_string())),
                ("cat", Json::str("fedskel")),
                ("ts", Json::num(ev.ts_us as f64)),
                ("dur", Json::num(ev.dur_us as f64)),
                ("args", Json::obj(vec![("path", Json::Str(ev.path.clone()))])),
            ]));
        }
    }
    let doc = Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("schema", Json::str(PROFILE_SCHEMA)),
                ("version", Json::num(PROFILE_VERSION as f64)),
                ("dropped_events", Json::num(dropped as f64)),
            ]),
        ),
        ("traceEvents", Json::Arr(events)),
    ]);
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing profile {}", path.display()))?;
    Ok(ChromeExport { events: n_events, threads: n_threads, dropped })
}

/// Parse an exported Chrome-trace profile back into a self-time-ranked
/// attribution table (used by `fedskel report --profile` / `watch
/// --profile`, and as CI's format validator: malformed JSON, a missing
/// `traceEvents` array, or a profile with zero complete events all
/// error).
pub fn report_from_chrome(path: &Path) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading profile {}", path.display()))?;
    let doc = json::parse(&text).context("profile is not valid JSON")?;
    let events = match doc.get("traceEvents")? {
        Json::Arr(a) => a,
        _ => bail!("traceEvents is not an array"),
    };
    // (total µs, count) per path, folded from complete events.
    let mut agg: BTreeMap<String, (f64, u64)> = BTreeMap::new();
    let mut n = 0usize;
    for ev in events {
        if ev.get("ph")?.as_str()? != "X" {
            continue;
        }
        n += 1;
        let dur = ev.get("dur")?.as_f64()?;
        let path = match ev.opt("args").and_then(|a| a.opt("path")) {
            Some(p) => p.as_str()?.to_string(),
            None => ev.get("name")?.as_str()?.to_string(),
        };
        let e = agg.entry(path).or_insert((0.0, 0));
        e.0 += dur;
        e.1 += 1;
    }
    if n == 0 {
        bail!("profile has no complete (ph:\"X\") events");
    }
    let mut rows: Vec<(&String, &(f64, u64))> = agg.iter().collect();
    rows.sort_by(|a, b| b.1 .0.total_cmp(&a.1 .0).then(a.0.cmp(b.0)));
    let mut t = Table::new(&["span", "count", "total ms", "mean ms"]);
    for (path, (us, count)) in rows.iter().take(24) {
        t.row(vec![
            (*path).clone(),
            count.to_string(),
            format!("{:.3}", us / 1e3),
            format!("{:.3}", us / 1e3 / *count as f64),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!("{n} complete events across {} span paths\n", agg.len()));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The profiler is global state; tests that enable it must not run
    // interleaved with each other. Serialize on one mutex.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_scope_records_nothing() {
        let _g = lock();
        reset();
        disable();
        {
            let _s = scope("never");
        }
        assert!(!span_stats().contains_key("never"));
    }

    #[test]
    fn nested_scopes_build_paths_and_self_time() {
        let _g = lock();
        reset();
        enable();
        {
            let _outer = scope("outer");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = scope("inner");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        disable();
        let stats = span_stats();
        let outer = &stats["outer"];
        let inner = &stats["outer/inner"];
        assert_eq!(outer.count, 1);
        assert_eq!(inner.count, 1);
        assert!(outer.total_secs >= inner.total_secs);
        assert!(outer.child_secs >= inner.total_secs * 0.99);
        assert!(outer.self_secs() > 0.0);
        // coverage of "outer" = child share of outer's wall time
        let cov = coverage_of("outer").unwrap();
        assert!(cov > 0.0 && cov <= 1.0, "{cov}");
    }

    #[test]
    fn threads_merge_and_registry_drains() {
        let _g = lock();
        reset();
        enable();
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    let _a = scope("work");
                });
            }
        });
        {
            let _a = scope("work");
        }
        disable();
        let stats = span_stats();
        assert_eq!(stats["work"].count, 3);
        let mut reg = Registry::new();
        drain_into_registry(&mut reg);
        assert_eq!(reg.histogram("prof/work").unwrap().count, 3);
    }

    #[test]
    fn chrome_export_roundtrips_through_report() {
        let _g = lock();
        reset();
        enable();
        {
            let _a = scope("alpha");
            let _b = scope("beta");
        }
        disable();
        let path = std::env::temp_dir().join("fedskel_prof_export_test.json");
        // Other test threads may record spans while the profiler is
        // globally enabled, so assert lower bounds, not exact counts.
        let out = export_chrome(&path).unwrap();
        assert!(out.events >= 2, "{}", out.events);
        assert_eq!(out.dropped, 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"traceEvents\""), "{text}");
        assert!(text.contains("alpha/beta"), "{text}");
        let report = report_from_chrome(&path).unwrap();
        assert!(report.contains("alpha"), "{report}");
        assert!(report.contains("complete events"), "{report}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn attribution_table_ranks_by_self_time() {
        let _g = lock();
        reset();
        enable();
        {
            let _s = scope("slow");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        {
            let _f = scope("fast");
        }
        disable();
        // unlimited rows: concurrently-running tests may record their own
        // spans while the profiler is enabled, and a top-N cut could
        // evict the near-zero-self-time "fast" row
        let t = attribution_table(usize::MAX);
        let (islow, ifast) = (t.find("slow").unwrap(), t.find("fast").unwrap());
        assert!(islow < ifast, "{t}");
        assert!(t.contains("profiled wall time"), "{t}");
    }

    #[test]
    fn report_rejects_malformed_profiles() {
        let path = std::env::temp_dir().join("fedskel_prof_bad_test.json");
        std::fs::write(&path, "{not json").unwrap();
        assert!(report_from_chrome(&path).is_err());
        std::fs::write(&path, r#"{"traceEvents":[]}"#).unwrap();
        assert!(report_from_chrome(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
