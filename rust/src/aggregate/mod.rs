//! Server-side aggregation for every method in the paper's evaluation
//! (Tables 2–4; FedSkel's partial aggregation is §3.2). Invariant: a
//! channel no participant covered keeps its previous global value
//! bit-identically, and aggregation order is client-id order so results
//! are independent of worker scheduling.
//!
//! * [`fedavg`] — McMahan et al.'s weighted parameter averaging.
//! * [`fedskel_aggregate`] — FedSkel's partial aggregation: each client
//!   contributes only its skeleton channels of the prunable layers (plus
//!   all non-prunable parameters); the server averages per channel over
//!   the clients that actually cover it and keeps the old global value for
//!   uncovered channels.
//! * [`lg_fedavg_aggregate`] — LG-FedAvg: only the designated *global*
//!   parameter tensors (the classifier head) are averaged; representation
//!   layers stay local to each client.
//! * FedMTL needs no special aggregation — clients keep personalized
//!   models trained with a prox-to-global term (handled in the train
//!   artifact via `mu`); the server still FedAvg-aggregates to maintain
//!   the anchor model.
//!
//! Download-side masking ([`apply_download`]) is the mirror image: a
//! FedSkel client only *receives* its skeleton channels, which is where
//! the personalization the paper reports comes from (non-skeleton channels
//! keep their local values).

use anyhow::{bail, Result};

use crate::model::{Params, PrunableSpec};
use crate::tensor::Tensor;

/// Every update must carry the same number of tensors as the global
/// model; checked once up front so the accumulation loops can't fail
/// halfway through.
fn check_update_lens(global: &Params, updates: &[Update]) -> Result<()> {
    for u in updates {
        if u.params.len() != global.len() {
            bail!(
                "update from client {} has {} tensors, global has {}",
                u.client,
                u.params.len(),
                global.len()
            );
        }
    }
    Ok(())
}

/// One client's round contribution.
#[derive(Debug, Clone)]
pub struct Update {
    pub client: usize,
    /// Aggregation weight (= local sample count, per FedAvg).
    pub weight: f64,
    /// The client's post-training parameters (full tensors; for FedSkel
    /// only the skeleton channels differ from what it downloaded).
    pub params: Params,
    /// Per-prunable-layer skeleton channel indices. Empty ⇒ full update.
    pub skeleton: Vec<Vec<i32>>,
}

/// Weighted average of full parameter sets (FedAvg).
pub fn fedavg(global: &Params, updates: &[Update]) -> Result<Params> {
    if updates.is_empty() {
        return Ok(global.clone());
    }
    let total: f64 = updates.iter().map(|u| u.weight).sum();
    if total <= 0.0 {
        bail!("non-positive total weight");
    }
    check_update_lens(global, updates)?;
    let mut out: Params = global.iter().map(|t| Tensor::zeros(t.shape())).collect();
    for u in updates {
        let w = (u.weight / total) as f32;
        for (o, p) in out.iter_mut().zip(&u.params) {
            o.axpy(w, p)?;
        }
    }
    Ok(out)
}

/// FedSkel partial aggregation (see module docs).
///
/// For each prunable layer's weight tensor `[..., C]` and bias `[C]`:
/// channel `c`'s new value is the weight-averaged value over clients whose
/// skeleton contains `c`; channels no client covers keep the global value.
/// All non-prunable tensors are fully averaged over all clients.
pub fn fedskel_aggregate(
    global: &Params,
    updates: &[Update],
    prunable: &[PrunableSpec],
) -> Result<Params> {
    if updates.is_empty() {
        return Ok(global.clone());
    }
    let total: f64 = updates.iter().map(|u| u.weight).sum();
    if total <= 0.0 {
        bail!("non-positive total weight");
    }
    check_update_lens(global, updates)?;

    // Which params are channel-wise (prunable)?
    let mut channelwise: Vec<Option<usize>> = vec![None; global.len()]; // param -> prunable layer id
    for (li, p) in prunable.iter().enumerate() {
        channelwise[p.weight_param] = Some(li);
        channelwise[p.bias_param] = Some(li);
    }

    let mut out = global.clone();

    // 1) non-prunable tensors: plain weighted average.
    for (pi, slot) in channelwise.iter().enumerate() {
        if slot.is_none() {
            let mut acc = Tensor::zeros(global[pi].shape());
            for u in updates {
                acc.axpy((u.weight / total) as f32, &u.params[pi])?;
            }
            out[pi] = acc;
        }
    }

    // 2) prunable tensors: per-channel coverage-weighted average.
    for (li, p) in prunable.iter().enumerate() {
        let channels = p.channels;
        // per-channel accumulated weight
        let mut cover = vec![0.0f64; channels];
        for u in updates {
            let skel = skeleton_of(u, li, channels)?;
            for &c in skel {
                cover[c as usize] += u.weight;
            }
        }
        for &pi in &[p.weight_param, p.bias_param] {
            let t = &global[pi];
            let last = *t.shape().last().unwrap();
            if last != channels {
                bail!("prunable {} param {} last dim {} != channels {}", p.name, pi, last, channels);
            }
            let rows = t.len() / channels;
            let mut acc = vec![0.0f64; t.len()];
            for u in updates {
                let skel = skeleton_of(u, li, channels)?;
                let data = u.params[pi].data();
                for &c in skel {
                    let c = c as usize;
                    let w = u.weight / cover[c];
                    for r in 0..rows {
                        acc[r * channels + c] += w * data[r * channels + c] as f64;
                    }
                }
            }
            let dst = out[pi].data_mut();
            let gsrc = global[pi].data();
            for c in 0..channels {
                if cover[c] > 0.0 {
                    for r in 0..rows {
                        dst[r * channels + c] = acc[r * channels + c] as f32;
                    }
                } else {
                    for r in 0..rows {
                        dst[r * channels + c] = gsrc[r * channels + c];
                    }
                }
            }
        }
    }
    Ok(out)
}

fn skeleton_of<'a>(u: &'a Update, layer: usize, channels: usize) -> Result<&'a [i32]> {
    if u.skeleton.is_empty() {
        bail!("FedSkel update from client {} lacks skeleton indices", u.client);
    }
    let s = &u.skeleton[layer];
    if s.iter().any(|&c| c < 0 || c as usize >= channels) {
        bail!("skeleton index out of range for layer {layer}");
    }
    Ok(s)
}

/// LG-FedAvg: average only the listed global parameter tensors; the rest
/// keep the server's previous values (they are client-local anyway).
pub fn lg_fedavg_aggregate(
    global: &Params,
    updates: &[Update],
    global_param_ids: &[usize],
) -> Result<Params> {
    if updates.is_empty() {
        return Ok(global.clone());
    }
    let total: f64 = updates.iter().map(|u| u.weight).sum();
    if total <= 0.0 {
        bail!("non-positive total weight");
    }
    check_update_lens(global, updates)?;
    let mut out = global.clone();
    for &pi in global_param_ids {
        if pi >= global.len() {
            bail!("global param id {pi} out of range");
        }
        let mut acc = Tensor::zeros(global[pi].shape());
        for u in updates {
            acc.axpy((u.weight / total) as f32, &u.params[pi])?;
        }
        out[pi] = acc;
    }
    Ok(out)
}

/// Download-side masking: overwrite `local` with the global values the
/// client is entitled to receive.
///
/// * `skeleton` non-empty ⇒ FedSkel: prunable layers receive only skeleton
///   channels; non-prunable tensors are received in full.
/// * `only_params` set ⇒ LG-FedAvg: receive exactly those tensors.
/// * both empty ⇒ full download (FedAvg / FedMTL anchor).
pub fn apply_download(
    local: &mut Params,
    global: &Params,
    prunable: &[PrunableSpec],
    skeleton: &[Vec<i32>],
    only_params: Option<&[usize]>,
) -> Result<()> {
    if local.len() != global.len() {
        bail!("param count mismatch");
    }
    if let Some(ids) = only_params {
        for &pi in ids {
            local[pi] = global[pi].clone();
        }
        return Ok(());
    }
    if skeleton.is_empty() {
        for (l, g) in local.iter_mut().zip(global) {
            *l = g.clone();
        }
        return Ok(());
    }
    // FedSkel: full download of non-prunable tensors...
    let mut channelwise = vec![false; local.len()];
    for p in prunable {
        channelwise[p.weight_param] = true;
        channelwise[p.bias_param] = true;
    }
    for pi in 0..local.len() {
        if !channelwise[pi] {
            local[pi] = global[pi].clone();
        }
    }
    // ...and skeleton channels of prunable tensors.
    for (li, p) in prunable.iter().enumerate() {
        let channels = p.channels;
        for &pi in &[p.weight_param, p.bias_param] {
            let rows = global[pi].len() / channels;
            let g = global[pi].data();
            let l = local[pi].data_mut();
            for &c in &skeleton[li] {
                let c = c as usize;
                for r in 0..rows {
                    l[r * channels + c] = g[r * channels + c];
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn t(shape: &[usize], v: f32) -> Tensor {
        let mut x = Tensor::zeros(shape);
        x.data_mut().fill(v);
        x
    }

    fn prun() -> Vec<PrunableSpec> {
        vec![PrunableSpec { name: "l0".into(), channels: 4, weight_param: 0, bias_param: 1 }]
    }

    /// params: [0] weight [2,4] (channelwise), [1] bias [4], [2] head [3]
    fn global() -> Params {
        vec![t(&[2, 4], 1.0), t(&[4], 1.0), t(&[3], 1.0)]
    }

    fn upd(client: usize, weight: f64, v: f32, skel: Vec<i32>) -> Update {
        Update {
            client,
            weight,
            params: vec![t(&[2, 4], v), t(&[4], v), t(&[3], v)],
            skeleton: if skel.is_empty() { vec![] } else { vec![skel] },
        }
    }

    #[test]
    fn fedavg_weighted_mean() {
        let g = global();
        let ups = vec![upd(0, 1.0, 2.0, vec![]), upd(1, 3.0, 6.0, vec![])];
        let out = fedavg(&g, &ups).unwrap();
        // (1*2 + 3*6)/4 = 5
        assert!(out.iter().all(|t| t.data().iter().all(|&x| (x - 5.0).abs() < 1e-6)));
    }

    #[test]
    fn fedavg_empty_keeps_global() {
        let g = global();
        let out = fedavg(&g, &[]).unwrap();
        assert_eq!(out, g);
    }

    #[test]
    fn fedskel_covers_and_keeps() {
        let g = global();
        // client0 (w=1) covers {0,1} with value 2; client1 (w=1) covers {1,2} with 4.
        let ups = vec![upd(0, 1.0, 2.0, vec![0, 1]), upd(1, 1.0, 4.0, vec![1, 2])];
        let out = fedskel_aggregate(&g, &ups, &prun()).unwrap();
        let w = out[0].data(); // [2,4] rows share column values
        assert_eq!(w[0], 2.0); // ch0: only client0
        assert_eq!(w[1], 3.0); // ch1: avg(2,4)
        assert_eq!(w[2], 4.0); // ch2: only client1
        assert_eq!(w[3], 1.0); // ch3: uncovered → global
        // bias mirrors
        assert_eq!(out[1].data(), &[2.0, 3.0, 4.0, 1.0]);
        // head fully averaged: avg(2,4)=3
        assert!(out[2].data().iter().all(|&x| (x - 3.0).abs() < 1e-6));
    }

    #[test]
    fn fedskel_weighted_coverage() {
        let g = global();
        let ups = vec![upd(0, 1.0, 0.0, vec![0]), upd(1, 3.0, 8.0, vec![0])];
        let out = fedskel_aggregate(&g, &ups, &prun()).unwrap();
        assert_eq!(out[0].data()[0], 6.0); // (1*0+3*8)/4
    }

    #[test]
    fn fedskel_requires_skeleton() {
        let g = global();
        let ups = vec![upd(0, 1.0, 2.0, vec![])];
        assert!(fedskel_aggregate(&g, &ups, &prun()).is_err());
    }

    #[test]
    fn fedskel_identity_equals_fedavg() {
        let g = global();
        let ups = vec![
            upd(0, 2.0, 2.0, vec![0, 1, 2, 3]),
            upd(1, 2.0, 4.0, vec![0, 1, 2, 3]),
        ];
        let skel = fedskel_aggregate(&g, &ups, &prun()).unwrap();
        let avg = fedavg(&g, &ups).unwrap();
        for (a, b) in skel.iter().zip(&avg) {
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn lg_fedavg_only_named_params() {
        let g = global();
        let ups = vec![upd(0, 1.0, 3.0, vec![]), upd(1, 1.0, 5.0, vec![])];
        let out = lg_fedavg_aggregate(&g, &ups, &[2]).unwrap();
        assert!(out[2].data().iter().all(|&x| (x - 4.0).abs() < 1e-6));
        assert_eq!(out[0], g[0]); // representation untouched
        assert!(lg_fedavg_aggregate(&g, &ups, &[9]).is_err());
    }

    #[test]
    fn download_full() {
        let g = global();
        let mut local = vec![t(&[2, 4], 9.0), t(&[4], 9.0), t(&[3], 9.0)];
        apply_download(&mut local, &g, &prun(), &[], None).unwrap();
        assert_eq!(local, g);
    }

    #[test]
    fn download_skeleton_mixes() {
        let g = global();
        let mut local = vec![t(&[2, 4], 9.0), t(&[4], 9.0), t(&[3], 9.0)];
        let skel = vec![vec![1i32, 3]];
        apply_download(&mut local, &g, &prun(), &skel, None).unwrap();
        // prunable weight: only cols 1,3 replaced
        assert_eq!(local[0].data(), &[9.0, 1.0, 9.0, 1.0, 9.0, 1.0, 9.0, 1.0]);
        assert_eq!(local[1].data(), &[9.0, 1.0, 9.0, 1.0]);
        // head replaced in full
        assert_eq!(local[2].data(), &[1.0, 1.0, 1.0]);
    }

    #[test]
    fn download_lg_only_head() {
        let g = global();
        let mut local = vec![t(&[2, 4], 9.0), t(&[4], 9.0), t(&[3], 9.0)];
        apply_download(&mut local, &g, &prun(), &[], Some(&[2])).unwrap();
        assert_eq!(local[0].data()[0], 9.0);
        assert_eq!(local[2].data(), &[1.0, 1.0, 1.0]);
    }
}
