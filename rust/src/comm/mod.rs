//! Communication accounting + bandwidth model (Table 2 substrate).
//!
//! Counts the parameters each method moves per round, per client, both
//! directions — the quantity Table 2 reports ("Volume of parameters
//! communication", in parameter counts). A simple bandwidth model converts
//! volumes to seconds for the heterogeneity simulator (Fig. 5's round-time
//! = compute + comm).

use crate::model::{ModelSpec, PrunableSpec};

/// Which parts of the model a client exchanges in a round.
#[derive(Debug, Clone, PartialEq)]
pub enum ExchangeKind {
    /// Everything (FedAvg; FedSkel SetSkel rounds; FedMTL).
    Full,
    /// Skeleton channels of prunable layers + all non-prunable tensors
    /// (FedSkel UpdateSkel rounds). Per-layer skeleton sizes k_l.
    Skeleton(Vec<usize>),
    /// Only the listed parameter tensors (LG-FedAvg's global layers).
    ParamSubset(Vec<usize>),
    /// Nothing (client idle this round).
    None,
}

/// Parameters moved one-way for an exchange.
pub fn params_moved(spec: &ModelSpec, kind: &ExchangeKind) -> usize {
    match kind {
        ExchangeKind::Full => spec.num_params,
        ExchangeKind::None => 0,
        ExchangeKind::ParamSubset(ids) => ids.iter().map(|&i| spec.params[i].numel()).sum(),
        ExchangeKind::Skeleton(ks) => {
            let mut total = 0usize;
            let mut channelwise = vec![None; spec.params.len()];
            for (li, p) in spec.prunable.iter().enumerate() {
                channelwise[p.weight_param] = Some(li);
                channelwise[p.bias_param] = Some(li);
            }
            for (pi, p) in spec.params.iter().enumerate() {
                match channelwise[pi] {
                    None => total += p.numel(),
                    Some(li) => {
                        let c = channels_of(&spec.prunable[li]);
                        let rows = p.numel() / c;
                        total += rows * ks[li].min(c);
                    }
                }
            }
            total
        }
    }
}

fn channels_of(p: &PrunableSpec) -> usize {
    p.channels
}

/// Running totals across a training run: logical parameter counts (the
/// quantity Table 2 reports) *and* measured bytes-on-the-wire (what the
/// transport layer's encoder actually produced, frames included).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CommLedger {
    pub upload_params: u64,
    pub download_params: u64,
    /// Exact encoded frame bytes, client → server.
    pub upload_wire_bytes: u64,
    /// Exact encoded frame bytes, server → client.
    pub download_wire_bytes: u64,
    /// Wire bytes spent on exchanges the scheduler *actively discarded*:
    /// downloads already shipped to a client that dropped mid-round, and
    /// both directions of an update that missed a round deadline. Kept
    /// out of the useful-byte counters so Table-2 parity is unaffected —
    /// wasted traffic is a cost of the failure/policy model, not of the
    /// method. (An async update still in flight when a finite run ends
    /// is *not* wasted: its exchange stays booked as useful traffic,
    /// since only the simulation horizon — not the protocol — kept it
    /// from aggregating.)
    pub wasted_wire_bytes: u64,
    /// What the same useful exchanges would have cost as plain dense-f32
    /// frames (`wire::encoded_len` at f32), client → server. Splitting
    /// raw from compressed bytes is what lets
    /// [`CommLedger::compression_ratio`] report the *achieved* ratio of
    /// the [`crate::compress`] pipeline rather than a nominal one.
    pub upload_raw_bytes: u64,
    /// Dense-f32 frame cost of the useful downloads, server → client.
    pub download_raw_bytes: u64,
    pub rounds: u64,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one client's round exchange (same kind both directions by
    /// default; FedSkel's upload and download are both skeleton-sized).
    pub fn record(&mut self, spec: &ModelSpec, up: &ExchangeKind, down: &ExchangeKind) {
        self.record_params(params_moved(spec, up) as u64, params_moved(spec, down) as u64);
    }

    /// Record one exchange's logical parameter counts directly — the form
    /// the trace fold uses, where the counts were already resolved when
    /// the `exchange` event was emitted ([`crate::trace::fold`]).
    pub fn record_params(&mut self, up: u64, down: u64) {
        self.upload_params += up;
        self.download_params += down;
    }

    /// Record one exchange's measured wire bytes (encoded frame lengths).
    pub fn record_wire(&mut self, up_bytes: u64, down_bytes: u64) {
        self.upload_wire_bytes += up_bytes;
        self.download_wire_bytes += down_bytes;
    }

    /// Record frame bytes that were spent but whose update never reached
    /// aggregation (mid-round dropouts, deadline drops).
    pub fn record_wasted(&mut self, bytes: u64) {
        self.wasted_wire_bytes += bytes;
    }

    /// Record what one exchange would have cost as dense-f32 frames —
    /// the raw side of the raw-vs-compressed split. Call next to
    /// [`CommLedger::record_wire`] so both counters cover the same
    /// exchanges.
    pub fn record_raw(&mut self, up_bytes: u64, down_bytes: u64) {
        self.upload_raw_bytes += up_bytes;
        self.download_raw_bytes += down_bytes;
    }

    /// Total dense-f32 frame bytes of the useful exchanges.
    pub fn total_raw_bytes(&self) -> u64 {
        self.upload_raw_bytes + self.download_raw_bytes
    }

    /// Achieved compression ratio: raw ÷ measured wire bytes (1.0 = no
    /// compression; > 1 = the wire carried fewer bytes than dense f32
    /// frames would have).
    pub fn compression_ratio(&self) -> f64 {
        if self.total_wire_bytes() == 0 {
            return 1.0;
        }
        self.total_raw_bytes() as f64 / self.total_wire_bytes() as f64
    }

    pub fn end_round(&mut self) {
        self.rounds += 1;
    }

    pub fn total_params(&self) -> u64 {
        self.upload_params + self.download_params
    }

    /// Total *nominal* bytes at f32 (4 bytes per logical parameter, no
    /// framing) — Table 2's unit. See [`CommLedger::total_wire_bytes`] for
    /// what the encoder actually put on the wire.
    pub fn total_bytes(&self) -> u64 {
        self.total_params() * 4
    }

    /// Total measured bytes-on-the-wire, both directions.
    pub fn total_wire_bytes(&self) -> u64 {
        self.upload_wire_bytes + self.download_wire_bytes
    }

    /// Reduction vs a baseline ledger (e.g. FedAvg), in percent.
    pub fn reduction_vs(&self, baseline: &CommLedger) -> f64 {
        if baseline.total_params() == 0 {
            return 0.0;
        }
        100.0 * (1.0 - self.total_params() as f64 / baseline.total_params() as f64)
    }

    /// Wire-byte reduction vs a baseline ledger, in percent.
    pub fn wire_reduction_vs(&self, baseline: &CommLedger) -> f64 {
        if baseline.total_wire_bytes() == 0 {
            return 0.0;
        }
        100.0 * (1.0 - self.total_wire_bytes() as f64 / baseline.total_wire_bytes() as f64)
    }
}

/// Seconds to move `params` over a link of `mbps` megabits/s (f32 payload).
pub fn comm_seconds(params: usize, mbps: f64) -> f64 {
    let bits = params as f64 * 32.0;
    bits / (mbps * 1e6)
}

/// Seconds to move `bytes` over a link of `mbps` megabits/s — the
/// measured-wire-bytes counterpart of [`comm_seconds`].
pub fn comm_seconds_bytes(bytes: u64, mbps: f64) -> f64 {
    bytes as f64 * 8.0 / (mbps * 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{ArtifactSpec, ParamSpec};
    use std::collections::BTreeMap;

    /// lenet-shaped toy: weight [6,4] prunable (4 ch), bias [4], head [10].
    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            input_shape: vec![4, 4, 1],
            num_classes: 2,
            train_batch: 8,
            eval_batch: 8,
            num_params: 24 + 4 + 10,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![6, 4], init: "he".into() },
                ParamSpec { name: "b".into(), shape: vec![4], init: "zeros".into() },
                ParamSpec { name: "head".into(), shape: vec![10], init: "he".into() },
            ],
            prunable: vec![PrunableSpec { name: "w".into(), channels: 4, weight_param: 0, bias_param: 1 }],
            artifacts: BTreeMap::<String, ArtifactSpec>::new(),
        }
    }

    #[test]
    fn full_and_none() {
        let s = spec();
        assert_eq!(params_moved(&s, &ExchangeKind::Full), 38);
        assert_eq!(params_moved(&s, &ExchangeKind::None), 0);
    }

    #[test]
    fn skeleton_counts_rows_times_k() {
        let s = spec();
        // k=1: weight 6*1 + bias 1 + head 10 = 17
        assert_eq!(params_moved(&s, &ExchangeKind::Skeleton(vec![1])), 17);
        // k=4 (identity) == full
        assert_eq!(params_moved(&s, &ExchangeKind::Skeleton(vec![4])), 38);
        // k clamped to channels
        assert_eq!(params_moved(&s, &ExchangeKind::Skeleton(vec![9])), 38);
    }

    #[test]
    fn param_subset() {
        let s = spec();
        assert_eq!(params_moved(&s, &ExchangeKind::ParamSubset(vec![2])), 10);
        assert_eq!(params_moved(&s, &ExchangeKind::ParamSubset(vec![0, 1])), 28);
    }

    #[test]
    fn ledger_accumulates_and_reduces() {
        let s = spec();
        let mut fedavg = CommLedger::new();
        let mut fedskel = CommLedger::new();
        for round in 0..4u32 {
            // FedSkel: 1 SetSkel (full) : 3 UpdateSkel (skeleton)
            let kind = if round == 0 {
                ExchangeKind::Full
            } else {
                ExchangeKind::Skeleton(vec![1])
            };
            fedskel.record(&s, &kind, &kind);
            fedskel.end_round();
            fedavg.record(&s, &ExchangeKind::Full, &ExchangeKind::Full);
            fedavg.end_round();
        }
        assert_eq!(fedavg.total_params(), 8 * 38);
        assert_eq!(fedskel.total_params(), 2 * 38 + 6 * 17);
        let red = fedskel.reduction_vs(&fedavg);
        assert!(red > 40.0 && red < 60.0, "reduction {red}");
        assert_eq!(fedavg.total_bytes(), 8 * 38 * 4);
    }

    #[test]
    fn ledger_tracks_wire_bytes() {
        let mut a = CommLedger::new();
        let mut b = CommLedger::new();
        a.record_wire(100, 300);
        a.record_wire(50, 50);
        b.record_wire(500, 500);
        assert_eq!(a.total_wire_bytes(), 500);
        assert_eq!(a.upload_wire_bytes, 150);
        assert!((a.wire_reduction_vs(&b) - 50.0).abs() < 1e-9);
        assert_eq!(CommLedger::new().wire_reduction_vs(&CommLedger::new()), 0.0);
    }

    #[test]
    fn raw_bytes_and_compression_ratio() {
        let mut l = CommLedger::new();
        assert_eq!(l.compression_ratio(), 1.0, "empty ledger reports no compression");
        l.record_wire(100, 150);
        l.record_raw(400, 600);
        assert_eq!(l.total_raw_bytes(), 1000);
        assert!((l.compression_ratio() - 4.0).abs() < 1e-12);
        // wasted traffic is excluded from both sides of the split
        l.record_wasted(50);
        assert_eq!(l.total_raw_bytes(), 1000);
        assert_eq!(l.total_wire_bytes(), 250);
    }

    #[test]
    fn wasted_bytes_stay_out_of_useful_totals() {
        let mut l = CommLedger::new();
        l.record_wire(100, 100);
        l.record_wasted(70);
        l.record_wasted(30);
        assert_eq!(l.wasted_wire_bytes, 100);
        assert_eq!(l.total_wire_bytes(), 200, "wasted bytes never fold into the useful totals");
        assert_eq!(CommLedger::new().wasted_wire_bytes, 0);
    }

    #[test]
    fn comm_seconds_bytes_matches_param_form() {
        // 1000 params at f32 = 4000 bytes: both paths agree
        assert!((comm_seconds(1000, 10.0) - comm_seconds_bytes(4000, 10.0)).abs() < 1e-12);
    }

    #[test]
    fn comm_seconds_scales() {
        // 1M params * 32 bits over 32 Mbps = 1 s
        assert!((comm_seconds(1_000_000, 32.0) - 1.0).abs() < 1e-9);
        assert!(comm_seconds(1000, 1.0) > comm_seconds(1000, 100.0));
    }
}
