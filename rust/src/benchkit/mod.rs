//! Criterion-substitute benchmark harness (offline registry lacks
//! criterion — DESIGN.md §3).
//!
//! Same discipline as criterion's core loop: warmup, N timed samples,
//! robust stats (median/p95), throughput helpers, and a uniform report
//! format the bench binaries print.
//!
//! Paper: the timing harness under every Table 1 and Fig. 5 measurement.
//! Invariant: reported numbers are medians over `samples` runs, so a
//! single scheduler hiccup cannot fabricate a speedup.

use std::time::Instant;

/// Statistics over one benchmark's samples.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn from_samples(name: &str, mut xs: Vec<f64>) -> BenchStats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        BenchStats {
            name: name.to_string(),
            samples: n,
            mean_s: mean,
            median_s: xs[n / 2],
            p95_s: xs[((n as f64 * 0.95) as usize).min(n - 1)],
            std_s: var.sqrt(),
            min_s: xs[0],
        }
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<40} mean {:>10} median {:>10} p95 {:>10} std {:>9} (n={})",
            self.name,
            fmt_secs(self.mean_s),
            fmt_secs(self.median_s),
            fmt_secs(self.p95_s),
            fmt_secs(self.std_s),
            self.samples
        )
    }
}

/// Human duration formatting.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// The harness: warmup then sample.
pub struct Bench {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, sample_iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup: usize, samples: usize) -> Bench {
        Bench { warmup_iters: warmup, sample_iters: samples }
    }

    /// Quick profile for long-running macro benches.
    pub fn quick() -> Bench {
        Bench { warmup_iters: 1, sample_iters: 5 }
    }

    /// Time `f` (one call = one sample).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut xs = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t = Instant::now();
            f();
            xs.push(t.elapsed().as_secs_f64());
        }
        let stats = BenchStats::from_samples(name, xs);
        println!("{}", stats.report_line());
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_samples() {
        let s = BenchStats::from_samples("t", vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median_s, 2.0);
        assert_eq!(s.min_s, 1.0);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert_eq!(s.samples, 3);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }

    #[test]
    fn run_counts_iters() {
        let mut calls = 0;
        let b = Bench::new(1, 3);
        let s = b.run("count", || calls += 1);
        assert_eq!(calls, 4);
        assert_eq!(s.samples, 3);
    }
}
