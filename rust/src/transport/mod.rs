//! Pluggable transport layer: framed round messages actually *move*
//! between the server and the client fleet, with exact byte accounting.
//!
//! * [`wire`] — the framed, versioned, checksummed binary codec
//!   (frame layout table in its module docs).
//! * [`pool`] — the parallel client worker pool (`std::thread` +
//!   channels) the coordinator dispatches local-training jobs onto.
//! * [`proto`] — the serve/client control-plane codec (handshake, job
//!   dispatch, outcome return) spoken between `fedskel serve` and
//!   `fedskel client` processes.
//! * [`Transport`] — the seam itself. Implementations:
//!   [`Loopback`] (in-memory queues, zero link cost — the unit-test and
//!   single-host substrate), [`SimNet`] (the same queues behind a
//!   per-client bandwidth/latency link model drawn from
//!   [`crate::hetero::DeviceProfile`]s, so a round's communication time is
//!   *measured frame bytes* over the client's simulated link — exactly the
//!   quantity Fig. 5's round time adds to compute), and
//!   [`tcp::TcpTransport`] (real sockets between real processes — see
//!   `docs/TRANSPORT.md`).
//! * [`fault::FaultInjector`] — a seeded, deterministic chaos wrapper
//!   (drop / delay / reorder / truncate) composable over any inner
//!   transport, so link failure is *tested*, not assumed away.
//!
//! ## `recv` semantics
//!
//! [`Transport::recv`] returns `Ok(None)` when no message is queued for
//! the peer — a typed would-block, **not** an error. In-process
//! transports deliver synchronously, so their callers historically never
//! hit the empty case; real sockets (and the fault injector) hit it
//! routinely, and a caller must be able to distinguish "nothing yet —
//! retry or back off" from a genuine transport failure (`Err`).
//!
//! Every later scaling PR (sharded aggregation, compression ablations)
//! plugs in here: implement [`Transport`] and the coordinator, ledger,
//! and benches keep working unchanged.

pub mod fault;
pub mod pool;
pub mod proto;
pub mod tcp;
pub mod wire;

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::hetero::DeviceProfile;

/// A transport endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Peer {
    Server,
    Client(usize),
}

/// One framed message in flight.
#[derive(Debug, Clone)]
pub struct Envelope {
    pub from: Peer,
    pub to: Peer,
    /// An encoded [`wire`] frame.
    pub frame: Vec<u8>,
}

/// What a send cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Receipt {
    /// Exact bytes on the wire (the frame length).
    pub bytes: usize,
    /// Simulated link seconds for this transfer (0 for loopback).
    pub sim_secs: f64,
}

/// The transport seam: deliver framed messages between peers.
pub trait Transport: Send {
    /// Queue `msg` for its destination; returns the measured cost.
    fn send(&mut self, msg: Envelope) -> Result<Receipt>;

    /// Pop the next message addressed to `to` (FIFO per peer).
    ///
    /// `Ok(None)` means no message is currently queued — a typed
    /// would-block the caller may retry after; `Err` is reserved for
    /// genuine transport failures (a dead socket, a poisoned lock).
    fn recv(&mut self, to: Peer) -> Result<Option<Envelope>>;

    /// Messages currently queued for `to`.
    fn pending(&self, to: Peer) -> usize;

    fn name(&self) -> &'static str;
}

/// Which transport a run uses (config-selectable).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    Loopback,
    /// Per-client bandwidth/latency simulation over the fleet profiles.
    #[default]
    SimNet,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "loopback" => TransportKind::Loopback,
            "simnet" | "sim" => TransportKind::SimNet,
            "tcp" => bail!(
                "tcp is not an in-process transport — split the run across real \
                 processes with `fedskel serve` / `fedskel client` (docs/TRANSPORT.md)"
            ),
            _ => bail!("unknown transport '{s}' (loopback|simnet)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Loopback => "loopback",
            TransportKind::SimNet => "simnet",
        }
    }

    /// Build the transport for a fleet.
    pub fn build(&self, fleet: &[DeviceProfile]) -> Box<dyn Transport> {
        match self {
            TransportKind::Loopback => Box::new(Loopback::new()),
            TransportKind::SimNet => Box::new(SimNet::new(fleet)),
        }
    }
}

/// Shared per-peer FIFO queues.
#[derive(Debug, Default)]
struct Queues {
    q: BTreeMap<Peer, VecDeque<Envelope>>,
}

impl Queues {
    fn push(&mut self, msg: Envelope) {
        self.q.entry(msg.to).or_default().push_back(msg);
    }

    fn pop(&mut self, to: Peer) -> Option<Envelope> {
        self.q.get_mut(&to).and_then(|q| q.pop_front())
    }

    fn pending(&self, to: Peer) -> usize {
        self.q.get(&to).map(|q| q.len()).unwrap_or(0)
    }
}

/// In-memory loopback: messages arrive instantly, links cost nothing.
#[derive(Debug, Default)]
pub struct Loopback {
    queues: Queues,
    /// Total bytes ever sent (both directions).
    pub bytes_sent: u64,
}

impl Loopback {
    pub fn new() -> Loopback {
        Loopback::default()
    }
}

impl Transport for Loopback {
    fn send(&mut self, msg: Envelope) -> Result<Receipt> {
        let bytes = msg.frame.len();
        self.bytes_sent += bytes as u64;
        self.queues.push(msg);
        Ok(Receipt { bytes, sim_secs: 0.0 })
    }

    fn recv(&mut self, to: Peer) -> Result<Option<Envelope>> {
        Ok(self.queues.pop(to))
    }

    fn pending(&self, to: Peer) -> usize {
        self.queues.pending(to)
    }

    fn name(&self) -> &'static str {
        "loopback"
    }
}

/// Simulated network: loopback delivery plus a per-client link model.
///
/// A transfer touching `Client(i)` (either direction) costs
/// `latency_s + bytes·8 / (bandwidth_mbps·1e6)` simulated seconds on that
/// client's link; server↔server never happens. Profiles come from the
/// heterogeneity fleet so Fig. 5's "comm" term uses the same device table
/// as its "compute" term.
#[derive(Debug)]
pub struct SimNet {
    queues: Queues,
    links: Vec<DeviceProfile>,
    pub bytes_sent: u64,
    /// Accumulated simulated link seconds across all transfers.
    pub sim_secs_total: f64,
}

impl SimNet {
    pub fn new(fleet: &[DeviceProfile]) -> SimNet {
        SimNet {
            queues: Queues::default(),
            links: fleet.to_vec(),
            bytes_sent: 0,
            sim_secs_total: 0.0,
        }
    }

    fn client_of(msg: &Envelope) -> Option<usize> {
        match (msg.from, msg.to) {
            (Peer::Client(i), _) => Some(i),
            (_, Peer::Client(i)) => Some(i),
            _ => None,
        }
    }
}

impl Transport for SimNet {
    fn send(&mut self, msg: Envelope) -> Result<Receipt> {
        let bytes = msg.frame.len();
        let sim_secs = match Self::client_of(&msg) {
            Some(i) => {
                let Some(link) = self.links.get(i) else {
                    bail!("simnet: client {i} has no link profile");
                };
                link.latency_s + crate::comm::comm_seconds_bytes(bytes as u64, link.bandwidth_mbps)
            }
            None => 0.0,
        };
        self.bytes_sent += bytes as u64;
        self.sim_secs_total += sim_secs;
        self.queues.push(msg);
        Ok(Receipt { bytes, sim_secs })
    }

    fn recv(&mut self, to: Peer) -> Result<Option<Envelope>> {
        Ok(self.queues.pop(to))
    }

    fn pending(&self, to: Peer) -> usize {
        self.queues.pending(to)
    }

    fn name(&self) -> &'static str {
        "simnet"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::equidistant_fleet;

    fn env(from: Peer, to: Peer, n: usize) -> Envelope {
        Envelope { from, to, frame: vec![0u8; n] }
    }

    #[test]
    fn loopback_fifo_per_peer() {
        let mut t = Loopback::new();
        t.send(env(Peer::Server, Peer::Client(0), 10)).unwrap();
        t.send(env(Peer::Server, Peer::Client(1), 20)).unwrap();
        t.send(env(Peer::Server, Peer::Client(0), 30)).unwrap();
        assert_eq!(t.pending(Peer::Client(0)), 2);
        assert_eq!(t.recv(Peer::Client(0)).unwrap().unwrap().frame.len(), 10);
        assert_eq!(t.recv(Peer::Client(0)).unwrap().unwrap().frame.len(), 30);
        assert_eq!(t.recv(Peer::Client(1)).unwrap().unwrap().frame.len(), 20);
        // empty queue is a typed would-block (`Ok(None)`), never an error
        assert!(t.recv(Peer::Client(0)).unwrap().is_none());
        assert!(t.recv(Peer::Client(7)).unwrap().is_none());
        assert_eq!(t.bytes_sent, 60);
    }

    #[test]
    fn loopback_receipt_is_free() {
        let mut t = Loopback::new();
        let r = t.send(env(Peer::Client(3), Peer::Server, 128)).unwrap();
        assert_eq!(r.bytes, 128);
        assert_eq!(r.sim_secs, 0.0);
    }

    #[test]
    fn simnet_charges_the_client_link() {
        let fleet = equidistant_fleet(2, 0.5, 1.0, 8.0); // 8 Mbit/s → 1 byte/µs
        let mut t = SimNet::new(&fleet);
        let up = t.send(env(Peer::Client(1), Peer::Server, 1_000_000)).unwrap();
        assert!((up.sim_secs - 1.0).abs() < 1e-9, "{}", up.sim_secs);
        let down = t.send(env(Peer::Server, Peer::Client(0), 500_000)).unwrap();
        assert!((down.sim_secs - 0.5).abs() < 1e-9);
        assert_eq!(t.bytes_sent, 1_500_000);
        assert!((t.sim_secs_total - 1.5).abs() < 1e-9);
        // delivery still works, and the empty queue is a typed would-block
        assert_eq!(t.recv(Peer::Server).unwrap().unwrap().frame.len(), 1_000_000);
        assert!(t.recv(Peer::Server).unwrap().is_none());
        assert!(t.send(env(Peer::Server, Peer::Client(9), 1)).is_err());
    }

    #[test]
    fn simnet_latency_adds() {
        let mut fleet = equidistant_fleet(1, 1.0, 1.0, 8.0);
        fleet[0].latency_s = 0.25;
        let mut t = SimNet::new(&fleet);
        let r = t.send(env(Peer::Server, Peer::Client(0), 1_000_000)).unwrap();
        assert!((r.sim_secs - 1.25).abs() < 1e-9);
    }

    #[test]
    fn kind_parse_and_build() {
        assert_eq!(TransportKind::parse("loopback").unwrap(), TransportKind::Loopback);
        assert_eq!(TransportKind::parse("SimNet").unwrap(), TransportKind::SimNet);
        assert!(TransportKind::parse("tcp").is_err());
        let fleet = equidistant_fleet(2, 0.5, 1.0, 100.0);
        assert_eq!(TransportKind::Loopback.build(&fleet).name(), "loopback");
        assert_eq!(TransportKind::SimNet.build(&fleet).name(), "simnet");
    }
}
