//! Control-plane codec for `fedskel serve` / `fedskel client`.
//!
//! The split-process deployment keeps **all federation state on the
//! server** (sampling, skeletons, aggregation, the virtual clock, the
//! checkpoint): remote `fedskel client` processes are stateless compute
//! workers that execute [`TrainJob`]s via
//! [`crate::transport::pool::run_local_steps`] — exactly the function the
//! in-process worker pool runs — and mail back [`TrainOutcome`]s. That is
//! what makes multi-process digests bitwise equal to in-process runs and
//! lets a SIGKILLed server resume from its `.fsnap` with clients none the
//! wiser (they hold nothing to lose).
//!
//! ## Frame layout (little-endian throughout)
//!
//! | bytes | field |
//! |-------|-------|
//! | 0..4  | magic `b"FSKP"` |
//! | 4..6  | protocol version (u16, = [`PROTO_VERSION`]) |
//! | 6     | message kind (0 Hello, 1 Welcome, 2 Reject, 3 Job, 4 Outcome, 5 Shutdown) |
//! | 7..11 | body length (u32) |
//! | 11..  | body |
//! | last 4| FNV-1a 32 checksum of the body |
//!
//! Parameter sets inside `Job`/`Outcome` bodies travel as length-prefixed
//! F32 `Full` frames of the [`super::wire`] codec — the same bitwise
//! construction the snapshot format uses — so the data plane has exactly
//! one float encoding in the whole repo.
//!
//! ## Handshake
//!
//! `client → Hello {wire_version, determinism_key, worker}` (the key is
//! empty on first contact; a reconnecting client echoes the one it was
//! welcomed with). `server → Welcome {slot, model, determinism_key}` on
//! success, `Reject {reason}` on a proto/wire version or key mismatch —
//! two runs with different training knobs must not silently mix workers.
//!
//! Revision policy mirrors `docs/WIRE_FORMAT.md`: any layout change bumps
//! [`PROTO_VERSION`]; decoders reject unknown versions with a typed error.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::pool::{TrainJob, TrainOutcome};
use super::wire::{self, Quant, RoundMsg, WirePayload};
use crate::kernels::{KernelTier, Parallelism, Precision};
use crate::model::{ModelSpec, Params};

/// Control-frame magic (distinct from the data plane's `FSKL`).
pub const MAGIC: [u8; 4] = *b"FSKP";
/// Control-protocol version.
pub const PROTO_VERSION: u16 = 1;
/// Fixed bytes before the body.
pub const HEADER_LEN: usize = 11;
/// Trailing checksum bytes.
pub const FOOTER_LEN: usize = 4;

/// One serve/client control message.
#[derive(Debug, Clone)]
pub enum CtrlMsg {
    /// Client → server on connect. `determinism_key` is empty on first
    /// contact and echoes the `Welcome` key on reconnect.
    Hello { wire_version: u16, determinism_key: String, worker: String },
    /// Server → client: handshake accepted. `slot` is the worker's index
    /// in the server's roster; `model` names the backend to build.
    Welcome { slot: u32, model: String, determinism_key: String },
    /// Server → client: handshake refused (version/key mismatch).
    Reject { reason: String },
    /// Server → client: one local-training work order. `seq` is globally
    /// unique per run — outcomes dedup on it.
    Job { seq: u64, job: TrainJob },
    /// Client → server: the finished work order.
    Outcome { seq: u64, outcome: TrainOutcome },
    /// Server → client: run over, exit cleanly.
    Shutdown { reason: String },
}

impl CtrlMsg {
    fn kind(&self) -> u8 {
        match self {
            CtrlMsg::Hello { .. } => 0,
            CtrlMsg::Welcome { .. } => 1,
            CtrlMsg::Reject { .. } => 2,
            CtrlMsg::Job { .. } => 3,
            CtrlMsg::Outcome { .. } => 4,
            CtrlMsg::Shutdown { .. } => 5,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CtrlMsg::Hello { .. } => "hello",
            CtrlMsg::Welcome { .. } => "welcome",
            CtrlMsg::Reject { .. } => "reject",
            CtrlMsg::Job { .. } => "job",
            CtrlMsg::Outcome { .. } => "outcome",
            CtrlMsg::Shutdown { .. } => "shutdown",
        }
    }
}

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(b: &mut Vec<u8>, v: f32) {
    b.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

/// Params as one length-prefixed F32 `Full` wire frame (bitwise — the
/// snapshot format's construction).
fn put_params(b: &mut Vec<u8>, params: &Params) {
    let msg =
        RoundMsg { round: 0, client: 0, weight: 0.0, payload: WirePayload::Full(params.clone()) };
    let frame = wire::encode(&msg, Quant::F32);
    put_u32(b, frame.len() as u32);
    b.extend_from_slice(&frame);
}

fn put_job(b: &mut Vec<u8>, seq: u64, job: &TrainJob) {
    put_u64(b, seq);
    put_u32(b, job.client as u32);
    put_u32(b, job.bucket as u32);
    put_u32(b, job.skeleton.len() as u32);
    for layer in &job.skeleton {
        put_u32(b, layer.len() as u32);
        for &c in layer {
            b.extend_from_slice(&c.to_le_bytes());
        }
    }
    put_params(b, &job.local);
    put_params(b, &job.global);
    put_u32(b, job.batches.len() as u32);
    for (x, y) in &job.batches {
        put_u32(b, x.len() as u32);
        for &v in x {
            put_f32(b, v);
        }
        put_u32(b, y.len() as u32);
        for &v in y {
            b.extend_from_slice(&v.to_le_bytes());
        }
    }
    put_f32(b, job.lr);
    put_f32(b, job.mu);
    b.push(job.want_importance as u8);
    put_u32(b, job.par.threads() as u32);
    b.push(match job.par.tier() {
        KernelTier::Scalar => 0,
        KernelTier::Simd => 1,
    });
    b.push(match job.precision {
        Precision::F32 => 0,
        Precision::Int8 => 1,
    });
}

fn put_outcome(b: &mut Vec<u8>, seq: u64, out: &TrainOutcome) {
    put_u64(b, seq);
    put_u32(b, out.client as u32);
    put_params(b, &out.params);
    put_f32(b, out.mean_loss);
    put_u32(b, out.importance_sums.len() as u32);
    for layer in &out.importance_sums {
        put_u32(b, layer.len() as u32);
        for &v in layer {
            put_f32(b, v);
        }
    }
    put_u64(b, out.steps as u64);
}

/// Encode a control message into one checksummed frame.
pub fn encode(msg: &CtrlMsg) -> Vec<u8> {
    let mut body = Vec::new();
    match msg {
        CtrlMsg::Hello { wire_version, determinism_key, worker } => {
            put_u16(&mut body, *wire_version);
            put_str(&mut body, determinism_key);
            put_str(&mut body, worker);
        }
        CtrlMsg::Welcome { slot, model, determinism_key } => {
            put_u32(&mut body, *slot);
            put_str(&mut body, model);
            put_str(&mut body, determinism_key);
        }
        CtrlMsg::Reject { reason } | CtrlMsg::Shutdown { reason } => {
            put_str(&mut body, reason);
        }
        CtrlMsg::Job { seq, job } => put_job(&mut body, *seq, job),
        CtrlMsg::Outcome { seq, outcome } => put_outcome(&mut body, *seq, outcome),
    }
    let mut frame = Vec::with_capacity(HEADER_LEN + body.len() + FOOTER_LEN);
    frame.extend_from_slice(&MAGIC);
    put_u16(&mut frame, PROTO_VERSION);
    frame.push(msg.kind());
    put_u32(&mut frame, body.len() as u32);
    frame.extend_from_slice(&body);
    put_u32(&mut frame, wire::fnv1a32(&body));
    frame
}

/// Bounds-checked body reader.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("proto body truncated at byte {} (wanted {n} more)", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// An element count, guarded so a corrupt length can't allocate more
    /// than the bytes that actually remain.
    fn count(&mut self, min_item: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let left = self.buf.len() - self.pos;
        if n.saturating_mul(min_item.max(1)) > left {
            bail!("proto count {n} exceeds remaining {left} bytes");
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| anyhow::anyhow!("proto string not UTF-8"))
    }

    fn params(&mut self, spec: &ModelSpec) -> Result<Params> {
        let n = self.count(1)?;
        let frame = self.take(n)?;
        let msg = wire::decode(spec, frame)?;
        match msg.payload {
            WirePayload::Full(ps) => Ok(ps),
            _ => bail!("proto param frame is not a Full payload"),
        }
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("proto body has {} trailing bytes", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

fn get_job(r: &mut Reader, spec: &ModelSpec) -> Result<(u64, TrainJob)> {
    let seq = r.u64()?;
    let client = r.u32()? as usize;
    let bucket = r.u32()? as usize;
    let layers = r.count(4)?;
    let mut skeleton = Vec::with_capacity(layers);
    for _ in 0..layers {
        let n = r.count(4)?;
        let mut layer = Vec::with_capacity(n);
        for _ in 0..n {
            layer.push(r.i32()?);
        }
        skeleton.push(layer);
    }
    let local = r.params(spec)?;
    let global = Arc::new(r.params(spec)?);
    let nb = r.count(8)?;
    let mut batches = Vec::with_capacity(nb);
    for _ in 0..nb {
        let nx = r.count(4)?;
        let mut x = Vec::with_capacity(nx);
        for _ in 0..nx {
            x.push(r.f32()?);
        }
        let ny = r.count(4)?;
        let mut y = Vec::with_capacity(ny);
        for _ in 0..ny {
            y.push(r.i32()?);
        }
        batches.push((x, y));
    }
    let lr = r.f32()?;
    let mu = r.f32()?;
    let want_importance = r.u8()? != 0;
    let threads = r.u32()? as usize;
    let tier = match r.u8()? {
        0 => KernelTier::Scalar,
        1 => KernelTier::Simd,
        t => bail!("unknown kernel tier code {t}"),
    };
    let precision = match r.u8()? {
        0 => Precision::F32,
        1 => Precision::Int8,
        p => bail!("unknown precision code {p}"),
    };
    Ok((
        seq,
        TrainJob {
            client,
            bucket,
            skeleton,
            local,
            global,
            batches,
            lr,
            mu,
            want_importance,
            par: Parallelism::new(threads).with_tier(tier),
            precision,
        },
    ))
}

fn get_outcome(r: &mut Reader, spec: &ModelSpec) -> Result<(u64, TrainOutcome)> {
    let seq = r.u64()?;
    let client = r.u32()? as usize;
    let params = r.params(spec)?;
    let mean_loss = r.f32()?;
    let layers = r.count(4)?;
    let mut importance_sums = Vec::with_capacity(layers);
    for _ in 0..layers {
        let n = r.count(4)?;
        let mut layer = Vec::with_capacity(n);
        for _ in 0..n {
            layer.push(r.f32()?);
        }
        importance_sums.push(layer);
    }
    let steps = r.u64()? as usize;
    Ok((seq, TrainOutcome { client, params, mean_loss, importance_sums, steps }))
}

/// Decode one control frame. `spec` is required for `Job`/`Outcome`
/// bodies (their params travel as wire frames); pass `None` before the
/// handshake has named the model.
pub fn decode(frame: &[u8], spec: Option<&ModelSpec>) -> Result<CtrlMsg> {
    if frame.len() < HEADER_LEN + FOOTER_LEN {
        bail!("proto frame too short ({} bytes)", frame.len());
    }
    if frame[0..4] != MAGIC {
        bail!("bad proto magic {:02x?}", &frame[0..4]);
    }
    let version = u16::from_le_bytes(frame[4..6].try_into().unwrap());
    if version != PROTO_VERSION {
        bail!("unsupported proto version {version} (expected {PROTO_VERSION})");
    }
    let kind = frame[6];
    let body_len = u32::from_le_bytes(frame[7..11].try_into().unwrap()) as usize;
    if frame.len() != HEADER_LEN + body_len + FOOTER_LEN {
        bail!(
            "proto frame length mismatch: header says {} body bytes, frame has {}",
            body_len,
            frame.len() - HEADER_LEN - FOOTER_LEN
        );
    }
    let body = &frame[HEADER_LEN..HEADER_LEN + body_len];
    let want = u32::from_le_bytes(frame[HEADER_LEN + body_len..].try_into().unwrap());
    let got = wire::fnv1a32(body);
    if want != got {
        bail!("proto checksum mismatch (stored {want:#010x}, computed {got:#010x})");
    }
    let mut r = Reader { buf: body, pos: 0 };
    let msg = match kind {
        0 => CtrlMsg::Hello {
            wire_version: r.u16()?,
            determinism_key: r.str()?,
            worker: r.str()?,
        },
        1 => CtrlMsg::Welcome { slot: r.u32()?, model: r.str()?, determinism_key: r.str()? },
        2 => CtrlMsg::Reject { reason: r.str()? },
        3 => {
            let Some(spec) = spec else { bail!("job frame needs a model spec to decode") };
            let (seq, job) = get_job(&mut r, spec)?;
            CtrlMsg::Job { seq, job }
        }
        4 => {
            let Some(spec) = spec else { bail!("outcome frame needs a model spec to decode") };
            let (seq, outcome) = get_outcome(&mut r, spec)?;
            CtrlMsg::Outcome { seq, outcome }
        }
        5 => CtrlMsg::Shutdown { reason: r.str()? },
        k => bail!("unknown proto message kind {k}"),
    };
    r.done()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_params;
    use crate::runtime::mock::toy_spec;

    fn job(client: usize) -> TrainJob {
        let spec = toy_spec();
        let params = init_params(&spec, client as u64);
        let numel: usize = spec.input_shape.iter().product();
        TrainJob {
            client,
            bucket: 100,
            skeleton: vec![vec![0, 2], vec![1]],
            local: params.clone(),
            global: Arc::new(params),
            batches: vec![(vec![0.25f32; spec.train_batch * numel], vec![1i32; spec.train_batch])],
            lr: 0.05,
            mu: 0.01,
            want_importance: true,
            par: Parallelism::new(3).with_tier(KernelTier::Simd),
            precision: Precision::Int8,
        }
    }

    #[test]
    fn hello_welcome_roundtrip_without_a_spec() {
        let hello = CtrlMsg::Hello {
            wire_version: wire::VERSION,
            determinism_key: String::new(),
            worker: "w-42".into(),
        };
        let CtrlMsg::Hello { wire_version, determinism_key, worker } =
            decode(&encode(&hello), None).unwrap()
        else {
            panic!("wrong kind");
        };
        assert_eq!(wire_version, wire::VERSION);
        assert_eq!(determinism_key, "");
        assert_eq!(worker, "w-42");

        let welcome =
            CtrlMsg::Welcome { slot: 7, model: "lenet".into(), determinism_key: "k=v".into() };
        let CtrlMsg::Welcome { slot, model, determinism_key } =
            decode(&encode(&welcome), None).unwrap()
        else {
            panic!("wrong kind");
        };
        assert_eq!((slot, model.as_str(), determinism_key.as_str()), (7, "lenet", "k=v"));
    }

    #[test]
    fn job_roundtrips_bitwise() {
        let spec = toy_spec();
        let j = job(5);
        let frame = encode(&CtrlMsg::Job { seq: 99, job: j.clone() });
        let CtrlMsg::Job { seq, job: back } = decode(&frame, Some(&spec)).unwrap() else {
            panic!("wrong kind");
        };
        assert_eq!(seq, 99);
        assert_eq!(back.client, j.client);
        assert_eq!(back.bucket, j.bucket);
        assert_eq!(back.skeleton, j.skeleton);
        assert_eq!(back.local, j.local);
        assert_eq!(*back.global, *j.global);
        assert_eq!(back.batches, j.batches);
        assert_eq!(back.lr.to_bits(), j.lr.to_bits());
        assert_eq!(back.mu.to_bits(), j.mu.to_bits());
        assert_eq!(back.want_importance, j.want_importance);
        assert_eq!(back.par.threads(), 3);
        assert_eq!(back.par.tier(), KernelTier::Simd);
        assert_eq!(back.precision, Precision::Int8);
    }

    #[test]
    fn outcome_roundtrips_bitwise() {
        let spec = toy_spec();
        let out = TrainOutcome {
            client: 2,
            params: init_params(&spec, 11),
            mean_loss: 0.625,
            importance_sums: vec![vec![1.5, -0.25, 3.0]],
            steps: 4,
        };
        let frame = encode(&CtrlMsg::Outcome { seq: 7, outcome: out.clone() });
        let CtrlMsg::Outcome { seq, outcome: back } = decode(&frame, Some(&spec)).unwrap() else {
            panic!("wrong kind");
        };
        assert_eq!(seq, 7);
        assert_eq!(back.client, out.client);
        assert_eq!(back.params, out.params);
        assert_eq!(back.mean_loss.to_bits(), out.mean_loss.to_bits());
        assert_eq!(back.importance_sums, out.importance_sums);
        assert_eq!(back.steps, out.steps);
    }

    #[test]
    fn corruption_is_a_typed_error_never_a_panic() {
        let spec = toy_spec();
        let good = encode(&CtrlMsg::Job { seq: 1, job: job(0) });
        // every strict prefix decodes to an error, not a panic
        for cut in 0..good.len().min(64) {
            assert!(decode(&good[..cut], Some(&spec)).is_err());
        }
        assert!(decode(&good[..good.len() - 1], Some(&spec)).is_err());
        // flip one body byte → checksum mismatch
        let mut bad = good.clone();
        bad[HEADER_LEN + 3] ^= 0xFF;
        let e = decode(&bad, Some(&spec)).unwrap_err().to_string();
        assert!(e.contains("checksum"), "{e}");
        // wrong version and wrong magic are named errors
        let mut v = good.clone();
        v[4] = 9;
        assert!(decode(&v, Some(&spec)).unwrap_err().to_string().contains("version"));
        let mut m = good;
        m[0] = b'X';
        assert!(decode(&m, Some(&spec)).unwrap_err().to_string().contains("magic"));
        // a job without a spec is refused, not mis-decoded
        let j = encode(&CtrlMsg::Job { seq: 1, job: job(0) });
        assert!(decode(&j, None).unwrap_err().to_string().contains("model spec"));
    }

    #[test]
    fn shutdown_and_reject_carry_reasons() {
        let CtrlMsg::Shutdown { reason } =
            decode(&encode(&CtrlMsg::Shutdown { reason: "run complete".into() }), None).unwrap()
        else {
            panic!("wrong kind");
        };
        assert_eq!(reason, "run complete");
        let CtrlMsg::Reject { reason } =
            decode(&encode(&CtrlMsg::Reject { reason: "key mismatch".into() }), None).unwrap()
        else {
            panic!("wrong kind");
        };
        assert_eq!(reason, "key mismatch");
    }
}
