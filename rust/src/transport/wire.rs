//! Byte-accurate wire codec for federated round payloads.
//!
//! Everything a client and the server exchange in a round travels as one
//! framed, versioned, checksummed binary message. Tensor *shapes* never
//! travel — both ends share the [`ModelSpec`] manifest contract and the
//! decoder reconstructs shapes from it — so the wire carries only what
//! Table 2 charges for: values, plus the skeleton channel indices FedSkel
//! genuinely has to ship.
//!
//! ## Frame layout (all integers little-endian)
//!
//! | offset   | size | field |
//! |----------|------|-------|
//! | 0        | 4    | magic `b"FSKL"` |
//! | 4        | 2    | version (= 1) |
//! | 6        | 1    | payload kind (0 = Full, 1 = Skeleton, 2 = ParamSubset, 3 = AnchorDelta) |
//! | 7        | 1    | low nibble: quantization (0 = f32, 1 = f16, 2 = int8); high nibble: frame flags |
//! | 8        | 4    | round index |
//! | 12       | 4    | client id |
//! | 16       | 8    | aggregation weight (f64) |
//! | 24       | 4    | body length in bytes |
//! | 28       | body | payload body (see below) |
//! | 28+body  | 4    | FNV-1a-32 checksum of the body |
//!
//! ## Frame flags (byte 7, high nibble)
//!
//! | flag | bit | meaning |
//! |------|-----|---------|
//! | `DELTA` | `0x10` | body values are *arithmetic deltas* vs the receiver's anchor — apply with [`WirePayload::add_into`], not [`WirePayload::overlay_into`] (the [`crate::compress`] upload path) |
//! | `DESC`  | `0x20` | every value block is *self-described*: a descriptor byte precedes it, enabling a per-param quant override and top-k sparse blocks |
//!
//! A flag-free frame is byte-for-byte the pre-compression format — the
//! `Identity` compressor and default config never set a flag, which is
//! what pins the PR-4 golden digests.
//!
//! ## Body layout by kind
//!
//! * **Full** — `u32` tensor count, then every parameter tensor's value
//!   block in manifest order.
//! * **Skeleton** — `u32` prunable-layer count; per layer: `u32 k`,
//!   `k × u32` channel indices, the weight rows gathered at those channels
//!   (`rows × k` values), then `k` bias values. Then `u32` count and each
//!   non-prunable tensor as `u32 param_id` + value block.
//! * **ParamSubset** — `u32` entry count; per entry `u32 param_id` +
//!   value block.
//! * **AnchorDelta** — the server→client download delta format: `u32`
//!   entry count; per entry `u32 param_id`, then `u32 k` — `0xFFFF_FFFF`
//!   means a dense value block of the whole tensor follows; any other `k`
//!   means `k × u32` ascending changed flat indices followed by a value
//!   block of `k` *absolute* (not arithmetic-delta) values. Parameters
//!   whose frame-quant image is bitwise-unchanged vs the anchor are
//!   simply omitted and cost 0 bytes.
//!   Decoding requires the receiver's recorded anchor
//!   ([`decode_frame`]); the decoder returns the reconstructed
//!   [`WirePayload::Full`].
//!
//! ## Value blocks by quantization
//!
//! | quant | bytes for n values |
//! |-------|--------------------|
//! | f32   | `4·n` |
//! | f16   | `2·n` (IEEE 754 half, round-to-nearest) |
//! | int8  | `4 + n` (one f32 symmetric scale = max·abs/127, then i8) |
//!
//! ## Self-described blocks (`DESC` flag)
//!
//! When the `DESC` flag is set, each value block is preceded by one
//! descriptor byte: low nibble = the block's quant code (overriding the
//! frame default — how small tensors stay f32 while big ones go int8),
//! bit `0x80` = sparse. A sparse block is `u32 k`, `k × u32` strictly
//! ascending indices, then a `k`-value quant block; the decoder scatters
//! the values into zeros (the top-k compressor's wire form).
//!
//! The standalone, versioned copy of this spec — with a worked
//! field-by-field example frame — lives in `docs/WIRE_FORMAT.md`.
//!
//! [`encoded_len`] computes the exact frame size for an
//! [`ExchangeKind`] without building a payload, so pure accounting
//! (Table 2 at 100 clients × 1000 rounds) stays O(1) per round while the
//! numbers remain those of the real encoder — a property the codec tests
//! pin by comparing `encode(..).len()` against it.

use anyhow::{bail, Result};

use crate::comm::ExchangeKind;
use crate::model::{ModelSpec, Params};
use crate::prof;
use crate::tensor::Tensor;

/// Profiler span name for one frame kind byte (encode or decode side).
fn kind_span(kind: u8, encode: bool) -> &'static str {
    match (kind, encode) {
        (0, true) => "encode:full",
        (1, true) => "encode:skeleton",
        (2, true) => "encode:subset",
        (_, true) => "encode:anchor_delta",
        (0, false) => "decode:full",
        (1, false) => "decode:skeleton",
        (2, false) => "decode:subset",
        (_, false) => "decode:anchor_delta",
    }
}

/// Frame magic.
pub const MAGIC: [u8; 4] = *b"FSKL";
/// Wire format version.
pub const VERSION: u16 = 1;
/// Fixed header bytes before the body.
pub const HEADER_LEN: usize = 28;
/// Trailing checksum bytes.
pub const FOOTER_LEN: usize = 4;

/// Frame flag (byte 7, high nibble): body values are arithmetic deltas
/// vs the receiver's anchor — apply with [`WirePayload::add_into`].
pub const FLAG_DELTA: u8 = 0x10;
/// Frame flag (byte 7, high nibble): value blocks are self-described
/// (descriptor byte per block: per-param quant override + sparse form).
pub const FLAG_DESC: u8 = 0x20;
/// Descriptor-byte bit marking a sparse (top-k) block.
const DESC_SPARSE: u8 = 0x80;

/// Value-block quantization modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Quant {
    /// Exact 4-byte floats (bit-exact round trip).
    #[default]
    F32,
    /// IEEE 754 half precision (2 bytes/value).
    F16,
    /// Symmetric per-tensor int8 (1 byte/value + 4-byte scale).
    Int8,
}

impl Quant {
    pub fn parse(s: &str) -> Result<Quant> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "f32" => Quant::F32,
            "f16" => Quant::F16,
            "int8" | "i8" => Quant::Int8,
            _ => bail!("unknown quantization '{s}' — valid modes: f32|f16|int8"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Quant::F32 => "f32",
            Quant::F16 => "f16",
            Quant::Int8 => "int8",
        }
    }

    fn byte_code(&self) -> u8 {
        match self {
            Quant::F32 => 0,
            Quant::F16 => 1,
            Quant::Int8 => 2,
        }
    }

    fn from_byte(b: u8) -> Result<Quant> {
        Ok(match b {
            0 => Quant::F32,
            1 => Quant::F16,
            2 => Quant::Int8,
            _ => bail!("bad quant byte {b}"),
        })
    }

    /// Encoded size of a block of `n` values.
    pub fn block_len(&self, n: usize) -> usize {
        match self {
            Quant::F32 => 4 * n,
            Quant::F16 => 2 * n,
            Quant::Int8 => 4 + n,
        }
    }
}

/// How one value block of a payload is encoded under the `DESC` frame
/// flag: a per-block quant (the *per-param quant override* — e.g. biases
/// stay f32 while weight tensors go int8) and an optional top-k sparse
/// index set. Plans are produced by [`crate::compress`] compressors, one
/// per value block in payload traversal order.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockPlan {
    /// Quantization of this block's values (overrides the frame default).
    pub quant: Quant,
    /// Top-k sparse: strictly ascending flat indices to carry. `None`
    /// encodes the block dense.
    pub idx: Option<Vec<u32>>,
}

impl BlockPlan {
    /// A dense block at `quant`.
    pub fn dense(quant: Quant) -> BlockPlan {
        BlockPlan { quant, idx: None }
    }

    /// Encoded bytes of this block for `n` values (descriptor included).
    pub fn encoded_len(&self, n: usize) -> usize {
        1 + match &self.idx {
            None => self.quant.block_len(n),
            Some(idx) => 4 + 4 * idx.len() + self.quant.block_len(idx.len()),
        }
    }
}

/// One changed parameter of an [`WirePayload::AnchorDelta`] download:
/// either the whole tensor (`idx == None`) or the changed flat positions
/// and their new *absolute* values. Invariant (upheld by
/// [`WirePayload::anchor_delta`], required of hand-built entries):
/// `idx`, when present, is strictly ascending and the same length as
/// `vals` — [`encode`] panics on entries that violate it.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaEntry {
    pub pid: usize,
    /// Ascending changed flat indices; `None` = dense whole tensor.
    pub idx: Option<Vec<u32>>,
    pub vals: Vec<f32>,
}

/// One prunable layer's sparse skeleton update: the selected channels,
/// the weight rows gathered at them, and the matching bias entries.
#[derive(Debug, Clone, PartialEq)]
pub struct SkelLayerUpdate {
    /// Selected output channels, in the order the values are packed.
    pub idx: Vec<i32>,
    /// `rows × k` weight values, row-major over (row, selected channel).
    pub weight: Vec<f32>,
    /// `k` bias values.
    pub bias: Vec<f32>,
}

/// The decoded content of a round message.
#[derive(Debug, Clone, PartialEq)]
pub enum WirePayload {
    /// Every parameter tensor, manifest order.
    Full(Params),
    /// Sparse skeleton channels per prunable layer + full non-prunable
    /// tensors tagged with their param ids.
    Skeleton {
        layers: Vec<SkelLayerUpdate>,
        others: Vec<(usize, Tensor)>,
    },
    /// Only the listed parameter tensors.
    ParamSubset(Vec<(usize, Tensor)>),
    /// Server→client download as changed-vs-anchor entries (absolute
    /// values; unchanged parameters are omitted). The decoder resolves
    /// this against the receiver's anchor into a [`WirePayload::Full`].
    AnchorDelta(Vec<DeltaEntry>),
}

impl WirePayload {
    fn kind_byte(&self) -> u8 {
        match self {
            WirePayload::Full(_) => 0,
            WirePayload::Skeleton { .. } => 1,
            WirePayload::ParamSubset(_) => 2,
            WirePayload::AnchorDelta(_) => 3,
        }
    }

    /// Build a full-exchange payload.
    pub fn full(params: &Params) -> WirePayload {
        WirePayload::Full(params.clone())
    }

    /// Build a skeleton payload: gather `skeleton[l]` channels of every
    /// prunable layer's weight/bias and carry all non-prunable tensors
    /// whole.
    pub fn skeleton(spec: &ModelSpec, params: &Params, skeleton: &[Vec<i32>]) -> Result<WirePayload> {
        if skeleton.len() != spec.prunable.len() {
            bail!("skeleton has {} layers, spec {}", skeleton.len(), spec.prunable.len());
        }
        if params.len() != spec.params.len() {
            bail!("params len {} != spec {}", params.len(), spec.params.len());
        }
        let mut channelwise = vec![false; params.len()];
        let mut layers = Vec::with_capacity(spec.prunable.len());
        for (li, p) in spec.prunable.iter().enumerate() {
            channelwise[p.weight_param] = true;
            channelwise[p.bias_param] = true;
            let c = p.channels;
            let idx = &skeleton[li];
            if idx.iter().any(|&ch| ch < 0 || ch as usize >= c) {
                bail!("skeleton index out of range for layer {li}");
            }
            let w = &params[p.weight_param];
            let rows = w.len() / c;
            let wd = w.data();
            let mut weight = Vec::with_capacity(rows * idx.len());
            for r in 0..rows {
                for &ch in idx {
                    weight.push(wd[r * c + ch as usize]);
                }
            }
            let bd = params[p.bias_param].data();
            let bias: Vec<f32> = idx.iter().map(|&ch| bd[ch as usize]).collect();
            layers.push(SkelLayerUpdate { idx: idx.clone(), weight, bias });
        }
        let others = params
            .iter()
            .enumerate()
            .filter(|(pi, _)| !channelwise[*pi])
            .map(|(pi, t)| (pi, t.clone()))
            .collect();
        Ok(WirePayload::Skeleton { layers, others })
    }

    /// Build a parameter-subset payload (LG-FedAvg's global tensors).
    pub fn subset(spec: &ModelSpec, params: &Params, ids: &[usize]) -> Result<WirePayload> {
        let mut entries = Vec::with_capacity(ids.len());
        for &pi in ids {
            if pi >= spec.params.len() {
                bail!("param id {pi} out of range");
            }
            entries.push((pi, params[pi].clone()));
        }
        Ok(WirePayload::ParamSubset(entries))
    }

    /// Build a download delta payload: only the parameters (and within
    /// them, only the flat positions) where what the wire would deliver
    /// — the `quant` image of the current value — differs bitwise from
    /// what the receiving client already holds (`anchor`). Falls back to
    /// a dense entry at the quant-dependent break-even
    /// `changed · (4 + value_bytes) ≥ numel · value_bytes` (half the
    /// tensor at f32, a third at f16), where the index list would
    /// outweigh the savings; stable parameters are omitted
    /// entirely and cost 0 wire bytes. `quant` must be the frame quant
    /// the payload will be encoded at, and must be elementwise
    /// (f32/f16): under int8 the delivered values would depend on which
    /// elements ship, so it is rejected.
    pub fn anchor_delta(
        spec: &ModelSpec,
        anchor: &Params,
        current: &Params,
        quant: Quant,
    ) -> Result<WirePayload> {
        if quant == Quant::Int8 {
            bail!("anchor-delta needs an elementwise quant (f32|f16)");
        }
        if anchor.len() != spec.params.len() || current.len() != spec.params.len() {
            bail!(
                "anchor-delta wants {} tensors (anchor {}, current {})",
                spec.params.len(),
                anchor.len(),
                current.len()
            );
        }
        let mut entries = Vec::new();
        for (pid, (a, c)) in anchor.iter().zip(current).enumerate() {
            if a.shape() != c.shape() {
                bail!("anchor-delta tensor {pid} shape mismatch");
            }
            let (ad, cd) = (a.data(), c.data());
            // compare the quant image, not the raw value: under f16 the
            // anchor holds f16-decoded values, and an element is stable
            // exactly when its f16 image equals them — comparing raw f32
            // would mark everything changed and inflate the frame. At
            // f32 the image IS the value, so skip the copy.
            let cq;
            let cmp: &[f32] = match quant {
                Quant::F32 => cd,
                _ => {
                    cq = quant_roundtrip(cd, quant);
                    &cq
                }
            };
            let changed: Vec<u32> = (0..cd.len())
                .filter(|&j| ad[j].to_bits() != cmp[j].to_bits())
                .map(|j| j as u32)
                .collect();
            if changed.is_empty() {
                continue;
            }
            // sparse costs (4 index + vb value) bytes per changed
            // element vs vb per element dense — break even where the
            // frame quant's value bytes say, not at a fixed 50%
            let vb = match quant {
                Quant::F32 => 4,
                Quant::F16 => 2,
                Quant::Int8 => unreachable!("rejected above"),
            };
            if changed.len() * (4 + vb) >= cd.len() * vb {
                entries.push(DeltaEntry { pid, idx: None, vals: cd.to_vec() });
            } else {
                let vals = changed.iter().map(|&j| cd[j as usize]).collect();
                entries.push(DeltaEntry { pid, idx: Some(changed), vals });
            }
        }
        // when everything changed (FedAvg early training), the delta
        // form costs the dense values PLUS 8 bytes/entry of pid+k
        // framing — ship the cheaper plain Full payload instead (the
        // receiver's anchor tracking handles both forms identically)
        let delta_body: usize = 4
            + entries
                .iter()
                .map(|e| {
                    8 + match &e.idx {
                        None => quant.block_len(e.vals.len()),
                        Some(idx) => 4 * idx.len() + quant.block_len(idx.len()),
                    }
                })
                .sum::<usize>();
        let full_body: usize =
            4 + spec.params.iter().map(|p| quant.block_len(p.numel())).sum::<usize>();
        if delta_body >= full_body {
            return Ok(WirePayload::full(current));
        }
        Ok(WirePayload::AnchorDelta(entries))
    }

    /// Scalar parameters this payload carries — matches
    /// [`crate::comm::params_moved`] for the corresponding
    /// [`ExchangeKind`].
    pub fn params_carried(&self) -> usize {
        match self {
            WirePayload::Full(ps) => ps.iter().map(|t| t.len()).sum(),
            WirePayload::Skeleton { layers, others } => {
                layers.iter().map(|l| l.weight.len() + l.bias.len()).sum::<usize>()
                    + others.iter().map(|(_, t)| t.len()).sum::<usize>()
            }
            WirePayload::ParamSubset(es) => es.iter().map(|(_, t)| t.len()).sum(),
            WirePayload::AnchorDelta(es) => es.iter().map(|e| e.vals.len()).sum(),
        }
    }

    /// Apply this payload onto `target` — the decode-then-apply half of
    /// every exchange. Full replaces everything; Skeleton scatters the
    /// selected channels and replaces non-prunable tensors; ParamSubset
    /// replaces only the listed tensors.
    pub fn overlay_into(&self, spec: &ModelSpec, target: &mut Params) -> Result<()> {
        if target.len() != spec.params.len() {
            bail!("target len {} != spec {}", target.len(), spec.params.len());
        }
        match self {
            WirePayload::Full(ps) => {
                if ps.len() != target.len() {
                    bail!("full payload has {} tensors, want {}", ps.len(), target.len());
                }
                for (t, p) in target.iter_mut().zip(ps) {
                    if t.shape() != p.shape() {
                        bail!("full payload tensor shape mismatch");
                    }
                    *t = p.clone();
                }
            }
            WirePayload::Skeleton { layers, others } => {
                if layers.len() != spec.prunable.len() {
                    bail!("skeleton payload has {} layers, spec {}", layers.len(), spec.prunable.len());
                }
                for (li, (p, l)) in spec.prunable.iter().zip(layers).enumerate() {
                    let c = p.channels;
                    let k = l.idx.len();
                    let w = &mut target[p.weight_param];
                    let rows = w.len() / c;
                    if l.weight.len() != rows * k || l.bias.len() != k {
                        bail!("skeleton layer {li} value counts mismatch");
                    }
                    let wd = w.data_mut();
                    for r in 0..rows {
                        for (j, &ch) in l.idx.iter().enumerate() {
                            if ch < 0 || ch as usize >= c {
                                bail!("skeleton layer {li} channel {ch} out of range");
                            }
                            wd[r * c + ch as usize] = l.weight[r * k + j];
                        }
                    }
                    let bd = target[p.bias_param].data_mut();
                    for (j, &ch) in l.idx.iter().enumerate() {
                        bd[ch as usize] = l.bias[j];
                    }
                }
                for (pi, t) in others {
                    if *pi >= target.len() || target[*pi].shape() != t.shape() {
                        bail!("skeleton payload other tensor {pi} mismatch");
                    }
                    target[*pi] = t.clone();
                }
            }
            WirePayload::ParamSubset(es) => {
                for (pi, t) in es {
                    if *pi >= target.len() || target[*pi].shape() != t.shape() {
                        bail!("subset payload tensor {pi} mismatch");
                    }
                    target[*pi] = t.clone();
                }
            }
            WirePayload::AnchorDelta(_) => {
                bail!("anchor-delta payloads are resolved against the anchor at decode time")
            }
        }
        Ok(())
    }

    /// Add this payload's values onto `target` — the apply half of a
    /// `DELTA`-flagged frame, whose values are arithmetic update deltas
    /// vs the shared anchor ([`crate::compress`] uploads). Structure
    /// mirrors [`WirePayload::overlay_into`]: Full adds every tensor,
    /// Skeleton scatter-adds the selected channels and adds non-prunable
    /// tensors whole, ParamSubset adds only the listed tensors.
    pub fn add_into(&self, spec: &ModelSpec, target: &mut Params) -> Result<()> {
        if target.len() != spec.params.len() {
            bail!("target len {} != spec {}", target.len(), spec.params.len());
        }
        match self {
            WirePayload::Full(ps) => {
                if ps.len() != target.len() {
                    bail!("full payload has {} tensors, want {}", ps.len(), target.len());
                }
                for (t, p) in target.iter_mut().zip(ps) {
                    t.axpy(1.0, p)?;
                }
            }
            WirePayload::Skeleton { layers, others } => {
                if layers.len() != spec.prunable.len() {
                    bail!("skeleton payload has {} layers, spec {}", layers.len(), spec.prunable.len());
                }
                for (li, (p, l)) in spec.prunable.iter().zip(layers).enumerate() {
                    let c = p.channels;
                    let k = l.idx.len();
                    let w = &mut target[p.weight_param];
                    let rows = w.len() / c;
                    if l.weight.len() != rows * k || l.bias.len() != k {
                        bail!("skeleton layer {li} value counts mismatch");
                    }
                    let wd = w.data_mut();
                    for r in 0..rows {
                        for (j, &ch) in l.idx.iter().enumerate() {
                            if ch < 0 || ch as usize >= c {
                                bail!("skeleton layer {li} channel {ch} out of range");
                            }
                            wd[r * c + ch as usize] += l.weight[r * k + j];
                        }
                    }
                    let bd = target[p.bias_param].data_mut();
                    for (j, &ch) in l.idx.iter().enumerate() {
                        bd[ch as usize] += l.bias[j];
                    }
                }
                for (pi, t) in others {
                    if *pi >= target.len() || target[*pi].shape() != t.shape() {
                        bail!("skeleton payload other tensor {pi} mismatch");
                    }
                    target[*pi].axpy(1.0, t)?;
                }
            }
            WirePayload::ParamSubset(es) => {
                for (pi, t) in es {
                    if *pi >= target.len() || target[*pi].shape() != t.shape() {
                        bail!("subset payload tensor {pi} mismatch");
                    }
                    target[*pi].axpy(1.0, t)?;
                }
            }
            WirePayload::AnchorDelta(_) => {
                bail!("anchor-delta payloads are resolved against the anchor at decode time")
            }
        }
        Ok(())
    }
}

/// One round message: routing metadata + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundMsg {
    pub round: u32,
    pub client: u32,
    /// Aggregation weight (sample count) — 0.0 for downloads.
    pub weight: f64,
    pub payload: WirePayload,
}

/// Exact frame size for an [`ExchangeKind`] without building a payload.
/// `ExchangeKind::None` encodes nothing and costs 0 bytes.
pub fn encoded_len(spec: &ModelSpec, kind: &ExchangeKind, quant: Quant) -> usize {
    let body = match kind {
        ExchangeKind::None => return 0,
        ExchangeKind::Full => {
            4 + spec.params.iter().map(|p| quant.block_len(p.numel())).sum::<usize>()
        }
        ExchangeKind::ParamSubset(ids) => {
            4 + ids
                .iter()
                .map(|&pi| 4 + quant.block_len(spec.params[pi].numel()))
                .sum::<usize>()
        }
        ExchangeKind::Skeleton(ks) => {
            let mut channelwise = vec![false; spec.params.len()];
            let mut total = 4usize;
            for (li, p) in spec.prunable.iter().enumerate() {
                channelwise[p.weight_param] = true;
                channelwise[p.bias_param] = true;
                let k = ks[li].min(p.channels);
                let rows = spec.params[p.weight_param].numel() / p.channels;
                total += 4 + 4 * k + quant.block_len(rows * k) + quant.block_len(k);
            }
            total += 4;
            for (pi, p) in spec.params.iter().enumerate() {
                if !channelwise[pi] {
                    total += 4 + quant.block_len(p.numel());
                }
            }
            total
        }
    };
    HEADER_LEN + body + FOOTER_LEN
}

/// How a frame is encoded beyond the payload itself: frame-default
/// quant, the `DELTA` flag, and (for compressed frames) one
/// [`BlockPlan`] per value block in payload traversal order — providing
/// them sets the `DESC` flag.
#[derive(Debug, Clone, Default)]
pub struct FrameOpts<'a> {
    pub quant: Quant,
    /// Body values are arithmetic deltas (apply with
    /// [`WirePayload::add_into`]).
    pub delta: bool,
    /// Per-block encoding plans; count must match the payload's blocks.
    pub plans: Option<&'a [BlockPlan]>,
}

/// Writes each value block either at the frame quant (plan-free frames,
/// byte-identical to the pre-compression format) or per its plan.
struct BlockSink<'a> {
    plans: Option<&'a [BlockPlan]>,
    next: usize,
    quant: Quant,
}

impl<'a> BlockSink<'a> {
    fn put(&mut self, buf: &mut Vec<u8>, vals: &[f32]) -> Result<()> {
        let Some(plans) = self.plans else {
            put_values(buf, vals, self.quant);
            return Ok(());
        };
        let Some(plan) = plans.get(self.next) else {
            bail!("fewer block plans ({}) than payload value blocks", plans.len());
        };
        self.next += 1;
        match &plan.idx {
            None => {
                buf.push(plan.quant.byte_code());
                put_values(buf, vals, plan.quant);
            }
            Some(idx) => {
                buf.push(plan.quant.byte_code() | DESC_SPARSE);
                put_u32(buf, idx.len() as u32);
                let mut gathered = Vec::with_capacity(idx.len());
                let mut prev: Option<u32> = None;
                for &i in idx {
                    if i as usize >= vals.len() {
                        bail!("sparse plan index {i} out of range for block of {}", vals.len());
                    }
                    if prev.is_some_and(|p| i <= p) {
                        bail!("sparse plan indices must be strictly ascending");
                    }
                    prev = Some(i);
                    put_u32(buf, i);
                    gathered.push(vals[i as usize]);
                }
                put_values(buf, &gathered, plan.quant);
            }
        }
        Ok(())
    }
}

/// Encode a round message into one wire frame (plan-free, non-delta —
/// the pre-compression format, byte for byte).
///
/// # Panics
///
/// On a payload violating its own structural invariant (a hand-built
/// [`DeltaEntry`] with mismatched `idx`/`vals` lengths) — a programmer
/// error, not a wire condition. Builder-constructed payloads never
/// panic; use [`encode_opts`] for a `Result`.
pub fn encode(msg: &RoundMsg, quant: Quant) -> Vec<u8> {
    encode_opts(msg, &FrameOpts { quant, delta: false, plans: None })
        .expect("encode: payload violates its structural invariants")
}

/// Encode a round message with explicit frame options (delta flag,
/// per-block compression plans).
pub fn encode_opts(msg: &RoundMsg, opts: &FrameOpts) -> Result<Vec<u8>> {
    let _span = prof::scope(kind_span(msg.payload.kind_byte(), true));
    let quant = opts.quant;
    let mut sink = BlockSink { plans: opts.plans, next: 0, quant };
    let mut body = Vec::new();
    match &msg.payload {
        WirePayload::Full(ps) => {
            put_u32(&mut body, ps.len() as u32);
            for t in ps {
                sink.put(&mut body, t.data())?;
            }
        }
        WirePayload::Skeleton { layers, others } => {
            put_u32(&mut body, layers.len() as u32);
            for l in layers {
                put_u32(&mut body, l.idx.len() as u32);
                for &ch in &l.idx {
                    put_u32(&mut body, ch as u32);
                }
                sink.put(&mut body, &l.weight)?;
                sink.put(&mut body, &l.bias)?;
            }
            put_u32(&mut body, others.len() as u32);
            for (pi, t) in others {
                put_u32(&mut body, *pi as u32);
                sink.put(&mut body, t.data())?;
            }
        }
        WirePayload::ParamSubset(es) => {
            put_u32(&mut body, es.len() as u32);
            for (pi, t) in es {
                put_u32(&mut body, *pi as u32);
                sink.put(&mut body, t.data())?;
            }
        }
        WirePayload::AnchorDelta(es) => {
            put_u32(&mut body, es.len() as u32);
            for e in es {
                put_u32(&mut body, e.pid as u32);
                match &e.idx {
                    None => put_u32(&mut body, u32::MAX),
                    Some(idx) => {
                        if idx.len() != e.vals.len() {
                            bail!(
                                "anchor-delta entry {}: {} indices for {} values",
                                e.pid,
                                idx.len(),
                                e.vals.len()
                            );
                        }
                        put_u32(&mut body, idx.len() as u32);
                        for &i in idx {
                            put_u32(&mut body, i);
                        }
                    }
                }
                sink.put(&mut body, &e.vals)?;
            }
        }
    }
    if let Some(plans) = opts.plans {
        if sink.next != plans.len() {
            bail!("{} block plans for {} payload value blocks", plans.len(), sink.next);
        }
    }

    let mut flags = 0u8;
    if opts.delta {
        flags |= FLAG_DELTA;
    }
    if opts.plans.is_some() {
        flags |= FLAG_DESC;
    }
    let mut frame = Vec::with_capacity(HEADER_LEN + body.len() + FOOTER_LEN);
    frame.extend_from_slice(&MAGIC);
    frame.extend_from_slice(&VERSION.to_le_bytes());
    frame.push(msg.payload.kind_byte());
    frame.push(quant.byte_code() | flags);
    frame.extend_from_slice(&msg.round.to_le_bytes());
    frame.extend_from_slice(&msg.client.to_le_bytes());
    frame.extend_from_slice(&msg.weight.to_le_bytes());
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    let sum = {
        let _cs = prof::scope("checksum");
        fnv1a32(&body)
    };
    frame.extend_from_slice(&body);
    frame.extend_from_slice(&sum.to_le_bytes());
    Ok(frame)
}

/// Decode one wire frame. Shapes come from `spec`; the checksum, version,
/// and every count are validated before any tensor is built. Rejects
/// `DELTA`-flagged and anchor-delta frames — those need the caller to
/// hold an anchor; use [`decode_frame`] for them.
pub fn decode(spec: &ModelSpec, frame: &[u8]) -> Result<RoundMsg> {
    let (msg, delta) = decode_frame(spec, frame, None)?;
    if delta {
        bail!("delta-flagged frame needs decode_frame (the values are update deltas)");
    }
    Ok(msg)
}

/// Decode one wire frame, resolving anchor-delta downloads against the
/// receiver's recorded `anchor` (which must be `Some` for kind-3 frames)
/// and reporting whether the `DELTA` flag was set — in which case the
/// returned payload's values are arithmetic update deltas and must be
/// applied with [`WirePayload::add_into`].
pub fn decode_frame(
    spec: &ModelSpec,
    frame: &[u8],
    anchor: Option<&Params>,
) -> Result<(RoundMsg, bool)> {
    if frame.len() < HEADER_LEN + FOOTER_LEN {
        bail!("frame too short: {} bytes", frame.len());
    }
    if frame[0..4] != MAGIC {
        bail!("bad magic");
    }
    let version = u16::from_le_bytes([frame[4], frame[5]]);
    if version != VERSION {
        bail!("unsupported wire version {version}");
    }
    let kind = frame[6];
    let _span = prof::scope(kind_span(kind, false));
    let flags = frame[7] & 0xf0;
    if flags & !(FLAG_DELTA | FLAG_DESC) != 0 {
        bail!("unknown frame flags {:#04x}", flags);
    }
    let quant = Quant::from_byte(frame[7] & 0x0f)?;
    let desc = flags & FLAG_DESC != 0;
    let delta = flags & FLAG_DELTA != 0;
    let round = u32::from_le_bytes(frame[8..12].try_into().unwrap());
    let client = u32::from_le_bytes(frame[12..16].try_into().unwrap());
    let weight = f64::from_le_bytes(frame[16..24].try_into().unwrap());
    let body_len = u32::from_le_bytes(frame[24..28].try_into().unwrap()) as usize;
    if frame.len() != HEADER_LEN + body_len + FOOTER_LEN {
        bail!("frame length {} != header+{body_len}+footer", frame.len());
    }
    let body = &frame[HEADER_LEN..HEADER_LEN + body_len];
    let sum = u32::from_le_bytes(frame[HEADER_LEN + body_len..].try_into().unwrap());
    let body_sum = {
        let _cs = prof::scope("checksum");
        fnv1a32(body)
    };
    if body_sum != sum {
        bail!("checksum mismatch");
    }

    let mut r = Reader { buf: body, pos: 0 };
    let payload = match kind {
        0 => {
            let n = r.u32()? as usize;
            if n != spec.params.len() {
                bail!("full payload has {n} tensors, spec wants {}", spec.params.len());
            }
            let mut ps = Vec::with_capacity(n);
            for p in &spec.params {
                let data = r.block(p.numel(), quant, desc)?;
                ps.push(Tensor::from_vec(&p.shape, data)?);
            }
            WirePayload::Full(ps)
        }
        1 => {
            let n = r.u32()? as usize;
            if n != spec.prunable.len() {
                bail!("skeleton payload has {n} layers, spec wants {}", spec.prunable.len());
            }
            let mut channelwise = vec![false; spec.params.len()];
            let mut layers = Vec::with_capacity(n);
            for p in &spec.prunable {
                channelwise[p.weight_param] = true;
                channelwise[p.bias_param] = true;
                let k = r.u32()? as usize;
                if k > p.channels {
                    bail!("skeleton k {k} > channels {}", p.channels);
                }
                let mut idx = Vec::with_capacity(k);
                for _ in 0..k {
                    let ch = r.u32()?;
                    if ch as usize >= p.channels {
                        bail!("skeleton channel {ch} out of range");
                    }
                    idx.push(ch as i32);
                }
                let rows = spec.params[p.weight_param].numel() / p.channels;
                let weight = r.block(rows * k, quant, desc)?;
                let bias = r.block(k, quant, desc)?;
                layers.push(SkelLayerUpdate { idx, weight, bias });
            }
            let m = r.u32()? as usize;
            let mut others = Vec::with_capacity(m);
            for _ in 0..m {
                let pi = r.u32()? as usize;
                if pi >= spec.params.len() || channelwise[pi] {
                    bail!("bad non-prunable param id {pi}");
                }
                let p = &spec.params[pi];
                let data = r.block(p.numel(), quant, desc)?;
                others.push((pi, Tensor::from_vec(&p.shape, data)?));
            }
            WirePayload::Skeleton { layers, others }
        }
        2 => {
            let n = r.u32()? as usize;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let pi = r.u32()? as usize;
                if pi >= spec.params.len() {
                    bail!("subset param id {pi} out of range");
                }
                let p = &spec.params[pi];
                let data = r.block(p.numel(), quant, desc)?;
                entries.push((pi, Tensor::from_vec(&p.shape, data)?));
            }
            WirePayload::ParamSubset(entries)
        }
        3 => {
            let Some(anchor) = anchor else {
                bail!("anchor-delta frame needs the receiver's recorded anchor");
            };
            if anchor.len() != spec.params.len() {
                bail!("anchor has {} tensors, spec wants {}", anchor.len(), spec.params.len());
            }
            let n = r.u32()? as usize;
            let mut full: Params = anchor.clone();
            let mut last_pid: Option<usize> = None;
            for _ in 0..n {
                let pid = r.u32()? as usize;
                if pid >= spec.params.len() {
                    bail!("anchor-delta param id {pid} out of range");
                }
                if last_pid.is_some_and(|p| pid <= p) {
                    bail!("anchor-delta entries must be in ascending param order");
                }
                last_pid = Some(pid);
                let numel = spec.params[pid].numel();
                if full[pid].len() != numel {
                    bail!("anchor tensor {pid} has {} values, spec wants {numel}", full[pid].len());
                }
                let k = r.u32()?;
                if k == u32::MAX {
                    let data = r.block(numel, quant, desc)?;
                    full[pid] = Tensor::from_vec(&spec.params[pid].shape, data)?;
                } else {
                    let k = k as usize;
                    if k > numel {
                        bail!("anchor-delta entry {pid}: {k} changed of {numel} values");
                    }
                    let idx = r.ascending_indices(k, numel)?;
                    let vals = r.block(k, quant, desc)?;
                    let d = full[pid].data_mut();
                    for (v, &i) in vals.iter().zip(&idx) {
                        d[i as usize] = *v;
                    }
                }
            }
            WirePayload::Full(full)
        }
        k => bail!("unknown payload kind {k}"),
    };
    if r.pos != body.len() {
        bail!("trailing {} bytes in body", body.len() - r.pos);
    }
    Ok((RoundMsg { round, client, weight, payload }, delta))
}

// --------------------------------------------------------------- plumbing

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_values(buf: &mut Vec<u8>, vals: &[f32], quant: Quant) {
    match quant {
        Quant::F32 => {
            for &v in vals {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        Quant::F16 => {
            for &v in vals {
                buf.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
            }
        }
        Quant::Int8 => {
            let scale = int8_scale(vals);
            buf.extend_from_slice(&scale.to_le_bytes());
            for &v in vals {
                buf.push(int8_quantize(v, scale) as u8);
            }
        }
    }
}

/// Symmetric per-block int8 scale: `max |v| / 127` (0 for all-zero blocks).
///
/// Public because [`crate::kernels::int8`] reuses the *same* quantizer on
/// the compute side (per-tensor activation / per-channel weight scales),
/// keeping wire and compute int8 semantics identical.
pub fn int8_scale(vals: &[f32]) -> f32 {
    let max_abs = vals.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if max_abs > 0.0 {
        max_abs / 127.0
    } else {
        0.0
    }
}

/// Symmetric int8 quantization at `scale` (round-to-nearest, clamped to
/// ±127). Shared with [`crate::kernels::int8`] — see [`int8_scale`].
pub fn int8_quantize(v: f32, scale: f32) -> i8 {
    if scale > 0.0 {
        (v / scale).round().clamp(-127.0, 127.0) as i8
    } else {
        0
    }
}

/// The exact values a decoder reconstructs for `vals` encoded dense at
/// `quant` — quantize-then-dequantize, implemented with the same scale
/// and conversion routines as [`encode`]/[`decode`], so
/// [`crate::compress`]'s error-feedback residuals are bitwise consistent
/// with what the server actually receives.
pub fn quant_roundtrip(vals: &[f32], quant: Quant) -> Vec<f32> {
    match quant {
        Quant::F32 => vals.to_vec(),
        Quant::F16 => vals.iter().map(|&v| f16_bits_to_f32(f32_to_f16_bits(v))).collect(),
        Quant::Int8 => {
            let scale = int8_scale(vals);
            vals.iter().map(|&v| int8_quantize(v, scale) as f32 * scale).collect()
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("body truncated at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read `k` strictly ascending u32 indices, each `< n` — the shared
    /// index-list form of sparse blocks and anchor-delta entries.
    fn ascending_indices(&mut self, k: usize, n: usize) -> Result<Vec<u32>> {
        let mut idx = Vec::with_capacity(k);
        let mut prev: Option<u32> = None;
        for _ in 0..k {
            let i = self.u32()?;
            if i as usize >= n {
                bail!("sparse index {i} out of range ({n} values)");
            }
            if prev.is_some_and(|p| i <= p) {
                bail!("sparse indices must be strictly ascending");
            }
            prev = Some(i);
            idx.push(i);
        }
        Ok(idx)
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn values(&mut self, n: usize, quant: Quant) -> Result<Vec<f32>> {
        match quant {
            Quant::F32 => {
                let raw = self.take(4 * n)?;
                Ok(raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect())
            }
            Quant::F16 => {
                let raw = self.take(2 * n)?;
                Ok(raw
                    .chunks_exact(2)
                    .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                    .collect())
            }
            Quant::Int8 => {
                let scale = self.f32()?;
                let raw = self.take(n)?;
                Ok(raw.iter().map(|&b| (b as i8) as f32 * scale).collect())
            }
        }
    }

    /// Read one value block of logical length `n`: a plain quant block
    /// when the frame is not self-described, else a descriptor byte
    /// followed by a dense or sparse (scatter-into-zeros) block.
    fn block(&mut self, n: usize, frame_quant: Quant, desc: bool) -> Result<Vec<f32>> {
        if !desc {
            return self.values(n, frame_quant);
        }
        let d = self.take(1)?[0];
        if d & !(DESC_SPARSE | 0x0f) != 0 {
            bail!("unknown block descriptor bits {d:#04x}");
        }
        let quant = Quant::from_byte(d & 0x0f)?;
        if d & DESC_SPARSE == 0 {
            return self.values(n, quant);
        }
        let k = self.u32()? as usize;
        if k > n {
            bail!("sparse block carries {k} of {n} values");
        }
        let idx = self.ascending_indices(k, n)?;
        let vals = self.values(k, quant)?;
        let mut out = vec![0.0f32; n];
        for (v, &i) in vals.iter().zip(&idx) {
            out[i as usize] = *v;
        }
        Ok(out)
    }
}

/// Peek `(round, client)` out of a frame header without decoding the
/// body — `None` if the buffer is too short or the magic is wrong.
///
/// The coordinator's reliable-exchange loop uses this to recognise
/// stray frames (a delayed duplicate from an earlier retry, a reordered
/// neighbour) *before* paying for a full decode, so mismatched frames
/// can be discarded and ledgered as waste instead of double-aggregated.
pub fn peek_ids(frame: &[u8]) -> Option<(u32, u32)> {
    if frame.len() < HEADER_LEN || frame[0..4] != MAGIC {
        return None;
    }
    let round = u32::from_le_bytes(frame[8..12].try_into().unwrap());
    let client = u32::from_le_bytes(frame[12..16].try_into().unwrap());
    Some((round, client))
}

/// FNV-1a 32-bit.
pub fn fnv1a32(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// f32 → IEEE 754 half bits, round-to-nearest.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 255 {
        // inf / nan
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal half
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = (m >> shift) as u16;
        let round = ((m >> (shift - 1)) & 1) as u16;
        return sign | (half + round);
    }
    let half = ((e as u32) << 10 | (mant >> 13)) as u16;
    let round = ((mant >> 12) & 1) as u16;
    sign | (half + round)
}

/// IEEE 754 half bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: renormalize
            let mut e: i32 = 127 - 15 + 1;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x03ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::params_moved;
    use crate::model::init_params;
    use crate::runtime::mock::toy_spec;

    fn msg(payload: WirePayload) -> RoundMsg {
        RoundMsg { round: 3, client: 7, weight: 40.0, payload }
    }

    #[test]
    fn full_roundtrip_bit_exact() {
        let spec = toy_spec();
        let params = init_params(&spec, 5);
        let m = msg(WirePayload::full(&params));
        let frame = encode(&m, Quant::F32);
        assert_eq!(frame.len(), encoded_len(&spec, &ExchangeKind::Full, Quant::F32));
        let back = decode(&spec, &frame).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn peek_ids_reads_the_header_without_decoding() {
        let spec = toy_spec();
        let params = init_params(&spec, 5);
        let frame = encode(&msg(WirePayload::full(&params)), Quant::F32);
        assert_eq!(peek_ids(&frame), Some((3, 7)));
        // truncated-to-header still peeks; shorter does not
        assert_eq!(peek_ids(&frame[..HEADER_LEN]), Some((3, 7)));
        assert_eq!(peek_ids(&frame[..HEADER_LEN - 1]), None);
        // wrong magic is not a frame
        let mut bad = frame.clone();
        bad[0] = b'X';
        assert_eq!(peek_ids(&bad), None);
    }

    #[test]
    fn skeleton_roundtrip_and_len() {
        let spec = toy_spec();
        let params = init_params(&spec, 9);
        let skel = vec![vec![1i32, 3]];
        let m = msg(WirePayload::skeleton(&spec, &params, &skel).unwrap());
        let frame = encode(&m, Quant::F32);
        assert_eq!(frame.len(), encoded_len(&spec, &ExchangeKind::Skeleton(vec![2]), Quant::F32));
        let back = decode(&spec, &frame).unwrap();
        assert_eq!(back, m);
        // k == channels (identity skeleton) also round-trips
        let full_skel = vec![vec![0i32, 1, 2, 3]];
        let m2 = msg(WirePayload::skeleton(&spec, &params, &full_skel).unwrap());
        let f2 = encode(&m2, Quant::F32);
        assert_eq!(f2.len(), encoded_len(&spec, &ExchangeKind::Skeleton(vec![4]), Quant::F32));
        assert_eq!(decode(&spec, &f2).unwrap(), m2);
    }

    #[test]
    fn empty_skeleton_roundtrips() {
        let spec = toy_spec();
        let params = init_params(&spec, 2);
        let m = msg(WirePayload::skeleton(&spec, &params, &[vec![]]).unwrap());
        let frame = encode(&m, Quant::F32);
        assert_eq!(frame.len(), encoded_len(&spec, &ExchangeKind::Skeleton(vec![0]), Quant::F32));
        let back = decode(&spec, &frame).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.payload.params_carried(), params[2].len() + params[3].len());
    }

    #[test]
    fn subset_roundtrip() {
        let spec = toy_spec();
        let params = init_params(&spec, 1);
        let m = msg(WirePayload::subset(&spec, &params, &[2, 3]).unwrap());
        let frame = encode(&m, Quant::F32);
        assert_eq!(
            frame.len(),
            encoded_len(&spec, &ExchangeKind::ParamSubset(vec![2, 3]), Quant::F32)
        );
        assert_eq!(decode(&spec, &frame).unwrap(), m);
    }

    #[test]
    fn value_bytes_match_comm_ledger_accounting() {
        // at f32, value bytes on the wire == 4 × params_moved; everything
        // else is the fixed frame + index overhead computed here by hand.
        let spec = toy_spec();
        for (kind, idx_overhead, counts) in [
            (ExchangeKind::Full, 0usize, 4usize),
            // skeleton: per-layer (k count + k idx), others count + 1 id
            (ExchangeKind::Skeleton(vec![2]), 4 * 2, 4 + 4 + 4 + 2 * 4),
            (ExchangeKind::ParamSubset(vec![0, 2]), 0, 4 + 2 * 4),
        ] {
            let len = encoded_len(&spec, &kind, Quant::F32);
            let values = 4 * params_moved(&spec, &kind);
            assert_eq!(
                len,
                HEADER_LEN + FOOTER_LEN + counts + idx_overhead + values,
                "{kind:?}"
            );
        }
        assert_eq!(encoded_len(&spec, &ExchangeKind::None, Quant::F32), 0);
    }

    #[test]
    fn skeleton_encodes_fewer_bytes_than_full() {
        let spec = toy_spec();
        let full = encoded_len(&spec, &ExchangeKind::Full, Quant::F32);
        let skel = encoded_len(&spec, &ExchangeKind::Skeleton(vec![1]), Quant::F32);
        assert!(skel < full, "skeleton {skel} !< full {full}");
    }

    #[test]
    fn quantized_sizes_and_error_bounds() {
        let spec = toy_spec();
        let params = init_params(&spec, 3);
        let m = msg(WirePayload::full(&params));
        let f32_len = encode(&m, Quant::F32).len();
        let f16 = encode(&m, Quant::F16);
        let i8f = encode(&m, Quant::Int8);
        assert!(f16.len() < f32_len);
        assert!(i8f.len() < f16.len());
        assert_eq!(f16.len(), encoded_len(&spec, &ExchangeKind::Full, Quant::F16));
        assert_eq!(i8f.len(), encoded_len(&spec, &ExchangeKind::Full, Quant::Int8));

        for (frame, tol) in [(f16, 1e-3f32), (i8f, 2e-2f32)] {
            let back = decode(&spec, &frame).unwrap();
            let WirePayload::Full(ps) = &back.payload else { panic!("wrong kind") };
            for (a, b) in ps.iter().zip(&params) {
                let scale = b.max_abs().max(1e-6);
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert!((x - y).abs() <= tol * scale, "{x} vs {y} (tol {tol})");
                }
            }
        }
    }

    #[test]
    fn f16_conversion_basics() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, 6.1e-5, 3.14159] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!((back - v).abs() <= v.abs() * 1e-3 + 1e-7, "{v} -> {back}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e9)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // subnormal half survives
        let v = 3.0e-7f32;
        let back = f16_bits_to_f32(f32_to_f16_bits(v));
        assert!((back - v).abs() < 1e-7);
    }

    #[test]
    fn corruption_is_detected() {
        let spec = toy_spec();
        let params = init_params(&spec, 0);
        let mut frame = encode(&msg(WirePayload::full(&params)), Quant::F32);
        // flip one body byte → checksum must catch it
        let mid = HEADER_LEN + 5;
        frame[mid] ^= 0xff;
        assert!(decode(&spec, &frame).is_err());
        // bad magic
        let mut f2 = encode(&msg(WirePayload::full(&params)), Quant::F32);
        f2[0] = b'X';
        assert!(decode(&spec, &f2).is_err());
        // truncation
        let f3 = encode(&msg(WirePayload::full(&params)), Quant::F32);
        assert!(decode(&spec, &f3[..f3.len() - 8]).is_err());
        assert!(decode(&spec, &[]).is_err());
    }

    #[test]
    fn overlay_full_and_subset() {
        let spec = toy_spec();
        let a = init_params(&spec, 1);
        let b = init_params(&spec, 2);
        let mut target = b.clone();
        WirePayload::full(&a).overlay_into(&spec, &mut target).unwrap();
        assert_eq!(target, a);
        let mut target = b.clone();
        WirePayload::subset(&spec, &a, &[2]).unwrap().overlay_into(&spec, &mut target).unwrap();
        assert_eq!(target[2], a[2]);
        assert_eq!(target[0], b[0]);
    }

    #[test]
    fn overlay_skeleton_scatters_only_selected_channels() {
        let spec = toy_spec();
        let src = init_params(&spec, 4);
        let base = init_params(&spec, 8);
        let skel = vec![vec![0i32, 2]];
        let p = WirePayload::skeleton(&spec, &src, &skel).unwrap();
        let mut target = base.clone();
        p.overlay_into(&spec, &mut target).unwrap();
        let c = spec.prunable[0].channels;
        let rows = src[0].len() / c;
        for r in 0..rows {
            for ch in 0..c {
                let want = if ch == 0 || ch == 2 { src[0].data() } else { base[0].data() };
                assert_eq!(target[0].data()[r * c + ch], want[r * c + ch]);
            }
        }
        // bias mirrors, non-prunable tensors replaced whole
        assert_eq!(target[1].data()[1], base[1].data()[1]);
        assert_eq!(target[1].data()[2], src[1].data()[2]);
        assert_eq!(target[2], src[2]);
        assert_eq!(target[3], src[3]);
    }

    #[test]
    fn params_carried_matches_params_moved() {
        let spec = toy_spec();
        let params = init_params(&spec, 6);
        for (payload, kind) in [
            (WirePayload::full(&params), ExchangeKind::Full),
            (
                WirePayload::skeleton(&spec, &params, &[vec![1, 2]]).unwrap(),
                ExchangeKind::Skeleton(vec![2]),
            ),
            (
                WirePayload::subset(&spec, &params, &[2, 3]).unwrap(),
                ExchangeKind::ParamSubset(vec![2, 3]),
            ),
        ] {
            assert_eq!(payload.params_carried(), params_moved(&spec, &kind));
        }
    }

    // ---------------------------------------- compression-era additions

    #[test]
    fn plain_frames_carry_no_flags() {
        // the pre-compression format is preserved byte for byte: no
        // frame flag is ever set on the plan-free path, and decode_frame
        // reports delta = false.
        let spec = toy_spec();
        let params = init_params(&spec, 3);
        for quant in [Quant::F32, Quant::F16, Quant::Int8] {
            let frame = encode(&msg(WirePayload::full(&params)), quant);
            assert_eq!(frame[7], quant.byte_code(), "flags must be zero at {quant:?}");
            let (back, delta) = decode_frame(&spec, &frame, None).unwrap();
            assert!(!delta);
            assert_eq!(back, decode(&spec, &frame).unwrap());
        }
    }

    #[test]
    fn quant_roundtrip_matches_the_decoder_bitwise() {
        // compress/ relies on this to compute error-feedback residuals:
        // the host-side roundtrip must equal what the wire delivers.
        let spec = toy_spec();
        let params = init_params(&spec, 11);
        for quant in [Quant::F32, Quant::F16, Quant::Int8] {
            let frame = encode(&msg(WirePayload::full(&params)), quant);
            let back = decode(&spec, &frame).unwrap();
            let WirePayload::Full(ps) = &back.payload else { panic!("wrong kind") };
            for (got, orig) in ps.iter().zip(&params) {
                let want = quant_roundtrip(orig.data(), quant);
                assert_eq!(got.data(), &want[..], "{quant:?}");
            }
        }
    }

    #[test]
    fn anchor_delta_roundtrips_bitwise_and_omits_unchanged() {
        let spec = toy_spec();
        let anchor = init_params(&spec, 1);
        let mut current = anchor.clone();
        // two sparse changes in param 0; every element of param 2 moves
        current[0].data_mut()[3] = 9.0;
        current[0].data_mut()[17] = -2.0;
        for v in current[2].data_mut() {
            *v += 1.0;
        }
        let payload = WirePayload::anchor_delta(&spec, &anchor, &current, Quant::F32).unwrap();
        let WirePayload::AnchorDelta(entries) = &payload else { panic!("wrong kind") };
        assert_eq!(entries.len(), 2, "unchanged params must be omitted");
        assert_eq!(entries[0].pid, 0);
        assert_eq!(entries[0].idx.as_deref(), Some(&[3u32, 17][..]));
        assert_eq!(entries[1].pid, 2);
        assert!(entries[1].idx.is_none(), "fully-changed tensors go dense");
        assert_eq!(payload.params_carried(), 2 + current[2].len());

        let frame = encode(&msg(payload), Quant::F32);
        // decoding needs the anchor…
        assert!(decode_frame(&spec, &frame, None).is_err());
        assert!(decode(&spec, &frame).is_err());
        // …and reconstructs the sender's params bitwise
        let (back, delta) = decode_frame(&spec, &frame, Some(&anchor)).unwrap();
        assert!(!delta);
        assert_eq!(back.payload, WirePayload::Full(current));
        // the delta frame is smaller than the full one it replaces
        let full = encode(&msg(WirePayload::full(&anchor)), Quant::F32);
        assert!(frame.len() < full.len(), "{} !< {}", frame.len(), full.len());

        // when every element changed, the delta framing would only add
        // bytes — the builder falls back to a plain Full payload
        let mut other = init_params(&spec, 9);
        for t in other.iter_mut() {
            for v in t.data_mut() {
                *v += 1.0;
            }
        }
        let fb = WirePayload::anchor_delta(&spec, &anchor, &other, Quant::F32).unwrap();
        assert!(matches!(fb, WirePayload::Full(_)), "all-changed must ship plain Full");
    }

    #[test]
    fn anchor_delta_of_identical_params_is_empty() {
        let spec = toy_spec();
        let params = init_params(&spec, 7);
        let payload = WirePayload::anchor_delta(&spec, &params, &params, Quant::F32).unwrap();
        let WirePayload::AnchorDelta(entries) = &payload else { panic!("wrong kind") };
        assert!(entries.is_empty());
        let frame = encode(&msg(payload), Quant::F32);
        assert_eq!(frame.len(), HEADER_LEN + 4 + FOOTER_LEN);
        let (back, _) = decode_frame(&spec, &frame, Some(&params)).unwrap();
        assert_eq!(back.payload, WirePayload::Full(params));
    }

    #[test]
    fn anchor_delta_under_f16_skips_stable_elements() {
        // the delta-down contract under a lossy-but-elementwise quant:
        // the anchor holds f16-decoded values, so stability is judged on
        // the f16 image — stable params cost ~0 bytes and the
        // reconstruction equals a plain f16 Full download bitwise.
        let spec = toy_spec();
        let prev = init_params(&spec, 12);
        let f16_image = |ps: &Params| -> Params {
            ps.iter()
                .map(|t| {
                    Tensor::from_vec(t.shape(), quant_roundtrip(t.data(), Quant::F16)).unwrap()
                })
                .collect()
        };
        let anchor = f16_image(&prev);
        // nothing changed server-side → nothing ships
        let payload = WirePayload::anchor_delta(&spec, &anchor, &prev, Quant::F16).unwrap();
        let WirePayload::AnchorDelta(entries) = &payload else { panic!("wrong kind") };
        assert!(entries.is_empty(), "f16-stable params must cost ~0 bytes");
        // one real change ships as one sparse element…
        let mut cur = prev.clone();
        cur[0].data_mut()[7] = 42.0;
        let payload = WirePayload::anchor_delta(&spec, &anchor, &cur, Quant::F16).unwrap();
        let WirePayload::AnchorDelta(entries) = &payload else { panic!("wrong kind") };
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].pid, 0);
        assert_eq!(entries[0].idx.as_deref(), Some(&[7u32][..]));
        // …and reconstructs exactly what a plain f16 download delivers
        let frame = encode(&msg(payload), Quant::F16);
        let (back, _) = decode_frame(&spec, &frame, Some(&anchor)).unwrap();
        assert_eq!(back.payload, WirePayload::Full(f16_image(&cur)));
        // int8's per-block scale cannot uphold the contract — rejected
        assert!(WirePayload::anchor_delta(&spec, &anchor, &cur, Quant::Int8).is_err());
    }

    #[test]
    fn planned_blocks_roundtrip_sparse_and_per_param_quant() {
        let spec = toy_spec();
        let params = init_params(&spec, 5);
        let m = msg(WirePayload::full(&params));
        // per-block overrides: sparse f32 / dense int8 / dense f32 /
        // sparse f32 — one plan per param tensor of the toy spec
        let plans = vec![
            BlockPlan { quant: Quant::F32, idx: Some(vec![0, 5, 31]) },
            BlockPlan::dense(Quant::Int8),
            BlockPlan::dense(Quant::F32),
            BlockPlan { quant: Quant::F32, idx: Some(vec![1]) },
        ];
        let frame =
            encode_opts(&m, &FrameOpts { quant: Quant::F32, delta: true, plans: Some(&plans) })
                .unwrap();
        assert_eq!(frame[7], Quant::F32.byte_code() | FLAG_DELTA | FLAG_DESC);
        // BlockPlan::encoded_len is the analytic mirror of the encoder,
        // exactly as encoded_len is for plan-free frames
        let blocks: usize =
            spec.params.iter().zip(&plans).map(|(p, pl)| pl.encoded_len(p.numel())).sum();
        assert_eq!(frame.len(), HEADER_LEN + 4 + blocks + FOOTER_LEN);
        // plain decode refuses delta frames; decode_frame reports them
        assert!(decode(&spec, &frame).is_err());
        let (back, delta) = decode_frame(&spec, &frame, None).unwrap();
        assert!(delta);
        let WirePayload::Full(ps) = &back.payload else { panic!("wrong kind") };
        // sparse block: carried positions exact, the rest zero
        for (j, (got, orig)) in ps[0].data().iter().zip(params[0].data()).enumerate() {
            if [0usize, 5, 31].contains(&j) {
                assert_eq!(got, orig);
            } else {
                assert_eq!(*got, 0.0);
            }
        }
        // dense int8 block matches the host-side roundtrip bitwise
        assert_eq!(ps[1].data(), &quant_roundtrip(params[1].data(), Quant::Int8)[..]);
        // dense f32 block is exact
        assert_eq!(ps[2], params[2]);
        assert_eq!(ps[3].data()[1], params[3].data()[1]);
        assert_eq!(ps[3].data()[0], 0.0);

        // add_into onto zeros reproduces the decoded values
        let mut target: Vec<Tensor> =
            spec.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        back.payload.add_into(&spec, &mut target).unwrap();
        assert_eq!(&target, ps);
    }

    #[test]
    fn plan_count_mismatch_is_rejected() {
        let spec = toy_spec();
        let params = init_params(&spec, 5);
        let m = msg(WirePayload::full(&params));
        let plans = vec![BlockPlan::dense(Quant::F32); 3]; // toy has 4 blocks
        assert!(
            encode_opts(&m, &FrameOpts { quant: Quant::F32, delta: false, plans: Some(&plans) })
                .is_err()
        );
        let plans = vec![BlockPlan::dense(Quant::F32); 5];
        assert!(
            encode_opts(&m, &FrameOpts { quant: Quant::F32, delta: false, plans: Some(&plans) })
                .is_err()
        );
    }

    #[test]
    fn add_into_skeleton_scatter_adds_only_selected_channels() {
        let spec = toy_spec();
        let src = init_params(&spec, 4);
        let base = init_params(&spec, 8);
        let skel = vec![vec![0i32, 2]];
        let p = WirePayload::skeleton(&spec, &src, &skel).unwrap();
        let mut target = base.clone();
        p.add_into(&spec, &mut target).unwrap();
        let c = spec.prunable[0].channels;
        let rows = src[0].len() / c;
        for r in 0..rows {
            for ch in 0..c {
                let want = if ch == 0 || ch == 2 {
                    base[0].data()[r * c + ch] + src[0].data()[r * c + ch]
                } else {
                    base[0].data()[r * c + ch]
                };
                assert_eq!(target[0].data()[r * c + ch], want);
            }
        }
        // non-prunable tensors are added whole
        assert_eq!(target[2].data()[0], base[2].data()[0] + src[2].data()[0]);
    }
}
