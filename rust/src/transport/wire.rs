//! Byte-accurate wire codec for federated round payloads.
//!
//! Everything a client and the server exchange in a round travels as one
//! framed, versioned, checksummed binary message. Tensor *shapes* never
//! travel — both ends share the [`ModelSpec`] manifest contract and the
//! decoder reconstructs shapes from it — so the wire carries only what
//! Table 2 charges for: values, plus the skeleton channel indices FedSkel
//! genuinely has to ship.
//!
//! ## Frame layout (all integers little-endian)
//!
//! | offset   | size | field |
//! |----------|------|-------|
//! | 0        | 4    | magic `b"FSKL"` |
//! | 4        | 2    | version (= 1) |
//! | 6        | 1    | payload kind (0 = Full, 1 = Skeleton, 2 = ParamSubset) |
//! | 7        | 1    | quantization (0 = f32, 1 = f16, 2 = int8) |
//! | 8        | 4    | round index |
//! | 12       | 4    | client id |
//! | 16       | 8    | aggregation weight (f64) |
//! | 24       | 4    | body length in bytes |
//! | 28       | body | payload body (see below) |
//! | 28+body  | 4    | FNV-1a-32 checksum of the body |
//!
//! ## Body layout by kind
//!
//! * **Full** — `u32` tensor count, then every parameter tensor's value
//!   block in manifest order.
//! * **Skeleton** — `u32` prunable-layer count; per layer: `u32 k`,
//!   `k × u32` channel indices, the weight rows gathered at those channels
//!   (`rows × k` values), then `k` bias values. Then `u32` count and each
//!   non-prunable tensor as `u32 param_id` + value block.
//! * **ParamSubset** — `u32` entry count; per entry `u32 param_id` +
//!   value block.
//!
//! ## Value blocks by quantization
//!
//! | quant | bytes for n values |
//! |-------|--------------------|
//! | f32   | `4·n` |
//! | f16   | `2·n` (IEEE 754 half, round-to-nearest) |
//! | int8  | `4 + n` (one f32 symmetric scale = max·abs/127, then i8) |
//!
//! [`encoded_len`] computes the exact frame size for an
//! [`ExchangeKind`] without building a payload, so pure accounting
//! (Table 2 at 100 clients × 1000 rounds) stays O(1) per round while the
//! numbers remain those of the real encoder — a property the codec tests
//! pin by comparing `encode(..).len()` against it.

use anyhow::{bail, Result};

use crate::comm::ExchangeKind;
use crate::model::{ModelSpec, Params};
use crate::tensor::Tensor;

/// Frame magic.
pub const MAGIC: [u8; 4] = *b"FSKL";
/// Wire format version.
pub const VERSION: u16 = 1;
/// Fixed header bytes before the body.
pub const HEADER_LEN: usize = 28;
/// Trailing checksum bytes.
pub const FOOTER_LEN: usize = 4;

/// Value-block quantization modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Quant {
    /// Exact 4-byte floats (bit-exact round trip).
    #[default]
    F32,
    /// IEEE 754 half precision (2 bytes/value).
    F16,
    /// Symmetric per-tensor int8 (1 byte/value + 4-byte scale).
    Int8,
}

impl Quant {
    pub fn parse(s: &str) -> Result<Quant> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "f32" => Quant::F32,
            "f16" => Quant::F16,
            "int8" | "i8" => Quant::Int8,
            _ => bail!("unknown quantization '{s}' (f32|f16|int8)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Quant::F32 => "f32",
            Quant::F16 => "f16",
            Quant::Int8 => "int8",
        }
    }

    fn byte_code(&self) -> u8 {
        match self {
            Quant::F32 => 0,
            Quant::F16 => 1,
            Quant::Int8 => 2,
        }
    }

    fn from_byte(b: u8) -> Result<Quant> {
        Ok(match b {
            0 => Quant::F32,
            1 => Quant::F16,
            2 => Quant::Int8,
            _ => bail!("bad quant byte {b}"),
        })
    }

    /// Encoded size of a block of `n` values.
    pub fn block_len(&self, n: usize) -> usize {
        match self {
            Quant::F32 => 4 * n,
            Quant::F16 => 2 * n,
            Quant::Int8 => 4 + n,
        }
    }
}

/// One prunable layer's sparse skeleton update: the selected channels,
/// the weight rows gathered at them, and the matching bias entries.
#[derive(Debug, Clone, PartialEq)]
pub struct SkelLayerUpdate {
    /// Selected output channels, in the order the values are packed.
    pub idx: Vec<i32>,
    /// `rows × k` weight values, row-major over (row, selected channel).
    pub weight: Vec<f32>,
    /// `k` bias values.
    pub bias: Vec<f32>,
}

/// The decoded content of a round message.
#[derive(Debug, Clone, PartialEq)]
pub enum WirePayload {
    /// Every parameter tensor, manifest order.
    Full(Params),
    /// Sparse skeleton channels per prunable layer + full non-prunable
    /// tensors tagged with their param ids.
    Skeleton {
        layers: Vec<SkelLayerUpdate>,
        others: Vec<(usize, Tensor)>,
    },
    /// Only the listed parameter tensors.
    ParamSubset(Vec<(usize, Tensor)>),
}

impl WirePayload {
    fn kind_byte(&self) -> u8 {
        match self {
            WirePayload::Full(_) => 0,
            WirePayload::Skeleton { .. } => 1,
            WirePayload::ParamSubset(_) => 2,
        }
    }

    /// Build a full-exchange payload.
    pub fn full(params: &Params) -> WirePayload {
        WirePayload::Full(params.clone())
    }

    /// Build a skeleton payload: gather `skeleton[l]` channels of every
    /// prunable layer's weight/bias and carry all non-prunable tensors
    /// whole.
    pub fn skeleton(spec: &ModelSpec, params: &Params, skeleton: &[Vec<i32>]) -> Result<WirePayload> {
        if skeleton.len() != spec.prunable.len() {
            bail!("skeleton has {} layers, spec {}", skeleton.len(), spec.prunable.len());
        }
        if params.len() != spec.params.len() {
            bail!("params len {} != spec {}", params.len(), spec.params.len());
        }
        let mut channelwise = vec![false; params.len()];
        let mut layers = Vec::with_capacity(spec.prunable.len());
        for (li, p) in spec.prunable.iter().enumerate() {
            channelwise[p.weight_param] = true;
            channelwise[p.bias_param] = true;
            let c = p.channels;
            let idx = &skeleton[li];
            if idx.iter().any(|&ch| ch < 0 || ch as usize >= c) {
                bail!("skeleton index out of range for layer {li}");
            }
            let w = &params[p.weight_param];
            let rows = w.len() / c;
            let wd = w.data();
            let mut weight = Vec::with_capacity(rows * idx.len());
            for r in 0..rows {
                for &ch in idx {
                    weight.push(wd[r * c + ch as usize]);
                }
            }
            let bd = params[p.bias_param].data();
            let bias: Vec<f32> = idx.iter().map(|&ch| bd[ch as usize]).collect();
            layers.push(SkelLayerUpdate { idx: idx.clone(), weight, bias });
        }
        let others = params
            .iter()
            .enumerate()
            .filter(|(pi, _)| !channelwise[*pi])
            .map(|(pi, t)| (pi, t.clone()))
            .collect();
        Ok(WirePayload::Skeleton { layers, others })
    }

    /// Build a parameter-subset payload (LG-FedAvg's global tensors).
    pub fn subset(spec: &ModelSpec, params: &Params, ids: &[usize]) -> Result<WirePayload> {
        let mut entries = Vec::with_capacity(ids.len());
        for &pi in ids {
            if pi >= spec.params.len() {
                bail!("param id {pi} out of range");
            }
            entries.push((pi, params[pi].clone()));
        }
        Ok(WirePayload::ParamSubset(entries))
    }

    /// Scalar parameters this payload carries — matches
    /// [`crate::comm::params_moved`] for the corresponding
    /// [`ExchangeKind`].
    pub fn params_carried(&self) -> usize {
        match self {
            WirePayload::Full(ps) => ps.iter().map(|t| t.len()).sum(),
            WirePayload::Skeleton { layers, others } => {
                layers.iter().map(|l| l.weight.len() + l.bias.len()).sum::<usize>()
                    + others.iter().map(|(_, t)| t.len()).sum::<usize>()
            }
            WirePayload::ParamSubset(es) => es.iter().map(|(_, t)| t.len()).sum(),
        }
    }

    /// Apply this payload onto `target` — the decode-then-apply half of
    /// every exchange. Full replaces everything; Skeleton scatters the
    /// selected channels and replaces non-prunable tensors; ParamSubset
    /// replaces only the listed tensors.
    pub fn overlay_into(&self, spec: &ModelSpec, target: &mut Params) -> Result<()> {
        if target.len() != spec.params.len() {
            bail!("target len {} != spec {}", target.len(), spec.params.len());
        }
        match self {
            WirePayload::Full(ps) => {
                if ps.len() != target.len() {
                    bail!("full payload has {} tensors, want {}", ps.len(), target.len());
                }
                for (t, p) in target.iter_mut().zip(ps) {
                    if t.shape() != p.shape() {
                        bail!("full payload tensor shape mismatch");
                    }
                    *t = p.clone();
                }
            }
            WirePayload::Skeleton { layers, others } => {
                if layers.len() != spec.prunable.len() {
                    bail!("skeleton payload has {} layers, spec {}", layers.len(), spec.prunable.len());
                }
                for (li, (p, l)) in spec.prunable.iter().zip(layers).enumerate() {
                    let c = p.channels;
                    let k = l.idx.len();
                    let w = &mut target[p.weight_param];
                    let rows = w.len() / c;
                    if l.weight.len() != rows * k || l.bias.len() != k {
                        bail!("skeleton layer {li} value counts mismatch");
                    }
                    let wd = w.data_mut();
                    for r in 0..rows {
                        for (j, &ch) in l.idx.iter().enumerate() {
                            if ch < 0 || ch as usize >= c {
                                bail!("skeleton layer {li} channel {ch} out of range");
                            }
                            wd[r * c + ch as usize] = l.weight[r * k + j];
                        }
                    }
                    let bd = target[p.bias_param].data_mut();
                    for (j, &ch) in l.idx.iter().enumerate() {
                        bd[ch as usize] = l.bias[j];
                    }
                }
                for (pi, t) in others {
                    if *pi >= target.len() || target[*pi].shape() != t.shape() {
                        bail!("skeleton payload other tensor {pi} mismatch");
                    }
                    target[*pi] = t.clone();
                }
            }
            WirePayload::ParamSubset(es) => {
                for (pi, t) in es {
                    if *pi >= target.len() || target[*pi].shape() != t.shape() {
                        bail!("subset payload tensor {pi} mismatch");
                    }
                    target[*pi] = t.clone();
                }
            }
        }
        Ok(())
    }
}

/// One round message: routing metadata + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundMsg {
    pub round: u32,
    pub client: u32,
    /// Aggregation weight (sample count) — 0.0 for downloads.
    pub weight: f64,
    pub payload: WirePayload,
}

/// Exact frame size for an [`ExchangeKind`] without building a payload.
/// `ExchangeKind::None` encodes nothing and costs 0 bytes.
pub fn encoded_len(spec: &ModelSpec, kind: &ExchangeKind, quant: Quant) -> usize {
    let body = match kind {
        ExchangeKind::None => return 0,
        ExchangeKind::Full => {
            4 + spec.params.iter().map(|p| quant.block_len(p.numel())).sum::<usize>()
        }
        ExchangeKind::ParamSubset(ids) => {
            4 + ids
                .iter()
                .map(|&pi| 4 + quant.block_len(spec.params[pi].numel()))
                .sum::<usize>()
        }
        ExchangeKind::Skeleton(ks) => {
            let mut channelwise = vec![false; spec.params.len()];
            let mut total = 4usize;
            for (li, p) in spec.prunable.iter().enumerate() {
                channelwise[p.weight_param] = true;
                channelwise[p.bias_param] = true;
                let k = ks[li].min(p.channels);
                let rows = spec.params[p.weight_param].numel() / p.channels;
                total += 4 + 4 * k + quant.block_len(rows * k) + quant.block_len(k);
            }
            total += 4;
            for (pi, p) in spec.params.iter().enumerate() {
                if !channelwise[pi] {
                    total += 4 + quant.block_len(p.numel());
                }
            }
            total
        }
    };
    HEADER_LEN + body + FOOTER_LEN
}

/// Encode a round message into one wire frame.
pub fn encode(msg: &RoundMsg, quant: Quant) -> Vec<u8> {
    let mut body = Vec::new();
    match &msg.payload {
        WirePayload::Full(ps) => {
            put_u32(&mut body, ps.len() as u32);
            for t in ps {
                put_values(&mut body, t.data(), quant);
            }
        }
        WirePayload::Skeleton { layers, others } => {
            put_u32(&mut body, layers.len() as u32);
            for l in layers {
                put_u32(&mut body, l.idx.len() as u32);
                for &ch in &l.idx {
                    put_u32(&mut body, ch as u32);
                }
                put_values(&mut body, &l.weight, quant);
                put_values(&mut body, &l.bias, quant);
            }
            put_u32(&mut body, others.len() as u32);
            for (pi, t) in others {
                put_u32(&mut body, *pi as u32);
                put_values(&mut body, t.data(), quant);
            }
        }
        WirePayload::ParamSubset(es) => {
            put_u32(&mut body, es.len() as u32);
            for (pi, t) in es {
                put_u32(&mut body, *pi as u32);
                put_values(&mut body, t.data(), quant);
            }
        }
    }

    let mut frame = Vec::with_capacity(HEADER_LEN + body.len() + FOOTER_LEN);
    frame.extend_from_slice(&MAGIC);
    frame.extend_from_slice(&VERSION.to_le_bytes());
    frame.push(msg.payload.kind_byte());
    frame.push(quant.byte_code());
    frame.extend_from_slice(&msg.round.to_le_bytes());
    frame.extend_from_slice(&msg.client.to_le_bytes());
    frame.extend_from_slice(&msg.weight.to_le_bytes());
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    let sum = fnv1a32(&body);
    frame.extend_from_slice(&body);
    frame.extend_from_slice(&sum.to_le_bytes());
    frame
}

/// Decode one wire frame. Shapes come from `spec`; the checksum, version,
/// and every count are validated before any tensor is built.
pub fn decode(spec: &ModelSpec, frame: &[u8]) -> Result<RoundMsg> {
    if frame.len() < HEADER_LEN + FOOTER_LEN {
        bail!("frame too short: {} bytes", frame.len());
    }
    if frame[0..4] != MAGIC {
        bail!("bad magic");
    }
    let version = u16::from_le_bytes([frame[4], frame[5]]);
    if version != VERSION {
        bail!("unsupported wire version {version}");
    }
    let kind = frame[6];
    let quant = Quant::from_byte(frame[7])?;
    let round = u32::from_le_bytes(frame[8..12].try_into().unwrap());
    let client = u32::from_le_bytes(frame[12..16].try_into().unwrap());
    let weight = f64::from_le_bytes(frame[16..24].try_into().unwrap());
    let body_len = u32::from_le_bytes(frame[24..28].try_into().unwrap()) as usize;
    if frame.len() != HEADER_LEN + body_len + FOOTER_LEN {
        bail!("frame length {} != header+{body_len}+footer", frame.len());
    }
    let body = &frame[HEADER_LEN..HEADER_LEN + body_len];
    let sum = u32::from_le_bytes(frame[HEADER_LEN + body_len..].try_into().unwrap());
    if fnv1a32(body) != sum {
        bail!("checksum mismatch");
    }

    let mut r = Reader { buf: body, pos: 0 };
    let payload = match kind {
        0 => {
            let n = r.u32()? as usize;
            if n != spec.params.len() {
                bail!("full payload has {n} tensors, spec wants {}", spec.params.len());
            }
            let mut ps = Vec::with_capacity(n);
            for p in &spec.params {
                let data = r.values(p.numel(), quant)?;
                ps.push(Tensor::from_vec(&p.shape, data)?);
            }
            WirePayload::Full(ps)
        }
        1 => {
            let n = r.u32()? as usize;
            if n != spec.prunable.len() {
                bail!("skeleton payload has {n} layers, spec wants {}", spec.prunable.len());
            }
            let mut channelwise = vec![false; spec.params.len()];
            let mut layers = Vec::with_capacity(n);
            for p in &spec.prunable {
                channelwise[p.weight_param] = true;
                channelwise[p.bias_param] = true;
                let k = r.u32()? as usize;
                if k > p.channels {
                    bail!("skeleton k {k} > channels {}", p.channels);
                }
                let mut idx = Vec::with_capacity(k);
                for _ in 0..k {
                    let ch = r.u32()?;
                    if ch as usize >= p.channels {
                        bail!("skeleton channel {ch} out of range");
                    }
                    idx.push(ch as i32);
                }
                let rows = spec.params[p.weight_param].numel() / p.channels;
                let weight = r.values(rows * k, quant)?;
                let bias = r.values(k, quant)?;
                layers.push(SkelLayerUpdate { idx, weight, bias });
            }
            let m = r.u32()? as usize;
            let mut others = Vec::with_capacity(m);
            for _ in 0..m {
                let pi = r.u32()? as usize;
                if pi >= spec.params.len() || channelwise[pi] {
                    bail!("bad non-prunable param id {pi}");
                }
                let p = &spec.params[pi];
                let data = r.values(p.numel(), quant)?;
                others.push((pi, Tensor::from_vec(&p.shape, data)?));
            }
            WirePayload::Skeleton { layers, others }
        }
        2 => {
            let n = r.u32()? as usize;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let pi = r.u32()? as usize;
                if pi >= spec.params.len() {
                    bail!("subset param id {pi} out of range");
                }
                let p = &spec.params[pi];
                let data = r.values(p.numel(), quant)?;
                entries.push((pi, Tensor::from_vec(&p.shape, data)?));
            }
            WirePayload::ParamSubset(entries)
        }
        k => bail!("unknown payload kind {k}"),
    };
    if r.pos != body.len() {
        bail!("trailing {} bytes in body", body.len() - r.pos);
    }
    Ok(RoundMsg { round, client, weight, payload })
}

// --------------------------------------------------------------- plumbing

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_values(buf: &mut Vec<u8>, vals: &[f32], quant: Quant) {
    match quant {
        Quant::F32 => {
            for &v in vals {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        Quant::F16 => {
            for &v in vals {
                buf.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
            }
        }
        Quant::Int8 => {
            let max_abs = vals.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 0.0 };
            buf.extend_from_slice(&scale.to_le_bytes());
            for &v in vals {
                let q = if scale > 0.0 {
                    (v / scale).round().clamp(-127.0, 127.0) as i8
                } else {
                    0
                };
                buf.push(q as u8);
            }
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("body truncated at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn values(&mut self, n: usize, quant: Quant) -> Result<Vec<f32>> {
        match quant {
            Quant::F32 => {
                let raw = self.take(4 * n)?;
                Ok(raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect())
            }
            Quant::F16 => {
                let raw = self.take(2 * n)?;
                Ok(raw
                    .chunks_exact(2)
                    .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                    .collect())
            }
            Quant::Int8 => {
                let scale = self.f32()?;
                let raw = self.take(n)?;
                Ok(raw.iter().map(|&b| (b as i8) as f32 * scale).collect())
            }
        }
    }
}

/// FNV-1a 32-bit.
pub fn fnv1a32(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// f32 → IEEE 754 half bits, round-to-nearest.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 255 {
        // inf / nan
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal half
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = (m >> shift) as u16;
        let round = ((m >> (shift - 1)) & 1) as u16;
        return sign | (half + round);
    }
    let half = ((e as u32) << 10 | (mant >> 13)) as u16;
    let round = ((mant >> 12) & 1) as u16;
    sign | (half + round)
}

/// IEEE 754 half bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: renormalize
            let mut e: i32 = 127 - 15 + 1;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x03ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::params_moved;
    use crate::model::init_params;
    use crate::runtime::mock::toy_spec;

    fn msg(payload: WirePayload) -> RoundMsg {
        RoundMsg { round: 3, client: 7, weight: 40.0, payload }
    }

    #[test]
    fn full_roundtrip_bit_exact() {
        let spec = toy_spec();
        let params = init_params(&spec, 5);
        let m = msg(WirePayload::full(&params));
        let frame = encode(&m, Quant::F32);
        assert_eq!(frame.len(), encoded_len(&spec, &ExchangeKind::Full, Quant::F32));
        let back = decode(&spec, &frame).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn skeleton_roundtrip_and_len() {
        let spec = toy_spec();
        let params = init_params(&spec, 9);
        let skel = vec![vec![1i32, 3]];
        let m = msg(WirePayload::skeleton(&spec, &params, &skel).unwrap());
        let frame = encode(&m, Quant::F32);
        assert_eq!(frame.len(), encoded_len(&spec, &ExchangeKind::Skeleton(vec![2]), Quant::F32));
        let back = decode(&spec, &frame).unwrap();
        assert_eq!(back, m);
        // k == channels (identity skeleton) also round-trips
        let full_skel = vec![vec![0i32, 1, 2, 3]];
        let m2 = msg(WirePayload::skeleton(&spec, &params, &full_skel).unwrap());
        let f2 = encode(&m2, Quant::F32);
        assert_eq!(f2.len(), encoded_len(&spec, &ExchangeKind::Skeleton(vec![4]), Quant::F32));
        assert_eq!(decode(&spec, &f2).unwrap(), m2);
    }

    #[test]
    fn empty_skeleton_roundtrips() {
        let spec = toy_spec();
        let params = init_params(&spec, 2);
        let m = msg(WirePayload::skeleton(&spec, &params, &[vec![]]).unwrap());
        let frame = encode(&m, Quant::F32);
        assert_eq!(frame.len(), encoded_len(&spec, &ExchangeKind::Skeleton(vec![0]), Quant::F32));
        let back = decode(&spec, &frame).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.payload.params_carried(), params[2].len() + params[3].len());
    }

    #[test]
    fn subset_roundtrip() {
        let spec = toy_spec();
        let params = init_params(&spec, 1);
        let m = msg(WirePayload::subset(&spec, &params, &[2, 3]).unwrap());
        let frame = encode(&m, Quant::F32);
        assert_eq!(
            frame.len(),
            encoded_len(&spec, &ExchangeKind::ParamSubset(vec![2, 3]), Quant::F32)
        );
        assert_eq!(decode(&spec, &frame).unwrap(), m);
    }

    #[test]
    fn value_bytes_match_comm_ledger_accounting() {
        // at f32, value bytes on the wire == 4 × params_moved; everything
        // else is the fixed frame + index overhead computed here by hand.
        let spec = toy_spec();
        for (kind, idx_overhead, counts) in [
            (ExchangeKind::Full, 0usize, 4usize),
            // skeleton: per-layer (k count + k idx), others count + 1 id
            (ExchangeKind::Skeleton(vec![2]), 4 * 2, 4 + 4 + 4 + 2 * 4),
            (ExchangeKind::ParamSubset(vec![0, 2]), 0, 4 + 2 * 4),
        ] {
            let len = encoded_len(&spec, &kind, Quant::F32);
            let values = 4 * params_moved(&spec, &kind);
            assert_eq!(
                len,
                HEADER_LEN + FOOTER_LEN + counts + idx_overhead + values,
                "{kind:?}"
            );
        }
        assert_eq!(encoded_len(&spec, &ExchangeKind::None, Quant::F32), 0);
    }

    #[test]
    fn skeleton_encodes_fewer_bytes_than_full() {
        let spec = toy_spec();
        let full = encoded_len(&spec, &ExchangeKind::Full, Quant::F32);
        let skel = encoded_len(&spec, &ExchangeKind::Skeleton(vec![1]), Quant::F32);
        assert!(skel < full, "skeleton {skel} !< full {full}");
    }

    #[test]
    fn quantized_sizes_and_error_bounds() {
        let spec = toy_spec();
        let params = init_params(&spec, 3);
        let m = msg(WirePayload::full(&params));
        let f32_len = encode(&m, Quant::F32).len();
        let f16 = encode(&m, Quant::F16);
        let i8f = encode(&m, Quant::Int8);
        assert!(f16.len() < f32_len);
        assert!(i8f.len() < f16.len());
        assert_eq!(f16.len(), encoded_len(&spec, &ExchangeKind::Full, Quant::F16));
        assert_eq!(i8f.len(), encoded_len(&spec, &ExchangeKind::Full, Quant::Int8));

        for (frame, tol) in [(f16, 1e-3f32), (i8f, 2e-2f32)] {
            let back = decode(&spec, &frame).unwrap();
            let WirePayload::Full(ps) = &back.payload else { panic!("wrong kind") };
            for (a, b) in ps.iter().zip(&params) {
                let scale = b.max_abs().max(1e-6);
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert!((x - y).abs() <= tol * scale, "{x} vs {y} (tol {tol})");
                }
            }
        }
    }

    #[test]
    fn f16_conversion_basics() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, 6.1e-5, 3.14159] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!((back - v).abs() <= v.abs() * 1e-3 + 1e-7, "{v} -> {back}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e9)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // subnormal half survives
        let v = 3.0e-7f32;
        let back = f16_bits_to_f32(f32_to_f16_bits(v));
        assert!((back - v).abs() < 1e-7);
    }

    #[test]
    fn corruption_is_detected() {
        let spec = toy_spec();
        let params = init_params(&spec, 0);
        let mut frame = encode(&msg(WirePayload::full(&params)), Quant::F32);
        // flip one body byte → checksum must catch it
        let mid = HEADER_LEN + 5;
        frame[mid] ^= 0xff;
        assert!(decode(&spec, &frame).is_err());
        // bad magic
        let mut f2 = encode(&msg(WirePayload::full(&params)), Quant::F32);
        f2[0] = b'X';
        assert!(decode(&spec, &f2).is_err());
        // truncation
        let f3 = encode(&msg(WirePayload::full(&params)), Quant::F32);
        assert!(decode(&spec, &f3[..f3.len() - 8]).is_err());
        assert!(decode(&spec, &[]).is_err());
    }

    #[test]
    fn overlay_full_and_subset() {
        let spec = toy_spec();
        let a = init_params(&spec, 1);
        let b = init_params(&spec, 2);
        let mut target = b.clone();
        WirePayload::full(&a).overlay_into(&spec, &mut target).unwrap();
        assert_eq!(target, a);
        let mut target = b.clone();
        WirePayload::subset(&spec, &a, &[2]).unwrap().overlay_into(&spec, &mut target).unwrap();
        assert_eq!(target[2], a[2]);
        assert_eq!(target[0], b[0]);
    }

    #[test]
    fn overlay_skeleton_scatters_only_selected_channels() {
        let spec = toy_spec();
        let src = init_params(&spec, 4);
        let base = init_params(&spec, 8);
        let skel = vec![vec![0i32, 2]];
        let p = WirePayload::skeleton(&spec, &src, &skel).unwrap();
        let mut target = base.clone();
        p.overlay_into(&spec, &mut target).unwrap();
        let c = spec.prunable[0].channels;
        let rows = src[0].len() / c;
        for r in 0..rows {
            for ch in 0..c {
                let want = if ch == 0 || ch == 2 { src[0].data() } else { base[0].data() };
                assert_eq!(target[0].data()[r * c + ch], want[r * c + ch]);
            }
        }
        // bias mirrors, non-prunable tensors replaced whole
        assert_eq!(target[1].data()[1], base[1].data()[1]);
        assert_eq!(target[1].data()[2], src[1].data()[2]);
        assert_eq!(target[2], src[2]);
        assert_eq!(target[3], src[3]);
    }

    #[test]
    fn params_carried_matches_params_moved() {
        let spec = toy_spec();
        let params = init_params(&spec, 6);
        for (payload, kind) in [
            (WirePayload::full(&params), ExchangeKind::Full),
            (
                WirePayload::skeleton(&spec, &params, &[vec![1, 2]]).unwrap(),
                ExchangeKind::Skeleton(vec![2]),
            ),
            (
                WirePayload::subset(&spec, &params, &[2, 3]).unwrap(),
                ExchangeKind::ParamSubset(vec![2, 3]),
            ),
        ] {
            assert_eq!(payload.params_carried(), params_moved(&spec, &kind));
        }
    }
}
