//! Parallel client worker pool.
//!
//! The coordinator packages each participant's local-training work into a
//! self-contained [`TrainJob`] (post-download params, the round's global
//! anchor, pre-filled minibatches, skeleton, hyperparameters) and the pool
//! runs jobs concurrently on `std::thread` workers, each owning its own
//! [`Backend`]. Batches are filled *before* dispatch from the client's own
//! deterministic [`crate::data::shard::Batcher`], so results are
//! independent of worker scheduling — the pool changes wall-clock, never
//! semantics.
//!
//! [`run_local_steps`] is the single implementation of "one client's local
//! round"; the coordinator's inline (sequential) path calls it on its own
//! backend, the workers call it on theirs. Each job carries the client's
//! compute-thread budget ([`TrainJob::par`], from its
//! [`crate::hetero::DeviceProfile::cores`]); the executing backend is
//! switched to that budget before stepping, so a Pi-class client really
//! trains on 1 thread while a desktop-class client fans out — results
//! stay bitwise identical either way.
//!
//! Worker threads are named `client-worker-{i}` so panics and stuck
//! rounds are attributable to a specific worker.
//!
//! Results come back in *completion order* on the result channel, each
//! tagged with its submission slot; [`WorkerPool::run`] routes them back
//! into submission order by slot (never by client id), so one batch may
//! legally contain the same client more than once.

use std::marker::PhantomData;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::kernels::{Parallelism, Precision};
use crate::metrics::Mean;
use crate::model::Params;
use crate::runtime::step::Backend;

/// One client's local-training work order.
#[derive(Debug, Clone)]
pub struct TrainJob {
    pub client: usize,
    /// Ratio bucket (selects the train artifact).
    pub bucket: usize,
    /// Per-prunable-layer skeleton channel indices, sized for `bucket`.
    pub skeleton: Vec<Vec<i32>>,
    /// The client's post-download local parameters.
    pub local: Params,
    /// Server anchor (FedProx pull target). Shared across a round's jobs
    /// — the anchor is read-only during training, so the coordinator
    /// hands every job the same `Arc` instead of cloning the model per
    /// participant.
    pub global: Arc<Params>,
    /// Pre-filled minibatches, one `(x, y)` pair per local step.
    pub batches: Vec<(Vec<f32>, Vec<i32>)>,
    pub lr: f32,
    pub mu: f32,
    /// Accumulate channel importance (SetSkel rounds).
    pub want_importance: bool,
    /// Compute-thread budget for this client's local training — its
    /// simulated device's core count ([`crate::hetero::DeviceProfile::cores`]).
    /// Applied to the executing backend before the first step. Results
    /// are bitwise independent of it; only wall-clock changes, which is
    /// how compute heterogeneity becomes emergent in pool runs.
    pub par: Parallelism,
    /// Forward-pass arithmetic for this client's local training — its
    /// simulated device's capability class
    /// ([`crate::hetero::DeviceProfile::precision`]). Applied to the
    /// executing backend before the first step. Int8 changes results
    /// (it is an approximation); eval on the server stays f32.
    pub precision: Precision,
}

/// What a local round produced.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub client: usize,
    /// Post-training local parameters.
    pub params: Params,
    pub mean_loss: f32,
    /// Per-layer channel importance *summed* over the steps (empty unless
    /// requested).
    pub importance_sums: Vec<Vec<f32>>,
    /// Steps executed (= batches in the job).
    pub steps: usize,
}

/// Run one job's local steps on a backend. The one code path both the
/// sequential coordinator loop and every pool worker execute.
///
/// Takes the job by value: the post-download params are *moved* into the
/// training loop (no defensive clone — the job's buffers are dead after
/// the round anyway), and the round's shared `Arc` anchor is only ever
/// borrowed.
pub fn run_local_steps<B: Backend>(backend: &mut B, job: TrainJob) -> Result<TrainOutcome> {
    backend.set_parallelism(job.par);
    backend.set_precision(job.precision);
    let client = job.client;
    let steps = job.batches.len();
    let mut local = job.local;
    let mut loss_mean = Mean::default();
    let mut importance_sums: Vec<Vec<f32>> = Vec::new();
    for (x, y) in &job.batches {
        let out = backend.train_step(
            job.bucket,
            &local,
            &job.global,
            x,
            y,
            &job.skeleton,
            job.lr,
            job.mu,
        )?;
        local = out.params;
        loss_mean.add(out.loss as f64);
        if job.want_importance {
            if importance_sums.is_empty() {
                importance_sums = out.importance;
            } else {
                for (sum, imp) in importance_sums.iter_mut().zip(&out.importance) {
                    for (s, v) in sum.iter_mut().zip(imp) {
                        *s += v;
                    }
                }
            }
        }
    }
    Ok(TrainOutcome {
        client,
        params: local,
        mean_loss: loss_mean.get() as f32,
        importance_sums,
        steps,
    })
}

/// Worker → pool messages, tagged with the job's submission slot so
/// completion-ordered arrivals route back deterministically — the pool
/// never has to guess by client id, and a round may legally contain any
/// mix of clients (the event-driven coordinator relies on this).
enum WorkerMsg {
    Done(usize, Box<TrainOutcome>),
    Failed { seq: usize, client: usize, error: String },
}

/// A fixed fleet of training workers, one backend each.
///
/// The struct itself has no bounds on `B` (it only stores channels and
/// join handles), so it can sit inside a generic coordinator even when `B`
/// isn't `Send`; *constructing* a pool requires `B: Backend + Send`.
pub struct WorkerPool<B> {
    job_tx: Option<Sender<(usize, TrainJob)>>,
    res_rx: Receiver<WorkerMsg>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    _backend: PhantomData<fn() -> B>,
}

impl<B: Backend + Send + 'static> WorkerPool<B> {
    /// Spawn one worker per backend. Workers pull jobs from a shared
    /// queue, so a fast worker naturally takes more jobs.
    pub fn new(backends: Vec<B>) -> Result<WorkerPool<B>> {
        if backends.is_empty() {
            bail!("worker pool needs at least one backend");
        }
        let workers = backends.len();
        let (job_tx, job_rx) = channel::<(usize, TrainJob)>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = channel::<WorkerMsg>();
        let mut handles = Vec::with_capacity(workers);
        for (i, mut backend) in backends.into_iter().enumerate() {
            let rx = Arc::clone(&job_rx);
            let tx = res_tx.clone();
            // Named threads: a panic (or a `top -H` during a stuck round)
            // says *which* worker died instead of an anonymous
            // `<unnamed>` thread.
            let worker = std::thread::Builder::new()
                .name(format!("client-worker-{i}"))
                .spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("job queue poisoned");
                        guard.recv()
                    };
                    let Ok((seq, job)) = job else { break }; // senders dropped → shut down
                    let client = job.client;
                    // catch panics too: a worker that dies without reporting
                    // would leave run() waiting on a message that never comes
                    // while the other workers keep the channel open.
                    let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                        run_local_steps(&mut backend, job)
                    }));
                    let msg = match result {
                        Ok(Ok(out)) => WorkerMsg::Done(seq, Box::new(out)),
                        Ok(Err(e)) => WorkerMsg::Failed { seq, client, error: format!("{e:#}") },
                        Err(panic) => {
                            let what = panic
                                .downcast_ref::<&str>()
                                .map(|s| s.to_string())
                                .or_else(|| panic.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "worker panicked".into());
                            WorkerMsg::Failed { seq, client, error: format!("panic: {what}") }
                        }
                    };
                    if tx.send(msg).is_err() {
                        break; // pool dropped mid-round
                    }
                })
                .map_err(|e| anyhow::anyhow!("spawning client-worker-{i}: {e}"))?;
            handles.push(worker);
        }
        Ok(WorkerPool {
            job_tx: Some(job_tx),
            res_rx,
            handles,
            workers,
            _backend: PhantomData,
        })
    }
}

impl<B> WorkerPool<B> {
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Dispatch a round's jobs and wait for all of them. Workers report
    /// completions in *completion order*; each message carries its
    /// submission slot, so the returned vector is in submission order
    /// regardless of which worker finished first — and a batch may
    /// contain any mix of client ids (the slot, not the id, routes).
    pub fn run(&self, jobs: Vec<TrainJob>) -> Result<Vec<TrainOutcome>> {
        let n = jobs.len();
        let tx = self.job_tx.as_ref().expect("pool already shut down");
        for (seq, job) in jobs.into_iter().enumerate() {
            tx.send((seq, job)).map_err(|_| anyhow::anyhow!("worker pool is gone"))?;
        }
        let mut done: Vec<Option<TrainOutcome>> = (0..n).map(|_| None).collect();
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..n {
            match self.res_rx.recv() {
                Ok(WorkerMsg::Done(seq, out)) => match done.get_mut(seq) {
                    Some(slot) if slot.is_none() => *slot = Some(*out),
                    _ => bail!("worker returned unexpected job slot {seq}"),
                },
                Ok(WorkerMsg::Failed { seq, client, error }) => {
                    if first_err.is_none() {
                        first_err =
                            Some(anyhow::anyhow!("client {client} training failed: {error}"));
                    }
                    // keep draining so the pool stays consistent
                    if let Some(slot) = done.get_mut(seq) {
                        *slot = Some(TrainOutcome {
                            client,
                            params: Vec::new(),
                            mean_loss: f32::NAN,
                            importance_sums: Vec::new(),
                            steps: 0,
                        });
                    }
                }
                Err(_) => bail!("all workers exited with {n} jobs outstanding"),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        done.into_iter()
            .map(|o| o.ok_or_else(|| anyhow::anyhow!("missing outcome")))
            .collect()
    }
}

/// Shutdown ordering (load-bearing, do not reorder): the job sender is
/// closed **first**, which makes every idle worker's `recv` fail and
/// break out of its loop; only **then** are the threads joined. Joining
/// before closing the queue would deadlock — workers block in `recv`
/// forever while `join` waits on them.
impl<B> Drop for WorkerPool<B> {
    fn drop(&mut self) {
        drop(self.job_tx.take()); // 1) close the queue → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join(); // 2) now joining cannot deadlock
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_params;
    use crate::runtime::mock::{toy_spec, MockBackend};
    use crate::skeleton::identity_skeleton;

    fn job(client: usize, steps: usize, want_importance: bool) -> TrainJob {
        let spec = toy_spec();
        let params = init_params(&spec, client as u64);
        let numel: usize = spec.input_shape.iter().product();
        TrainJob {
            client,
            bucket: 100,
            skeleton: identity_skeleton(&[4]),
            local: params.clone(),
            global: Arc::new(params),
            batches: (0..steps)
                .map(|_| (vec![0.5f32; spec.train_batch * numel], vec![0i32; spec.train_batch]))
                .collect(),
            lr: 0.1,
            mu: 0.0,
            want_importance,
            par: Parallelism::serial(),
            precision: Precision::F32,
        }
    }

    #[test]
    fn run_local_steps_matches_manual_loop() {
        let mut a = MockBackend::toy();
        let out = run_local_steps(&mut a, job(0, 3, true)).unwrap();
        assert_eq!(out.steps, 3);
        assert_eq!(a.calls, 3);
        // manual replay on a fresh backend gives identical params
        let mut b = MockBackend::toy();
        let j = job(0, 3, true);
        let mut local = j.local.clone();
        for (x, y) in &j.batches {
            let o = b
                .train_step(j.bucket, &local, &j.global, x, y, &j.skeleton, j.lr, j.mu)
                .unwrap();
            local = o.params;
        }
        assert_eq!(out.params, local);
        // importance summed over 3 steps: mock gives mean|x|·(c+1) per step
        assert_eq!(out.importance_sums.len(), 1);
        assert!((out.importance_sums[0][1] - 3.0 * 0.5 * 2.0).abs() < 1e-5);
    }

    #[test]
    fn pool_runs_jobs_concurrently_and_in_order() {
        let pool = WorkerPool::new(vec![MockBackend::toy(), MockBackend::toy(), MockBackend::toy()])
            .unwrap();
        assert_eq!(pool.workers(), 3);
        let jobs: Vec<TrainJob> = (0..8).map(|c| job(c, 2, false)).collect();
        let outs = pool.run(jobs).unwrap();
        assert_eq!(outs.len(), 8);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.client, i, "submission order preserved");
            assert_eq!(o.steps, 2);
            assert!(o.mean_loss.is_finite());
        }
        // a second round on the same pool still works
        let outs2 = pool.run((0..4).map(|c| job(c, 1, false)).collect()).unwrap();
        assert_eq!(outs2.len(), 4);
    }

    #[test]
    fn pool_results_equal_inline_results() {
        // same jobs through a 1-worker pool and an inline backend: params
        // must be bit-identical (scheduling never changes semantics).
        let jobs: Vec<TrainJob> = (0..3).map(|c| job(c, 2, false)).collect();
        let pool = WorkerPool::new(vec![MockBackend::toy()]).unwrap();
        let pooled = pool.run(jobs.clone()).unwrap();
        let mut inline = MockBackend::toy();
        for (j, p) in jobs.into_iter().zip(&pooled) {
            let client = j.client;
            let o = run_local_steps(&mut inline, j).unwrap();
            assert_eq!(o.params, p.params, "client {client}");
        }
    }

    #[test]
    fn duplicate_client_ids_route_by_submission_slot() {
        // completion-ordered messages carry their slot, so a batch may
        // contain the same client twice (the event-driven coordinator's
        // freedom to reship work relies on this).
        let pool = WorkerPool::new(vec![MockBackend::toy(), MockBackend::toy()]).unwrap();
        let jobs = vec![job(3, 1, false), job(3, 2, false), job(3, 1, false)];
        let outs = pool.run(jobs).unwrap();
        assert_eq!(outs.len(), 3);
        assert!(outs.iter().all(|o| o.client == 3));
        assert_eq!(outs[1].steps, 2, "slot order preserved, not client-id order");
    }

    #[test]
    fn empty_pool_is_rejected() {
        assert!(WorkerPool::<MockBackend>::new(vec![]).is_err());
    }

    #[test]
    fn drop_with_idle_workers_joins_cleanly() {
        // the shutdown-ordering contract, asserted behaviorally: dropping
        // a pool whose workers are all blocked in recv() must close the
        // queue first and then join — this test completing (rather than
        // hanging the suite) is the assertion.
        let pool = WorkerPool::new(vec![MockBackend::toy(), MockBackend::toy()]).unwrap();
        pool.run(vec![job(0, 1, false)]).unwrap(); // workers are alive + idle
        drop(pool);
    }

    /// Delegates to a mock but records the executing thread's name and
    /// every thread budget it is handed — pins the `client-worker-{i}`
    /// naming and the per-job [`Parallelism`] plumbing.
    struct NameProbe {
        inner: MockBackend,
        names: Arc<Mutex<Vec<String>>>,
        budgets: Arc<Mutex<Vec<usize>>>,
    }

    impl Backend for NameProbe {
        fn spec(&self) -> &crate::model::ModelSpec {
            self.inner.spec()
        }

        #[allow(clippy::too_many_arguments)]
        fn train_step(
            &mut self,
            bucket: usize,
            params: &Params,
            global: &Params,
            x: &[f32],
            y: &[i32],
            skeleton: &[Vec<i32>],
            lr: f32,
            mu: f32,
        ) -> Result<crate::runtime::step::StepOut> {
            let name = std::thread::current().name().unwrap_or("<unnamed>").to_string();
            self.names.lock().expect("probe lock").push(name);
            self.inner.train_step(bucket, params, global, x, y, skeleton, lr, mu)
        }

        fn eval_logits(&mut self, params: &Params, x: &[f32]) -> Result<crate::tensor::Tensor> {
            self.inner.eval_logits(params, x)
        }

        fn batch_time_secs(&mut self, bucket: usize) -> Result<f64> {
            self.inner.batch_time_secs(bucket)
        }

        fn set_parallelism(&mut self, par: Parallelism) {
            self.budgets.lock().expect("probe lock").push(par.threads());
        }
    }

    #[test]
    fn worker_threads_are_named_and_receive_job_budgets() {
        let names = Arc::new(Mutex::new(Vec::new()));
        let budgets = Arc::new(Mutex::new(Vec::new()));
        let backends: Vec<NameProbe> = (0..2)
            .map(|_| NameProbe {
                inner: MockBackend::toy(),
                names: Arc::clone(&names),
                budgets: Arc::clone(&budgets),
            })
            .collect();
        let pool = WorkerPool::new(backends).unwrap();
        let jobs: Vec<TrainJob> = (0..4)
            .map(|c| {
                let mut j = job(c, 1, false);
                j.par = Parallelism::new(c + 1);
                j
            })
            .collect();
        pool.run(jobs).unwrap();
        let seen = names.lock().unwrap();
        assert_eq!(seen.len(), 4);
        assert!(
            seen.iter().all(|n| n.starts_with("client-worker-")),
            "unexpected worker thread names: {seen:?}"
        );
        let mut got = budgets.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3, 4], "every job's core budget must reach a backend");
    }

    #[test]
    fn worker_panic_surfaces_as_error_not_hang() {
        // an out-of-range skeleton index makes the mock panic on slice
        // indexing; the pool must report it and stay drainable
        let pool = WorkerPool::new(vec![MockBackend::toy(), MockBackend::toy()]).unwrap();
        let mut bad = job(0, 1, false);
        bad.skeleton = vec![vec![99]]; // channel 99 of 4 → index panic
        let jobs = vec![bad, job(1, 1, false), job(2, 1, false)];
        let err = pool.run(jobs).expect_err("panicked job must error");
        assert!(format!("{err:#}").contains("client 0"), "{err:#}");
        // the pool is still usable afterwards
        let outs = pool.run(vec![job(3, 1, false)]).unwrap();
        assert_eq!(outs[0].client, 3);
    }
}
