//! Seeded, deterministic fault injection over any [`Transport`].
//!
//! FedSkel's target deployment — heterogeneous edge devices on slow
//! uplinks — loses frames in practice, yet every in-process transport
//! delivers perfectly. [`FaultInjector`] wraps an inner transport and
//! perturbs its `send` path with four composable fault classes, each
//! drawn from one seeded [`Rng`] stream so a failing case replays
//! exactly from its seed:
//!
//! | fault | effect on the frame |
//! |---|---|
//! | `drop` | vanishes — never enters the inner transport |
//! | `truncate` | cut mid-frame at a seeded offset, then delivered (decode fails typed) |
//! | `reorder` | held back one send slot: the *next* frame to the same peer overtakes it |
//! | `delay` | held back 2–4 send slots to the same peer |
//!
//! Held frames are released by later `send`s to the same peer, so the
//! coordinator's retry loop (resend on empty `recv`) always makes
//! progress: the retry itself flushes whatever the injector is sitting
//! on. Fates are decided by one uniform draw per send against the plan's
//! cumulative probabilities, so the fault sequence is a pure function of
//! `(seed, send order)`.
//!
//! Accounting contract (see `docs/TRANSPORT.md`): a dropped or held
//! frame still cost its bytes at the sender, so `send` returns a receipt
//! with the frame's length either way — but with `sim_secs = 0.0`; the
//! simulated-link seconds of a frame are charged when it actually enters
//! the inner transport. Retransmission *waste* is the coordinator's to
//! ledger (it knows which attempt finally decoded), via
//! [`crate::trace::RunEvent::FaultRetry`].

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::{Envelope, Peer, Receipt, Transport};
use crate::util::Rng;

/// The four fault probabilities + the seed — parsed from the `--fault`
/// CLI/config spec (`drop=0.1,delay=0.05,reorder=0.05,truncate=0.01,seed=7`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// P(frame vanishes).
    pub drop: f64,
    /// P(frame held 2–4 send slots).
    pub delay: f64,
    /// P(frame held 1 send slot — the next frame to the peer overtakes it).
    pub reorder: f64,
    /// P(frame cut mid-body at a seeded offset).
    pub truncate: f64,
    /// Seed of the injector's private RNG stream.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan { drop: 0.0, delay: 0.0, reorder: 0.0, truncate: 0.0, seed: 0 }
    }
}

impl FaultPlan {
    /// Parse a `key=value` comma list. Unknown keys are typed errors;
    /// omitted keys default to 0 (seed included).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((key, val)) = part.split_once('=') else {
                bail!("fault spec '{part}' is not key=value");
            };
            let (key, val) = (key.trim(), val.trim());
            match key {
                "drop" => plan.drop = parse_prob(key, val)?,
                "delay" => plan.delay = parse_prob(key, val)?,
                "reorder" => plan.reorder = parse_prob(key, val)?,
                "truncate" => plan.truncate = parse_prob(key, val)?,
                "seed" => {
                    plan.seed = val
                        .parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("fault seed '{val}' is not a u64"))?
                }
                other => bail!(
                    "unknown fault key '{other}' (drop|delay|reorder|truncate|seed)"
                ),
            }
        }
        let total = plan.drop + plan.delay + plan.reorder + plan.truncate;
        if total > 1.0 {
            bail!("fault probabilities sum to {total} > 1");
        }
        Ok(plan)
    }

    /// Canonical spec string — parses back to an equal plan (config
    /// JSON round-trip).
    pub fn spec(&self) -> String {
        format!(
            "drop={},delay={},reorder={},truncate={},seed={}",
            self.drop, self.delay, self.reorder, self.truncate, self.seed
        )
    }
}

fn parse_prob(key: &str, val: &str) -> Result<f64> {
    let p: f64 = val
        .parse()
        .map_err(|_| anyhow::anyhow!("fault {key} '{val}' is not a number"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("fault {key} must be a probability in [0, 1], got {p}");
    }
    Ok(p)
}

/// Counters the injector keeps about what it did (tests assert on them;
/// they never feed back into the run).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Frames offered to `send`.
    pub sent: u64,
    /// Frames that vanished.
    pub dropped: u64,
    /// Frames cut mid-body.
    pub truncated: u64,
    /// Frames held one slot.
    pub reordered: u64,
    /// Frames held 2–4 slots.
    pub delayed: u64,
    /// Held frames that have since been released into the inner transport.
    pub released: u64,
    /// Bytes of dropped frames (never entered the inner transport).
    pub dropped_bytes: u64,
}

/// A held frame: delivered into the inner transport after `after` more
/// sends to its destination peer.
#[derive(Debug)]
struct Held {
    after: u32,
    env: Envelope,
}

/// The composable chaos wrapper: any [`Transport`] inside, a seeded
/// [`FaultPlan`] on top.
pub struct FaultInjector {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    rng: Rng,
    held: BTreeMap<Peer, Vec<Held>>,
    pub stats: FaultStats,
}

impl FaultInjector {
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan) -> FaultInjector {
        let rng = Rng::new(plan.seed ^ 0xFA17_FA17_FA17_FA17);
        FaultInjector { inner, plan, rng, held: BTreeMap::new(), stats: FaultStats::default() }
    }

    /// The wrapped transport (tests inspect its counters).
    pub fn inner(&self) -> &dyn Transport {
        self.inner.as_ref()
    }

    /// Flush every held frame into the inner transport, in hold order.
    pub fn release_all(&mut self) -> Result<()> {
        let held = std::mem::take(&mut self.held);
        for (_, frames) in held {
            for h in frames {
                self.stats.released += 1;
                self.inner.send(h.env)?;
            }
        }
        Ok(())
    }

    /// Decrement hold counts for `to` and deliver everything that
    /// reached zero (in hold order).
    fn tick_holds(&mut self, to: Peer) -> Result<()> {
        let Some(frames) = self.held.get_mut(&to) else { return Ok(()) };
        for h in frames.iter_mut() {
            h.after = h.after.saturating_sub(1);
        }
        let mut due = Vec::new();
        frames.retain_mut(|h| {
            if h.after == 0 {
                due.push(std::mem::replace(
                    &mut h.env,
                    Envelope { from: to, to, frame: Vec::new() },
                ));
                false
            } else {
                true
            }
        });
        if frames.is_empty() {
            self.held.remove(&to);
        }
        for env in due {
            self.stats.released += 1;
            self.inner.send(env)?;
        }
        Ok(())
    }
}

impl Transport for FaultInjector {
    fn send(&mut self, mut msg: Envelope) -> Result<Receipt> {
        self.stats.sent += 1;
        let to = msg.to;
        let bytes = msg.frame.len();
        let u = self.rng.uniform() as f64;
        let p = &self.plan;
        let receipt = if u < p.drop {
            self.stats.dropped += 1;
            self.stats.dropped_bytes += bytes as u64;
            Receipt { bytes, sim_secs: 0.0 }
        } else if u < p.drop + p.truncate {
            self.stats.truncated += 1;
            let cut = 1 + self.rng.below(bytes.saturating_sub(1).max(1));
            msg.frame.truncate(cut);
            let r = self.inner.send(msg)?;
            // the sender paid for the whole frame even though only a
            // prefix survived the link
            Receipt { bytes, sim_secs: r.sim_secs }
        } else if u < p.drop + p.truncate + p.reorder {
            self.stats.reordered += 1;
            self.held.entry(to).or_default().push(Held { after: 1, env: msg });
            Receipt { bytes, sim_secs: 0.0 }
        } else if u < p.drop + p.truncate + p.reorder + p.delay {
            self.stats.delayed += 1;
            let after = 2 + self.rng.below(3) as u32;
            self.held.entry(to).or_default().push(Held { after, env: msg });
            Receipt { bytes, sim_secs: 0.0 }
        } else {
            self.inner.send(msg)?
        };
        self.tick_holds(to)?;
        Ok(receipt)
    }

    fn recv(&mut self, to: Peer) -> Result<Option<Envelope>> {
        self.inner.recv(to)
    }

    fn pending(&self, to: Peer) -> usize {
        self.inner.pending(to) + self.held.get(&to).map(|v| v.len()).unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        "fault"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::Loopback;

    fn env(n: usize, tag: u8) -> Envelope {
        Envelope { from: Peer::Server, to: Peer::Client(0), frame: vec![tag; n] }
    }

    fn injector(plan: FaultPlan) -> FaultInjector {
        FaultInjector::new(Box::new(Loopback::new()), plan)
    }

    #[test]
    fn parse_spec_round_trips_and_validates() {
        let p = FaultPlan::parse("drop=0.1,delay=0.05,reorder=0.2,truncate=0.01,seed=7").unwrap();
        assert_eq!(p.drop, 0.1);
        assert_eq!(p.seed, 7);
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);
        // omitted keys default, blanks tolerated
        let q = FaultPlan::parse("drop=0.5").unwrap();
        assert_eq!(q.delay, 0.0);
        assert_eq!(q.seed, 0);
        assert!(FaultPlan::parse("drop=1.5").is_err());
        assert!(FaultPlan::parse("jitter=0.1").is_err());
        assert!(FaultPlan::parse("drop").is_err());
        assert!(FaultPlan::parse("drop=0.6,delay=0.6").is_err());
    }

    #[test]
    fn no_faults_is_the_identity_wrapper() {
        let mut t = injector(FaultPlan::default());
        for i in 0..20u8 {
            let r = t.send(env(10 + i as usize, i)).unwrap();
            assert_eq!(r.bytes, 10 + i as usize);
        }
        for i in 0..20u8 {
            let e = t.recv(Peer::Client(0)).unwrap().unwrap();
            assert_eq!(e.frame[0], i, "FIFO preserved");
        }
        assert!(t.recv(Peer::Client(0)).unwrap().is_none());
        assert_eq!(t.stats.dropped + t.stats.delayed + t.stats.reordered + t.stats.truncated, 0);
    }

    #[test]
    fn drop_vanishes_frames_but_charges_bytes() {
        let mut t = injector(FaultPlan { drop: 1.0, ..FaultPlan::default() });
        let r = t.send(env(64, 1)).unwrap();
        assert_eq!(r.bytes, 64);
        assert!(t.recv(Peer::Client(0)).unwrap().is_none());
        assert_eq!(t.stats.dropped, 1);
        assert_eq!(t.stats.dropped_bytes, 64);
        assert_eq!(t.pending(Peer::Client(0)), 0);
    }

    #[test]
    fn truncate_delivers_a_strict_prefix() {
        let mut t = injector(FaultPlan { truncate: 1.0, seed: 3, ..FaultPlan::default() });
        t.send(env(100, 9)).unwrap();
        let e = t.recv(Peer::Client(0)).unwrap().unwrap();
        assert!(!e.frame.is_empty() && e.frame.len() < 100, "got {}", e.frame.len());
        assert!(e.frame.iter().all(|&b| b == 9));
        assert_eq!(t.stats.truncated, 1);
    }

    #[test]
    fn reorder_swaps_with_the_next_send_to_the_peer() {
        let mut t = injector(FaultPlan { reorder: 0.5, seed: 1, ..FaultPlan::default() });
        // send until a reorder actually triggers, then one more frame to
        // flush it; delivery order must differ from send order exactly
        // where the injector says it held a frame
        for i in 0..32u8 {
            t.send(env(8, i)).unwrap();
        }
        t.release_all().unwrap();
        assert!(t.stats.reordered > 0, "seeded plan must fire at p=0.5 over 32 sends");
        let mut got = Vec::new();
        while let Some(e) = t.recv(Peer::Client(0)).unwrap() {
            got.push(e.frame[0]);
        }
        assert_eq!(got.len(), 32, "reorder never loses frames");
        let sorted: Vec<u8> = (0..32).collect();
        assert_ne!(got, sorted, "order must actually change");
        let mut re_sorted = got.clone();
        re_sorted.sort_unstable();
        assert_eq!(re_sorted, sorted);
    }

    #[test]
    fn held_frames_count_as_pending_and_release_on_later_sends() {
        let mut t = injector(FaultPlan { delay: 1.0, seed: 2, ..FaultPlan::default() });
        t.send(env(8, 0)).unwrap();
        assert_eq!(t.pending(Peer::Client(0)), 1, "held frame is still pending");
        assert!(t.recv(Peer::Client(0)).unwrap().is_none(), "but not deliverable yet");
        // later sends tick the hold down (delay holds 2–4 slots)
        for i in 1..6u8 {
            t.send(env(8, i)).unwrap();
        }
        t.release_all().unwrap();
        let mut got = Vec::new();
        while let Some(e) = t.recv(Peer::Client(0)).unwrap() {
            got.push(e.frame[0]);
        }
        assert_eq!(got.len(), 6);
    }

    #[test]
    fn same_seed_same_fate_sequence() {
        let plan = FaultPlan::parse("drop=0.2,delay=0.2,reorder=0.2,truncate=0.2,seed=42").unwrap();
        let mut a = injector(plan.clone());
        let mut b = injector(plan);
        for i in 0..64u8 {
            a.send(env(40, i)).unwrap();
            b.send(env(40, i)).unwrap();
        }
        assert_eq!(a.stats, b.stats);
        loop {
            let (x, y) = (a.recv(Peer::Client(0)).unwrap(), b.recv(Peer::Client(0)).unwrap());
            match (x, y) {
                (None, None) => break,
                (Some(xe), Some(ye)) => {
                    assert_eq!(xe.frame, ye.frame, "identical delivery streams");
                }
                _ => panic!("streams diverged"),
            }
        }
    }
}
